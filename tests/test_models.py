"""Per-arch smoke tests + decode/forward equivalence.

Every assigned architecture instantiates its REDUCED (same-family) config
and runs one forward + one train step on CPU, asserting finite outputs and
correct shapes.  The decode tests verify the strongest invariant we have:
one-token decode against a prefill-built cache reproduces the full-sequence
forward logits (KV ring buffers, SSD states and RG-LRU states included).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed import sharding
from repro.models import encdec, lm
from repro.models.layers import ShardCtx, single_device_mesh
from repro.train import optim, schedules, step as step_lib

ARCHS = registry.ARCH_IDS


def _ctx():
    return sharding.make_ctx(single_device_mesh())


def _batch(cfg, B=2, S=16, is_encdec=False, seed=0):
    rng = np.random.default_rng(seed)
    if is_encdec:
        return {
            "frontend_embeds": jnp.asarray(
                rng.standard_normal((B, cfg.n_frames, cfg.d_model)),
                jnp.float32),
            "tokens": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    b = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S - F)), jnp.int32),
         "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)}
    if F:
        b["frontend_embeds"] = jnp.asarray(
            rng.standard_normal((B, F, cfg.d_model)), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    entry = registry.get(arch)
    cfg = entry.smoke()
    ctx = _ctx()
    key = jax.random.PRNGKey(0)
    init_p = encdec.init_params if entry.is_encdec else lm.init_params
    params = init_p(cfg, key)
    batch = _batch(cfg, is_encdec=entry.is_encdec)

    opt = optim.adamw(schedules.constant(1e-3))
    fn = step_lib.make_train_step(cfg, ctx, opt)
    state = step_lib.init_state(cfg, opt, key)
    state2, metrics = jax.jit(fn)(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(state2["step"]) == 1
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(state2["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_loss_decreases(arch):
    entry = registry.get(arch)
    cfg = entry.smoke()
    ctx = _ctx()
    key = jax.random.PRNGKey(1)
    opt = optim.adamw(schedules.constant(3e-3))
    fn = jax.jit(step_lib.make_train_step(cfg, ctx, opt))
    state = step_lib.init_state(cfg, opt, key)
    batch = _batch(cfg, is_encdec=entry.is_encdec, seed=3)
    losses = []
    for _ in range(8):           # same batch: loss must drop
        state, m = fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (arch, losses)


DECODE_ARCHS = [a for a in ARCHS if not registry.get(a).is_encdec]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """prefill(t[:T]) + decode(t[T]) logits == forward(t[:T+1]) last logits."""
    import dataclasses
    entry = registry.get(arch)
    cfg = entry.smoke()
    if cfg.frontend != "none":
        cfg = type(cfg)(**{**cfg.__dict__, "frontend": "none",
                           "frontend_tokens": 0})

    # MoE: equivalence requires drop-free capacity (cf = E/k) — capacity
    # dropping legitimately differs between prefill and decode token counts
    def fix(blk):
        if blk.moe is None:
            return blk
        cf = float(blk.moe.n_experts) / blk.moe.top_k
        m = dataclasses.replace(blk.moe, capacity_factor=cf,
                                decode_capacity_factor=cf)
        return dataclasses.replace(blk, moe=m)
    if any(b.moe is not None for b in cfg.all_blocks()):
        cfg = dataclasses.replace(
            cfg, prefix=tuple(map(fix, cfg.prefix)),
            pattern=tuple(map(fix, cfg.pattern)))
    ctx = _ctx()
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(7)
    T, B = 12, 2
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)),
                       jnp.int32)

    h, _ = lm.forward(params, toks, cfg, ctx)
    ref = lm.logits_from_h(params, h, cfg, ctx)[:, -1]

    _, cache = lm.prefill(params, toks[:, :T], cfg, ctx)
    # grow full-attn caches T -> T+1 so decode can write slot T
    def grow(x):
        for ax in (1, 2):
            if x.ndim > ax + 1 and x.shape[ax] == T:
                pad = [(0, 0)] * x.ndim
                pad[ax] = (0, 4)
                return jnp.pad(x, pad)
        return x
    cache = jax.tree.map(grow, cache)
    # ring caches: roll so slot (pos % W) holds position pos
    windows = {b.window for b in cfg.all_blocks()
               if b.window is not None and b.window < T}
    def roll(x):
        for ax in (1, 2):
            if x.ndim > ax + 1 and x.shape[ax] in windows:
                W = x.shape[ax]
                return jnp.roll(x, (T - W) % W, axis=ax)
        return x
    if windows:
        cache = jax.tree.map(roll, cache)
    got, _ = lm.decode_step(params, toks[:, T:T + 1], cache,
                            jnp.int32(T), cfg, ctx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_whisper_decode_matches_forward():
    entry = registry.get("whisper-base")
    cfg = entry.smoke()
    ctx = _ctx()
    params = encdec.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(9)
    B, T = 2, 10
    frames = jnp.asarray(rng.standard_normal((B, cfg.n_frames, cfg.d_model)),
                         jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, T + 1)), jnp.int32)

    enc_out = encdec.encode(params, frames, cfg, ctx)
    h = encdec.decode_train(params, enc_out, toks, cfg, ctx)
    ref = jnp.einsum("bd,dv->bv", h[:, -1], params["embed"].T)

    cache = encdec.init_cache(cfg, B, T + 4)
    cache = encdec.precompute_cross_cache(params, enc_out, cfg, ctx, cache)
    logits = None
    for t in range(T + 1):
        logits, cache = encdec.decode_step(params, toks[:, t:t + 1], cache,
                                           jnp.int32(t), cfg, ctx)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_param_count_matches_init():
    for arch in ARCHS:
        entry = registry.get(arch)
        cfg = entry.smoke()
        init_p = encdec.init_params if entry.is_encdec else lm.init_params
        params = jax.eval_shape(lambda: init_p(cfg, jax.random.PRNGKey(0)))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        assert n == cfg.param_count(), arch


def test_moe_aux_metrics_present():
    entry = registry.get("olmoe-1b-7b")
    cfg = entry.smoke()
    ctx = _ctx()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    _, metrics = lm.loss_fn(params, batch, cfg, ctx)
    n_moe = sum(1 for b in cfg.all_blocks() if b.moe is not None)
    # max_expert_load (M0 metric) is maxed over layers; with 16 tokens x
    # top-2 over 8 experts the max layer load is at least the mean 4
    assert float(metrics["max_expert_load"]) >= 32 / 8
    assert 0.0 <= float(metrics["dropped_frac"]) < n_moe
    assert float(metrics["moe_lb_loss"]) > 0.0
