"""Stage-1 sparsity modules: Tl1, synops loss, pruning, sigma-delta
calibration (+ hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import sparsity as sp
from repro.sparsity.sigma_delta import delta_sparsity, sigma_delta_messages

pytestmark = pytest.mark.quick


def test_tl1_decreases_with_sparsity():
    dense = [jnp.ones((100,))]
    sparse = [jnp.concatenate([jnp.ones((10,)), jnp.zeros((90,))])]
    assert float(sp.tl1_regularizer(sparse)) < \
        float(sp.tl1_regularizer(dense))


def test_tl1_gradient_drives_down():
    x = jnp.asarray(np.random.default_rng(0).uniform(0.1, 1.0, 64),
                    jnp.float32)
    g = jax.grad(lambda a: sp.tl1_regularizer([a]))(x)
    assert np.all(np.asarray(g) > 0)       # positive acts pushed to zero


@given(st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_prune_masks_hit_target(s):
    params = {"w": jnp.asarray(
        np.random.default_rng(1).standard_normal((64, 64)), jnp.float32)}
    masks = sp.magnitude_prune_masks(params, s)
    got = 1.0 - float(jnp.mean(masks["w"]))
    assert abs(got - s) < 0.02


def test_prune_keeps_largest():
    w = jnp.asarray([[0.01, 5.0] * 32] * 64, jnp.float32)
    masks = sp.magnitude_prune_masks({"w": w}, 0.5)
    assert float(jnp.sum(masks["w"][:, 1::2])) == 64 * 32   # big kept


def test_synops_loss_weighs_fanout():
    acts = [jnp.ones((10,)), jnp.ones((10,))]
    hi = sp.synops_loss(acts, [1000, 1])
    acts2 = [jnp.zeros((10,)), jnp.ones((10,))]   # silence the big-fanout
    lo = sp.synops_loss(acts2, [1000, 1])
    assert float(lo) < float(hi)


@given(st.floats(0.2, 0.95))
@settings(max_examples=10, deadline=None)
def test_sigma_delta_calibration(target):
    rng = np.random.default_rng(3)
    deltas = [rng.standard_normal(5000), rng.standard_normal(5000) * 0.1]
    thetas = sp.calibrate_thresholds(deltas, float(target))
    for d, t in zip(deltas, thetas):
        got = delta_sparsity(d, t)
        assert got >= target - 0.02
        assert got <= target + 0.05


def test_sigma_delta_reconstruction_bounded():
    rng = np.random.default_rng(4)
    theta = 0.2
    ref = np.zeros(32)
    acts = np.zeros(32)
    for _ in range(20):
        acts = acts + rng.standard_normal(32) * 0.3
        q, ref = sigma_delta_messages(acts, ref, theta)
    assert np.max(np.abs(ref - acts)) <= theta + 1e-9


# ------------------------------- exact-k pruning properties (PR 9)

@given(st.integers(2, 40), st.integers(2, 40), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_prune_exact_k(rows, cols, s):
    """Kept count is round(n*(1-s)) within one element, any shape/target."""
    w = jnp.asarray(np.random.default_rng(rows * 97 + cols)
                    .standard_normal((rows, cols)), jnp.float32)
    masks = sp.magnitude_prune_masks({"w": w}, s, min_size=1)
    kept = int(jnp.sum(masks["w"]))
    assert abs(kept - round(rows * cols * (1.0 - s))) <= 1
    assert set(np.unique(np.asarray(masks["w"]))) <= {0.0, 1.0}


def test_prune_respects_min_size_and_ndim():
    params = {
        "small": jnp.asarray(np.arange(12, dtype=np.float32).reshape(3, 4)),
        "vec": jnp.asarray(np.arange(128, dtype=np.float32)),
        "big": jnp.ones((16, 16), jnp.float32),
    }
    masks = sp.magnitude_prune_masks(params, 0.9, min_size=64)
    assert float(jnp.min(masks["small"])) == 1.0    # size < min_size
    assert float(jnp.min(masks["vec"])) == 1.0      # ndim < 2
    assert float(jnp.mean(masks["big"])) < 0.2      # actually pruned


def test_prune_tie_determinism():
    """All-equal magnitudes: ties break toward the lowest flat index, so
    the kept set is exactly the first k entries — twice in a row."""
    w = jnp.ones((16, 16), jnp.float32)
    m1 = sp.magnitude_prune_masks({"w": w}, 0.5, min_size=1)["w"]
    m2 = sp.magnitude_prune_masks({"w": w}, 0.5, min_size=1)["w"]
    assert np.array_equal(np.asarray(m1), np.asarray(m2))
    flat = np.asarray(m1).reshape(-1)
    k = int(flat.sum())
    assert k == 128
    assert np.all(flat[:k] == 1.0) and np.all(flat[k:] == 0.0)


@given(st.floats(0.05, 0.95))
@settings(max_examples=10, deadline=None)
def test_prune_jit_eager_bit_parity(s):
    """magnitude_prune_masks is jit-safe and bit-identical to eager."""
    params = {"a": jnp.asarray(np.random.default_rng(7)
                               .standard_normal((24, 24)), jnp.float32),
              "b": jnp.asarray(np.random.default_rng(8)
                               .standard_normal((8, 8)), jnp.float32)}
    eager = sp.magnitude_prune_masks(params, s, min_size=1)
    jitted = jax.jit(
        lambda p, sv: sp.magnitude_prune_masks(p, sv, min_size=1)
    )(params, jnp.float32(s))
    for k in params:
        assert np.array_equal(np.asarray(eager[k]), np.asarray(jitted[k]))


@given(st.floats(0.2, 0.8), st.floats(0.02, 0.15))
@settings(max_examples=10, deadline=None)
def test_calibrate_thresholds_monotone(target, bump):
    """Larger sparsity target never yields a smaller threshold."""
    deltas = [np.random.default_rng(11).standard_normal(4000)]
    lo = sp.calibrate_thresholds(deltas, float(target))[0]
    hi = sp.calibrate_thresholds(deltas, float(min(target + bump, 0.99)))[0]
    assert hi >= lo - 1e-12


def test_sigma_delta_message_roundtrip():
    """Cumulative sum of the emitted messages IS the decoder state, and it
    tracks the activation sequence within theta at every step."""
    rng = np.random.default_rng(5)
    theta = 0.15
    acts = np.cumsum(rng.standard_normal((12, 16)) * 0.2, axis=0)
    ref = np.zeros(16)
    msgs = []
    for t in range(12):
        q, ref = sigma_delta_messages(acts[t], ref, theta)
        msgs.append(q)
        recon = np.sum(msgs, axis=0)          # decoder: integrate messages
        assert np.allclose(recon, ref)
        assert np.max(np.abs(recon - acts[t])) <= theta + 1e-9


def test_sigma_delta_densities_match_encoder():
    rng = np.random.default_rng(6)
    seq = np.cumsum(rng.standard_normal((10, 32)) * 0.3, axis=0)
    seq = np.maximum(seq, 0.0)
    dens = sp.sigma_delta_densities([seq], [0.25])[0]
    # recount by hand
    ref, fired = np.zeros(32), 0
    for t in range(10):
        q, ref = sigma_delta_messages(seq[t], ref, 0.25)
        fired += int(np.count_nonzero(q))
    assert dens == fired / seq.size
