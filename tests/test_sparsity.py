"""Stage-1 sparsity modules: Tl1, synops loss, pruning, sigma-delta
calibration (+ hypothesis property tests)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro import sparsity as sp
from repro.sparsity.sigma_delta import delta_sparsity, sigma_delta_messages


def test_tl1_decreases_with_sparsity():
    dense = [jnp.ones((100,))]
    sparse = [jnp.concatenate([jnp.ones((10,)), jnp.zeros((90,))])]
    assert float(sp.tl1_regularizer(sparse)) < \
        float(sp.tl1_regularizer(dense))


def test_tl1_gradient_drives_down():
    x = jnp.asarray(np.random.default_rng(0).uniform(0.1, 1.0, 64),
                    jnp.float32)
    g = jax.grad(lambda a: sp.tl1_regularizer([a]))(x)
    assert np.all(np.asarray(g) > 0)       # positive acts pushed to zero


@given(st.floats(0.1, 0.9))
@settings(max_examples=10, deadline=None)
def test_prune_masks_hit_target(s):
    params = {"w": jnp.asarray(
        np.random.default_rng(1).standard_normal((64, 64)), jnp.float32)}
    masks = sp.magnitude_prune_masks(params, s)
    got = 1.0 - float(jnp.mean(masks["w"]))
    assert abs(got - s) < 0.02


def test_prune_keeps_largest():
    w = jnp.asarray([[0.01, 5.0] * 32] * 64, jnp.float32)
    masks = sp.magnitude_prune_masks({"w": w}, 0.5)
    assert float(jnp.sum(masks["w"][:, 1::2])) == 64 * 32   # big kept


def test_synops_loss_weighs_fanout():
    acts = [jnp.ones((10,)), jnp.ones((10,))]
    hi = sp.synops_loss(acts, [1000, 1])
    acts2 = [jnp.zeros((10,)), jnp.ones((10,))]   # silence the big-fanout
    lo = sp.synops_loss(acts2, [1000, 1])
    assert float(lo) < float(hi)


@given(st.floats(0.2, 0.95))
@settings(max_examples=10, deadline=None)
def test_sigma_delta_calibration(target):
    rng = np.random.default_rng(3)
    deltas = [rng.standard_normal(5000), rng.standard_normal(5000) * 0.1]
    thetas = sp.calibrate_thresholds(deltas, float(target))
    for d, t in zip(deltas, thetas):
        got = delta_sparsity(d, t)
        assert got >= target - 0.02
        assert got <= target + 0.05


def test_sigma_delta_reconstruction_bounded():
    rng = np.random.default_rng(4)
    theta = 0.2
    ref = np.zeros(32)
    acts = np.zeros(32)
    for _ in range(20):
        acts = acts + rng.standard_normal(32) * 0.3
        q, ref = sigma_delta_messages(acts, ref, theta)
    assert np.max(np.abs(ref - acts)) <= theta + 1e-9
