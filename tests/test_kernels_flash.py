"""flash_attn Pallas kernel vs jnp oracle: shape/dtype/mask sweeps in
interpret mode (CPU) + hypothesis property test."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attn import flash_attention, flash_attention_ref


def _rand(key, B, Sq, Skv, H, K, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, Sq, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(kk, (B, Skv, K, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(kv, (B, Skv, K, hd), jnp.float32).astype(dtype)
    return q, k, v


CASES = [
    # B, Sq, Skv, H, K, hd, window, softcap, dtype
    (1, 128, 128, 2, 2, 64, None, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, None, None, jnp.float32),      # GQA
    (1, 128, 256, 4, 1, 128, None, None, jnp.float32),     # MQA, Sq<Skv
    (1, 256, 256, 2, 2, 64, 128, None, jnp.float32),       # local window
    (1, 128, 128, 2, 2, 64, None, 50.0, jnp.float32),      # softcap
    (1, 128, 128, 2, 2, 64, None, None, jnp.bfloat16),
    (1, 200, 200, 2, 2, 64, None, None, jnp.float32),      # padding path
]


@pytest.mark.quick
def test_flash_quick_smoke():
    """One small case for the CI kernels step (interpret mode executes the
    kernel body); the full sweep below stays in the tier-1 run."""
    q, k, v = _rand(jax.random.PRNGKey(1), 1, 128, 128, 2, 2, 64,
                    jnp.float32)
    got = flash_attention(q, k, v, causal=True, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Skv,H,K,hd,window,softcap,dtype", CASES)
def test_flash_matches_ref(B, Sq, Skv, H, K, hd, window, softcap, dtype):
    q, k, v = _rand(jax.random.PRNGKey(0), B, Sq, Skv, H, K, hd, dtype)
    got = flash_attention(q, k, v, causal=True, window=window,
                          softcap=softcap, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window,
                              softcap=softcap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@given(st.integers(1, 3), st.sampled_from([128, 256]),
       st.sampled_from([1, 2, 4]), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_flash_property_gqa(B, S, G, seed):
    K, hd = 2, 64
    q, k, v = _rand(jax.random.PRNGKey(seed), B, S, S, K * G, K, hd,
                    jnp.float32)
    got = flash_attention(q, k, v, interpret=True)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def _zoo_specs():
    """Unique attention shapes the model-zoo frontend lowers for the smoke
    archs — the (heads, head_dim, seq) points that now matter."""
    from repro.configs import registry
    from repro.neuromorphic.frontend import lowering_spec
    seen = {}
    for arch in ("gemma2-2b", "mamba2-1.3b", "olmoe-1b-7b", "whisper-base"):
        _, attn = lowering_spec(registry.get(arch).smoke())
        for s in attn:
            key = (s.heads, s.kv_heads, s.head_dim, s.seq, s.causal,
                   s.window, s.softcap)
            seen.setdefault(key, f"{arch}:{s.name}")
    return [pytest.param(*k, id=v) for k, v in seen.items()]


@pytest.mark.quick
@pytest.mark.parametrize("H,K,hd,S,causal,window,softcap", _zoo_specs())
def test_flash_compiler_lowered_shapes(H, K, hd, S, causal, window, softcap):
    """Pallas vs oracle at exactly the shapes compile_network records as
    AttnSpecs (GQA sliding-window/softcap, full-context, non-causal
    encoder/cross) — CI coverage for the kernel where the frontend uses it."""
    q, k, v = _rand(jax.random.PRNGKey(11), 1, S, S, H, K, hd, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=causal, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_rows_sum_to_one_property():
    """Degenerate v=1 -> output must be exactly 1 (softmax normalization
    survives the lazy accumulation)."""
    B, S, H, K, hd = 1, 256, 2, 2, 64
    q, k, _ = _rand(jax.random.PRNGKey(7), B, S, S, H, K, hd, jnp.float32)
    v = jnp.ones((B, S, K, hd), jnp.float32)
    got = flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(got), 1.0, rtol=1e-5, atol=1e-5)
