"""Tests for the floorline performance model (§VI-A)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.analytical import Bottleneck
from repro.core.floorline import (FloorlineModel, WorkloadPoint, fit_floorline,
                                  floorline_curve)


def model():
    return FloorlineModel(mem_latency=2.0, act_latency=5.0, t0=10.0)


class TestClassification:
    def test_on_slope_is_memory_bound(self):
        m = model()
        p = WorkloadPoint(max_synops=1000, max_acts=10,
                          time=m.predicted_time(1000, 10))
        assert m.classify(p) is Bottleneck.MEMORY

    def test_on_floor_is_compute_bound(self):
        m = model()
        p = WorkloadPoint(max_synops=1, max_acts=500,
                          time=m.predicted_time(1, 500))
        assert m.classify(p) is Bottleneck.COMPUTE

    def test_above_line_is_traffic_bound(self):
        m = model()
        bound = m.predicted_time(1000, 10)
        p = WorkloadPoint(max_synops=1000, max_acts=10, time=bound * 2.0)
        assert m.classify(p) is Bottleneck.TRAFFIC

    def test_recommendations_match_states(self):
        m = model()
        mem = WorkloadPoint(1000, 10, m.predicted_time(1000, 10))
        assert "partition" in m.recommend(mem).action
        assert m.recommend(mem).state is Bottleneck.MEMORY

    def test_efficiency_leq_one_above_line(self):
        m = model()
        p = WorkloadPoint(1000, 10, m.predicted_time(1000, 10) * 3)
        assert m.efficiency(p) <= 1.0


class TestFit:
    def test_recovers_known_parameters(self):
        true = FloorlineModel(mem_latency=1.5, act_latency=4.0, t0=0.0)
        rng = np.random.default_rng(0)
        pts = []
        for _ in range(60):
            s = float(rng.uniform(10, 10000))
            a = float(rng.uniform(10, 500))
            pts.append(WorkloadPoint(s, a, true.predicted_time(s, a)))
        fit = fit_floorline(pts)
        assert fit.mem_latency == pytest.approx(1.5, rel=0.15)
        assert fit.act_latency == pytest.approx(4.0, rel=0.15)

    def test_fit_ignores_traffic_outliers(self):
        true = FloorlineModel(mem_latency=1.0, act_latency=1.0, t0=0.0)
        rng = np.random.default_rng(1)
        pts = [WorkloadPoint(s := float(rng.uniform(100, 5000)), 10.0,
                             true.predicted_time(s, 10.0))
               for _ in range(40)]
        # add traffic-bound points 5x above the line
        pts += [WorkloadPoint(s := float(rng.uniform(100, 5000)), 10.0,
                              5 * true.predicted_time(s, 10.0))
                for _ in range(10)]
        fit = fit_floorline(pts)
        assert fit.mem_latency == pytest.approx(1.0, rel=0.2)

    def test_fit_empty_raises(self):
        with pytest.raises(ValueError):
            fit_floorline([])

    @given(st.floats(0.1, 10), st.floats(0.1, 10))
    @settings(max_examples=30, deadline=None)
    def test_fit_roundtrip_property(self, mem, act):
        true = FloorlineModel(mem_latency=mem, act_latency=act, t0=0.0)
        rng = np.random.default_rng(42)
        pts = []
        for _ in range(50):
            s = float(rng.uniform(1, 1000))
            a = float(rng.uniform(1, 1000))
            pts.append(WorkloadPoint(s, a, true.predicted_time(s, a)))
        fit = fit_floorline(pts)
        # predicted times agree even if individual params are degenerate
        for p in pts[:10]:
            assert fit.predicted_time(p.max_synops, p.max_acts) == pytest.approx(
                p.time, rel=0.35)


def test_floorline_curve_shape_and_floor():
    m = model()
    xs, ys = floorline_curve(m, max_acts=100, synops_range=(1, 10000))
    assert xs.shape == ys.shape
    assert np.all(np.diff(ys) >= -1e-9)          # monotone non-decreasing
    assert ys[0] == pytest.approx(m.compute_floor(100))   # flat floor at left
