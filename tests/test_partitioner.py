"""Tests for the §VI-B backtracking partitioning/mapping optimizer."""

import numpy as np
import pytest

from repro.core.partitioner import optimize_partitioning
from repro.neuromorphic import (loihi2_like, make_inputs,
                                programmed_fc_network, simulate)
from repro.neuromorphic.partition import validate_partition


def setup_workload(wd=0.6, ad=0.3, sizes=(1024, 1024, 1024, 1024)):
    net = programmed_fc_network(list(sizes), weight_densities=[wd] * (len(sizes) - 1),
                                act_densities=[ad] * (len(sizes) - 1), seed=0,
                                weight_format="sparse")
    xs = make_inputs(sizes[0], ad, 3, seed=1)
    return net, xs


class TestOptimizer:
    def test_memory_bound_workload_improves(self):
        prof = loihi2_like()
        net, xs = setup_workload()
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=40)
        base = simulate(net, xs, prof)
        assert res.report.time_per_step < base.time_per_step * 0.75
        assert validate_partition(net, res.partition, prof)

    def test_never_exceeds_core_budget(self):
        prof = loihi2_like()
        net, xs = setup_workload()
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=60)
        for step in res.history:
            assert step.partition.total_cores <= prof.n_cores

    def test_accepted_steps_monotone_time(self):
        """Backtracking invariant: every accepted step improves time."""
        prof = loihi2_like()
        net, xs = setup_workload()
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=40)
        accepted = [s.time for s in res.history if s.accepted]
        assert all(t2 < t1 for t1, t2 in zip(accepted, accepted[1:]))

    def test_trace_walks_down_memory_slope(self):
        """§VII-C: the iterative procedure traces the memory boundary —
        max synops and time both decrease along accepted steps."""
        prof = loihi2_like()
        net, xs = setup_workload()
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=40)
        trace = res.trace
        assert len(trace) >= 3
        syn = [p[0] for p in trace]
        assert all(s2 <= s1 + 1e-9 for s1, s2 in zip(syn, syn[1:]))

    def test_terminates_on_compute_floor(self):
        """A compute-bound workload (tiny synops) can't improve much by
        splitting once neurons/core are small; optimizer must terminate."""
        prof = loihi2_like()
        net, xs = setup_workload(wd=0.02, ad=0.05, sizes=(256, 256, 256))
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=30)
        assert res.history[-1].iteration <= 30

    def test_history_records_rejections(self):
        prof = loihi2_like()
        net, xs = setup_workload()
        res = optimize_partitioning(
            net, prof, lambda p, m: simulate(net, xs, prof, p, m),
            max_iters=40)
        assert any(not s.accepted for s in res.history)
        assert any("backtrack" in s.note for s in res.history if not s.accepted)
