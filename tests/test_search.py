"""Tests for the evolutionary mapping-search subsystem
(:mod:`repro.core.search`) and the population repricing path
(:func:`repro.neuromorphic.timestep.simulate_population`)."""

import numpy as np
import pytest

from repro.core.partitioner import SimEvaluator, optimize_partitioning
from repro.core.search import (Candidate, EpsParetoArchive, decode,
                               decode_population, encode, encode_population,
                               evolutionary_search, greedy_then_evolve,
                               knee_point, mutate, pareto_ranks,
                               seeded_population)
from repro.neuromorphic import (Partition, SimLayer, SimNetwork, fc_network,
                                loihi2_like, make_inputs, minimal_partition,
                                ordered_mapping, programmed_fc_network,
                                random_mapping, simulate, simulate_population,
                                strided_mapping)
from repro.neuromorphic.network import _exact_density_mask
from repro.neuromorphic.partition import validate_partition

quick = pytest.mark.quick


def fc_workload(sizes=(192, 256, 256, 128), wd=0.6, ad=0.3, steps=3):
    net = programmed_fc_network(
        list(sizes), weight_densities=[wd] * (len(sizes) - 1),
        act_densities=[ad] * (len(sizes) - 1), seed=0,
        weight_format="sparse")
    xs = make_inputs(sizes[0], ad, steps, seed=1)
    return net, xs


def conv_workload(steps=3):
    """conv -> conv -> fc stack, mixed layer kinds for the repricing path."""
    rng = np.random.default_rng(2)
    layers = []
    h = w = 8
    c_prev = 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, 0.6, rng)
        layers.append(SimLayer(name=f"conv{i}", kind="conv", weights=wgt,
                               stride=2, in_hw=(h, w)))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc))
    net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
    return net, make_inputs(net.in_size, 0.4, steps, seed=3)


class TestEncoding:
    @quick
    def test_round_trip_exact(self):
        prof = loihi2_like()
        net, _ = fc_workload()
        rng = np.random.default_rng(0)
        p0 = minimal_partition(net, prof)
        for part in (p0, p0.split(0).split(0), p0.split(1).split(2)):
            for mk in (ordered_mapping, strided_mapping,
                       lambda p, pr: random_mapping(p, pr, rng)):
                mapping = mk(part, prof)
                cand = encode(part, mapping, prof.n_cores)
                p2, m2 = decode(cand)
                assert p2 == part
                assert tuple(m2.phys) == tuple(mapping.phys)
                # fixed-shape genome: every physical slot appears once
                assert sorted(cand.perm) == list(range(prof.n_cores))

    @quick
    def test_population_arrays_round_trip(self):
        prof = loihi2_like()
        net, _ = fc_workload()
        rng = np.random.default_rng(1)
        p0 = minimal_partition(net, prof)
        cands = [encode(p0.split(int(l)), random_mapping(p0.split(int(l)),
                                                         prof, rng),
                        prof.n_cores)
                 for l in rng.integers(0, len(net.layers), size=5)]
        cores, perm = encode_population(cands)
        assert cores.shape == (5, len(net.layers))
        assert perm.shape == (5, prof.n_cores)
        assert decode_population(cores, perm) == cands

    @quick
    def test_split_pulls_next_gene_into_use(self):
        """A split changes the partition but not the genome's placement
        genes: the new core is expressed from the existing permutation."""
        prof = loihi2_like()
        net, _ = fc_workload()
        p0 = minimal_partition(net, prof)
        cand = encode(p0, strided_mapping(p0, prof), prof.n_cores)
        grown = Candidate(p0.split(0).cores, cand.perm)
        assert grown.n_logical == cand.n_logical + 1
        assert grown.mapping().phys[:cand.n_logical] == cand.mapping().phys


class TestPopulationRepricing:
    def _assert_reports_identical(self, r_pop, r_one):
        for field in ("times", "energies", "per_core_synops", "per_core_acts",
                      "per_core_msgs_out", "outputs"):
            assert np.array_equal(getattr(r_pop, field),
                                  getattr(r_one, field)), field
        assert r_pop.time_per_step == r_one.time_per_step
        assert r_pop.energy_per_step == r_one.energy_per_step
        assert r_pop.max_synops == r_one.max_synops
        assert r_pop.max_acts == r_one.max_acts
        assert r_pop.max_link_load == r_one.max_link_load
        assert r_pop.bottleneck_stage == r_one.bottleneck_stage
        assert r_pop.metrics == r_one.metrics

    @quick
    def test_fc_population_matches_simulate_bit_for_bit(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        rng = np.random.default_rng(4)
        p0 = minimal_partition(net, prof)
        pairs = [(p0, ordered_mapping(p0, prof)),
                 (p0.split(0), strided_mapping(p0.split(0), prof)),
                 (p0.split(1).split(1),
                  random_mapping(p0.split(1).split(1), prof, rng))]
        reports = simulate_population(net, xs, prof, pairs)
        assert len(reports) == len(pairs)
        for (p, m), rp in zip(pairs, reports):
            self._assert_reports_identical(
                rp, simulate(net, xs, prof, p, m, engine="batched"))

    def test_conv_population_matches_simulate(self):
        net, xs = conv_workload()
        prof = loihi2_like()
        parts = [Partition((1, 1, 1)), Partition((2, 4, 2)),
                 Partition((4, 8, 1))]
        pairs = [(p, strided_mapping(p, prof)) for p in parts]
        for (p, m), rp in zip(pairs,
                              simulate_population(net, xs, prof, pairs)):
            self._assert_reports_identical(rp, simulate(net, xs, prof, p, m))

    @quick
    def test_empty_core_segments(self):
        """Candidates whose padded population gather hits empty segments
        (more cores than neurons) still price exactly."""
        net = fc_network([16, 6, 8], weight_density=1.0, seed=19)
        xs = make_inputs(16, 0.8, 3, seed=20)
        prof = loihi2_like()
        pairs = [(Partition((1, 1)), ordered_mapping(Partition((1, 1)),
                                                     prof)),
                 (Partition((7, 2)), strided_mapping(Partition((7, 2)),
                                                     prof))]
        for (p, m), rp in zip(pairs,
                              simulate_population(net, xs, prof, pairs)):
            self._assert_reports_identical(rp, simulate(net, xs, prof, p, m))

    @quick
    def test_evaluator_counts_and_matches(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        p0 = minimal_partition(net, prof)
        r_single = ev(p0, strided_mapping(p0, prof))
        rs = ev.evaluate_population(
            [(p0, strided_mapping(p0, prof)), (p0, ordered_mapping(p0, prof))])
        assert ev.n_evals == 3
        self._assert_reports_identical(rs[0], r_single)

    @quick
    def test_empty_population(self):
        net, xs = fc_workload()
        assert simulate_population(net, xs, loihi2_like(), []) == []


class TestSearch:
    def test_never_worse_than_seed(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        rng = np.random.default_rng(5)
        seeds = seeded_population(net, prof, size=8, rng=rng)
        seed_reports = ev.evaluate_population([decode(c) for c in seeds])
        best_seed_time = min(r.time_per_step for r in seed_reports)
        res = evolutionary_search(net, prof, ev, population_size=8,
                                  generations=4, seed=7,
                                  seed_candidates=seeds)
        assert res.report.time_per_step <= best_seed_time
        assert res.seed_best_time == best_seed_time
        assert validate_partition(net, res.partition, prof)

    def test_never_worse_than_greedy(self):
        """Elitism + greedy seeding: the pipeline cannot lose to §VI-B."""
        net, xs = fc_workload()
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        greedy, evo = greedy_then_evolve(net, prof, ev, population_size=8,
                                         generations=3, seed=0)
        assert evo.report.time_per_step <= greedy.report.time_per_step

    @quick
    def test_fixed_seed_determinism(self):
        net, xs = fc_workload(sizes=(96, 128, 64), steps=2)
        prof = loihi2_like()
        runs = []
        for _ in range(2):
            ev = SimEvaluator(net, xs, prof)
            runs.append(evolutionary_search(net, prof, ev, population_size=6,
                                            generations=3, seed=11))
        a, b = runs
        assert a.candidate == b.candidate
        assert a.report.time_per_step == b.report.time_per_step
        assert [g.best_time for g in a.history] == \
            [g.best_time for g in b.history]
        assert a.n_evals == b.n_evals

    @quick
    def test_budget_respected(self):
        net, xs = fc_workload(sizes=(96, 128, 64), steps=2)
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        res = evolutionary_search(net, prof, ev, population_size=6,
                                  generations=50, seed=1,
                                  max_evaluations=20)
        assert res.n_evals <= 20
        assert ev.n_evals == res.n_evals

    @quick
    def test_mutation_yields_valid_distinct_candidates(self):
        net, xs = fc_workload(sizes=(96, 128, 64), steps=2)
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        p0 = minimal_partition(net, prof)
        cand = encode(p0, strided_mapping(p0, prof), prof.n_cores)
        report = ev(*decode(cand))
        rng = np.random.default_rng(3)
        for _ in range(25):
            child = mutate(cand, report, net, prof, rng)
            assert child != cand
            assert validate_partition(net, child.partition(), prof)
            assert sorted(child.perm) == list(range(prof.n_cores))

    @quick
    def test_pareto_ranks_known_points(self):
        t = np.array([1.0, 2.0, 3.0, 2.0])
        e = np.array([3.0, 1.0, 2.0, 2.0])
        r = pareto_ranks(t, e)
        # (1,3) and (2,1) are mutually nondominated; (2,2) is dominated
        # only by rank-0 (2,1); (3,2) is also dominated by rank-1 (2,2)
        assert list(r) == [0, 0, 2, 1]
        # the lexicographic (time, energy) minimum is always rank 0
        assert r[int(np.lexsort((e, t))[0])] == 0

    @quick
    def test_knee_point_prefers_balanced_corner(self):
        t = np.array([1.0, 5.0, 2.0])
        e = np.array([5.0, 1.0, 2.0])
        assert knee_point(t, e) == 2

    @quick
    def test_eps_archive_bounds_and_dominance(self):
        arch = EpsParetoArchive(eps=0.05)
        rng = np.random.default_rng(0)
        cores = np.ones(2, np.int32)
        perm = np.arange(4, dtype=np.int32)
        for _ in range(200):
            arch.add(float(rng.uniform(1, 10)), float(rng.uniform(1, 10)),
                     cores, perm, report=None)
        cands, reports = arch.front()
        assert 0 < len(cands) <= 200
        ts = [it["time"] for it in arch._items]
        es = [it["energy"] for it in arch._items]
        # archive members never plainly dominate one another
        for i in range(len(ts)):
            for j in range(len(ts)):
                if i != j:
                    assert not (ts[i] <= ts[j] and es[i] <= es[j]
                                and (ts[i] < ts[j] or es[i] < es[j]))

    @quick
    def test_eps_archive_batch_update_equals_sequential(self):
        """update_batch must be exactly the sequential add() fold — same
        members, same order, same admission count."""
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @given(seed=st.integers(0, 300), eps=st.sampled_from([0.0, 0.02, 0.2]))
        @settings(max_examples=40, deadline=None)
        def check(seed, eps):
            rng = np.random.default_rng(seed)
            K = int(rng.integers(1, 40))
            # small integer grid -> frequent duplicates and eps-near ties
            t = rng.integers(1, 8, K).astype(float)
            e = rng.integers(1, 8, K).astype(float)
            cores = rng.integers(1, 4, (K, 2)).astype(np.int32)
            perm = np.tile(np.arange(6, dtype=np.int32), (K, 1))
            seq, bat = EpsParetoArchive(eps), EpsParetoArchive(eps)
            added_seq = sum(seq.add(t[k], e[k], cores[k], perm[k], None)
                            for k in range(K))
            added_bat = bat.update_batch(t, e, cores, perm)
            assert added_bat == added_seq
            a = [(it["time"], it["energy"], it["cores"].tobytes())
                 for it in seq._items]
            b = [(it["time"], it["energy"], it["cores"].tobytes())
                 for it in bat._items]
            assert a == b

        check()

    @quick
    def test_eps_archive_rejects_non_finite_points(self):
        """Regression: a NaN point admitted to the archive is never
        dominated (NaN comparisons are all False) and would pin the front
        forever; inf points must lose to every finite one.  Both ``add``
        and ``update_batch`` refuse them outright."""
        cores = np.ones(2, np.int32)
        perm = np.arange(4, dtype=np.int32)
        arch = EpsParetoArchive(eps=0.05)
        assert arch.add(2.0, 3.0, cores, perm, None)
        for t, e in ((np.nan, 1.0), (1.0, np.nan), (np.inf, 1.0),
                     (1.0, -np.inf), (np.nan, np.nan)):
            assert not arch.add(t, e, cores, perm, None)
        assert len(arch) == 1
        K = 5
        t = np.array([1.0, np.nan, 0.5, np.inf, 0.25])
        e = np.array([1.0, 0.1, np.nan, 0.1, 0.5])
        batch = EpsParetoArchive(eps=0.05)
        added = batch.update_batch(
            t, e, np.tile(cores, (K, 1)), np.tile(perm, (K, 1)))
        assert added == 2                      # only the finite rows 0, 4
        assert all(np.isfinite(it["time"]) and np.isfinite(it["energy"])
                   for it in batch._items)

    def test_search_returns_front_with_knee(self):
        net, xs = fc_workload(sizes=(96, 128, 64), steps=2)
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        res = evolutionary_search(net, prof, ev, population_size=6,
                                  generations=4, seed=3)
        assert res.front and len(res.front) == len(res.front_reports)
        front_t = [r.time_per_step for r in res.front_reports]
        front_e = [r.energy_per_step for r in res.front_reports]
        # sorted by time, mutually nondominated
        assert front_t == sorted(front_t)
        assert all(r == 0 for r in pareto_ranks(front_t, front_e))
        # the best-time result is on (or within eps of) the front
        assert min(front_t) <= res.report.time_per_step * (1 + 0.01 + 1e-12)
        knee_c, knee_r = res.knee()
        assert knee_c in res.front
        assert res.history[-1].front_size == len(res.front)

    def test_history_is_monotone_and_counts_evals(self):
        net, xs = fc_workload(sizes=(96, 128, 64), steps=2)
        prof = loihi2_like()
        ev = SimEvaluator(net, xs, prof)
        res = evolutionary_search(net, prof, ev, population_size=6,
                                  generations=5, seed=2)
        best = [g.best_time for g in res.history]
        assert all(t2 <= t1 for t1, t2 in zip(best, best[1:]))
        evals = [g.n_evals for g in res.history]
        assert all(e2 > e1 for e1, e2 in zip(evals, evals[1:]))
        assert res.history[-1].n_evals == res.n_evals
