"""Array-native population-pricing pipeline tests: batched flow-matrix
construction (:func:`repro.neuromorphic.noc.flow_matrix_population`), the
padded population batch contract, and the jitted ``jax.vmap`` pricing
backend (:func:`repro.neuromorphic.timestep.price_population_vmap`).

Parity contract (``docs/simulator.md``): the NumPy population path is
bit-identical to per-candidate ``simulate``; the vmap path runs the same
float64 formulas under XLA (which may reassociate/fuse), so it is asserted
to ``rtol=1e-9`` instead.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.partitioner import SimEvaluator
from repro.core.search import (Population, decode, decode_population,
                               encode, encode_population, move_tables,
                               seeded_population)
from repro.neuromorphic import (Partition, SimLayer, SimNetwork, fc_network,
                                loihi2_like, make_inputs, minimal_partition,
                                ordered_mapping, programmed_fc_network,
                                random_mapping, simulate, simulate_population,
                                speck_like, strided_mapping)
from repro.neuromorphic.network import _exact_density_mask
from repro.neuromorphic.noc import (_flow_matrix, _pair_hops, _path_incidence,
                                    flow_cache_clear, flow_matrix_population,
                                    router_incidence_population)
from repro.neuromorphic.timestep import (build_population_batch,
                                         population_pad_width,
                                         precompute_pricing,
                                         price_population_device)

quick = pytest.mark.quick

RTOL = 1e-9


def fc_workload(sizes=(96, 128, 128, 64), wd=0.6, ad=0.3, steps=3):
    net = programmed_fc_network(
        list(sizes), weight_densities=[wd] * (len(sizes) - 1),
        act_densities=[ad] * (len(sizes) - 1), seed=0,
        weight_format="sparse")
    return net, make_inputs(sizes[0], ad, steps, seed=1)


def conv_workload(steps=3):
    rng = np.random.default_rng(2)
    layers = []
    h = w = 8
    c_prev = 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, 0.6, rng)
        layers.append(SimLayer(name=f"conv{i}", kind="conv", weights=wgt,
                               stride=2, in_hw=(h, w)))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc))
    net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
    return net, make_inputs(net.in_size, 0.4, steps, seed=3)


def random_genomes(rng, n_cores_phys, n=8):
    """Random (cores, phys) genome rows of varying layer counts/sizes."""
    rows = []
    for _ in range(n):
        n_layers = int(rng.integers(2, 6))
        cores = rng.integers(1, 5, size=n_layers)
        phys = rng.permutation(n_cores_phys)[:int(cores.sum())]
        rows.append((tuple(int(c) for c in cores),
                     tuple(int(p) for p in phys)))
    return rows


class TestFlowMatrixPopulation:
    @quick
    def test_matches_per_candidate_flow_matrix(self):
        prof = loihi2_like()
        rng = np.random.default_rng(0)
        rows = random_genomes(rng, prof.n_cores, n=10)
        n_pad = max(sum(c) for c, _ in rows) + 2
        flow_cache_clear()
        P, dup = flow_matrix_population([c for c, _ in rows],
                                        [p for _, p in rows],
                                        prof.grid, prof.n_cores, n_pad)
        for k, (cores, phys) in enumerate(rows):
            P1, d1 = _flow_matrix(cores, phys, prof.grid, prof.n_cores)
            n = P1.shape[0]
            assert np.array_equal(P[k, :n], P1)
            assert np.array_equal(dup[k, :n], d1)
            # padding contract: no flow, no duplication beyond n_logical
            assert not P[k, n:].any()
            assert not dup[k, n:].any()

    @quick
    def test_cache_hits_reproduce_scatter(self):
        prof = loihi2_like()
        rng = np.random.default_rng(1)
        rows = random_genomes(rng, prof.n_cores, n=6)
        n_pad = max(sum(c) for c, _ in rows) + 1
        flow_cache_clear()
        first = flow_matrix_population([c for c, _ in rows],
                                       [p for _, p in rows],
                                       prof.grid, prof.n_cores, n_pad)
        again = flow_matrix_population([c for c, _ in rows],
                                       [p for _, p in rows],
                                       prof.grid, prof.n_cores, n_pad)
        assert np.array_equal(first[0], again[0])
        assert np.array_equal(first[1], again[1])

    @quick
    def test_router_incidence_fold_is_exact(self):
        """msgs @ (P @ inc) == (msgs @ P) @ inc: integer counts make the
        reassociation lossless, so the folded structures must equal the
        explicit product bit-for-bit."""
        prof = loihi2_like()
        rng = np.random.default_rng(2)
        rows = random_genomes(rng, prof.n_cores, n=6)
        n_pad = max(sum(c) for c, _ in rows)
        flow_cache_clear()
        P, dup = flow_matrix_population([c for c, _ in rows],
                                        [p for _, p in rows],
                                        prof.grid, prof.n_cores, n_pad)
        PL, ph, dup2 = router_incidence_population(
            [c for c, _ in rows], [p for _, p in rows],
            prof.grid, prof.n_cores, n_pad)
        inc = _path_incidence(prof.grid).astype(np.float64)
        hops = _pair_hops(prof.grid).astype(np.float64)
        assert np.array_equal(PL, P.astype(np.float64) @ inc)
        assert np.array_equal(ph, P.astype(np.float64) @ hops)
        assert np.array_equal(dup, dup2)


class TestPopulationBatch:
    @quick
    def test_padding_and_masking_contract(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        cache = precompute_pricing(net, xs, prof)
        p0 = minimal_partition(net, prof)
        pairs = [(p0, ordered_mapping(p0, prof)),
                 (p0.split(0).split(1), strided_mapping(p0.split(0).split(1),
                                                        prof))]
        batch = build_population_batch(cache, net, prof, pairs)
        n_pad = population_pad_width(net, prof)
        assert batch.mask.shape == (2, n_pad)
        for k, (part, _) in enumerate(pairs):
            n = part.total_cores
            assert batch.n_logical[k] == n
            assert batch.mask[k, :n].all() and not batch.mask[k, n:].any()
            # padded cores gather empty segments: lo == hi == 0
            assert not batch.seg_lo[k, n:].any()
            assert not batch.seg_hi[k, n:].any()
            assert not batch.neurons[k, n:].any()
            assert not batch.PL[k, n:].any()
            # live cores cover each layer's neuron range exactly
            assert batch.neurons[k, :n].sum() == \
                sum(l.n_neurons for l in net.layers)


def _assert_reports_close(a, b):
    for f in ("times", "energies", "per_core_synops", "per_core_acts",
              "per_core_msgs_out"):
        va, vb = getattr(a, f), getattr(b, f)
        assert va.shape == vb.shape, f
        assert np.allclose(va, vb, rtol=RTOL, atol=RTOL), f
    for f in ("time_per_step", "energy_per_step", "max_synops", "max_acts",
              "max_link_load"):
        assert np.isclose(getattr(a, f), getattr(b, f), rtol=RTOL), f
    assert a.bottleneck_stage == b.bottleneck_stage
    assert a.n_cores_active == b.n_cores_active
    ma, mb = a.metrics, b.metrics
    assert np.isclose(ma.msgs_total, mb.msgs_total, rtol=RTOL)
    assert np.isclose(ma.weight_density, mb.weight_density, rtol=RTOL)
    assert np.isclose(ma.act_density, mb.act_density, rtol=RTOL)
    for s in ("synops", "acts", "traffic"):
        sa, sb = getattr(ma, s), getattr(mb, s)
        assert (sa.n_units, sa.n_active) == (sb.n_units, sb.n_active), s
        assert np.isclose(sa.total, sb.total, rtol=RTOL), s
        assert np.isclose(sa.max, sb.max, rtol=RTOL), s
        assert np.isclose(sa.imbalance, sb.imbalance, rtol=RTOL), s


class TestVmapBackend:
    @quick
    def test_fc_parity_with_simulate(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        rng = np.random.default_rng(4)
        p0 = minimal_partition(net, prof)
        pairs = [(p0, ordered_mapping(p0, prof)),
                 (p0.split(0), strided_mapping(p0.split(0), prof)),
                 (p0.split(1).split(1),
                  random_mapping(p0.split(1).split(1), prof, rng))]
        reports = simulate_population(net, xs, prof, pairs, backend="vmap")
        for (p, m), rp in zip(pairs, reports):
            _assert_reports_close(
                rp, simulate(net, xs, prof, p, m, engine="batched"))

    def test_conv_parity_with_numpy_backend(self):
        net, xs = conv_workload()
        prof = loihi2_like()
        parts = [Partition((1, 1, 1)), Partition((2, 4, 2)),
                 Partition((4, 8, 1))]
        pairs = [(p, strided_mapping(p, prof)) for p in parts]
        r_np = simulate_population(net, xs, prof, pairs)
        r_vm = simulate_population(net, xs, prof, pairs, backend="vmap")
        for a, b in zip(r_np, r_vm):
            _assert_reports_close(a, b)

    @quick
    def test_empty_core_segments(self):
        net = fc_network([16, 6, 8], weight_density=1.0, seed=19)
        xs = make_inputs(16, 0.8, 3, seed=20)
        prof = loihi2_like()
        pairs = [(Partition((1, 1)), ordered_mapping(Partition((1, 1)),
                                                     prof)),
                 (Partition((7, 2)), strided_mapping(Partition((7, 2)),
                                                     prof))]
        for (p, m), rp in zip(pairs, simulate_population(net, xs, prof,
                                                         pairs,
                                                         backend="vmap")):
            _assert_reports_close(rp, simulate(net, xs, prof, p, m))

    def test_async_platform_parity(self):
        """Speck-style chips take the pipeline-latency branch of the jitted
        program (per-layer segment maxima instead of the barrier max)."""
        prof = speck_like()
        rng = np.random.default_rng(7)
        layers = []
        h = w = 8
        c_prev = 2
        for i, c in enumerate((4, 4)):
            wgt = rng.normal(0, 1 / 3.0,
                             (3, 3, c_prev, c)).astype(np.float32)
            layers.append(SimLayer(name=f"c{i}", kind="conv", weights=wgt,
                                   stride=2, in_hw=(h, w), neuron_model="if",
                                   threshold=1.0))
            h, w, c_prev = h // 2, w // 2, c
        net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
        xs = make_inputs(net.in_size, 0.4, 3, seed=8)
        p = minimal_partition(net, prof)
        pairs = [(p, ordered_mapping(p, prof))]
        for (pp, m), rp in zip(pairs, simulate_population(net, xs, prof,
                                                          pairs,
                                                          backend="vmap")):
            _assert_reports_close(rp, simulate(net, xs, prof, pp, m))

    @quick
    def test_large_population_parity_spot_checks(self):
        """A seeded 32-candidate population vmap-prices to the same results
        as the NumPy path (spot-checked pointwise over the whole batch)."""
        net, xs = fc_workload(steps=2)
        prof = loihi2_like()
        rng = np.random.default_rng(9)
        pairs = [decode(c) for c in seeded_population(net, prof, size=32,
                                                      rng=rng)]
        r_np = simulate_population(net, xs, prof, pairs)
        r_vm = simulate_population(net, xs, prof, pairs, backend="vmap")
        assert len(r_np) == len(r_vm) == 32
        for a, b in zip(r_np, r_vm):
            _assert_reports_close(a, b)

    @quick
    def test_evaluator_vmap_backend_counts_and_matches(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        ev_np = SimEvaluator(net, xs, prof)
        ev_vm = SimEvaluator(net, xs, prof, cache=ev_np.cache,
                             population_backend="vmap")
        p0 = minimal_partition(net, prof)
        pairs = [(p0, strided_mapping(p0, prof)),
                 (p0.split(2), ordered_mapping(p0.split(2), prof))]
        a = ev_np.evaluate_population(pairs)
        b = ev_vm.evaluate_population(pairs)
        assert ev_vm.n_evals == 2
        for ra, rb in zip(a, b):
            _assert_reports_close(ra, rb)

    @quick
    def test_unknown_backend_raises(self):
        net, xs = fc_workload(steps=2)
        prof = loihi2_like()
        p0 = minimal_partition(net, prof)
        with pytest.raises(ValueError, match="backend"):
            simulate_population(net, xs, prof,
                                [(p0, ordered_mapping(p0, prof))],
                                backend="tpu")


class TestDeviceBackend:
    """The ``backend="device"`` pricing path: genome arrays in, the padded
    batch structures derived on device — same float64-roundoff parity
    contract as the vmap backend, and bit-identical to vmap itself (the
    two share the jitted pricing program; only structure construction
    differs, and structures are exact integers)."""

    @quick
    def test_fc_parity_with_numpy_and_vmap(self):
        net, xs = fc_workload()
        prof = loihi2_like()
        rng = np.random.default_rng(21)
        pairs = [decode(c) for c in seeded_population(net, prof, size=12,
                                                      rng=rng)]
        r_np = simulate_population(net, xs, prof, pairs)
        r_dev = simulate_population(net, xs, prof, pairs, backend="device")
        r_vm = simulate_population(net, xs, prof, pairs, backend="vmap")
        for a, b, c in zip(r_np, r_dev, r_vm):
            _assert_reports_close(a, b)
            assert b.time_per_step == c.time_per_step
            assert b.energy_per_step == c.energy_per_step

    @quick
    def test_empty_core_segments(self):
        net = fc_network([16, 6, 8], weight_density=1.0, seed=19)
        xs = make_inputs(16, 0.8, 3, seed=20)
        prof = loihi2_like()
        pairs = [(Partition((7, 2)), strided_mapping(Partition((7, 2)),
                                                     prof))]
        for (p, m), rp in zip(pairs, simulate_population(net, xs, prof,
                                                         pairs,
                                                         backend="device")):
            _assert_reports_close(rp, simulate(net, xs, prof, p, m))

    def test_async_platform_parity(self):
        prof = speck_like()
        rng = np.random.default_rng(7)
        layers = []
        h = w = 8
        c_prev = 2
        for i, c in enumerate((4, 4)):
            wgt = rng.normal(0, 1 / 3.0,
                             (3, 3, c_prev, c)).astype(np.float32)
            layers.append(SimLayer(name=f"c{i}", kind="conv", weights=wgt,
                                   stride=2, in_hw=(h, w), neuron_model="if",
                                   threshold=1.0))
            h, w, c_prev = h // 2, w // 2, c
        net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
        xs = make_inputs(net.in_size, 0.4, 3, seed=8)
        p = minimal_partition(net, prof)
        pairs = [(p, ordered_mapping(p, prof))]
        for (pp, m), rp in zip(pairs, simulate_population(net, xs, prof,
                                                          pairs,
                                                          backend="device")):
            _assert_reports_close(rp, simulate(net, xs, prof, pp, m))

    @quick
    def test_accepts_on_device_genome_arrays(self):
        """price_population_device is the re-pricing entry point for
        populations that already live on the accelerator: jnp inputs, no
        pre-built batch."""
        import jax.numpy as jnp

        from repro.core.search import Population
        net, xs = fc_workload(steps=2)
        prof = loihi2_like()
        cache = precompute_pricing(net, xs, prof)
        rng = np.random.default_rng(23)
        cands = seeded_population(net, prof, size=6, rng=rng)
        pop = Population.from_candidates(cands)
        reports = price_population_device(net, prof, cache,
                                          jnp.asarray(pop.cores),
                                          jnp.asarray(pop.perm))
        r_np = simulate_population(net, xs, prof,
                                   [decode(c) for c in cands], cache=cache)
        for a, b in zip(r_np, reports):
            _assert_reports_close(a, b)

    @quick
    def test_evaluator_device_backend_counts_and_matches(self):
        net, xs = fc_workload(steps=2)
        prof = loihi2_like()
        ev_np = SimEvaluator(net, xs, prof)
        ev_dev = SimEvaluator(net, xs, prof, cache=ev_np.cache,
                              population_backend="device")
        p0 = minimal_partition(net, prof)
        pairs = [(p0, strided_mapping(p0, prof)),
                 (p0.split(1), ordered_mapping(p0.split(1), prof))]
        a = ev_np.evaluate_population(pairs)
        b = ev_dev.evaluate_population(pairs)
        assert ev_dev.n_evals == 2
        for ra, rb in zip(a, b):
            _assert_reports_close(ra, rb)


class TestTensorFirstRoundTrip:
    @quick
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_population_round_trip(self, seed):
        """Hypothesis round-trip: random valid genomes survive
        encode_population -> decode_population and the Population view
        unchanged."""
        prof = loihi2_like()
        net, _ = fc_workload(steps=2)
        rng = np.random.default_rng(seed)
        tables = move_tables(net, prof)
        cands = []
        for _ in range(int(rng.integers(1, 7))):
            cores = np.ones(len(net.layers), np.int32)
            for _ in range(int(rng.integers(0, 8))):
                l = int(rng.integers(len(net.layers)))
                if tables.feasible[l, cores[l] + 1] \
                        and cores.sum() + 1 <= prof.n_cores:
                    cores[l] += 1
            part = Partition(tuple(int(x) for x in cores))
            cands.append(encode(part, random_mapping(part, prof, rng),
                                prof.n_cores))
        cores_mat, perm_mat = encode_population(cands)
        assert cores_mat.shape == (len(cands), len(net.layers))
        assert perm_mat.shape == (len(cands), prof.n_cores)
        assert decode_population(cores_mat, perm_mat) == cands
        pop = Population(cores_mat, perm_mat)
        assert pop.candidates() == cands
        for k, c in enumerate(cands):
            p, m = decode(c)
            pp, pm = pop.pairs()[k]
            assert pp == p
            assert tuple(pm.phys) == tuple(m.phys)
            # every row is a permutation of all physical slots
            assert sorted(pop.perm[k]) == list(range(prof.n_cores))


class TestTrainedProfileParity:
    """Acceptance contract for trained sparsity profiles: a profile-applied
    workload prices bit-identically on the numpy population backend (vs
    per-candidate ``simulate``) and to float64-roundoff parity on vmap and
    device — profile injection only rewrites the NETWORK (gates + masked
    weights), never the pricing math, so every backend guarantee holds."""

    def _profiled_workload(self, steps=3):
        from repro.sparsity import SparsityProfile
        rng = np.random.default_rng(31)
        net = fc_network([48, 64, 64, 32], weight_density=1.0, seed=30)
        masks = tuple(
            _exact_density_mask(l.weights.shape, d, rng).astype(np.float32)
            for l, d in zip(net.layers, (0.7, 0.5, 0.8)))
        profile = SparsityProfile(
            layer_names=tuple(l.name for l in net.layers),
            act_density=np.array([0.35, 0.5, 0.2]),
            weight_density=np.array([0.7, 0.5, 0.8]),
            weight_masks=masks, input_density=0.4)
        return net, profile, make_inputs(48, 0.4, steps, seed=32)

    @quick
    def test_three_way_backend_parity_under_profile(self):
        net, profile, xs = self._profiled_workload()
        prof = loihi2_like()
        applied = profile.apply(net)
        rng = np.random.default_rng(33)
        pairs = [decode(c) for c in seeded_population(applied, prof,
                                                      size=8, rng=rng)]
        r_np = simulate_population(net, xs, prof, pairs,
                                   sparsity_profile=profile)
        r_vm = simulate_population(applied, xs, prof, pairs,
                                   backend="vmap")
        r_dev = simulate_population(applied, xs, prof, pairs,
                                    backend="device")
        for (p, m), a, b, c in zip(pairs, r_np, r_vm, r_dev):
            ref = simulate(net, xs, prof, p, m, sparsity_profile=profile)
            # numpy population path is BIT-identical to simulate
            assert a.time_per_step == ref.time_per_step
            assert a.energy_per_step == ref.energy_per_step
            _assert_reports_close(a, b)
            _assert_reports_close(a, c)

    @quick
    def test_profile_injection_equals_pre_applied_net(self):
        net, profile, xs = self._profiled_workload()
        prof = loihi2_like()
        r1 = simulate(net, xs, prof, sparsity_profile=profile)
        r2 = simulate(profile.apply(net), xs, prof)
        assert r1.time_per_step == r2.time_per_step
        assert r1.energy_per_step == r2.energy_per_step

    def test_evaluator_profile_matches_applied_net(self):
        net, profile, xs = self._profiled_workload()
        prof = loihi2_like()
        e1 = SimEvaluator(net, xs, prof, sparsity_profile=profile)
        e2 = SimEvaluator(profile.apply(net), xs, prof)
        p0 = minimal_partition(profile.apply(net), prof)
        m0 = ordered_mapping(p0, prof)
        a, b = e1(p0, m0), e2(p0, m0)
        assert a.time_per_step == b.time_per_step
        assert a.energy_per_step == b.energy_per_step
