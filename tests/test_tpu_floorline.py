"""TPU floorline: hlo_cost trip-count analyzer, three-term model,
bottleneck classification, hillclimb accept/backtrack semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hlo_cost, tpu_floorline as tfl
from repro.core.analytical import Bottleneck
from repro.distributed.autoshard import HillResult, Move, hillclimb


def _compiled(M, R):
    def step(x, w):
        def layer(c, _):
            return jnp.tanh(c @ w), None

        def mb(c, xi):
            y, _ = jax.lax.scan(layer, xi, None, length=R)
            return c + jnp.sum(y), None
        s, _ = jax.lax.scan(mb, 0.0, x)
        return s
    x = jax.ShapeDtypeStruct((M, 64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    return jax.jit(step).lower(x, w).compile()


def test_hlo_cost_scan_trip_counts():
    for M, R in [(1, 1), (2, 3), (4, 4)]:
        c = hlo_cost.analyze(_compiled(M, R).as_text())
        assert c.flops == M * R * 2 * 64 ** 3, (M, R, c.flops)


def test_xla_cost_analysis_undercounts_scans():
    """The reason hlo_cost exists: XLA counts while bodies once."""
    ca = _compiled(4, 4).cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    assert ca["flops"] < 2 * 2 * 64 ** 3          # ~1 matmul, not 16


def test_roofline_terms_dominance():
    t = tfl.RooflineTerms(flops_per_chip=197e12, hbm_bytes_per_chip=819e9,
                          collective_bytes_per_chip=0, model_flops=1.0,
                          n_chips=1)
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 1.0) < 1e-9
    t2 = tfl.RooflineTerms(1e12, 819e9 * 5, 0, model_flops=1.0)
    assert t2.dominant == Bottleneck.MEMORY
    t3 = tfl.RooflineTerms(1e12, 1e9, 50e9 * 100, model_flops=1.0)
    assert t3.dominant == Bottleneck.TRAFFIC
    assert "collective" in t3.recommendation()


def test_model_flops_rules():
    from repro.configs import registry
    cfg = registry.get("kimi-k2-1t-a32b").config
    mf_train = tfl.model_flops_for(cfg, "train", 4096, 256)
    # MoE: active params only
    assert mf_train == 6.0 * cfg.active_param_count() * 4096 * 256
    mf_dec = tfl.model_flops_for(cfg, "decode", 32768, 128)
    assert mf_dec == 2.0 * cfg.active_param_count() * 128


def test_hillclimb_accepts_and_backtracks():
    calls = []

    def evaluate(**kw):
        calls.append(kw)
        bound = 10.0
        if kw.get("good"):
            bound -= 4.0
        if kw.get("bad"):
            bound += 1.0
        return {"bound_s": bound, "t_compute_s": 1, "t_memory_s": bound,
                "t_collective_s": 0.1, "dominant": "memory"}

    moves = [
        Move("bad-move", "should regress", Bottleneck.MEMORY, {"bad": True}),
        Move("good-move", "should help", Bottleneck.MEMORY, {"good": True}),
    ]
    res = hillclimb(evaluate, moves)
    assert isinstance(res, HillResult)
    assert res.best["bound_s"] == 6.0
    assert res.best_overrides == {"good": True}      # bad move backtracked
    accepted = [s for s in res.log if s.accepted]
    assert len(accepted) == 1 and accepted[0].move == "good-move"
    assert "| good-move |" in res.markdown()


def test_parse_collectives_fallback_regex():
    text = """
  %all-gather.5 = bf16[4,32,16,64]{3,2,1,0} all-gather(bf16[4,2,16,64]{3,2,1,0} %p), replica_groups=[16,16]<=[256], dimensions={1}
  %all-reduce.1 = f32[128]{0} all-reduce(f32[128]{0} %q), replica_groups={}
"""
    st = tfl.parse_collectives(text)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 1}
    assert st.bytes_by_kind["all-gather"] == 4 * 2 * 16 * 64 * 2
    assert st.bytes_by_kind["all-reduce"] == 128 * 4
