"""Serving engine: batched generation across families + greedy consistency
(engine decode path == running the model on the growing sequence)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed import sharding
from repro.models import lm
from repro.models.layers import single_device_mesh
from repro.serve.engine import Engine, ServeConfig

FAMS = ["granite-3-2b", "gemma2-2b", "mamba2-1.3b", "recurrentgemma-2b",
        "olmoe-1b-7b"]


@pytest.mark.parametrize("arch", FAMS)
def test_generate_runs(arch):
    cfg = registry.get(arch).smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, single_device_mesh(),
                 ServeConfig(max_new_tokens=6))
    out = eng.generate([[1, 2, 3, 4, 5, 6, 7, 8],
                        [2, 3, 4, 5, 6, 7, 8, 9]])
    assert len(out) == 2 and all(len(o) == 6 for o in out)
    assert all(0 <= t < cfg.vocab_size for o in out for t in o)


@pytest.mark.parametrize("arch", ["granite-3-2b", "recurrentgemma-2b"])
def test_engine_matches_teacher_forcing(arch):
    """Greedy engine output == argmax of the full forward run token by
    token (exercises prefill->decode cache handoff incl. ring rolls)."""
    cfg = registry.get(arch).smoke()
    ctx = sharding.make_ctx(single_device_mesh())
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    prompt = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5, 9]
    N = 5
    eng = Engine(cfg, params, single_device_mesh(),
                 ServeConfig(max_new_tokens=N))
    got = eng.generate([prompt])[0]

    seq = list(prompt)
    ref = []
    for _ in range(N):
        toks = jnp.asarray([seq], jnp.int32)
        h, _ = lm.forward(params, toks, cfg, ctx)
        logits = lm.logits_from_h(params, h, cfg, ctx)[0, -1]
        nxt = int(jnp.argmax(logits))
        ref.append(nxt)
        seq.append(nxt)
    assert got == ref, (got, ref)
