"""Sharding spec trees: structure matches params exactly for every arch;
spec dims stay within leaf ranks; ZeRO-1 / grad-spec extensions behave."""

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.models import encdec, lm
from repro.models.layers import ShardCtx, single_device_mesh


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_param_specs_match_structure(arch):
    entry = registry.get(arch)
    cfg = entry.smoke()
    ctx = sharding.make_ctx(single_device_mesh())
    init_p = encdec.init_params if entry.is_encdec else lm.init_params
    params = jax.eval_shape(lambda: init_p(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, ctx)
    jax.tree.map(lambda p, s: None, params, specs)   # structure must match
    for p, s in zip(jax.tree.leaves(params), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        assert len(tuple(s)) <= p.ndim, (arch, p.shape, s)


class _FakeMesh:
    """Production-mesh stand-in for spec construction (no devices)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.devices = np.empty(int(np.prod(list(shape.values()))))


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_full_config_specs_divisible(arch):
    """On the production-mesh axis sizes (2/16/16), every sharded dim of
    the FULL config must divide evenly — this is the static check behind
    the dry-run's success."""
    entry = registry.get(arch)
    cfg = entry.config
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    ctx = ShardCtx(mesh=mesh, dp=("pod", "data"), tp="model")
    init_p = encdec.init_params if entry.is_encdec else lm.init_params
    params = jax.eval_shape(lambda: init_p(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, ctx)
    sizes = {"pod": 2, "data": 16, "model": 16}
    for p, s in zip(jax.tree.leaves(params), jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, P))):
        for i, entry_ in enumerate(tuple(s)):
            axes = entry_ if isinstance(entry_, tuple) else (entry_,)
            n = int(np.prod([sizes[a] for a in axes if a is not None]))
            if n > 1:
                assert p.shape[i] % n == 0, (arch, p.shape, s, i)


def test_zero1_adds_data_axis():
    entry = registry.get("granite-3-2b")
    cfg = entry.smoke()
    ctx = sharding.make_ctx(single_device_mesh())
    params = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(cfg, ctx)
    z = sharding.zero1_specs(params, specs, ctx)
    # embed (V, d) is (model, None) -> ZeRO adds data on dim 1 (d)
    assert "data" in str(z["embed"])


def test_batch_specs_shard_dim0():
    ctx = sharding.make_ctx(single_device_mesh())
    import jax.numpy as jnp
    batch = {"tokens": jnp.zeros((8, 16), jnp.int32),
             "labels": jnp.zeros((8, 16), jnp.int32)}
    bs = sharding.batch_specs(batch, ctx)
    assert "data" in str(tuple(bs["tokens"])[0])


def test_make_ctx_unsharded_small_batch():
    mesh = single_device_mesh()
    ctx = sharding.make_ctx(mesh, batch_size=1)
    assert ctx.batch_sharded   # dp size 1 divides 1
