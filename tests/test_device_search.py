"""Device-resident evolutionary engine tests (:mod:`repro.core.device_search`).

Three layers of guarantees:

* **structure parity** — the on-device NoC flow structures
  (:func:`repro.neuromorphic.noc.flow_structures_rows`) are bit-identical
  to the host-built :func:`router_incidence_population` (integer counts in
  float64);
* **decision parity** — selection, mutation, and survival are the same
  array program under ``xp=numpy`` and ``xp=jax.numpy``; given the shared
  PRNG-key draws they must agree EXACTLY (integer genome ops);
* **trajectory parity** — a full ``engine="device"`` run and the host
  NumPy mirror (``reference=True``, bit-exact numpy pricing) replay the
  same fitness trajectory to float64 roundoff and land on the same final
  candidate, under the shared PRNG-key contract.

Plus the mutation edge cases of the array path: single-layer networks,
populations where no row has a feasible split/merge, and duplicate
phenotypes after mutation.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core.device_search import (STAGE_ID, _NumpyMirror,
                                      evolutionary_search_device,
                                      generation_draws, mutate_rows_array,
                                      pareto_ranks_array,
                                      survival_order_array)
from repro.core.partitioner import SimEvaluator
from repro.core.search import (Population, decode, encode,
                               evolutionary_search, move_tables, pareto_ranks,
                               seeded_population)
from repro.neuromorphic import (loihi2_like, make_inputs, minimal_partition,
                                programmed_fc_network, random_mapping,
                                strided_mapping)
from repro.neuromorphic.noc import (flow_structures_rows, incidence_tables,
                                    router_incidence_population)
from repro.neuromorphic.partition import validate_partition

quick = pytest.mark.quick


def fc_workload(sizes=(96, 128, 64), wd=0.6, ad=0.3, steps=2):
    net = programmed_fc_network(
        list(sizes), weight_densities=[wd] * (len(sizes) - 1),
        act_densities=[ad] * (len(sizes) - 1), seed=0,
        weight_format="sparse")
    return net, make_inputs(sizes[0], ad, steps, seed=1)


_WORKLOAD: dict = {}


def get_workload():
    """One shared (net, xs, prof, evaluator) so the device pricer/engine
    compile once for the whole module (also usable outside fixtures — the
    hypothesis shim cannot inject pytest fixtures)."""
    if not _WORKLOAD:
        net, xs = fc_workload()
        prof = loihi2_like()
        _WORKLOAD["value"] = (net, xs, prof, SimEvaluator(net, xs, prof))
    return _WORKLOAD["value"]


@pytest.fixture(scope="module")
def workload():
    return get_workload()


def _seed_rows(net, prof, n, seed=0):
    rng = np.random.default_rng(seed)
    pop = Population.from_candidates(
        seeded_population(net, prof, size=n, rng=rng))
    return pop.cores, pop.perm


class TestFlowStructuresDevice:
    @quick
    def test_bitwise_matches_host_fold(self):
        """flow_structures_rows == router_incidence_population, bit for
        bit, across random genomes (incl. a single-layer genome whose only
        destination is the I/O router)."""
        prof = loihi2_like()
        rng = np.random.default_rng(3)
        rows, cols = prof.grid
        cpr = max(1, prof.n_cores // (rows * cols))
        genomes = [((3, 2, 4), None), ((1,), None), ((2, 2), None)]
        genomes = [(np.asarray(c, np.int32),
                    rng.permutation(prof.n_cores)[:sum(c)].astype(np.int32))
                   for c, _ in genomes]
        n_pad = 12
        inc3, hops2 = incidence_tables(prof.grid)
        for cores, phys in genomes:
            L = len(cores)
            PL_h, ph_h, dup_h = router_incidence_population(
                [cores], [phys], prof.grid, prof.n_cores, n_pad)
            n = int(cores.sum())
            lid = np.zeros(n_pad, np.int32)
            router = np.zeros(n_pad, np.int32)
            alive = np.zeros(n_pad, np.float64)
            lid[:n] = np.repeat(np.arange(L), cores)
            router[:n] = phys // cpr
            alive[:n] = 1.0
            with enable_x64():
                PL_d, ph_d, dup_d = flow_structures_rows(
                    jnp.asarray(lid), jnp.asarray(router), jnp.asarray(alive),
                    L, jnp.asarray(inc3), jnp.asarray(hops2))
            assert np.array_equal(np.asarray(PL_d), PL_h[0])
            assert np.array_equal(np.asarray(ph_d), ph_h[0])
            assert np.array_equal(np.asarray(dup_d), dup_h[0])


class TestDecisionParity:
    """The same array program under numpy and jax.numpy: exact agreement."""

    def _draws(self, key, **kw):
        with enable_x64():
            return jax.device_get(generation_draws(key, **kw))

    @quick
    def test_mutation_parity_np_vs_jnp(self, workload):
        net, xs, prof, _ = workload
        tables = move_tables(net, prof)
        cores, perm = _seed_rows(net, prof, 16, seed=1)
        rng = np.random.default_rng(2)
        n = cores.shape[0]
        stage = rng.integers(0, 4, n).astype(np.int32)
        hot_mem = rng.integers(0, cores.shape[1], n).astype(np.int32)
        hot_act = rng.integers(0, cores.shape[1], n).astype(np.int32)
        for s in range(3):
            draws = self._draws(jax.random.PRNGKey(s), n_off=n, n_pop=n,
                                n_layers=cores.shape[1],
                                n_slots=perm.shape[1], tournament_k=3)
            parents = draws["tourn"].min(axis=1)
            args = (cores[parents], perm[parents], stage[parents],
                    hot_mem[parents], hot_act[parents], draws)
            c_np, p_np = mutate_rows_array(
                np, *args, np.asarray(tables.feasible),
                tables.n_cores_phys, 0.25)
            with enable_x64():
                c_j, p_j = mutate_rows_array(
                    jnp, *[jnp.asarray(a) if not isinstance(a, dict) else
                           {k: jnp.asarray(v) for k, v in a.items()}
                           for a in args],
                    jnp.asarray(tables.feasible), tables.n_cores_phys, 0.25)
            assert np.array_equal(c_np, np.asarray(c_j))
            assert np.array_equal(p_np, np.asarray(p_j))
            # every offspring row is a valid, changed genome
            for k in range(n):
                i = int(parents[k])
                changed = (not np.array_equal(c_np[k], cores[i])
                           or not np.array_equal(p_np[k], perm[i]))
                assert changed
                assert tables.valid_rows(c_np[k][None, :])[0]
                assert sorted(p_np[k]) == list(range(prof.n_cores))

    @quick
    def test_survival_parity_and_dedup(self, workload):
        net, xs, prof, _ = workload
        cores, perm = _seed_rows(net, prof, 10, seed=4)
        # inject duplicate phenotypes: rows 3/7 copy rows 0/1 (with a
        # shuffled dead tail on one of them — same phenotype, different
        # genome bytes)
        cores[3], perm[3] = cores[0], perm[0]
        cores[7] = cores[1]
        perm[7] = perm[1].copy()
        n_expr = int(cores[7].sum())
        perm[7, n_expr:] = perm[7, n_expr:][::-1]
        rng = np.random.default_rng(5)
        t = rng.uniform(1, 10, len(cores))
        e = rng.uniform(1, 10, len(cores))
        # duplicates must carry identical objectives (same phenotype)
        t[3], e[3] = t[0], e[0]
        t[7], e[7] = t[1], e[1]
        ranks = pareto_ranks(t, e)
        idx_np = survival_order_array(np, cores, perm, t, e, ranks, 6)
        with enable_x64():
            ranks_j = pareto_ranks_array(jnp.asarray(t), jnp.asarray(e))
            assert np.array_equal(np.asarray(ranks_j), ranks)
            idx_j = survival_order_array(
                jnp, jnp.asarray(cores), jnp.asarray(perm), jnp.asarray(t),
                jnp.asarray(e), ranks_j, 6)
        assert np.array_equal(idx_np, np.asarray(idx_j))
        # survivors are phenotype-unique (dup rows sorted behind)
        keys = {Population.row_key(cores[i], perm[i]) for i in idx_np}
        assert len(keys) == len(idx_np)

    @quick
    def test_pareto_ranks_device_known_points(self):
        t = np.array([1.0, 2.0, 3.0, 2.0])
        e = np.array([3.0, 1.0, 2.0, 2.0])
        with enable_x64():
            r = np.asarray(pareto_ranks_array(jnp.asarray(t),
                                              jnp.asarray(e)))
        assert list(r) == [0, 0, 2, 1]

    @given(seed=st.integers(0, 300), cap=st.integers(1, 24))
    @settings(max_examples=50, deadline=None)
    def test_pareto_ranks_tie_and_cap_parity(self, seed, cap):
        """Host and device ranks agree bit for bit on duplicate (time,
        energy) rows, and on everything below the survivor cutoff after
        rank-capped peeling (unpeeled rows carry the sentinel rank K on
        both sides)."""
        rng = np.random.default_rng(seed)
        k = int(rng.integers(2, 24))
        # tiny integer grid -> many exact duplicates and dominance ties
        t = rng.integers(0, 4, k).astype(np.float64)
        e = rng.integers(0, 4, k).astype(np.float64)
        full_h = pareto_ranks(t, e)
        cap_h = pareto_ranks(t, e, n_keep=cap)
        with enable_x64():
            full_d = np.asarray(pareto_ranks_array(jnp.asarray(t),
                                                   jnp.asarray(e)))
            cap_d = np.asarray(pareto_ranks_array(jnp.asarray(t),
                                                  jnp.asarray(e),
                                                  n_keep=cap))
        assert np.array_equal(full_h, full_d)
        assert np.array_equal(cap_h, cap_d)
        # duplicate rows always share a rank
        for i in range(k):
            same = (t == t[i]) & (e == e[i])
            assert (full_h[same] == full_h[i]).all()
            assert (cap_h[same] == cap_h[i]).all()
        # capped == full below the cutoff; sentinel only above it
        peeled = cap_h < k
        assert int(peeled.sum()) >= min(cap, k)
        assert np.array_equal(cap_h[peeled], full_h[peeled])
        if peeled.any() and (~peeled).any():
            assert full_h[~peeled].min() > full_h[peeled].max()


class TestDeviceEngine:
    def test_trajectory_parity_device_vs_numpy_mirror(self, workload):
        """The headline contract: same PRNG keys -> same fitness
        trajectory (float64 roundoff) and same final candidate, device
        (jitted, XLA pricing) vs host mirror (numpy pricing)."""
        net, xs, prof, ev = workload
        res = evolutionary_search(net, prof, ev, population_size=8,
                                  generations=4, seed=7, engine="device")
        ev2 = SimEvaluator(net, xs, prof, cache=ev.cache)
        ref = evolutionary_search_device(net, prof, ev2, population_size=8,
                                         generations=4, seed=7,
                                         reference=True)
        assert len(res.history) == len(ref.history)
        for a, b in zip(res.history, ref.history):
            assert np.isclose(a.best_time, b.best_time, rtol=1e-9)
            assert np.isclose(a.best_energy, b.best_energy, rtol=1e-9)
            assert np.isclose(a.mean_time, b.mean_time, rtol=1e-9)
            assert a.n_evals == b.n_evals
        assert res.candidate == ref.candidate

    @quick
    def test_never_worse_than_seed_and_valid(self, workload):
        net, xs, prof, ev = workload
        rng = np.random.default_rng(5)
        seeds = seeded_population(net, prof, size=8, rng=rng)
        seed_reports = ev.evaluate_population([decode(c) for c in seeds])
        best_seed = min(r.time_per_step for r in seed_reports)
        res = evolutionary_search(net, prof, ev, population_size=8,
                                  generations=4, seed=3,
                                  seed_candidates=seeds, engine="device")
        assert res.report.time_per_step <= best_seed * (1 + 1e-9)
        assert np.isclose(res.seed_best_time, best_seed, rtol=1e-9)
        assert validate_partition(net, res.partition, prof)
        # history is monotone; front exists and knee() resolves
        best = [g.best_time for g in res.history]
        assert all(t2 <= t1 * (1 + 1e-12) for t1, t2 in zip(best, best[1:]))
        assert res.front and res.knee() is not None

    @quick
    def test_determinism_and_budget(self, workload):
        net, xs, prof, ev = workload
        runs = []
        for _ in range(2):
            ev_i = SimEvaluator(net, xs, prof, cache=ev.cache)
            runs.append((evolutionary_search(net, prof, ev_i,
                                             population_size=6,
                                             generations=3, seed=11,
                                             max_evaluations=20,
                                             engine="device"), ev_i))
        (a, ev_a), (b, ev_b) = runs
        assert a.candidate == b.candidate
        assert [g.best_time for g in a.history] == \
            [g.best_time for g in b.history]
        assert a.n_evals == b.n_evals <= 20
        # the device engine charges the evaluator's ledger per generation
        assert ev_a.n_evals == a.n_evals

    @quick
    def test_requires_sim_evaluator_like(self, workload):
        net, xs, prof, ev = workload
        with pytest.raises(TypeError, match="SimEvaluator-like"):
            evolutionary_search(net, prof, lambda p, m: ev(p, m),
                                population_size=4, generations=2,
                                engine="device")

    @quick
    def test_unknown_engine_raises(self, workload):
        net, xs, prof, ev = workload
        with pytest.raises(ValueError, match="engine"):
            evolutionary_search(net, prof, ev, engine="tpu")


class TestMutationEdgeCases:
    @quick
    def test_single_layer_network(self):
        """One-layer genomes: no next layer (all traffic exits at the I/O
        router), hot layer is always 0, and the search still runs device-
        resident end to end."""
        net, xs = fc_workload(sizes=(64, 32))
        prof = loihi2_like()
        assert len(net.layers) == 1
        ev = SimEvaluator(net, xs, prof)
        res = evolutionary_search(net, prof, ev, population_size=6,
                                  generations=3, seed=2, engine="device")
        assert validate_partition(net, res.partition, prof)
        ev2 = SimEvaluator(net, xs, prof, cache=ev.cache)
        ref = evolutionary_search_device(net, prof, ev2, population_size=6,
                                         generations=3, seed=2,
                                         reference=True)
        assert res.candidate == ref.candidate

    @quick
    def test_all_moves_infeasible_falls_back_to_swap(self):
        """allow_partitioning=False masks every split AND every merge
        (all rows pinned at one core per layer): every mutation must fall
        through the cascade to a gene swap, and core counts never move."""
        import dataclasses
        net, xs = fc_workload(sizes=(48, 32, 16))
        prof = dataclasses.replace(loihi2_like(), allow_partitioning=False)
        tables = move_tables(net, prof)
        # the feasibility table really is all-false beyond one core
        assert not tables.feasible[:, 2:].any()
        ev = SimEvaluator(net, xs, prof)
        res = evolutionary_search(net, prof, ev, population_size=4,
                                  generations=3, seed=1, engine="device")
        assert tuple(res.partition.cores) == tuple(1 for _ in net.layers)
        assert validate_partition(net, res.partition, prof)

    @quick
    def test_duplicate_phenotypes_after_mutation_are_deduped(self, workload):
        """Force a degenerate population (every row the same phenotype):
        survivors stay that phenotype or improve, and the engine neither
        crashes nor double-counts the duplicate rows on the front."""
        net, xs, prof, ev = workload
        p0 = minimal_partition(net, prof)
        cand = encode(p0, strided_mapping(p0, prof), prof.n_cores)
        res = evolutionary_search(net, prof, ev, population_size=6,
                                  generations=2, seed=9,
                                  seed_candidates=[cand] * 6,
                                  engine="device")
        assert validate_partition(net, res.partition, prof)
        front_keys = {c for c in map(lambda c: (c.cores, c.perm), res.front)}
        assert len(front_keys) == len(res.front)

    @quick
    @settings(max_examples=4, deadline=None)
    @given(st.integers(min_value=0, max_value=2**31 - 1))
    def test_hypothesis_generation_step_parity(self, seed):
        """Property: for ANY key seed, the generation steps — the same
        survivors state, the same fold_in keys — produce identical
        offspring genomes on device and in the numpy mirror, and survival
        picks the same rows (objectives compared to float64 roundoff)."""
        net, xs, prof, ev = get_workload()
        ev_d = SimEvaluator(net, xs, prof, cache=ev.cache)
        res_d = evolutionary_search(net, prof, ev_d, population_size=6,
                                    generations=2, seed=seed,
                                    engine="device")
        ev_r = SimEvaluator(net, xs, prof, cache=ev.cache)
        res_r = evolutionary_search_device(net, prof, ev_r,
                                           population_size=6, generations=2,
                                           seed=seed, reference=True)
        for a, b in zip(res_d.history, res_r.history):
            assert np.isclose(a.best_time, b.best_time, rtol=1e-9)
        assert res_d.candidate == res_r.candidate
