"""Recovery tests for :mod:`repro.train.checkpoint` and the search-side
:class:`repro.core.resilience.SearchCheckpointer` built on it.

The properties under test are the crash-safety invariants documented in
``docs/robustness.md``: a partial write (``tmp.<step>`` left behind by a
crash mid-save) is never restored; a crash *between* the npz replace and
the ``meta.json`` replace still restores the newest complete snapshot
without pairing its arrays with the stale meta; ``keep``-pruning retains
exactly the newest ``keep`` steps whatever the save order.
"""

import json
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.resilience import (SearchCheckpointer, decode_bytes_set,
                                   encode_bytes_set, rng_from_state,
                                   rng_state)
from repro.train import checkpoint as ckpt

quick = pytest.mark.quick
pytestmark = pytest.mark.timeout(120)


def _state(step: int) -> dict:
    return {"w": np.full((3, 2), float(step)),
            "b": np.arange(4) + step}


class TestKeepPruning:
    @quick
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=1, max_value=6),
           st.integers(min_value=1, max_value=12))
    def test_round_trip_keeps_newest(self, keep, n_steps):
        """Whatever (keep, n_steps): only the newest ``keep`` step files
        survive, ``latest_step`` is the max, and restoring any surviving
        step round-trips its arrays exactly.  (No pytest fixtures here:
        ``@given`` tests cannot take function-scoped fixtures.)"""
        d = tempfile.mkdtemp(prefix="ckpt-prop-")
        try:
            for s in range(1, n_steps + 1):
                ckpt.save(d, s, _state(s), extra={"s": s}, keep=keep)
            on_disk = sorted(f for f in os.listdir(d)
                             if f.startswith("step_") and f.endswith(".npz"))
            expect = [f"step_{s:08d}.npz"
                      for s in range(max(1, n_steps - keep + 1), n_steps + 1)]
            assert on_disk == expect
            assert ckpt.latest_step(d) == n_steps
            for s in range(max(1, n_steps - keep + 1), n_steps + 1):
                state, got, extra = ckpt.restore(d, _state(0), step=s)
                assert got == s
                np.testing.assert_array_equal(state["w"], _state(s)["w"])
                np.testing.assert_array_equal(state["b"], _state(s)["b"])
                # extra pairs only with the step meta.json describes
                assert extra == ({"s": s} if s == n_steps else {})
        finally:
            shutil.rmtree(d, ignore_errors=True)


class TestCrashMidSave:
    @quick
    def test_partial_tmp_write_is_ignored(self, tmp_path):
        """A crash mid-``np.savez`` leaves ``tmp.<step>.npz`` garbage; the
        atomic-replace layout means restore never sees it and loads the
        newest COMPLETE checkpoint instead."""
        d = str(tmp_path)
        ckpt.save(d, 1, _state(1), extra={"s": 1})
        ckpt.save(d, 2, _state(2), extra={"s": 2})
        # crash while writing step 3: truncated npz under the tmp name
        with open(os.path.join(d, "tmp.3.npz"), "wb") as f:
            f.write(b"PK\x03\x04 not a complete archive")
        assert ckpt.latest_step(d) == 2
        state, step, extra = ckpt.restore(d, _state(0))
        assert step == 2
        np.testing.assert_array_equal(state["w"], _state(2)["w"])
        assert extra == {"s": 2}

    @quick
    def test_crash_between_npz_and_meta_replace(self, tmp_path):
        """Crash after ``os.replace`` of ``step_3.npz`` but before the
        ``meta.json`` replace: meta still says step 2.  The step files are
        authoritative — restore finds step 3 — and the stale meta's
        ``extra`` (which describes step 2's iterator state) must NOT be
        paired with step 3's arrays."""
        d = str(tmp_path)
        ckpt.save(d, 2, _state(2), extra={"iterator": "after-step-2"})
        meta_before = open(os.path.join(d, "meta.json")).read()
        ckpt.save(d, 3, _state(3), extra={"iterator": "after-step-3"})
        # roll meta.json back to simulate the crash window
        with open(os.path.join(d, "meta.json"), "w") as f:
            f.write(meta_before)
        assert json.load(open(os.path.join(d, "meta.json")))[
            "latest_step"] == 2
        assert ckpt.latest_step(d) == 3
        state, step, extra = ckpt.restore(d, _state(0))
        assert step == 3
        np.testing.assert_array_equal(state["w"], _state(3)["w"])
        assert extra == {}          # stale extra withheld, not mispaired

    @quick
    def test_lost_meta_json(self, tmp_path):
        """A torn/deleted ``meta.json`` does not orphan the checkpoints."""
        d = str(tmp_path)
        ckpt.save(d, 5, _state(5))
        os.remove(os.path.join(d, "meta.json"))
        assert ckpt.latest_step(d) == 5
        _, step, extra = ckpt.restore(d, _state(0))
        assert step == 5 and extra == {}


class TestSearchCheckpointer:
    @quick
    def test_snapshot_round_trip_is_self_contained(self, tmp_path):
        """Arrays + embedded JSON meta round-trip through one npz; the
        sidecar ``meta.json`` is never needed to restore."""
        d = str(tmp_path)
        sc = SearchCheckpointer(d, keep=2)
        rng = np.random.default_rng(7)
        rng.integers(0, 100, size=13)          # advance the stream
        tried = {b"alpha", b"bravo-longer", b""}
        buf, lens = encode_bytes_set(tried)
        arrays = {"cores": np.arange(6, dtype=np.int32).reshape(2, 3),
                  "times": np.asarray([1.5, 2.5]),
                  "tried_buf": buf, "tried_lens": lens}
        meta = {"engine": "numpy", "rng_state": rng_state(rng),
                "evals_used": 42, "history": [{"generation": 0}]}
        sc.save(3, arrays, meta)
        os.remove(os.path.join(d, "meta.json"))
        got_arrays, gen, got_meta = sc.restore()
        assert gen == 3
        np.testing.assert_array_equal(got_arrays["cores"], arrays["cores"])
        np.testing.assert_array_equal(got_arrays["times"], arrays["times"])
        assert decode_bytes_set(got_arrays["tried_buf"],
                                got_arrays["tried_lens"]) == tried
        assert got_meta["engine"] == "numpy"
        assert got_meta["evals_used"] == 42
        # the restored RNG continues the stream bit-identically
        rng2 = rng_from_state(got_meta["rng_state"])
        ref = np.random.default_rng(7)
        ref.integers(0, 100, size=13)
        np.testing.assert_array_equal(rng2.integers(0, 1 << 30, size=8),
                                      ref.integers(0, 1 << 30, size=8))

    @quick
    def test_restore_empty_dir_returns_none(self, tmp_path):
        assert SearchCheckpointer(str(tmp_path / "nope")).restore() is None
        assert SearchCheckpointer(str(tmp_path / "nope")).latest() is None

    @quick
    def test_due_cadence(self):
        sc = SearchCheckpointer("unused", every=3)
        assert [g for g in range(9) if sc.due(g, generations=8)] \
            == [0, 3, 6, 8]        # every 3rd plus always the final gen

    @quick
    def test_meta_key_is_reserved(self, tmp_path):
        sc = SearchCheckpointer(str(tmp_path))
        with pytest.raises(ValueError, match="reserved"):
            sc.save(0, {"_meta_json": np.zeros(1)}, {})


class TestSerializationHelpers:
    @quick
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=9),
           st.integers(min_value=0, max_value=40))
    def test_bytes_set_round_trip(self, n_keys, seed):
        rng = np.random.default_rng(seed)
        keys = {rng.integers(0, 256, size=int(rng.integers(0, 24)))
                .astype(np.uint8).tobytes() for _ in range(n_keys)}
        buf, lens = encode_bytes_set(keys)
        assert decode_bytes_set(buf, lens) == keys

    @quick
    def test_rng_state_wrong_bit_generator_rejected(self):
        state = dict(rng_state(np.random.default_rng(0)))
        state["bit_generator"] = "MT19937"
        with pytest.raises(ValueError, match="MT19937"):
            rng_from_state(state)
