"""Recovery tests for :mod:`repro.train.checkpoint` and the search-side
:class:`repro.core.resilience.SearchCheckpointer` built on it.

The properties under test are the crash-safety invariants documented in
``docs/robustness.md``: a partial write (``tmp.<step>`` left behind by a
crash mid-save) is never restored; a crash *between* the npz replace and
the ``meta.json`` replace still restores the newest complete snapshot
without pairing its arrays with the stale meta; ``keep``-pruning retains
exactly the newest ``keep`` steps whatever the save order.

Plus the sharded-engine extension: the island-model search gathers its
per-island device state to host and writes the SAME self-contained
``step_<gen>.npz`` layout (island-block row order), kill-and-resume is
bit-identical, and resume validates the island geometry recorded in the
snapshot meta.
"""

import json
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.resilience import (SearchCheckpointer, decode_bytes_set,
                                   encode_bytes_set, rng_from_state,
                                   rng_state)
from repro.train import checkpoint as ckpt

quick = pytest.mark.quick
pytestmark = pytest.mark.timeout(120)


def _state(step: int) -> dict:
    return {"w": np.full((3, 2), float(step)),
            "b": np.arange(4) + step}


class TestKeepPruning:
    @quick
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=1, max_value=6),
           st.integers(min_value=1, max_value=12))
    def test_round_trip_keeps_newest(self, keep, n_steps):
        """Whatever (keep, n_steps): only the newest ``keep`` step files
        survive, ``latest_step`` is the max, and restoring any surviving
        step round-trips its arrays exactly.  (No pytest fixtures here:
        ``@given`` tests cannot take function-scoped fixtures.)"""
        d = tempfile.mkdtemp(prefix="ckpt-prop-")
        try:
            for s in range(1, n_steps + 1):
                ckpt.save(d, s, _state(s), extra={"s": s}, keep=keep)
            on_disk = sorted(f for f in os.listdir(d)
                             if f.startswith("step_") and f.endswith(".npz"))
            expect = [f"step_{s:08d}.npz"
                      for s in range(max(1, n_steps - keep + 1), n_steps + 1)]
            assert on_disk == expect
            assert ckpt.latest_step(d) == n_steps
            for s in range(max(1, n_steps - keep + 1), n_steps + 1):
                state, got, extra = ckpt.restore(d, _state(0), step=s)
                assert got == s
                np.testing.assert_array_equal(state["w"], _state(s)["w"])
                np.testing.assert_array_equal(state["b"], _state(s)["b"])
                # extra pairs only with the step meta.json describes
                assert extra == ({"s": s} if s == n_steps else {})
        finally:
            shutil.rmtree(d, ignore_errors=True)


class TestCrashMidSave:
    @quick
    def test_partial_tmp_write_is_ignored(self, tmp_path):
        """A crash mid-``np.savez`` leaves ``tmp.<step>.npz`` garbage; the
        atomic-replace layout means restore never sees it and loads the
        newest COMPLETE checkpoint instead."""
        d = str(tmp_path)
        ckpt.save(d, 1, _state(1), extra={"s": 1})
        ckpt.save(d, 2, _state(2), extra={"s": 2})
        # crash while writing step 3: truncated npz under the tmp name
        with open(os.path.join(d, "tmp.3.npz"), "wb") as f:
            f.write(b"PK\x03\x04 not a complete archive")
        assert ckpt.latest_step(d) == 2
        state, step, extra = ckpt.restore(d, _state(0))
        assert step == 2
        np.testing.assert_array_equal(state["w"], _state(2)["w"])
        assert extra == {"s": 2}

    @quick
    def test_crash_between_npz_and_meta_replace(self, tmp_path):
        """Crash after ``os.replace`` of ``step_3.npz`` but before the
        ``meta.json`` replace: meta still says step 2.  The step files are
        authoritative — restore finds step 3 — and the stale meta's
        ``extra`` (which describes step 2's iterator state) must NOT be
        paired with step 3's arrays."""
        d = str(tmp_path)
        ckpt.save(d, 2, _state(2), extra={"iterator": "after-step-2"})
        meta_before = open(os.path.join(d, "meta.json")).read()
        ckpt.save(d, 3, _state(3), extra={"iterator": "after-step-3"})
        # roll meta.json back to simulate the crash window
        with open(os.path.join(d, "meta.json"), "w") as f:
            f.write(meta_before)
        assert json.load(open(os.path.join(d, "meta.json")))[
            "latest_step"] == 2
        assert ckpt.latest_step(d) == 3
        state, step, extra = ckpt.restore(d, _state(0))
        assert step == 3
        np.testing.assert_array_equal(state["w"], _state(3)["w"])
        assert extra == {}          # stale extra withheld, not mispaired

    @quick
    def test_lost_meta_json(self, tmp_path):
        """A torn/deleted ``meta.json`` does not orphan the checkpoints."""
        d = str(tmp_path)
        ckpt.save(d, 5, _state(5))
        os.remove(os.path.join(d, "meta.json"))
        assert ckpt.latest_step(d) == 5
        _, step, extra = ckpt.restore(d, _state(0))
        assert step == 5 and extra == {}


class TestSearchCheckpointer:
    @quick
    def test_snapshot_round_trip_is_self_contained(self, tmp_path):
        """Arrays + embedded JSON meta round-trip through one npz; the
        sidecar ``meta.json`` is never needed to restore."""
        d = str(tmp_path)
        sc = SearchCheckpointer(d, keep=2)
        rng = np.random.default_rng(7)
        rng.integers(0, 100, size=13)          # advance the stream
        tried = {b"alpha", b"bravo-longer", b""}
        buf, lens = encode_bytes_set(tried)
        arrays = {"cores": np.arange(6, dtype=np.int32).reshape(2, 3),
                  "times": np.asarray([1.5, 2.5]),
                  "tried_buf": buf, "tried_lens": lens}
        meta = {"engine": "numpy", "rng_state": rng_state(rng),
                "evals_used": 42, "history": [{"generation": 0}]}
        sc.save(3, arrays, meta)
        os.remove(os.path.join(d, "meta.json"))
        got_arrays, gen, got_meta = sc.restore()
        assert gen == 3
        np.testing.assert_array_equal(got_arrays["cores"], arrays["cores"])
        np.testing.assert_array_equal(got_arrays["times"], arrays["times"])
        assert decode_bytes_set(got_arrays["tried_buf"],
                                got_arrays["tried_lens"]) == tried
        assert got_meta["engine"] == "numpy"
        assert got_meta["evals_used"] == 42
        # the restored RNG continues the stream bit-identically
        rng2 = rng_from_state(got_meta["rng_state"])
        ref = np.random.default_rng(7)
        ref.integers(0, 100, size=13)
        np.testing.assert_array_equal(rng2.integers(0, 1 << 30, size=8),
                                      ref.integers(0, 1 << 30, size=8))

    @quick
    def test_restore_empty_dir_returns_none(self, tmp_path):
        assert SearchCheckpointer(str(tmp_path / "nope")).restore() is None
        assert SearchCheckpointer(str(tmp_path / "nope")).latest() is None

    @quick
    def test_due_cadence(self):
        sc = SearchCheckpointer("unused", every=3)
        assert [g for g in range(9) if sc.due(g, generations=8)] \
            == [0, 3, 6, 8]        # every 3rd plus always the final gen

    @quick
    def test_meta_key_is_reserved(self, tmp_path):
        sc = SearchCheckpointer(str(tmp_path))
        with pytest.raises(ValueError, match="reserved"):
            sc.save(0, {"_meta_json": np.zeros(1)}, {})


class TestSerializationHelpers:
    @quick
    @settings(max_examples=20, deadline=None)
    @given(st.integers(min_value=0, max_value=9),
           st.integers(min_value=0, max_value=40))
    def test_bytes_set_round_trip(self, n_keys, seed):
        rng = np.random.default_rng(seed)
        keys = {rng.integers(0, 256, size=int(rng.integers(0, 24)))
                .astype(np.uint8).tobytes() for _ in range(n_keys)}
        buf, lens = encode_bytes_set(keys)
        assert decode_bytes_set(buf, lens) == keys

    @quick
    def test_rng_state_wrong_bit_generator_rejected(self):
        state = dict(rng_state(np.random.default_rng(0)))
        state["bit_generator"] = "MT19937"
        with pytest.raises(ValueError, match="MT19937"):
            rng_from_state(state)


class TestShardedSearchCheckpoint:
    """The sharded engine's snapshots reuse the device-engine layout:
    island state gathered to host (island-block row order), archive and
    history embedded, geometry recorded in the meta."""

    @staticmethod
    def _workload():
        from repro.core.partitioner import SimEvaluator
        from repro.neuromorphic import (loihi2_like, make_inputs,
                                        programmed_fc_network)
        if "value" not in _SHARDED_WL:
            net = programmed_fc_network(
                [48, 64, 32], weight_densities=[0.6, 0.6],
                act_densities=[0.3, 0.3], seed=0, weight_format="sparse")
            xs = make_inputs(48, 0.3, 2, seed=1)
            prof = loihi2_like()
            _SHARDED_WL["value"] = (net, xs, prof,
                                    SimEvaluator(net, xs, prof))
        return _SHARDED_WL["value"]

    def _run(self, d=None, resume=False, fault_plan=None, **kw):
        from repro.core.partitioner import SimEvaluator
        from repro.core.search import evolutionary_search
        net, xs, prof, ev = self._workload()
        args = dict(population_size=16, generations=4, seed=3,
                    engine="sharded", migrate_every=2)
        args.update(kw)
        return evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            checkpoint_dir=d, resume=resume, fault_plan=fault_plan, **args)

    def test_kill_and_resume_is_bit_identical(self, tmp_path):
        """Kill after generation 2 of 4 (snapshot on disk), resume: the
        trajectory, front and final candidate equal the uninterrupted
        run's EXACTLY (each generation is a pure function of the island
        keys and the gathered state)."""
        from repro.core.resilience import FaultPlan, SimulatedCrash
        full = self._run()
        d = str(tmp_path / "ck")
        with pytest.raises(SimulatedCrash):
            self._run(d=d, fault_plan=FaultPlan(kill_after_gen=2))
        res = self._run(d=d, resume=True)
        assert [(g.generation, g.best_time, g.best_energy, g.mean_time,
                 g.n_evals, g.front_size) for g in res.history] \
            == [(g.generation, g.best_time, g.best_energy, g.mean_time,
                 g.n_evals, g.front_size) for g in full.history]
        assert res.front == full.front
        assert res.candidate == full.candidate

    @quick
    def test_snapshot_layout_is_shared_and_self_contained(self, tmp_path):
        """Sharded snapshots are ordinary step_<gen>.npz files: restorable
        by the bare SearchCheckpointer without the sidecar meta.json, with
        the gathered global state shapes and the island geometry in the
        embedded meta."""
        import jax
        net, xs, prof, ev = self._workload()
        d = str(tmp_path / "ck")
        self._run(d=d, generations=2)
        assert sorted(f for f in os.listdir(d) if f.endswith(".npz")) \
            == [f"step_{g:08d}.npz" for g in range(3)]
        os.remove(os.path.join(d, "meta.json"))
        arrays, gen, meta = SearchCheckpointer(d).restore()
        assert gen == 2
        assert meta["engine"] == "sharded"
        assert meta["n_islands"] == len(jax.devices())
        assert meta["migrate_every"] == 2
        assert arrays["cores"].shape == (16, len(net.layers))
        assert arrays["times"].shape == (16,)

    @quick
    def test_resume_rejects_geometry_mismatch(self, tmp_path):
        """A snapshot records (n_islands, migrate_every, n_migrants); a
        resume configured differently would silently change the trajectory
        — loud error instead."""
        d = str(tmp_path / "ck")
        self._run(d=d, generations=2)
        with pytest.raises(ValueError, match="migrate_every"):
            self._run(d=d, resume=True, migrate_every=3)

    @quick
    def test_resume_rejects_engine_mismatch(self, tmp_path):
        """A device-engine snapshot must not seed a sharded resume (and
        vice versa) even though the array layout matches at one island."""
        d = str(tmp_path / "ck")
        self._run(d=d, generations=2, engine="device")
        with pytest.raises(ValueError, match="'device'"):
            self._run(d=d, resume=True)


_SHARDED_WL: dict = {}
