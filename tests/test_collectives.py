"""int8 error-feedback gradient compression: quantizer properties +
convergence equivalence on a real multi-device (subprocess) DP run."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.distributed import collectives as C


@given(st.integers(0, 2**32 - 1), st.floats(0.1, 100.0))
@settings(max_examples=25, deadline=None)
def test_quantize_bounded_error(seed, scale):
    x = (jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale)
    q, s = C.quantize_int8(x)
    err = np.abs(np.asarray(C.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates():
    """With error feedback, the running SUM of compressed estimates tracks
    the true sum (bounded error), even for tiny gradients that always
    quantize to zero individually."""
    x = jnp.full((16,), 1e-3)
    err = jnp.zeros((16,))
    tot = jnp.zeros((16,))
    mesh = jax.make_mesh((1,), ("data",), devices=jax.devices()[:1])
    step = jax.jit(jax.shard_map(
        lambda e: C.compressed_psum_mean(x, e, ("data",)),
        mesh=mesh, in_specs=(jax.sharding.PartitionSpec(),),
        out_specs=(jax.sharding.PartitionSpec(),) * 2, check_vma=False))
    for _ in range(50):
        g, err = step(err)
        tot = tot + g
    np.testing.assert_allclose(np.asarray(tot), 50e-3, rtol=0.15)


_DP_RUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "src")
from repro.configs import registry
from repro.launch.mesh import make_mesh
from repro.train import data as data_lib, optim, schedules
from repro.train.loop import Trainer, TrainerConfig

compress = sys.argv[1] == "1"
mesh = make_mesh((4, 1), ("data", "model"))
cfg = registry.get("granite-3-2b").smoke()
data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
    vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=5))
opt = optim.adamw(schedules.constant(2e-3))
tcfg = TrainerConfig(steps=15, log_every=15, compress_grads=compress)
t = Trainer(cfg, mesh, opt, data, tcfg)
hist = t.run()
print("LOSS", hist[-1]["loss"])
"""


@pytest.mark.slow
def test_compressed_dp_matches_exact():
    env = {**os.environ, "PYTHONPATH": "src"}
    losses = {}
    for flag in ("0", "1"):
        r = subprocess.run([sys.executable, "-c", _DP_RUN, flag],
                           capture_output=True, text=True, cwd="/root/repo",
                           env=env, timeout=900)
        assert r.returncode == 0, r.stderr[-2000:]
        losses[flag] = float(r.stdout.split("LOSS", 1)[1])
    # int8 + error feedback must track the exact DP run closely
    assert abs(losses["1"] - losses["0"]) < 0.15 * abs(losses["0"]) + 0.1, \
        losses
