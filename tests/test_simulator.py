"""Tests for the neuromorphic chip simulator: exact counters, paper trends
(Figs 2-8), platform semantics, and conservation properties."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.metrics import proxy_gap
from repro.neuromorphic import (SimLayer, SimNetwork, akd1000_like, fc_network,
                                loihi2_like, make_inputs, minimal_partition,
                                ordered_mapping, programmed_fc_network,
                                simulate, speck_like, strided_mapping)
from repro.neuromorphic.partition import Partition, validate_partition


def small_inputs(n=256, density=0.4, steps=3, seed=1):
    return make_inputs(n, density, steps, seed)


class TestCounters:
    def test_fc_counters_exact(self):
        """Counters must equal hand-computed values for a tiny known net."""
        w = np.array([[1.0, 0.0, 2.0],
                      [0.0, 0.0, 3.0]], np.float32)
        layer = SimLayer(name="l0", kind="fc", weights=w)
        x = np.array([5.0, 0.0], np.float32)           # one active input
        y, st_, cnt, _ = layer.step(x, layer.init_state(), None)
        assert cnt.msgs_in == 1
        np.testing.assert_allclose(cnt.macs, [1, 0, 1])       # row 0 nnz
        np.testing.assert_allclose(cnt.fetches_dense, [1, 1, 1])
        np.testing.assert_allclose(y, [5.0, 0.0, 10.0])
        np.testing.assert_allclose(cnt.msgs_out, [1, 0, 1])

    def test_conv_macs_match_dense_einsum(self):
        """Conv MAC counts == conv of masks (exactness oracle)."""
        rng = np.random.default_rng(0)
        w = rng.normal(size=(3, 3, 2, 4)).astype(np.float32)
        w[np.abs(w) < 0.5] = 0.0
        layer = SimLayer(name="c0", kind="conv", weights=w, in_hw=(8, 8))
        x = rng.normal(size=(8 * 8 * 2,)).astype(np.float32)
        x[np.abs(x) < 0.8] = 0.0
        _, _, cnt, _ = layer.step(x, layer.init_state(), None)
        # total nnz MACs = sum over output positions of active-input x nnz-w
        assert cnt.macs.sum() > 0
        assert cnt.macs.sum() <= cnt.fetches_dense.sum()
        assert cnt.macs.shape == (layer.n_neurons,)

    def test_total_synops_equals_sum_of_cores(self):
        """Conservation: per-core segment sums preserve totals (M0 math)."""
        net = fc_network([128, 96, 64], weight_density=0.5, seed=0)
        xs = small_inputs(128)
        prof = loihi2_like()
        r1 = simulate(net, xs, prof, Partition((1, 1)))
        r4 = simulate(net, xs, prof, Partition((4, 4)))
        assert r1.metrics.synops.total == pytest.approx(
            r4.metrics.synops.total, rel=1e-6)

    @given(st.integers(1, 8), st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_partition_preserves_totals_property(self, c1, c2):
        net = fc_network([64, 48, 32], weight_density=0.7, seed=3)
        xs = small_inputs(64, steps=2)
        prof = loihi2_like()
        ra = simulate(net, xs, prof, Partition((c1, c2)))
        rb = simulate(net, xs, prof, Partition((1, 1)))
        assert ra.metrics.synops.total == pytest.approx(
            rb.metrics.synops.total, rel=1e-6)
        assert ra.metrics.msgs_total == pytest.approx(
            rb.metrics.msgs_total, rel=1e-6)


class TestPaperTrends:
    def test_fig2_dense_format_weight_sparsity_no_runtime_gain(self):
        prof = loihi2_like()
        xs = small_inputs()
        times, energies = [], []
        for wd in (1.0, 0.5, 0.1):
            net = programmed_fc_network([256] * 4, weight_densities=[wd] * 3,
                                        act_densities=[0.5] * 3, seed=0,
                                        weight_format="dense")
            r = simulate(net, xs, prof)
            times.append(r.time_per_step)
            energies.append(r.energy_per_step)
        assert times[0] == pytest.approx(times[-1], rel=1e-6)   # no time gain
        assert energies[0] > energies[-1]                       # small energy gain

    def test_fig3_sparse_format_weight_sparsity_linear_gain(self):
        prof = loihi2_like()
        xs = small_inputs()
        times = []
        for wd in (1.0, 0.5, 0.25):
            net = programmed_fc_network([256] * 4, weight_densities=[wd] * 3,
                                        act_densities=[0.5] * 3, seed=0,
                                        weight_format="sparse")
            times.append(simulate(net, xs, prof).time_per_step)
        assert times[0] > times[1] > times[2]
        # roughly linear: halving density should cut the synop-dominated time
        assert times[1] / times[0] < 0.75

    def test_fig4_format_crossover(self):
        """Sparse format loses at high weight density, wins at low."""
        prof = loihi2_like()
        xs = small_inputs()

        def t(fmt, wd):
            net = programmed_fc_network([256] * 4, weight_densities=[wd] * 3,
                                        act_densities=[0.5] * 3, seed=0,
                                        weight_format=fmt)
            return simulate(net, xs, prof).time_per_step

        assert t("sparse", 1.0) > t("dense", 1.0)    # dense wins when dense
        assert t("sparse", 0.2) < t("dense", 0.2)    # sparse wins when sparse

    def test_fig5_m0_imbalance_breaks_total_sparsity_proxy(self):
        """Same total activation density, different schedules => different
        performance; the imbalanced one is slower."""
        prof = loihi2_like()
        xs = small_inputs()
        uni = programmed_fc_network([256] * 5, weight_densities=[1.0] * 4,
                                    act_densities=[0.5] * 4, seed=0)
        lohi = programmed_fc_network([256] * 5, weight_densities=[1.0] * 4,
                                     act_densities=[0.9, 0.1, 0.9, 0.1], seed=0)
        r_uni = simulate(uni, xs, prof)
        r_lohi = simulate(lohi, xs, prof)
        assert r_lohi.time_per_step > r_uni.time_per_step
        assert proxy_gap(r_lohi.metrics) > proxy_gap(r_uni.metrics)

    def test_fig6_time_linear_in_max_synops(self):
        """Across schedules, time correlates with max per-core synops."""
        prof = loihi2_like()
        xs = small_inputs()
        pts = []
        for ad in ([0.8] * 4, [0.5] * 4, [0.2] * 4, [0.9, 0.1, 0.9, 0.1],
                   [0.1, 0.9, 0.1, 0.9], [0.7, 0.5, 0.3, 0.1]):
            net = programmed_fc_network([256] * 5, weight_densities=[1.0] * 4,
                                        act_densities=list(ad), seed=0)
            r = simulate(net, xs, prof)
            pts.append((r.max_synops, r.time_per_step))
        pts.sort()
        xs_, ts = np.array(pts).T
        corr = np.corrcoef(xs_, ts)[0, 1]
        assert corr > 0.98

    def test_fig7_partitioning_lowers_compute_floor_raises_energy(self):
        prof = loihi2_like()
        net = programmed_fc_network([256] * 4, weight_densities=[0.05] * 3,
                                    act_densities=[0.05] * 3, seed=0,
                                    weight_format="sparse")
        xs = make_inputs(256, 0.05, 3, seed=1)
        r1 = simulate(net, xs, prof, Partition((1, 1, 1)))
        r4 = simulate(net, xs, prof, Partition((4, 4, 4)))
        assert r4.time_per_step < r1.time_per_step          # floor lowered
        assert r4.energy_per_step > r1.energy_per_step      # power rose

    def test_fig8_strided_beats_ordered_under_high_utilization(self):
        prof = loihi2_like()
        net = programmed_fc_network([512] * 5, weight_densities=[0.4] * 4,
                                    act_densities=[0.9, 0.1, 0.9, 0.1], seed=0,
                                    weight_format="sparse")
        xs = make_inputs(512, 0.5, 3, seed=1)
        part = Partition((24, 24, 24, 24))
        r_ord = simulate(net, xs, prof, part, ordered_mapping(part, prof))
        r_str = simulate(net, xs, prof, part, strided_mapping(part, prof))
        assert r_str.max_link_load < r_ord.max_link_load    # less congestion


class TestPlatforms:
    def test_speck_rejects_partitioning(self):
        prof = speck_like()
        net = fc_network([64, 64], seed=0)
        assert not validate_partition(net, Partition((2,)), prof)

    def test_speck_async_energy_tracks_activity(self):
        prof = speck_like()
        net = fc_network([128, 128, 10], neuron_model="if", seed=0)
        for l in net.layers:
            l.threshold = 0.5
        lo = simulate(net, make_inputs(128, 0.05, 3, seed=2), prof)
        hi = simulate(net, make_inputs(128, 0.6, 3, seed=2), prof)
        assert lo.energy_per_step < hi.energy_per_step
        assert lo.time_per_step < hi.time_per_step

    def test_akd1000_dense_default(self):
        prof = akd1000_like()
        assert prof.default_format_fc == "dense"

    def test_minimal_partition_respects_capacity(self):
        prof = loihi2_like()
        net = fc_network([2048, 2048], seed=0)
        part = minimal_partition(net, prof)
        assert validate_partition(net, part, prof)
        # 2048*2048 weights / 64K per core => >= 64 cores
        assert part.cores[0] >= 64


class TestNeuronModels:
    def test_if_neuron_spikes_and_resets(self):
        w = np.eye(4, dtype=np.float32)
        layer = SimLayer(name="if0", kind="fc", weights=w, neuron_model="if",
                         threshold=1.0)
        st_ = layer.init_state()
        y1, st_, _, _ = layer.step(np.full(4, 0.6, np.float32), st_, None)
        assert y1.sum() == 0                     # below threshold
        y2, st_, _, _ = layer.step(np.full(4, 0.6, np.float32), st_, None)
        assert y2.sum() == 4                     # crossed threshold
        assert np.all(st_["v"] < 1.0)            # reset happened

    def test_sigma_delta_sends_only_changes(self):
        w = np.eye(3, dtype=np.float32)
        layer = SimLayer(name="sd0", kind="fc", weights=w,
                         neuron_model="sd_relu", threshold=0.01,
                         sends_deltas=True)
        st_ = layer.init_state()
        x = np.array([1.0, 2.0, 3.0], np.float32)
        y1, st_, c1, _ = layer.step(x, st_, None)
        assert c1.msgs_out.sum() == 3            # first frame: all change
        # identical input again, but as a *delta* stream the input is 0
        y2, st_, c2, _ = layer.step(np.zeros(3, np.float32), st_,
                                    np.asarray(x))
        assert c2.msgs_out.sum() == 0            # nothing changed

    def test_sigma_delta_reconstruction(self):
        """Accumulated sigma-delta messages reconstruct ReLU output within
        threshold quantization error."""
        rng = np.random.default_rng(0)
        w = rng.normal(size=(8, 8)).astype(np.float32)
        layer = SimLayer(name="sd", kind="fc", weights=w,
                         neuron_model="sd_relu", threshold=0.05,
                         sends_deltas=True)
        st_ = layer.init_state()
        acc = np.zeros(8, np.float32)
        x = rng.normal(size=(8,)).astype(np.float32)
        msgs = []
        for t in range(5):   # constant input: only the first step messages
            y, st_, c, _ = layer.step(x, st_, None)
            acc += y
            msgs.append(c.msgs_out.sum())
        target = np.maximum(x @ w, 0.0)
        np.testing.assert_allclose(acc, target, atol=0.06)
        assert sum(msgs[1:]) == 0    # steady input -> no further deltas


def test_report_fields_finite():
    prof = loihi2_like()
    net = fc_network([64, 32], seed=0)
    r = simulate(net, small_inputs(64, steps=2), prof)
    assert np.isfinite(r.time_per_step) and r.time_per_step > 0
    assert np.isfinite(r.energy_per_step) and r.energy_per_step > 0
    assert r.outputs.shape == (2, 32)
    assert r.bottleneck_stage in ("memory", "compute", "traffic", "barrier")
