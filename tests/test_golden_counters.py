"""Golden-counter regression fixtures.

Every pricing product is a function of the exact per-layer event counters,
so silent counter drift anywhere in ``compute.py`` / ``timestep.py`` / the
model-zoo frontend corrupts every downstream number while all parity suites
(which compare backends against *each other*) still pass.  These fixtures
freeze per-layer integer totals — MACs, weight fetches, input/output
messages (NoC traffic), evented activations — for the characterization
workloads and one compiled model smoke per family, and compare exactly.

Regenerate (after an *intentional* counter-semantics change) with::

    PYTHONPATH=src python tests/test_golden_counters.py --regen

and justify the diff in the commit.
"""

from __future__ import annotations

import json
import pathlib

import numpy as np
import pytest

from repro.neuromorphic import (EventCompute, SimLayer, SimNetwork,
                                compile_network, fc_network, make_inputs,
                                programmed_fc_network)
from repro.neuromorphic.network import _exact_density_mask
from repro.sparsity import SparsityProfile

quick = pytest.mark.quick

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
FIELDS = ("msgs_in", "macs", "fetches_dense", "msgs_out", "acts_evented")
PROFILE_PATH = GOLDEN_DIR / "trained_profile.npz"


# ------------------------------------------------------- workload builders
# Deterministic by construction: fixed seeds, exact density masks.

def _fc_characterization():
    net = programmed_fc_network(
        [32, 48, 48, 24], weight_densities=[0.8, 0.6, 0.9],
        act_densities=[0.25, 0.5, 0.1], seed=11)
    xs = make_inputs(32, 0.3, 8, seed=12)
    return net, xs


def _conv_characterization():
    rng = np.random.default_rng(13)
    layers, h, w, c_prev = [], 8, 8, 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, 0.6, rng)
        layers.append(SimLayer(name=f"conv{i}", kind="conv", weights=wgt,
                               stride=2, in_hw=(h, w)))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc))
    net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
    xs = make_inputs(net.in_size, 0.3, 6, seed=14)
    return net, xs


def _compiled(arch_id):
    def build():
        compiled = compile_network(arch_id, seed=0)
        return compiled.net, compiled.inputs(4, seed=5)
    return build


def _make_profile() -> SparsityProfile:
    """A stand-in trained profile, deterministic by construction (the
    densities/masks a SparseTrainer run would have measured)."""
    rng = np.random.default_rng(21)
    shapes = [(32, 48), (48, 48), (48, 24)]
    dens = (0.6, 0.8, 0.7)
    masks = tuple(_exact_density_mask(s, d, rng).astype(np.float32)
                  for s, d in zip(shapes, dens))
    return SparsityProfile(layer_names=("fc0", "fc1", "fc2"),
                           act_density=np.array([0.3, 0.45, 0.2]),
                           weight_density=np.array(dens, np.float64),
                           weight_masks=masks, input_density=0.3,
                           meta={"fixture": "golden"})


def _saved_profile() -> SparsityProfile:
    """Round-trip through the on-disk artifact: the fixture workloads are
    priced under the LOADED profile, so the save/load path is part of the
    frozen contract."""
    if not PROFILE_PATH.exists():
        _make_profile().save(PROFILE_PATH)
    return SparsityProfile.load(PROFILE_PATH)


def _fc_profile_sparse():
    """Dense fc stack under the saved trained profile: exact weight masks
    + exact-count message gates, counters frozen."""
    net = fc_network([32, 48, 48, 24], weight_density=1.0, seed=11)
    net = _saved_profile().apply(net, seed=17)
    xs = make_inputs(32, 0.3, 8, seed=12)
    return net, xs


def _compiled_profile(arch_id):
    """Compiled arch with the saved profile's densities resampled across
    its depth (the act_schedules-replacement injection path)."""
    def build():
        compiled = compile_network(arch_id, act_density=_saved_profile(),
                                   seed=0)
        return compiled.net, compiled.inputs(4, seed=5)
    return build


def _conv_fc_profile_event():
    """Weight-masked conv+fc stack under the saved trained profile, priced
    through the EVENT backend (gather mode — the deterministic CI path,
    with block-CSR weight skipping engaged): the weight-sparse tile/row
    skips must leave every counter exactly where the dense reference puts
    it, so this fixture freezes the same integers a dense run produces."""
    rng = np.random.default_rng(23)
    layers, h, w, c_prev = [], 8, 8, 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        layers.append(SimLayer(name=f"conv{i}", kind="conv", weights=wgt,
                               stride=2, in_hw=(h, w)))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 12)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc))
    net = _saved_profile().apply(SimNetwork(layers=layers, in_size=8 * 8 * 2),
                                 seed=19)
    xs = make_inputs(net.in_size, 0.3, 6, seed=24)
    return net, xs, EventCompute(mode="gather")


#: fixture name -> builder; one compiled smoke per family (lm/ssm/moe/encdec)
WORKLOADS = {
    "fc_characterization": _fc_characterization,
    "conv_characterization": _conv_characterization,
    "fc_profile_sparse": _fc_profile_sparse,
    "conv_fc_profile_event": _conv_fc_profile_event,
    "model_lm_gemma2": _compiled("gemma2-2b"),
    "model_lm_gemma2_profile": _compiled_profile("gemma2-2b"),
    "model_ssm_mamba2": _compiled("mamba2-1.3b"),
    "model_moe_olmoe": _compiled("olmoe-1b-7b"),
    "model_encdec_whisper": _compiled("whisper-base"),
}


def snapshot(name: str) -> dict:
    """Per-layer integer counter totals (exact: counters are integer-valued
    and well below 2**53, so float sums are lossless)."""
    built = WORKLOADS[name]()
    net, xs = built[0], built[1]
    compute = built[2] if len(built) > 2 else None
    _, counters = net.run_batch(xs, compute=compute)
    layers = []
    for lay, c in zip(net.layers, counters):
        row = {"name": lay.name}
        for f in FIELDS:
            row[f] = int(np.asarray(getattr(c, f), np.float64).sum())
        layers.append(row)
    totals = {f: sum(r[f] for r in layers) for f in FIELDS}
    return {"workload": name, "steps": int(xs.shape[0]),
            "layers": layers, "totals": totals}


def diff_snapshots(golden: dict, actual: dict) -> list[str]:
    """Human-readable field-level mismatches (empty == identical)."""
    out = []
    if golden["steps"] != actual["steps"]:
        out.append(f"steps: golden {golden['steps']} != {actual['steps']}")
    gl, al = golden["layers"], actual["layers"]
    if [r["name"] for r in gl] != [r["name"] for r in al]:
        out.append(f"layer names: golden {[r['name'] for r in gl]} != "
                   f"{[r['name'] for r in al]}")
        return out
    for g, a in zip(gl, al):
        for f in FIELDS:
            if g[f] != a[f]:
                out.append(f"layer {g['name']!r} {f}: golden {g[f]} != "
                           f"actual {a[f]} (drift {a[f] - g[f]:+d})")
    for f in FIELDS:
        if golden["totals"][f] != actual["totals"][f]:
            out.append(f"TOTAL {f}: golden {golden['totals'][f]} != "
                       f"actual {actual['totals'][f]}")
    return out


# ------------------------------------------------------------------- tests

@quick
@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_counters_match_golden(name):
    path = GOLDEN_DIR / f"{name}.json"
    assert path.exists(), \
        f"missing golden fixture {path}; regenerate with --regen"
    golden = json.loads(path.read_text())
    mismatches = diff_snapshots(golden, snapshot(name))
    assert not mismatches, (
        f"counter drift vs {path.name} — if intentional, regenerate the "
        "fixture and justify the diff:\n  " + "\n  ".join(mismatches))


@quick
def test_diff_detects_perturbation():
    """The harness itself must flag a single off-by-one counter."""
    golden = json.loads((GOLDEN_DIR / "fc_characterization.json").read_text())
    bad = json.loads(json.dumps(golden))          # deep copy
    bad["layers"][1]["macs"] += 1
    bad["totals"]["macs"] += 1
    out = diff_snapshots(golden, bad)
    assert any("macs" in line and "+1" in line for line in out), out


# ------------------------------------------------------------------- regen

def regen() -> None:
    GOLDEN_DIR.mkdir(exist_ok=True)
    for name in sorted(WORKLOADS):
        snap = snapshot(name)
        path = GOLDEN_DIR / f"{name}.json"
        path.write_text(json.dumps(snap, indent=1) + "\n")
        print(f"wrote {path} ({len(snap['layers'])} layers, "
              f"{snap['totals']['macs']} total MACs)")


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        regen()
    else:
        print(__doc__)
