"""Dense vs event LayerCompute backend parity.

The contract (``repro.neuromorphic.compute``): every backend produces the
SAME exact integer event counters — so every pricing product (SimReports,
caches, populations) is bit-identical across backends — while float
outputs may differ by contraction reassociation only (rtol <= 1e-6 with a
small atol floor for near-zero entries).  The event backend is exercised
in both kernel modes: ``gather`` (the CPU fast path) and ``pallas`` (the
real kernel body, interpret-auto-selected on CPU so CI executes it).
"""

import numpy as np
import pytest

from repro.neuromorphic import (EventCompute, SimLayer, SimNetwork,
                                fc_network, get_compute, loihi2_like,
                                make_inputs, programmed_fc_network,
                                register_compute, simulate,
                                simulate_population)
from repro.neuromorphic.compute import DenseCompute, LayerCompute, _im2col
from repro.neuromorphic.network import _exact_density_mask

quick = pytest.mark.quick

FLOAT_TOL = dict(rtol=1e-6, atol=1e-6)


def conv_stack(*, neuron_model="relu", sends_deltas=False, threshold=0.0,
               weight_density=0.6, seed=0):
    """conv -> conv -> fc stack (channel-major flat boundaries)."""
    rng = np.random.default_rng(seed)
    layers = []
    h = w = 8
    c_prev = 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, weight_density, rng)
        layers.append(SimLayer(
            name=f"conv{i}", kind="conv", weights=wgt, stride=2,
            in_hw=(h, w), neuron_model=neuron_model, threshold=threshold,
            sends_deltas=sends_deltas))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc,
                           neuron_model="relu"))
    return SimNetwork(layers=layers, in_size=8 * 8 * 2)


def assert_backends_match(net, xs, event="event"):
    """run_batch parity: exact counters, roundoff-equal outputs."""
    out_d, cnt_d = net.run_batch(xs, compute="dense")
    out_e, cnt_e = net.run_batch(xs, compute=event)
    np.testing.assert_allclose(out_e, out_d, **FLOAT_TOL)
    for l, (a, b) in enumerate(zip(cnt_d, cnt_e)):
        for field in ("msgs_in", "macs", "fetches_dense", "msgs_out",
                      "acts_evented"):
            assert np.array_equal(getattr(a, field), getattr(b, field)), \
                f"layer {l}: {field} diverged"
    return out_d, out_e


class TestFunctionalParity:
    @quick
    @pytest.mark.parametrize("density", [0.05, 0.3, 0.8])
    def test_fc_relu(self, density):
        net = fc_network([48, 64, 32], weight_density=0.6, seed=0)
        xs = make_inputs(48, density, 12, seed=1)
        assert_backends_match(net, xs)

    @quick
    def test_fc_programmed_gates(self):
        net = programmed_fc_network([40, 64, 48],
                                    weight_densities=[0.7, 0.7],
                                    act_densities=[0.1, 0.2], seed=2)
        xs = make_inputs(40, 0.2, 10, seed=3)
        assert_backends_match(net, xs)

    @quick
    def test_conv_stack(self):
        net = conv_stack(seed=0)
        xs = make_inputs(net.in_size, 0.3, 8, seed=4)
        assert_backends_match(net, xs)

    def test_sigma_delta_chain(self):
        """Delta reconstruction makes x_eff dense while the wire mask stays
        sparse — the two event compactions must diverge correctly."""
        net = fc_network([32, 48, 24], weight_density=0.8,
                         neuron_model="sd_relu", seed=5)
        for l in net.layers:
            l.threshold = 0.05
            l.sends_deltas = True
        xs = make_inputs(32, 0.4, 10, seed=6)
        assert_backends_match(net, xs)

    def test_if_spiking(self):
        net = fc_network([32, 40, 16], weight_density=0.7,
                         neuron_model="if", seed=7)
        for l in net.layers:
            l.threshold = 0.5
        xs = make_inputs(32, 0.5, 10, seed=8)
        assert_backends_match(net, xs)

    @quick
    def test_all_zero_inputs(self):
        """Event-free input: the event path must not fetch, and both
        backends must count zero everywhere."""
        net = fc_network([16, 24, 8], seed=0)
        xs = np.zeros((4, 16), np.float32)
        out_d, out_e = assert_backends_match(net, xs)
        assert np.array_equal(out_d, out_e)   # relu(0) exactly everywhere


class TestSimReportParity:
    @quick
    @pytest.mark.parametrize("workload", ["fc", "conv"])
    def test_counter_derived_reports_identical(self, workload):
        """``simulate(compute="event")`` prices from identical counters, so
        times/energies/per-core aggregates are bit-identical to dense."""
        if workload == "fc":
            net = fc_network([48, 96, 64, 32], weight_density=0.5, seed=1)
            xs = make_inputs(48, 0.25, 12, seed=2)
        else:
            net = conv_stack(seed=1)
            xs = make_inputs(net.in_size, 0.3, 6, seed=3)
        prof = loihi2_like()
        r_d = simulate(net, xs, prof, compute="dense")
        r_e = simulate(net, xs, prof, compute="event")
        np.testing.assert_allclose(r_e.outputs, r_d.outputs, **FLOAT_TOL)
        for field in ("times", "energies", "per_core_synops",
                      "per_core_acts", "per_core_msgs_out"):
            assert np.array_equal(getattr(r_e, field), getattr(r_d, field)), \
                f"{field} diverged"
        assert r_e.max_synops == r_d.max_synops
        assert r_e.max_acts == r_d.max_acts
        assert r_e.max_link_load == r_d.max_link_load
        assert r_e.bottleneck_stage == r_d.bottleneck_stage
        assert r_e.metrics == r_d.metrics

    @quick
    def test_reference_engine_honors_compute(self):
        net = fc_network([32, 48, 24], weight_density=0.6, seed=3)
        xs = make_inputs(32, 0.3, 6, seed=4)
        prof = loihi2_like()
        r_d = simulate(net, xs, prof, engine="reference", compute="dense")
        r_e = simulate(net, xs, prof, engine="reference", compute="event")
        np.testing.assert_allclose(r_e.outputs, r_d.outputs, **FLOAT_TOL)
        assert np.array_equal(r_e.times, r_d.times)
        assert np.array_equal(r_e.energies, r_d.energies)

    def test_population_pricing_identical(self):
        """A population priced from an event-compute cache matches the
        dense cache bit for bit (counters are the only cache contents)."""
        from repro.neuromorphic import minimal_partition, strided_mapping
        from repro.neuromorphic.noc import ordered_mapping
        net = fc_network([32, 64, 48], weight_density=0.6, seed=4)
        xs = make_inputs(32, 0.3, 6, seed=5)
        prof = loihi2_like()
        p0 = minimal_partition(net, prof)
        cands = [(p0, ordered_mapping(p0, prof)),
                 (p0, strided_mapping(p0, prof))]
        r_d = simulate_population(net, xs, prof, cands, compute="dense")
        r_e = simulate_population(net, xs, prof, cands, compute="event")
        for a, b in zip(r_d, r_e):
            assert np.array_equal(a.times, b.times)
            assert np.array_equal(a.energies, b.energies)


class TestPallasMode:
    """The real kernel body (interpret mode on CPU) behind the same seam."""

    @quick
    def test_fc_pallas(self):
        net = fc_network([48, 64, 32], weight_density=0.6, seed=0)
        xs = make_inputs(48, 0.3, 12, seed=1)
        assert_backends_match(net, xs, event=EventCompute(mode="pallas"))

    def test_conv_pallas(self):
        net = conv_stack(seed=2)
        xs = make_inputs(net.in_size, 0.3, 4, seed=2)
        assert_backends_match(net, xs, event=EventCompute(mode="pallas"))

    def test_pallas_gather_agree(self):
        """The two kernel modes express one semantic contract."""
        net = fc_network([32, 48, 24], weight_density=0.7, seed=6)
        xs = make_inputs(32, 0.2, 8, seed=7)
        out_g, cnt_g = net.run_batch(xs, compute=EventCompute(mode="gather"))
        out_p, cnt_p = net.run_batch(xs, compute=EventCompute(mode="pallas"))
        np.testing.assert_allclose(out_p, out_g, **FLOAT_TOL)
        for a, b in zip(cnt_g, cnt_p):
            assert np.array_equal(a.macs, b.macs)


class TestSeamPlumbing:
    @quick
    def test_registry_round_trip(self):
        assert isinstance(get_compute("dense"), DenseCompute)
        assert isinstance(get_compute("event"), EventCompute)
        assert get_compute("dense") is get_compute("dense")  # shared instance
        ev = EventCompute(mode="gather")
        assert get_compute(ev) is ev
        with pytest.raises(ValueError):
            get_compute("nope")
        with pytest.raises(ValueError):
            EventCompute(mode="bogus")

    @quick
    def test_register_custom_backend(self):
        class Tagged(DenseCompute):
            name = "tagged"
        register_compute("tagged", Tagged)
        try:
            assert isinstance(get_compute("tagged"), Tagged)
        finally:
            from repro.neuromorphic import compute as C
            C._REGISTRY.pop("tagged", None)
            C._INSTANCES.pop("tagged", None)

    @quick
    def test_default_compute_flip(self):
        """The process-wide default (benchmarks/run.py --compute) reroutes
        calls that omit compute=."""
        from repro.neuromorphic import compute as C
        net = fc_network([24, 32, 16], weight_density=0.6, seed=8)
        xs = make_inputs(24, 0.3, 5, seed=9)
        out_d, _ = net.run_batch(xs)
        old = C.DEFAULT_COMPUTE
        C.DEFAULT_COMPUTE = "event"
        try:
            out_e, _ = net.run_batch(xs)
        finally:
            C.DEFAULT_COMPUTE = old
        np.testing.assert_allclose(out_e, out_d, **FLOAT_TOL)

    @quick
    def test_evaluator_threads_compute(self):
        from repro.core.partitioner import SimEvaluator
        net = fc_network([24, 32, 16], weight_density=0.6, seed=8)
        xs = make_inputs(24, 0.3, 5, seed=9)
        prof = loihi2_like()
        ev_d = SimEvaluator(net, xs, prof)
        ev_e = SimEvaluator(net, xs, prof, compute="event")
        from repro.neuromorphic import minimal_partition
        from repro.neuromorphic.noc import ordered_mapping
        p0 = minimal_partition(net, prof)
        m0 = ordered_mapping(p0, prof)
        assert np.array_equal(ev_d(p0, m0).times, ev_e(p0, m0).times)


class TestIm2col:
    @quick
    @pytest.mark.parametrize("h,w,stride", [(8, 8, 2), (9, 7, 1), (6, 10, 2)])
    def test_matches_dense_conv_counters(self, h, w, stride):
        """The im2col receptive fields must be exactly the dense conv's —
        integer mask counts are the bit-level witness."""
        rng = np.random.default_rng(h * 10 + w + stride)
        cin, cout = 3, 5
        wgt = rng.normal(0, 0.3, (3, 3, cin, cout)).astype(np.float32)
        lay = SimLayer(name="c", kind="conv", weights=wgt, stride=stride,
                       in_hw=(h, w))
        net = SimNetwork(layers=[lay], in_size=h * w * cin)
        xs = make_inputs(net.in_size, 0.4, 3, seed=0)
        assert_backends_match(net, xs)

    @quick
    def test_patch_order_is_cin_kh_kw(self):
        """_im2col feature order must match _patch_weights' flattening."""
        x = np.arange(2 * 4 * 4, dtype=np.float32).reshape(1, 2, 4, 4)
        pat = _im2col(x, 3, 3, 1, 4, 4)
        # center tap of window (1,1): features [c*9 + 4] must be x[:, c, 1, 1]
        row = pat[1 * 4 + 1]
        assert row[0 * 9 + 4] == x[0, 0, 1, 1]
        assert row[1 * 9 + 4] == x[0, 1, 1, 1]
