"""Tests for the fault-tolerant search runtime
(:mod:`repro.core.resilience` wired through ``core/search.py``,
``core/device_search.py`` and the pricing stack).

Covers the PR-6 acceptance criteria:

* resume determinism — kill a checkpointed run after generation ``g``,
  resume, and the fitness trajectory, eps-Pareto front and knee match the
  uninterrupted run exactly, on both engines and both workload kinds;
* graceful degradation — injected backend failures demote down the
  ``device -> vmap -> numpy`` chain (logged), and the completed run matches
  a numpy-only run at rtol=1e-9;
* non-finite quarantine — an injected NaN pricing row never reaches the
  survivors, the eps-archive or ``SearchResult.front``, and the ordering of
  the finite rows is unperturbed.
"""

import numpy as np
import pytest

from repro.core.partitioner import SimEvaluator
from repro.core.resilience import (ALWAYS, FallbackChain, FaultPlan,
                                   InjectedFault, RetryPolicy,
                                   SimulatedCrash, finite_mean,
                                   quarantine_rows)
from repro.core.search import (Candidate, evolutionary_search, pareto_ranks,
                               seeded_population)
from repro.neuromorphic import (SimLayer, SimNetwork, loihi2_like,
                                make_inputs, programmed_fc_network,
                                simulate_population)
from repro.neuromorphic.network import _exact_density_mask

quick = pytest.mark.quick
pytestmark = pytest.mark.timeout(300)


def fc_workload(sizes=(48, 64, 32), wd=0.6, ad=0.3, steps=2):
    net = programmed_fc_network(
        list(sizes), weight_densities=[wd] * (len(sizes) - 1),
        act_densities=[ad] * (len(sizes) - 1), seed=0,
        weight_format="sparse")
    xs = make_inputs(sizes[0], ad, steps, seed=1)
    return net, xs


def conv_workload(steps=2):
    rng = np.random.default_rng(2)
    layers = []
    h = w = 8
    c_prev = 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, 0.6, rng)
        layers.append(SimLayer(name=f"conv{i}", kind="conv", weights=wgt,
                               stride=2, in_hw=(h, w)))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc))
    net = SimNetwork(layers=layers, in_size=8 * 8 * 2)
    return net, make_inputs(net.in_size, 0.4, steps, seed=3)


_WORKLOADS: dict = {}


def get_workload(kind: str):
    """(net, xs, prof, shared evaluator) per workload kind, module-cached so
    every test prices from one warm flow/jit cache."""
    if kind not in _WORKLOADS:
        net, xs = fc_workload() if kind == "fc" else conv_workload()
        prof = loihi2_like()
        _WORKLOADS[kind] = (net, xs, prof, SimEvaluator(net, xs, prof))
    return _WORKLOADS[kind]


def _traj(res):
    return [(g.generation, g.best_time, g.best_energy, g.mean_time,
             g.n_evals, g.front_size, g.n_quarantined) for g in res.history]


# ------------------------------------------------------- resume determinism

class TestResumeDeterminism:
    @pytest.mark.parametrize("engine", ["numpy", "device"])
    @pytest.mark.parametrize("kind", ["fc", "conv"])
    def test_kill_and_resume_matches_uninterrupted(self, tmp_path, engine,
                                                   kind):
        """Kill after generation 2 of 4 (checkpoint already on disk), resume
        from the directory: fitness trajectory, front and knee are identical
        to the run that never crashed."""
        net, xs, prof, ev = get_workload(kind)
        kw = dict(population_size=6, generations=4, seed=3, engine=engine)
        full = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache), **kw)
        d = str(tmp_path / "ck")
        with pytest.raises(SimulatedCrash):
            evolutionary_search(
                net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                checkpoint_dir=d, fault_plan=FaultPlan(kill_after_gen=2),
                **kw)
        res = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            checkpoint_dir=d, resume=True, **kw)
        assert _traj(res) == _traj(full)
        assert res.front == full.front
        assert [r.time_per_step for r in res.front_reports] == \
            [r.time_per_step for r in full.front_reports]
        assert res.knee()[0] == full.knee()[0]
        assert res.candidate == full.candidate

    @quick
    def test_resume_without_checkpoint_starts_fresh(self, tmp_path):
        """``resume=True`` on an empty directory is a cold start, not an
        error — the idiom is 'always pass --resume' in restart loops."""
        net, xs, prof, ev = get_workload("fc")
        res = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            population_size=4, generations=2, seed=0,
            checkpoint_dir=str(tmp_path / "empty"), resume=True)
        assert res.history[-1].generation == 2

    @quick
    def test_resume_rejects_engine_mismatch(self, tmp_path):
        """A numpy-engine snapshot must not silently seed a device-engine
        run (different RNG contracts): loud error instead."""
        net, xs, prof, ev = get_workload("fc")
        d = str(tmp_path / "ck")
        evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            population_size=4, generations=2, seed=0, checkpoint_dir=d)
        with pytest.raises(ValueError, match="engine"):
            evolutionary_search(
                net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                population_size=4, generations=3, seed=0,
                checkpoint_dir=d, resume=True, engine="device")

    @quick
    def test_checkpoint_every_still_resumes(self, tmp_path):
        """Sparse cadence (every=2) + kill at an unsnapshotted generation:
        resume replays from the newest snapshot and still converges to the
        uninterrupted trajectory (same per-generation RNG contract)."""
        net, xs, prof, ev = get_workload("fc")
        kw = dict(population_size=5, generations=4, seed=9)
        full = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache), **kw)
        d = str(tmp_path / "ck")
        with pytest.raises(SimulatedCrash):
            evolutionary_search(
                net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                checkpoint_dir=d, checkpoint_every=2,
                fault_plan=FaultPlan(kill_after_gen=3), **kw)
        res = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            checkpoint_dir=d, checkpoint_every=2, resume=True, **kw)
        assert _traj(res)[-1] == _traj(full)[-1]
        assert res.front == full.front


# ---------------------------------------------------- graceful degradation

class TestDegradation:
    def test_chain_demotes_to_numpy_and_matches(self):
        """Permanent device+vmap outage: the run completes on the numpy
        backend with two logged demotions, and the trajectory/front match a
        numpy-only run at rtol=1e-9 (criterion; the final link is the
        bit-exact reference backend, so equality is in fact exact)."""
        net, xs, prof, ev = get_workload("fc")
        kw = dict(population_size=6, generations=3, seed=3)
        faulty = SimEvaluator(
            net, xs, prof, cache=ev.cache, population_backend="device",
            fault_plan=FaultPlan(fail={"device": ALWAYS, "vmap": ALWAYS}),
            retry=RetryPolicy(max_retries=1))
        deg = evolutionary_search(net, prof, faulty, **kw)
        ref = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache), **kw)
        assert [(x.frm, x.to) for x in deg.demotions] == \
            [("device", "vmap"), ("vmap", "numpy")]
        assert faulty.active_backend == "numpy"
        np.testing.assert_allclose(
            [g.best_time for g in deg.history],
            [g.best_time for g in ref.history], rtol=1e-9)
        np.testing.assert_allclose(
            [g.best_energy for g in deg.history],
            [g.best_energy for g in ref.history], rtol=1e-9)
        assert deg.front == ref.front

    @quick
    def test_retry_absorbs_transient_fault(self):
        """One transient vmap fault, default one-retry policy: no demotion,
        result identical to the fault-free run on the same backend."""
        net, xs, prof, ev = get_workload("fc")
        kw = dict(population_size=5, generations=2, seed=1)
        faulty = SimEvaluator(net, xs, prof, cache=ev.cache,
                              population_backend="vmap",
                              fault_plan=FaultPlan(fail={"vmap": 1}))
        res = evolutionary_search(net, prof, faulty, **kw)
        clean = evolutionary_search(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache,
                                    population_backend="vmap"), **kw)
        assert res.demotions == []
        assert faulty.active_backend == "vmap"
        assert _traj(res) == _traj(clean)

    def test_device_engine_demotes_to_mirror(self):
        """Device-engine outage at init: the run completes on the host
        numpy mirror under the same per-generation PRNG contract — exactly
        equal to the ``reference=True`` mirror run, and within 1e-9 of the
        fault-free device run."""
        from repro.core.device_search import evolutionary_search_device
        net, xs, prof, ev = get_workload("fc")
        kw = dict(population_size=6, generations=3, seed=3)
        full = evolutionary_search_device(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache), **kw)
        mir = evolutionary_search_device(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            reference=True, **kw)
        deg = evolutionary_search_device(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            fault_plan=FaultPlan(fail={"device": ALWAYS}),
            retry=RetryPolicy(max_retries=0), **kw)
        assert [(x.frm, x.to) for x in deg.demotions] == \
            [("device", "numpy-mirror")]
        assert _traj(deg) == _traj(mir)
        np.testing.assert_allclose(
            [g.best_time for g in deg.history],
            [g.best_time for g in full.history], rtol=1e-9)

    @quick
    def test_exhausted_chain_raises_last_error(self):
        """The numpy reference backend is the last link: its failure
        propagates instead of looping."""
        chain = FallbackChain("numpy", retry=RetryPolicy(max_retries=0))

        def attempt(backend):
            raise InjectedFault(f"down: {backend}")
        with pytest.raises(InjectedFault, match="down: numpy"):
            chain.run(attempt)
        assert chain.demotions == []

    @quick
    def test_chain_never_absorbs_simulated_crash(self):
        """:class:`SimulatedCrash` models ``kill -9``: no retry or fallback
        handler may catch it."""
        chain = FallbackChain("device")
        with pytest.raises(SimulatedCrash):
            chain.run(lambda backend: (_ for _ in ()).throw(
                SimulatedCrash("kill")))
        assert chain.backend == "device" and chain.demotions == []


# ------------------------------------------------------ NaN/inf quarantine

class TestQuarantine:
    def test_nan_row_never_reaches_front_or_archive(self):
        """End-to-end: two scripted NaN pricing rows in generation 1.
        Every survivor statistic, archive point and front report stays
        finite, and the quarantine counter records exactly the injected
        rows."""
        net, xs, prof, ev = get_workload("fc")
        res = evolutionary_search(
            net, prof,
            SimEvaluator(net, xs, prof, cache=ev.cache,
                         fault_plan=FaultPlan(nan_rows={1: (0, 2)})),
            population_size=6, generations=3, seed=3)
        assert all(np.isfinite(g.best_time) for g in res.history)
        assert all(np.isfinite(g.best_energy) for g in res.history)
        assert all(np.isfinite(g.mean_time) for g in res.history)
        assert sum(g.n_quarantined for g in res.history) == 2
        # the eps-archive's items ARE the returned front: all finite
        assert len(res.front_reports) == res.history[-1].front_size
        for r in res.front_reports:
            assert np.isfinite(r.time_per_step)
            assert np.isfinite(r.energy_per_step)

    @quick
    def test_finite_ordering_unperturbed(self):
        """The survival sort of the finite rows is exactly the sort of the
        finite subset alone — quarantined rows behave as if never priced
        (they sort last, after every finite row)."""
        rng = np.random.default_rng(5)
        t = rng.uniform(10, 100, size=12)
        e = rng.uniform(10, 100, size=12)
        corrupt = np.array([1, 4, 7])
        tc, ec = t.copy(), e.copy()
        tc[corrupt] = np.nan
        ec[corrupt[0]] = np.inf          # mixed NaN/inf corruption
        qt, qe, bad = quarantine_rows(np, tc, ec)
        assert set(np.flatnonzero(bad)) == set(corrupt)
        order = np.lexsort((qe, qt, pareto_ranks(qt, qe)))
        # quarantined rows occupy exactly the tail
        assert set(order[-len(corrupt):]) == set(corrupt)
        finite = np.setdiff1d(np.arange(12), corrupt)
        ref = np.lexsort((e[finite], t[finite],
                          pareto_ranks(t[finite], e[finite])))
        np.testing.assert_array_equal(order[:-len(corrupt)], finite[ref])
        # finite rows pass through bit-unchanged
        np.testing.assert_array_equal(qt[finite], t[finite])
        np.testing.assert_array_equal(qe[finite], e[finite])

    @quick
    def test_unscreened_nan_would_rank_zero(self):
        """The failure mode quarantine exists for: NaN comparisons are all
        False, so an unscreened NaN row is never dominated and ranks 0."""
        t = np.array([1.0, np.nan, 3.0])
        e = np.array([3.0, np.nan, 1.0])
        assert pareto_ranks(t, e)[1] == 0          # poisoned
        qt, qe, _ = quarantine_rows(np, t, e)
        ranks = pareto_ranks(qt, qe)
        assert ranks[1] > max(ranks[0], ranks[2])  # quarantined: sorts last

    @quick
    def test_sorted_state_quarantines_under_jit(self):
        """The shared ``_sorted_state`` skeleton quarantines on the jnp
        path too (it is traced into the jitted init/step programs)."""
        import jax
        import jax.numpy as jnp
        from repro.core.device_search import (_sorted_state, enable_x64,
                                              pareto_ranks_array)
        K = 6
        t = np.array([30.0, np.nan, 10.0, np.inf, 20.0, 40.0])
        e = np.array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0])
        with enable_x64():
            out = dict(times=jnp.asarray(t), energies=jnp.asarray(e),
                       stage=jnp.zeros(K, jnp.int32),
                       hot_mem=jnp.zeros(K, jnp.int32),
                       hot_act=jnp.zeros(K, jnp.int32))
            cores = jnp.arange(K, dtype=jnp.int32)[:, None]
            perm = jnp.tile(jnp.arange(3, dtype=jnp.int32), (K, 1))
            state = jax.jit(
                lambda c, p, o: _sorted_state(jnp, pareto_ranks_array,
                                              c, p, o, K)
            )(cores, perm, out)
        times = np.asarray(state["times"])
        assert np.all(np.isinf(times[-2:]))        # rows 1 and 3, sentineled
        assert set(np.asarray(state["cores"])[:, 0][-2:].tolist()) == {1, 3}
        np.testing.assert_array_equal(np.sort(times[:4]),
                                      np.array([10.0, 20.0, 30.0, 40.0]))

    @quick
    def test_finite_mean_matches_mean_when_all_finite(self):
        rng = np.random.default_rng(0)
        v = rng.uniform(1, 9, size=17)
        assert finite_mean(np, v) == v.mean()      # bit-equal, same sum
        v2 = v.copy()
        v2[3] = np.nan
        keep = np.delete(v2, 3)
        assert finite_mean(np, v2) == keep.sum() / keep.size
        assert finite_mean(np, np.full(4, np.nan)) == np.inf


# ------------------------------------------------------- input validation

class TestValidation:
    @quick
    @pytest.mark.parametrize("engine", ["numpy", "device"])
    def test_population_size_too_small(self, engine):
        net, xs, prof, ev = get_workload("fc")
        with pytest.raises(ValueError, match="population_size"):
            evolutionary_search(net, prof, ev, population_size=1,
                                generations=2, engine=engine)

    @quick
    @pytest.mark.parametrize("engine", ["numpy", "device"])
    def test_generations_too_small(self, engine):
        net, xs, prof, ev = get_workload("fc")
        with pytest.raises(ValueError, match="generations"):
            evolutionary_search(net, prof, ev, population_size=4,
                                generations=0, engine=engine)

    @quick
    def test_seed_candidate_shape_mismatch(self):
        net, xs, prof, ev = get_workload("fc")
        bad = Candidate(cores=(1,) * (len(net.layers) + 1),
                        perm=tuple(range(prof.n_cores)))
        with pytest.raises(ValueError, match="seed candidate 0"):
            evolutionary_search(net, prof, ev, population_size=4,
                                generations=2, seed_candidates=[bad])

    @quick
    def test_simulate_population_rejects_disagreeing_pair(self):
        """A (partition, mapping) pair whose widths disagree fails loudly
        up front, naming the candidate, instead of a cryptic gather error
        deep in the flow build."""
        from repro.core.search import decode
        net, xs, prof, ev = get_workload("fc")
        rng = np.random.default_rng(0)
        good = [decode(c) for c in
                seeded_population(net, prof, size=3, rng=rng)]
        part0, _ = good[0]
        short = good[1][1]
        # graft a mapping truncated to fewer cores than the partition has
        short = type(short)(phys=short.phys[:part0.total_cores - 1])
        with pytest.raises(ValueError, match="candidate 0"):
            simulate_population(net, xs, prof, [(part0, short)] + good[1:],
                                cache=ev.cache)

    @quick
    def test_price_population_device_rejects_bad_shapes(self):
        from repro.neuromorphic.timestep import price_population_device
        net, xs, prof, ev = get_workload("fc")
        cores = np.ones((3, len(net.layers)), np.int32)
        perm = np.tile(np.arange(prof.n_cores, dtype=np.int32), (4, 1))
        with pytest.raises(ValueError):
            price_population_device(net, prof, ev.cache, cores, perm)


# ------------------------------------------------------- fault-plan basics

class TestFaultPlan:
    @quick
    def test_fail_budget_decrements(self):
        plan = FaultPlan(fail={"vmap": 2})
        for _ in range(2):
            with pytest.raises(InjectedFault):
                plan.check("vmap")
        plan.check("vmap")                         # budget spent: clean
        plan.check("device")                       # other sites untouched

    @quick
    def test_kill_fires_once(self):
        plan = FaultPlan(kill_after_gen=2)
        plan.after_generation(0)
        plan.after_generation(1)
        with pytest.raises(SimulatedCrash):
            plan.after_generation(2)
        plan.after_generation(3)                   # resumed run: no re-kill

    @quick
    def test_corrupt_schedule_is_per_call(self):
        plan = FaultPlan(nan_rows={1: (0,)})
        t0, e0 = plan.corrupt_arrays(np.ones(3), np.ones(3))
        assert np.isfinite(t0).all()               # call 0: clean
        t1, e1 = plan.corrupt_arrays(np.ones(3), np.ones(3))
        assert np.isnan(t1[0]) and np.isnan(e1[0])
        assert np.isfinite(t1[1:]).all()
