"""The floorline-guided sparsity-aware trainer (``repro.train.sparse``)
and its :class:`~repro.sparsity.profile.SparsityProfile` artifact.

The headline contract is checkpoint parity: killing the training loop at
an arbitrary step and resuming from the newest checkpoint reproduces the
uninterrupted run BIT-identically — loss curve, final masks, and the
extracted sparsity profile all match exactly.  Around it: floorline
guidance shape/normalization, profile save/load/apply round-trips, the
density-resampling injection path, and the mutual-exclusion guards on
``sparsity_profile=`` vs precomputed pricing."""

import dataclasses

import numpy as np
import pytest

from repro.neuromorphic import (fc_network, loihi2_like, make_inputs,
                                precompute_pricing, simulate,
                                simulate_population)
from repro.sparsity import SparsityProfile
from repro.train import SparseTrainConfig, SparseTrainer

quick = pytest.mark.quick
pytestmark = [pytest.mark.quick, pytest.mark.timeout(300)]

SIZES = (32, 24, 16, 10)            # images task: 32 = 2*4^2


def _cfg(**kw):
    base = dict(sizes=SIZES, steps=12, batch=32, seed=0)
    base.update(kw)
    return SparseTrainConfig(**base)


# ------------------------------------------------------ checkpoint parity

@quick
def test_kill_and_resume_bit_identical(tmp_path):
    """Kill at step 8 of an 18-step prune+fine-tune schedule (checkpoint
    cadence 5), resume in a FRESH trainer: losses, masks, params, and the
    extracted profile must equal the uninterrupted run bit-for-bit."""
    kw = dict(steps=12, lam=0.05, prune_sparsity=0.5, finetune_steps=6,
              min_prune_size=1, ckpt_every=5)
    ref = SparseTrainer(_cfg(ckpt_dir=str(tmp_path / "a"), **kw)).train()

    killed = SparseTrainer(_cfg(ckpt_dir=str(tmp_path / "b"), **kw))
    killed.train(stop_after=8)
    assert killed.step == 8
    resumed = SparseTrainer(_cfg(ckpt_dir=str(tmp_path / "b"), **kw))
    resumed.train(resume=True)

    assert resumed.step == ref.step == 18
    assert resumed.losses == ref.losses
    for a, b in zip(resumed.masks, ref.masks):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(resumed.masked_params(), ref.masked_params()):
        assert np.array_equal(a, b)
    pa = resumed.extract_profile()
    pb = ref.extract_profile()
    assert np.array_equal(pa.act_density, pb.act_density)
    assert np.array_equal(pa.weight_density, pb.weight_density)
    for a, b in zip(pa.weight_masks, pb.weight_masks):
        assert np.array_equal(a, b)


@quick
def test_resume_needs_ckpt_dir():
    with pytest.raises(ValueError, match="ckpt_dir"):
        SparseTrainer(_cfg()).train(resume=True)


def test_prune_without_finetune_rejected():
    with pytest.raises(ValueError, match="finetune_steps"):
        _cfg(prune_sparsity=0.5)


# ------------------------------------------------------------- the loop

@quick
def test_training_learns_and_prunes():
    tr = SparseTrainer(_cfg(steps=30, lam=0.02, prune_sparsity=0.5,
                            finetune_steps=10, min_prune_size=1)).train()
    assert tr.step == 40
    assert np.mean(tr.losses[-5:]) < np.mean(tr.losses[:5])
    met = tr.eval_metrics()
    assert met["acc"] > 0.5                      # synthetic task is easy
    dens = [float(np.mean(np.asarray(m))) for m in tr.masks]
    assert all(abs(d - 0.5) < 0.05 for d in dens)


def test_regularizer_cuts_activation_density():
    dense = SparseTrainer(_cfg(steps=30)).train().eval_metrics()
    sparse = SparseTrainer(_cfg(steps=30, lam=0.3)).train().eval_metrics()
    assert sparse["act_density"] < dense["act_density"]


@quick
def test_floorline_weights_shape_and_mean():
    tr = SparseTrainer(_cfg())
    w = tr.floorline_weights(loihi2_like(), probe_steps=2)
    assert w.shape == (len(SIZES) - 2,)
    assert np.all(w > 0)
    with pytest.raises(ValueError, match="layer_weights"):
        SparseTrainer(_cfg(), layer_weights=[1.0])


def test_sigma_delta_calibration_hits_target():
    cfg = SparseTrainConfig(sizes=(16, 24, 16), task="denoise", steps=15,
                            batch=16, seed=0)
    tr = SparseTrainer(cfg).train()
    profile, net = tr.calibrate_sigma_delta(0.4)
    assert len(profile.thresholds) == 2
    assert abs(profile.act_density[0] - 0.4) < 0.15
    assert net.layers[0].neuron_model == "sd_relu"
    xs = np.maximum(np.asarray(tr.data.batch(11_000)["noisy"][0]), 0.0)
    r = simulate(net, xs, loihi2_like(), sparsity_profile=profile)
    assert r.time_per_step > 0


# ------------------------------------------------------- profile artifact

def _profile(n=3, seed=0):
    rng = np.random.default_rng(seed)
    return SparsityProfile(
        layer_names=tuple(f"fc{i}" for i in range(n)),
        act_density=rng.uniform(0.2, 0.8, n),
        weight_density=np.full(n, 0.5),
        weight_masks=tuple(
            (rng.uniform(size=(8, 8)) < 0.5).astype(np.float32)
            for _ in range(n)),
        input_density=0.7, meta={"seed": seed})


@quick
def test_profile_save_load_roundtrip(tmp_path):
    p = _profile()
    path = tmp_path / "p.npz"
    p.save(path)
    q = SparsityProfile.load(path)
    assert q.layer_names == p.layer_names
    assert np.array_equal(q.act_density, p.act_density)
    assert np.array_equal(q.weight_density, p.weight_density)
    for a, b in zip(q.weight_masks, p.weight_masks):
        assert np.array_equal(a, b)
    assert q.input_density == p.input_density
    assert q.meta == p.meta


@quick
def test_profile_densities_resample():
    p = _profile()
    same = p.densities_for(3)
    assert np.allclose(same, p.act_density)
    up = p.densities_for(7)
    assert len(up) == 7
    assert up[0] == p.act_density[0] and up[-1] == p.act_density[-1]
    one = _profile(n=1).densities_for(4)
    assert np.allclose(one, _profile(n=1).act_density[0])


def test_profile_apply_is_deterministic_and_gates():
    net = fc_network([24, 20, 16, 12], weight_density=1.0, seed=3)
    p = SparsityProfile(layer_names=("a", "b", "c"),
                        act_density=np.array([0.25, 0.5, 1.0]),
                        weight_density=np.array([1.0, 1.0, 1.0]))
    n1, n2 = p.apply(net, seed=7), p.apply(net, seed=7)
    for l1, l2 in zip(n1.layers, n2.layers):
        assert np.array_equal(l1.msg_gate, l2.msg_gate)
    # exact gate counts over live neurons
    assert int(n1.layers[0].msg_gate.sum()) == round(0.25 * 20)
    assert int(n1.layers[1].msg_gate.sum()) == round(0.5 * 16)
    assert int(n1.layers[2].msg_gate.sum()) == 12


# ------------------------------------------- injection exclusion guards

def test_profile_precomputed_mutual_exclusion():
    net = fc_network([16, 12, 10], weight_density=0.8, seed=1)
    xs = make_inputs(16, 0.5, 2, seed=2)
    prof = loihi2_like()
    p = SparsityProfile(layer_names=("a", "b"),
                        act_density=np.array([0.5, 0.5]),
                        weight_density=np.array([1.0, 1.0]))
    cache = precompute_pricing(net, xs, prof)
    with pytest.raises(ValueError, match="sparsity_profile"):
        simulate(net, xs, prof, precomputed=cache, sparsity_profile=p)
    with pytest.raises(ValueError, match="sparsity_profile"):
        simulate_population(net, xs, prof, [], cache=cache,
                            sparsity_profile=p)
    from repro.core.partitioner import SimEvaluator
    with pytest.raises(ValueError, match="sparsity_profile"):
        SimEvaluator(net, xs, prof, cache=cache, sparsity_profile=p)
