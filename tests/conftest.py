"""Test-suite conftest.

Provides a minimal, deterministic stand-in for `hypothesis` when the real
package is absent (the execution image does not ship it and installing new
dependencies is off-limits).  The shim covers exactly the API surface this
suite uses — ``given`` / ``settings`` and the ``integers`` / ``floats`` /
``sampled_from`` / ``builds`` strategies — drawing a fixed number of
seeded-random examples per test.  If the real `hypothesis` is importable it
wins and the shim is never installed.
"""

from __future__ import annotations

import sys
import types

import jax

if not hasattr(jax, "shard_map"):
    # older jax: expose the repo's compat wrapper under the public name the
    # tests use (maps check_vma -> check_rep; see repro.distributed.compat)
    try:
        from repro.distributed.compat import shard_map as _compat_shard_map
        jax.shard_map = _compat_shard_map
    except ImportError:        # repro not on the path: leave jax untouched
        pass

try:                                    # real hypothesis wins when present
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _builds(target, **kw_strategies):
        return _Strategy(lambda rng: target(
            **{k: s.draw(rng) for k, s in kw_strategies.items()}))

    def _given(*arg_st, **kw_st):
        def deco(fn):
            # NOTE: signature intentionally (*args, **kwargs) so pytest does
            # not mistake the strategy parameter names for fixtures.
            def wrapper(*args, **kwargs):
                n = getattr(fn, "_shim_max_examples", 10)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in arg_st),
                       **{k: s.draw(rng) for k, s in kw_st.items()},
                       **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = _integers
    strategies.floats = _floats
    strategies.sampled_from = _sampled_from
    strategies.builds = _builds

    shim = types.ModuleType("hypothesis")
    shim.given = _given
    shim.settings = _settings
    shim.strategies = strategies
    shim.__version__ = "0.0-shim"
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
