"""Unit + property tests for the §III analytical model."""

import math

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.analytical import (Bottleneck, LayerConfig, OpCosts,
                                   layer_op_counts, min_cores_for_layer,
                                   p_neuron_messaged, predict_bottleneck,
                                   sweep_width_scaling)

cfg_st = st.builds(
    LayerConfig,
    n_neurons=st.integers(8, 4096),
    weight_density=st.floats(0.01, 1.0),
    msg_density=st.floats(0.01, 1.0),
    cores=st.integers(1, 32),
    cores_next=st.integers(1, 32),
    width_scale=st.floats(1.0, 8.0),
)


class TestBaseCase:
    def test_single_core_counts_match_formulas(self):
        cfg = LayerConfig(n_neurons=1000, weight_density=0.5, msg_density=0.2)
        c = layer_op_counts(cfg)
        assert c.synops_per_core == pytest.approx(0.2 * 0.5 * 1000**2)
        assert c.act_computes_per_core == pytest.approx(1000)
        assert c.traffic_total == pytest.approx(0.2 * 1000)

    def test_dense_low_sparsity_is_memory_bound(self):
        cfg = LayerConfig(n_neurons=1024, weight_density=1.0, msg_density=0.5)
        assert predict_bottleneck(cfg) is Bottleneck.MEMORY

    def test_extreme_sparsity_escapes_memory_bound(self):
        cfg = LayerConfig(n_neurons=1024, weight_density=0.001,
                          msg_density=0.001)
        assert predict_bottleneck(cfg) is not Bottleneck.MEMORY

    def test_p_neuron_messaged_monotone_and_bounded(self):
        ps = [p_neuron_messaged(n, 0.1) for n in (0, 1, 10, 100, 10000)]
        assert ps[0] == 0.0
        assert all(0.0 <= p <= 1.0 for p in ps)
        assert ps == sorted(ps)

    def test_idealized_acts_leq_full(self):
        cfg = LayerConfig(n_neurons=512, weight_density=0.01, msg_density=0.01)
        ideal = layer_op_counts(cfg, idealized_acts=True)
        full = layer_op_counts(cfg)
        assert ideal.act_computes_per_core <= full.act_computes_per_core


class TestVoluntaryPartitioning:
    """§III-C: synops/core fall linearly with C, traffic rises linearly."""

    def test_synops_fall_traffic_rises(self):
        base = LayerConfig(n_neurons=1024, weight_density=0.5, msg_density=0.3)
        c1 = layer_op_counts(base)
        c4 = layer_op_counts(LayerConfig(1024, 0.5, 0.3, cores=4, cores_next=4))
        assert c4.synops_per_core == pytest.approx(c1.synops_per_core / 4)
        assert c4.traffic_total == pytest.approx(c1.traffic_total * 4)

    def test_partitioning_shifts_memory_to_traffic(self):
        costs = OpCosts()
        narrow = LayerConfig(n_neurons=512, weight_density=0.2, msg_density=0.3)
        assert predict_bottleneck(narrow, costs) is Bottleneck.MEMORY
        split = LayerConfig(n_neurons=512, weight_density=0.2, msg_density=0.3,
                            cores=32, cores_next=32)
        assert predict_bottleneck(split, costs) is Bottleneck.TRAFFIC


class TestForcedUtilization:
    """§III-D: width x => cores O(x^2), traffic O(x^3), synops/core constant."""

    def test_cores_quadratic_traffic_cubic(self):
        base = LayerConfig(n_neurons=256, weight_density=0.5, msg_density=0.3)
        sweep = sweep_width_scaling(base, [1.0, 2.0, 4.0])
        c1, c2, c4 = sweep
        assert c2.cores_used == pytest.approx(4 * c1.cores_used)
        assert c4.cores_used == pytest.approx(16 * c1.cores_used)
        assert c2.traffic_total == pytest.approx(8 * c1.traffic_total)
        assert c4.traffic_total == pytest.approx(64 * c1.traffic_total)
        # synops per core do not change with width
        assert c2.synops_per_core == pytest.approx(c1.synops_per_core)
        assert c4.synops_per_core == pytest.approx(c1.synops_per_core)

    def test_wide_layers_go_traffic_bound(self):
        wide = LayerConfig(n_neurons=256, weight_density=0.5, msg_density=0.3,
                           width_scale=8.0)
        assert predict_bottleneck(wide) is Bottleneck.TRAFFIC


class TestProperties:
    @given(cfg_st)
    @settings(max_examples=100, deadline=None)
    def test_counts_nonnegative_and_finite(self, cfg):
        c = layer_op_counts(cfg)
        for v in (c.synops_per_core, c.act_computes_per_core, c.traffic_total):
            assert v >= 0 and math.isfinite(v)

    @given(cfg_st, st.floats(0.01, 1.0))
    @settings(max_examples=100, deadline=None)
    def test_synops_monotone_in_weight_density(self, cfg, w2):
        import dataclasses
        lo, hi = sorted([cfg.weight_density, w2])
        c_lo = layer_op_counts(dataclasses.replace(cfg, weight_density=lo))
        c_hi = layer_op_counts(dataclasses.replace(cfg, weight_density=hi))
        assert c_lo.synops_per_core <= c_hi.synops_per_core + 1e-9

    @given(cfg_st)
    @settings(max_examples=100, deadline=None)
    def test_more_cores_never_increases_per_core_synops(self, cfg):
        import dataclasses
        c1 = layer_op_counts(cfg)
        c2 = layer_op_counts(dataclasses.replace(cfg, cores=cfg.cores * 2))
        assert c2.synops_per_core <= c1.synops_per_core + 1e-9
        assert c2.act_computes_per_core <= c1.act_computes_per_core + 1e-9

    @given(st.integers(1, 10**6), st.integers(1, 10**4),
           st.integers(1, 8192), st.integers(1, 1 << 20))
    @settings(max_examples=100, deadline=None)
    def test_min_cores_satisfies_both_limits(self, n, fanin, npc, spc):
        c = min_cores_for_layer(n, fanin, neurons_per_core=npc,
                                synapses_per_core=spc)
        assert math.ceil(n / c) <= npc or c >= math.ceil(n / npc)
        assert c >= max(math.ceil(n / npc), math.ceil(n * fanin / spc))


def test_invalid_configs_rejected():
    with pytest.raises(ValueError):
        LayerConfig(n_neurons=10, weight_density=1.5, msg_density=0.5)
    with pytest.raises(ValueError):
        LayerConfig(n_neurons=10, weight_density=0.5, msg_density=-0.1)
    with pytest.raises(ValueError):
        LayerConfig(n_neurons=10, weight_density=0.5, msg_density=0.5, cores=0)
    with pytest.raises(ValueError):
        LayerConfig(n_neurons=10, weight_density=0.5, msg_density=0.5,
                    width_scale=0.5)
