"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode executes the kernel bodies on CPU).  The ``quick``-marked
subset is the CI kernels step's smoke pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels import (block_activity, event_matmul, event_matmul_pair,
                           pad_compact, sigma_delta_encode)
from repro.kernels.event_matmul.ref import (block_activity_ref,
                                            event_matmul_ref, event_stats_ref)
from repro.kernels.sigma_delta.ref import sigma_delta_ref

quick = pytest.mark.quick


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=1e-4, rtol=1e-4)


def make_block_sparse(rng, m, k, density, bm, bk, dtype):
    """Activations with a controlled fraction of active (bm, bk) tiles."""
    x = rng.normal(size=(m, k)).astype(np.float32)
    mb, kb = -(-m // bm), -(-k // bk)
    keep = rng.random((mb, kb)) < density
    mask = np.repeat(np.repeat(keep, bm, 0), bk, 1)[:m, :k]
    return jnp.asarray((x * mask), dtype=dtype)


class TestEventMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (256, 512, 256), (384, 256, 640),
        (130, 257, 100), (8, 1024, 128), (1, 128, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, m, k, n, dtype):
        rng = np.random.default_rng(m * 7 + k + n)
        x = make_block_sparse(rng, m, k, 0.5, 128, 128, dtype)
        w = jnp.asarray(rng.normal(size=(k, n)), dtype=dtype)
        y = event_matmul(x, w, threshold=0.0)
        xp = jnp.pad(x, [(0, (-m) % 128), (0, (-k) % 128)])
        wp = jnp.pad(w, [(0, (-k) % 128), (0, (-n) % 128)])
        yr = event_matmul_ref(xp, wp, threshold=0.0, bm=128, bk=128)[:m, :n]
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(yr, np.float32), **_tol(dtype))

    @pytest.mark.parametrize("blocks", [(128, 128, 128), (256, 128, 256),
                                        (8, 128, 128)])
    def test_block_size_sweep(self, blocks):
        bm, bk, bn = blocks
        rng = np.random.default_rng(3)
        x = make_block_sparse(rng, 2 * bm, 4 * bk, 0.4, bm, bk, jnp.float32)
        w = jnp.asarray(rng.normal(size=(4 * bk, 2 * bn)), jnp.float32)
        y = event_matmul(x, w, threshold=0.0, bm=bm, bk=bk, bn=bn)
        yr = event_matmul_ref(x, w, threshold=0.0, bm=bm, bk=bk)
        np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)

    @quick
    def test_threshold_drops_small_blocks(self):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(128, 256)) * 0.01, jnp.float32)
        w = jnp.asarray(rng.normal(size=(256, 128)), jnp.float32)
        y = event_matmul(x, w, threshold=1.0)     # everything sub-threshold
        assert float(jnp.abs(y).max()) == 0.0

    @quick
    def test_fully_dense_matches_plain_matmul(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(256, 384)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(384, 256)), jnp.float32)
        y = event_matmul(x, w, threshold=0.0)
        np.testing.assert_allclose(y, x @ w, atol=1e-3, rtol=1e-4)

    @quick
    def test_contraction_mismatch_raises(self):
        with pytest.raises(ValueError):
            event_matmul(jnp.zeros((8, 16)), jnp.zeros((32, 8)))

    @given(density=st.floats(0.0, 1.0), seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_property_matches_ref_any_density(self, density, seed):
        rng = np.random.default_rng(seed)
        x = make_block_sparse(rng, 256, 384, density, 128, 128, jnp.float32)
        w = jnp.asarray(rng.normal(size=(384, 128)), jnp.float32)
        y = event_matmul(x, w, threshold=0.0)
        yr = event_matmul_ref(x, w, threshold=0.0, bm=128, bk=128)
        np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)

    @quick
    def test_activity_counters(self):
        rng = np.random.default_rng(9)
        x = make_block_sparse(rng, 256, 512, 0.25, 128, 128, jnp.float32)
        act = block_activity(x, 0.0)
        stats = event_stats_ref(x, 0.0, 128, 128)
        assert int(act.sum()) == int(stats["active_blocks"])
        assert stats["block_density"] <= 1.0


class TestSigmaDelta:
    @pytest.mark.parametrize("shape", [(32, 512), (7, 300), (4, 16, 128),
                                       (1, 1)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shape_dtype_sweep(self, shape, dtype):
        rng = np.random.default_rng(sum(shape))
        a = jnp.asarray(rng.normal(size=shape), dtype)
        s = jnp.asarray(rng.normal(size=shape), dtype)
        q, s2 = sigma_delta_encode(a, s, theta=0.1)
        qr, sr = sigma_delta_ref(a, s, theta=0.1)
        np.testing.assert_allclose(np.asarray(q, np.float32),
                                   np.asarray(qr, np.float32), **_tol(dtype))
        np.testing.assert_allclose(np.asarray(s2, np.float32),
                                   np.asarray(sr, np.float32), **_tol(dtype))

    @quick
    def test_steady_state_sends_nothing(self):
        a = jnp.ones((16, 256))
        q1, s1 = sigma_delta_encode(a, jnp.zeros_like(a), theta=0.05)
        q2, s2 = sigma_delta_encode(a, s1, theta=0.05)
        assert float(jnp.abs(q2).max()) == 0.0

    def test_reconstruction_error_bounded_by_theta(self):
        """Property: after encoding, |a - s_new| < theta everywhere."""
        rng = np.random.default_rng(11)
        a = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
        s = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
        theta = 0.2
        _, s_new = sigma_delta_encode(a, s, theta=theta)
        assert float(jnp.abs(a - s_new).max()) < theta

    @given(theta=st.floats(0.01, 2.0), seed=st.integers(0, 50))
    @settings(max_examples=15, deadline=None)
    def test_property_messages_quantized(self, theta, seed):
        rng = np.random.default_rng(seed)
        a = jnp.asarray(rng.normal(size=(32, 256)), jnp.float32)
        q, _ = sigma_delta_encode(a, jnp.zeros_like(a), theta=theta)
        qn = np.asarray(q)
        nz = qn[qn != 0]
        # all messages are integer multiples of theta
        np.testing.assert_allclose(nz / theta, np.round(nz / theta),
                                   atol=1e-3)

    @quick
    def test_bad_theta_raises(self):
        with pytest.raises(ValueError):
            sigma_delta_encode(jnp.zeros((4, 4)), jnp.zeros((4, 4)), theta=0.0)


class TestSharedPadCompact:
    @quick
    def test_pad_compact_single_pad_contract(self):
        """One pad serves the activity map AND the kernel's index lists."""
        rng = np.random.default_rng(12)
        x = make_block_sparse(rng, 130, 200, 0.4, 128, 128, jnp.float32)
        xp, active, idx, cnt = pad_compact(x, 0.0, 128, 128)
        assert xp.shape == (256, 256)
        np.testing.assert_array_equal(np.asarray(active),
                                      np.asarray(block_activity(x, 0.0)))
        mb, kb = active.shape
        assert idx.shape == (mb, kb) and cnt.shape == (mb,)
        np.testing.assert_array_equal(np.asarray(cnt),
                                      np.asarray(active).sum(axis=1))
        # compacted indices enumerate exactly the active tiles, in order
        act_np = np.asarray(active)
        for m in range(mb):
            want = np.flatnonzero(act_np[m])
            np.testing.assert_array_equal(np.asarray(idx[m, :cnt[m]]), want)

    @quick
    def test_event_matmul_pair_matches_two_calls(self):
        """The simulator's batched entry point == two event matmuls."""
        rng = np.random.default_rng(13)
        x = make_block_sparse(rng, 64, 192, 0.5, 128, 128, jnp.float32)
        m = (jnp.abs(x) > 0).astype(jnp.float32)
        w = jnp.asarray(rng.normal(size=(192, 96)), jnp.float32)
        wm = (w != 0).astype(jnp.float32)
        y, macs = event_matmul_pair(x, m, w, wm, threshold=0.0)
        np.testing.assert_array_equal(y, event_matmul(x, w, threshold=0.0))
        np.testing.assert_array_equal(macs,
                                      event_matmul(m, wm, threshold=0.0))

    @quick
    def test_event_matmul_pair_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            event_matmul_pair(jnp.zeros((8, 16)), jnp.zeros((8, 8)),
                              jnp.zeros((16, 4)), jnp.zeros((16, 4)))


@quick
def test_kernels_jit_cacheable():
    """Repeated calls hit the jit cache (no retrace explosion)."""
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 128))
    y1 = event_matmul(x, w, threshold=0.0)
    y2 = event_matmul(x * 2, w, threshold=0.0)
    np.testing.assert_allclose(y2, 2 * y1, rtol=1e-5)
