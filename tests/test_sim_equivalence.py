"""Equivalence suite: the layer-major batched engine must match the
step-major reference engine — outputs allclose (float32 GEMM batching is
allowed to differ in the last ulp), every counter / per-core aggregate /
time / energy bit-identical — across layer kinds, all four neuron models,
sigma-delta delta chains, sync and async profiles, and both mappings."""

import numpy as np
import pytest

from repro.neuromorphic import (SimLayer, SimNetwork, fc_network, loihi2_like,
                                make_inputs, programmed_fc_network, simulate,
                                speck_like)
from repro.neuromorphic.network import _exact_density_mask
from repro.neuromorphic.noc import (Mapping, ordered_mapping, route_batch,
                                    route_step, strided_mapping)
from repro.neuromorphic.partition import Partition, minimal_partition


def assert_engines_match(net, xs, prof, part=None, mapping=None):
    r_ref = simulate(net, xs, prof, part, mapping, engine="reference")
    r_bat = simulate(net, xs, prof, part, mapping, engine="batched")
    np.testing.assert_allclose(r_bat.outputs, r_ref.outputs,
                               rtol=1e-5, atol=1e-6)
    # cost-model quantities must be BIT-identical (counters are integer
    # counts, and both engines use the same float op order on them)
    for field in ("times", "energies", "per_core_synops", "per_core_acts",
                  "per_core_msgs_out"):
        a, b = getattr(r_bat, field), getattr(r_ref, field)
        assert np.array_equal(a, b), f"{field} diverged"
    assert r_bat.max_synops == r_ref.max_synops
    assert r_bat.max_acts == r_ref.max_acts
    assert r_bat.max_link_load == r_ref.max_link_load
    assert r_bat.bottleneck_stage == r_ref.bottleneck_stage
    assert r_bat.n_cores_active == r_ref.n_cores_active
    assert r_bat.metrics == r_ref.metrics
    return r_ref, r_bat


def conv_stack(*, neuron_model="relu", sends_deltas=False, threshold=0.0,
               weight_density=0.6, seed=0):
    """conv -> conv -> fc stack (channel-major flat boundaries)."""
    rng = np.random.default_rng(seed)
    layers = []
    h = w = 8
    c_prev = 2
    for i, c in enumerate((4, 8)):
        wgt = rng.normal(0, 1 / 3.0, (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, weight_density, rng)
        layers.append(SimLayer(
            name=f"conv{i}", kind="conv", weights=wgt, stride=2,
            in_hw=(h, w), neuron_model=neuron_model, threshold=threshold,
            sends_deltas=sends_deltas))
        h, w, c_prev = h // 2, w // 2, c
    wfc = rng.normal(0, 0.3, (h * w * c_prev, 10)).astype(np.float32)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc,
                           neuron_model="relu"))
    return SimNetwork(layers=layers, in_size=8 * 8 * 2)


class TestCounterParity:
    """run_batch counter maps == per-step run counter maps, bit for bit."""

    @pytest.mark.parametrize("model,thr", [("relu", 0.0), ("if", 0.6),
                                           ("sd_relu", 0.03), ("ssm", 0.0)])
    def test_fc_counters_bit_identical(self, model, thr):
        net = fc_network([48, 64, 32], weight_density=0.5,
                         neuron_model=model, seed=3)
        for l in net.layers:
            l.threshold = thr
            if model == "sd_relu":
                l.sends_deltas = True
        xs = make_inputs(48, 0.5, 6, seed=4)
        _, ref = net.run(xs)
        _, bat = net.run_batch(xs)
        for l, bc in enumerate(bat):
            for t in range(xs.shape[0]):
                cm, sv = ref[t][l], bc.step_view(t)
                assert cm.msgs_in == sv.msgs_in
                for f in ("macs", "fetches_dense", "msgs_out",
                          "acts_evented"):
                    assert np.array_equal(getattr(cm, f), getattr(sv, f)), \
                        (l, t, f)

    def test_conv_counters_bit_identical(self):
        net = conv_stack(seed=1)
        xs = make_inputs(net.in_size, 0.4, 4, seed=2)
        _, ref = net.run(xs)
        _, bat = net.run_batch(xs)
        for l, bc in enumerate(bat):
            for t in range(xs.shape[0]):
                cm, sv = ref[t][l], bc.step_view(t)
                assert cm.msgs_in == sv.msgs_in
                assert np.array_equal(cm.macs, sv.macs)
                assert np.array_equal(cm.fetches_dense, sv.fetches_dense)
                assert np.array_equal(cm.msgs_out, sv.msgs_out)


class TestSimulateParity:
    @pytest.mark.parametrize("model,thr", [("relu", 0.0), ("if", 0.6),
                                           ("sd_relu", 0.03), ("ssm", 0.0)])
    def test_fc_all_neuron_models(self, model, thr):
        net = fc_network([96, 128, 64], weight_density=0.5,
                         neuron_model=model, seed=0)
        for l in net.layers:
            l.threshold = thr
        xs = make_inputs(96, 0.4, 5, seed=1)
        assert_engines_match(net, xs, loihi2_like())

    def test_fc_sigma_delta_chain(self):
        """sends_deltas downstream layers exercise the cumsum input
        reconstruction across every layer boundary."""
        net = fc_network([64, 96, 96, 32], neuron_model="sd_relu", seed=5)
        for l in net.layers:
            l.threshold, l.sends_deltas = 0.02, True
        xs = make_inputs(64, 0.5, 8, seed=6)
        assert_engines_match(net, xs, loihi2_like())

    def test_conv_stack_sync(self):
        net = conv_stack(seed=7)
        xs = make_inputs(net.in_size, 0.5, 4, seed=8)
        assert_engines_match(net, xs, loihi2_like())

    def test_conv_sigma_delta(self):
        net = conv_stack(neuron_model="sd_relu", sends_deltas=True,
                         threshold=0.05, seed=9)
        xs = make_inputs(net.in_size, 0.5, 5, seed=10)
        assert_engines_match(net, xs, loihi2_like())

    def test_async_speck_if(self):
        net = fc_network([96, 64, 10], neuron_model="if", seed=11)
        for l in net.layers:
            l.threshold = 0.5
        xs = make_inputs(96, 0.3, 6, seed=12)
        r_ref, _ = assert_engines_match(net, xs, speck_like())
        assert r_ref.bottleneck_stage == "memory"   # async is pipeline-sum

    def test_programmed_gates_force_active(self):
        net = programmed_fc_network([128] * 4, weight_densities=[0.5] * 3,
                                    act_densities=[0.9, 0.1, 0.5], seed=13,
                                    weight_format="sparse")
        xs = make_inputs(128, 0.5, 4, seed=14)
        assert_engines_match(net, xs, loihi2_like())

    @pytest.mark.parametrize("make_mapping", [ordered_mapping,
                                              strided_mapping])
    def test_partitioned_both_mappings(self, make_mapping):
        net = fc_network([128, 192, 192, 64], weight_density=0.4, seed=15)
        xs = make_inputs(128, 0.6, 5, seed=16)
        prof = loihi2_like()
        part = Partition((6, 8, 3))
        assert_engines_match(net, xs, prof, part, make_mapping(part, prof))

    def test_empty_core_partition(self):
        """More cores than neurons in a layer: empty segments must sum to 0
        in the batched aggregation too (reduceat would repeat a neuron)."""
        net = fc_network([16, 6, 8], weight_density=1.0, seed=19)
        xs = make_inputs(16, 0.8, 3, seed=20)
        part = Partition((7, 1))      # layer 0 has 6 neurons on 7 cores
        assert_engines_match(net, xs, loihi2_like(), part)

    def test_precomputed_run_reuse(self):
        """A cached run_batch result prices any partition identically."""
        net = fc_network([96, 96, 96, 48], weight_density=0.5, seed=17)
        xs = make_inputs(96, 0.5, 4, seed=18)
        prof = loihi2_like()
        pre = net.run_batch(xs)
        for cores in ((1, 1, 1), (4, 2, 2), (8, 8, 4)):
            part = Partition(cores)
            ra = simulate(net, xs, prof, part, precomputed=pre)
            rb = simulate(net, xs, prof, part, engine="reference")
            assert np.array_equal(ra.times, rb.times)
            assert np.array_equal(ra.per_core_synops, rb.per_core_synops)


class TestRouteBatchParity:
    def test_route_batch_matches_route_step(self):
        prof = loihi2_like()
        part = Partition((5, 7, 3))
        rng = np.random.default_rng(0)
        T, n = 6, part.total_cores
        msgs = rng.integers(0, 40, (T, n)).astype(np.float64)
        offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)
        for mapping in (ordered_mapping(part, prof),
                        strided_mapping(part, prof)):
            batch = route_batch(part, mapping, msgs, prof)
            for t in range(T):
                per_layer = [msgs[t, offsets[l]:offsets[l + 1]]
                             for l in range(len(part.cores))]
                step = route_step(part, mapping, per_layer, prof)
                assert np.array_equal(batch.router_loads[t],
                                      step.router_loads)
                assert batch.total_hops[t] == step.total_hops
                assert np.array_equal(batch.inject_per_core[t],
                                      step.inject_per_core)
