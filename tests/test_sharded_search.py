"""Island-model sharded search tests (:mod:`repro.core.device_search`).

The guarantees layered on top of the single-device engine's:

* **mesh-1 bit parity** — ``engine="sharded"`` with one island replays
  ``engine="device"`` EXACTLY (same :func:`island_keys` stream, same jitted
  step, collectives degenerate to identities);
* **mirror parity** — the jitted multi-island step and
  :class:`_ShardedHostMirror` (host NumPy, per-island blocks, list-form
  ring migration) agree on the full trajectory to float64 roundoff and on
  the final candidate exactly;
* **migration conservation** — the elite-block ring rotation moves rows
  between islands without duplicating or dropping any: the global genome
  multiset is invariant (hypothesis, over island geometries);
* **front assembly** — a row nondominated globally is nondominated on its
  island, so the front of the gathered population equals the front of the
  pooled per-island fronts (the property that makes per-island ranking +
  host assembly correct);
* **launch plumbing** — ``force_host_device_count`` rejects a too-late
  call in-process and actually yields N devices in a fresh process;
* **degradation** — a permanently failing jitted sharded step demotes to
  the host mirror and completes the identical trajectory.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to
exercise real multi-island meshes (CI does); on one device the
multi-island tests degenerate to a single island but stay valid.
"""

import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.core.device_search import (_ShardedHostMirror, _sharded_engine_for,
                                      evolutionary_search_sharded,
                                      island_keys)
from repro.core.partitioner import SimEvaluator
from repro.core.resilience import ALWAYS, FaultPlan, RetryPolicy
from repro.core.search import (Population, evolutionary_search, move_tables,
                               pareto_ranks, seeded_population)
from repro.distributed.sharding import island_mesh
from repro.neuromorphic import loihi2_like, make_inputs, programmed_fc_network
from repro.neuromorphic.timestep import (precompute_pricing,
                                         price_population_device,
                                         price_population_sharded)

quick = pytest.mark.quick
pytestmark = pytest.mark.timeout(600)

N_DEV = len(jax.devices())


def fc_workload(sizes=(64, 96, 48), wd=0.6, ad=0.3, steps=2):
    net = programmed_fc_network(
        list(sizes), weight_densities=[wd] * (len(sizes) - 1),
        act_densities=[ad] * (len(sizes) - 1), seed=0,
        weight_format="sparse")
    return net, make_inputs(sizes[0], ad, steps, seed=1)


_WORKLOAD: dict = {}


def get_workload():
    """One shared (net, xs, prof, evaluator) so the sharded engine
    compiles once per (n_off, migrate) variant for the whole module."""
    if not _WORKLOAD:
        net, xs = fc_workload()
        prof = loihi2_like()
        _WORKLOAD["value"] = (net, xs, prof, SimEvaluator(net, xs, prof))
    return _WORKLOAD["value"]


def _traj(res):
    return [(g.generation, g.best_time, g.best_energy, g.mean_time,
             g.n_evals, g.front_size, g.n_quarantined) for g in res.history]


def _search(net, prof, ev, **kw):
    kw.setdefault("population_size", 16)
    kw.setdefault("generations", 4)
    kw.setdefault("seed", 3)
    return evolutionary_search(net, prof, ev, **kw)


def _rows_multiset(state):
    cores = np.asarray(state["cores"])
    perm = np.asarray(state["perm"])
    return sorted(map(tuple, np.concatenate([cores, perm], axis=1).tolist()))


# ---------------------------------------------------------- PRNG contract

class TestIslandKeys:
    @quick
    def test_single_island_reduces_to_device_contract(self):
        """With one island, generation g's key IS fold_in(key, g) — the
        fact that makes mesh-1 runs bit-identical to engine="device"."""
        base = jax.random.PRNGKey(11)
        for gen in (0, 1, 5):
            np.testing.assert_array_equal(
                np.asarray(island_keys(base, gen, 1))[0],
                np.asarray(jax.random.fold_in(base, gen)))

    @quick
    def test_gen_island_packing(self):
        """Island i of generation g folds in g * n_islands + i: distinct
        across both axes, and consecutive generations do not collide with
        neighbouring islands' streams."""
        base = jax.random.PRNGKey(0)
        n = 4
        seen = set()
        for gen in range(3):
            keys = np.asarray(island_keys(base, gen, n))
            for i in range(n):
                np.testing.assert_array_equal(
                    keys[i],
                    np.asarray(jax.random.fold_in(base, gen * n + i)))
                seen.add(keys[i].tobytes())
        assert len(seen) == 3 * n


# ------------------------------------------------------------- bit parity

class TestMeshOneParity:
    @quick
    def test_sharded_one_island_is_bit_identical_to_device(self):
        """The tentpole contract: n_islands=1 replays engine="device"
        EXACTLY — trajectory, front, final candidate (float equality, not
        tolerance)."""
        net, xs, prof, ev = get_workload()
        dev = _search(net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                      engine="device")
        sh = _search(net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                     engine="sharded", n_islands=1)
        assert _traj(sh) == _traj(dev)
        assert sh.candidate == dev.candidate
        assert sh.front == dev.front
        assert sh.report.time_per_step == dev.report.time_per_step
        assert sh.n_evals == dev.n_evals


class TestMirrorParity:
    @quick
    def test_multi_island_matches_host_mirror(self):
        """Jitted multi-island run vs reference=True host replay: same
        candidate, trajectory equal to float64 roundoff, same migration
        cadence (migrate_every=2 exercises the ring twice in 4 gens)."""
        net, xs, prof, ev = get_workload()
        kw = dict(engine="sharded", n_islands=N_DEV, migrate_every=2)
        jit = _search(net, prof,
                      SimEvaluator(net, xs, prof, cache=ev.cache), **kw)
        ref = evolutionary_search_sharded(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            population_size=16, generations=4, seed=3, n_islands=N_DEV,
            migrate_every=2, reference=True)
        assert jit.candidate == ref.candidate
        for a, b in zip(jit.history, ref.history):
            np.testing.assert_allclose(
                [a.best_time, a.best_energy, a.mean_time],
                [b.best_time, b.best_energy, b.mean_time], rtol=1e-9)
            assert (a.generation, a.n_evals, a.n_quarantined) \
                == (b.generation, b.n_evals, b.n_quarantined)


# -------------------------------------------------- migration conservation

def _engine_and_state(local_pop, n_migrants, seed):
    net, xs, prof, ev = get_workload()
    n_islands = N_DEV
    mesh = island_mesh(n_islands)
    eng = _sharded_engine_for(net, prof, ev.cache, move_tables(net, prof),
                              mesh=mesh, local_pop=local_pop,
                              n_migrants=n_migrants, explore_prob=0.25,
                              tournament_k=3)
    pop = Population.from_candidates(seeded_population(
        net, prof, size=local_pop * n_islands,
        rng=np.random.default_rng(seed)))
    state, _ = eng.init(pop.cores, pop.perm)
    return eng, state


class TestMigrationConservation:
    @quick
    @settings(max_examples=8, deadline=None)
    @given(st.integers(min_value=1, max_value=4),
           st.integers(min_value=0, max_value=5))
    def test_ring_rotation_preserves_global_genome_multiset(
            self, n_migrants, seed):
        """Migration is a rotation, not a copy: across any island count,
        elite size and population, the multiset of global genome rows is
        unchanged (no row duplicated, none lost) — and objectives still
        pair with their rows afterwards."""
        eng, state = _engine_and_state(local_pop=6, n_migrants=n_migrants,
                                       seed=seed)
        before = _rows_multiset(jax.device_get(state))
        after_state = jax.device_get(eng.migrate(state))
        assert _rows_multiset(after_state) == before
        # the host mirror's list-form rotation lands on the same blocks
        net, xs, prof, ev = get_workload()
        mirror = _ShardedHostMirror(
            net, xs, prof, ev.cache, move_tables(net, prof),
            n_islands=N_DEV, local_pop=6, n_migrants=n_migrants,
            explore_prob=0.25, tournament_k=3)
        mref = mirror.migrate({k: np.asarray(v)
                               for k, v in jax.device_get(state).items()})
        np.testing.assert_array_equal(after_state["cores"], mref["cores"])
        np.testing.assert_array_equal(after_state["perm"], mref["perm"])

    @quick
    def test_migrated_rows_keep_their_objectives(self):
        """Each (genome -> time, energy) pairing survives the rotation:
        sort both sides by genome bytes and compare objectives exactly."""
        eng, state = _engine_and_state(local_pop=6, n_migrants=2, seed=0)
        def by_genome(s):
            s = jax.device_get(s)
            g = np.concatenate([np.asarray(s["cores"]),
                                np.asarray(s["perm"])], axis=1)
            order = np.lexsort(tuple(g[:, c] for c in range(g.shape[1])))
            return (g[order], np.asarray(s["times"])[order],
                    np.asarray(s["energies"])[order])
        g0, t0, e0 = by_genome(state)
        g1, t1, e1 = by_genome(eng.migrate(state))
        np.testing.assert_array_equal(g0, g1)
        np.testing.assert_array_equal(t0, t1)
        np.testing.assert_array_equal(e0, e1)


# ----------------------------------------------------------- front assembly

class TestFrontAssembly:
    @quick
    @settings(max_examples=15, deadline=None)
    @given(st.integers(min_value=1, max_value=8),
           st.integers(min_value=2, max_value=12),
           st.integers(min_value=0, max_value=99))
    def test_front_of_gathered_equals_front_of_pooled_island_fronts(
            self, n_islands, local, seed):
        """A globally nondominated row is nondominated on its island, so
        rank-0 of the gathered population == rank-0 of the pooled
        per-island rank-0 sets.  This is why per-island survival sorting +
        host-side assembly loses no Pareto point."""
        rng = np.random.default_rng(seed)
        t = rng.integers(1, 20, size=(n_islands, local)).astype(float)
        e = rng.integers(1, 20, size=(n_islands, local)).astype(float)
        gt, ge = t.ravel(), e.ravel()
        global_front = {(a, b) for a, b, r in
                        zip(gt, ge, pareto_ranks(gt, ge)) if r == 0}
        pooled_t, pooled_e = [], []
        for i in range(n_islands):
            r = pareto_ranks(t[i], e[i])
            pooled_t.extend(t[i][r == 0])
            pooled_e.extend(e[i][r == 0])
        pt, pe = np.asarray(pooled_t), np.asarray(pooled_e)
        assembled = {(a, b) for a, b, r in
                     zip(pt, pe, pareto_ranks(pt, pe)) if r == 0}
        assert assembled == global_front

    @quick
    def test_history_best_is_global_lexmin_of_final_state(self):
        """The in-program all_gather stats report the true global
        (time, then energy) leader — cross-checked on host against the
        gathered final state of a real multi-island run."""
        net, xs, prof, ev = get_workload()
        eng, state = _engine_and_state(local_pop=6, n_migrants=1, seed=4)
        keys = island_keys(jax.random.PRNGKey(7), 1, eng.n_islands)
        state, _, stats = eng.step(state, keys, n_off=6)
        h = jax.device_get(dict(state=state, stats=stats))
        ts = np.asarray(h["state"]["times"]).reshape(eng.n_islands, -1)
        es = np.asarray(h["state"]["energies"]).reshape(eng.n_islands, -1)
        assert float(np.asarray(h["stats"]["best_time"])[0]) \
            == float(ts.min())
        lead_t, lead_e = ts[:, 0], es[:, 0]
        want_e = float(np.where(lead_t == lead_t.min(), lead_e,
                                np.inf).min())
        assert float(np.asarray(h["stats"]["best_energy"])[0]) == want_e
        # every island carries the same (replicated) global stats
        assert len(set(np.asarray(h["stats"]["best_time"]).tolist())) == 1


# ------------------------------------------------------------ sharded pricer

class TestShardedPricer:
    @quick
    def test_matches_device_pricer_incl_ragged_population(self):
        """price_population_sharded == price_population_device for K both
        divisible and NOT divisible by the island count (pad rows are
        priced and trimmed, never returned)."""
        net, xs, prof, ev = get_workload()
        cache = ev.cache or precompute_pricing(net, xs, prof)
        for k in (N_DEV * 3, N_DEV * 3 + 1, 5):
            pop = Population.from_candidates(seeded_population(
                net, prof, size=k, rng=np.random.default_rng(k)))
            want = price_population_device(net, prof, cache,
                                           pop.cores, pop.perm)
            got = price_population_sharded(net, prof, cache,
                                           pop.cores, pop.perm)
            assert len(got) == len(want) == len(pop)
            for a, b in zip(got, want):
                assert a.time_per_step == b.time_per_step
                assert a.energy_per_step == b.energy_per_step
                assert a.bottleneck_stage == b.bottleneck_stage


# ------------------------------------------------------------- launch flags

class TestLaunchFlags:
    @quick
    def test_force_after_jax_import_raises(self):
        """jax is long imported in this process: asking for a different
        forced count must fail loudly instead of silently not applying."""
        from repro.launch.mesh import (force_host_device_count,
                                       forced_host_device_count)
        with pytest.raises(RuntimeError, match="before jax"):
            force_host_device_count(N_DEV + 1)
        # idempotent path: the count already in force is a no-op
        if forced_host_device_count() is not None:
            force_host_device_count(forced_host_device_count())

    @quick
    def test_apply_devices_flag_parses_and_rejects(self):
        from repro.launch.mesh import apply_devices_flag
        assert apply_devices_flag(["--quick"]) is None
        with pytest.raises(SystemExit):
            apply_devices_flag(["--devices", "eight"])

    @quick
    def test_forced_count_yields_devices_in_fresh_process(self):
        """End-to-end: force 3 host devices before jax in a clean process
        and observe exactly 3, sharded search included."""
        code = (
            "from repro.launch.mesh import force_host_device_count\n"
            "force_host_device_count(3)\n"
            "import jax\n"
            "assert len(jax.devices()) == 3, jax.devices()\n"
            "from repro.distributed.sharding import island_mesh\n"
            "assert island_mesh().shape['island'] == 3\n"
            "print('OK')\n")
        env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.abspath("src")] + sys.path)
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr
        assert "OK" in out.stdout

    @quick
    def test_island_mesh_rejects_oversubscription(self):
        with pytest.raises(RuntimeError, match="devices"):
            island_mesh(N_DEV + 1)


# ------------------------------------------------------------- degradation

class TestDegradation:
    def test_sharded_fault_demotes_to_mirror_and_matches(self):
        """A permanently failing jitted sharded step demotes to the host
        mirror and completes the reference trajectory (same island-keys
        contract on both sides)."""
        net, xs, prof, ev = get_workload()
        kw = dict(population_size=16, generations=3, seed=5,
                  n_islands=N_DEV, migrate_every=2)
        ref = evolutionary_search_sharded(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            reference=True, **kw)
        res = evolutionary_search_sharded(
            net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
            fault_plan=FaultPlan(fail={"sharded": ALWAYS}),
            retry=RetryPolicy(max_retries=1, backoff_s=0.0), **kw)
        assert [d.frm for d in res.demotions] == ["sharded"]
        assert res.demotions[0].to == "numpy-mirror"
        assert _traj(res) == _traj(ref)
        assert res.candidate == ref.candidate


# ------------------------------------------------------------- validation

class TestValidation:
    @quick
    def test_population_must_divide_into_islands(self):
        net, xs, prof, ev = get_workload()
        if N_DEV == 1:
            pytest.skip("needs >= 2 devices for a non-divisible split")
        with pytest.raises(ValueError, match="divide"):
            _search(net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                    engine="sharded", population_size=N_DEV * 4 + 1)

    @quick
    def test_islands_need_two_rows_each(self):
        net, xs, prof, ev = get_workload()
        if N_DEV == 1:
            pytest.skip("a single island cannot go below 2 rows without "
                        "tripping the population_size >= 2 check first")
        with pytest.raises(ValueError, match="at least 2"):
            _search(net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                    engine="sharded", population_size=N_DEV,
                    n_islands=N_DEV)

    @quick
    def test_unknown_engine_still_rejected(self):
        net, xs, prof, ev = get_workload()
        with pytest.raises(ValueError, match="unknown search engine"):
            _search(net, prof, SimEvaluator(net, xs, prof, cache=ev.cache),
                    engine="tpu")
