"""Dry-run launcher: representative cells lower+compile in a subprocess
(512 placeholder devices env is set by the module itself) on a reduced mesh
with SMOKE configs; artifact fields asserted.  The full 64-cell production
sweep is `python -m repro.launch.dryrun --all` — its committed results live
in experiments/dryrun/ and EXPERIMENTS.md."""

import json
import os
import subprocess
import sys

import pytest

CELLS = [
    ("granite-3-2b", "train_4k", "2,4"),            # dense TP
    ("kimi-k2-1t-a32b", "train_4k", "2,2,2"),       # MoE EP a2a, multipod
    ("phi3-medium-14b", "train_4k", "2,4"),         # context-parallel attn
    ("gemma2-2b", "decode_32k", "2,4"),             # windowed flash-decode
    ("mamba2-1.3b", "long_500k", "2,4"),            # SSM state decode
    ("whisper-base", "decode_32k", "2,2,2"),        # enc-dec cross cache
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", CELLS)
def test_dryrun_cell_smoke(arch, shape, mesh, tmp_path):
    env = {**os.environ, "PYTHONPATH": "src"}
    out = str(tmp_path)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--smoke", "--mesh-shape", mesh, "--out", out],
        capture_output=True, text=True, cwd="/root/repo", env=env,
        timeout=900)
    assert r.returncode == 0, (r.stdout[-1500:], r.stderr[-1500:])
    arts = [f for f in os.listdir(out) if f.endswith(".json")]
    assert len(arts) == 1
    rec = json.load(open(os.path.join(out, arts[0])))
    assert rec["ok"]
    assert rec["hlo_cost"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("memory", "compute", "traffic")
    assert "argument_bytes" in rec["memory_analysis"]
    if mesh.count(",") == 2 or arch != "mamba2-1.3b":
        # every sharded cell must actually communicate
        assert rec["hlo_cost"]["collective_bytes"] > 0


def test_production_sweep_artifacts_complete():
    """The committed production sweep must cover every assigned cell on
    both meshes (skips per DESIGN.md applied)."""
    d = "/root/repo/experiments/dryrun"
    if not os.path.isdir(d):
        pytest.skip("production sweep not present")
    from repro.configs import registry
    missing = []
    for arch, shape in registry.all_cells():
        for mesh in ("pod", "multipod"):
            p = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(p):
                missing.append((arch, shape, mesh))
                continue
            rec = json.load(open(p))
            assert rec["ok"], (arch, shape, mesh)
    assert not missing, missing
