"""Executable-docs suite: every fenced ``python`` block in ``README.md``
and ``docs/*.md`` is executed, so documentation cannot silently rot when
the API moves (the PR-3 lesson).

Contract for doc authors:

* Blocks fenced as ```` ```python ```` are RUN, top to bottom, one shared
  namespace per file — later blocks may use names bound by earlier ones.
* The namespace is pre-seeded with the **doc prelude**: a tiny priced
  workload every snippet may assume —
  ``np`` (NumPy), ``net`` / ``xs`` (a 3-layer fc ``SimNetwork`` + inputs),
  ``prof`` / ``profile`` (``loihi2_like()``), ``part`` / ``mapping``
  (its minimal partition, strided), and ``evaluator`` (a
  ``SimEvaluator`` over the workload).
* Illustrative non-code (ascii diagrams, shapes, pseudo-signatures) must
  use a plain ``` or ```text fence instead.

Marked ``quick`` so the CI quick path (and ``pytest -m quick``) always
gates the docs.
"""

import pathlib
import re

import pytest

quick = pytest.mark.quick

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
FENCE_RE = re.compile(r"```python[ \t]*\n(.*?)^```", re.S | re.M)


def _prelude() -> dict:
    """The documented namespace every snippet may assume (kept deliberately
    tiny so the whole docs suite runs in seconds)."""
    import numpy as np

    from repro.core.partitioner import SimEvaluator
    from repro.neuromorphic import (fc_network, loihi2_like, make_inputs,
                                    minimal_partition, strided_mapping)

    net = fc_network([32, 24, 16], weight_density=0.6, seed=0)
    xs = make_inputs(32, 0.4, 3, seed=1)
    prof = loihi2_like()
    part = minimal_partition(net, prof)
    mapping = strided_mapping(part, prof)
    evaluator = SimEvaluator(net, xs, prof)
    return dict(np=np, net=net, xs=xs, prof=prof, profile=prof, part=part,
                mapping=mapping, evaluator=evaluator)


def test_doc_files_exist():
    assert (ROOT / "README.md").exists()
    assert len(DOC_FILES) >= 6, [p.name for p in DOC_FILES]


@quick
@pytest.mark.parametrize("path", DOC_FILES, ids=lambda p: p.name)
def test_doc_snippets_execute(path):
    text = path.read_text()
    blocks = FENCE_RE.findall(text)
    if not blocks:
        pytest.skip(f"{path.name}: no fenced python blocks")
    ns = _prelude()
    for i, block in enumerate(blocks):
        code = compile(block, f"{path.name}[python block {i}]", "exec")
        try:
            exec(code, ns)
        except Exception as e:           # pragma: no cover - failure path
            pytest.fail(
                f"{path.name}, python block {i} failed: {type(e).__name__}: "
                f"{e}\n--- block ---\n{block}")
