"""Training loop: convergence, checkpoint/restart determinism, fault
recovery, elastic re-meshing (subprocess with 8 placeholder devices),
straggler detection, schedules and optimizers."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models.layers import single_device_mesh
from repro.train import data as data_lib
from repro.train import optim, schedules
from repro.train.loop import StragglerMonitor, Trainer, TrainerConfig


def _mk_trainer(tmp, steps=12, resume=False, ckpt_every=4, seed=0):
    cfg = registry.get("granite-3-2b").smoke()
    data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=1))
    opt = optim.adamw(schedules.constant(2e-3))
    tcfg = TrainerConfig(steps=steps, log_every=4, ckpt_every=ckpt_every,
                         ckpt_dir=tmp, resume=resume, seed=seed)
    return Trainer(cfg, single_device_mesh(), opt, data, tcfg)


def test_trainer_converges(tmp_path):
    t = _mk_trainer(str(tmp_path), steps=20)
    hist = t.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_checkpoint_resume_bit_identical(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # continuous 12-step run
    t_full = _mk_trainer(d1, steps=12, ckpt_every=100)
    full = t_full.run()
    # interrupted run: 8 steps, then resume to 12
    t1 = _mk_trainer(d2, steps=8, ckpt_every=8)
    t1.run()
    t2 = _mk_trainer(d2, steps=12, resume=True, ckpt_every=100)
    resumed = t2.run()
    a = next(h for h in full if h["step"] == 12)
    b = next(h for h in resumed if h["step"] == 12)
    np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)


def test_fault_recovery(tmp_path):
    t = _mk_trainer(str(tmp_path), steps=12, ckpt_every=4)
    calls = {"n": 0}

    def fault(step):
        if step == 6 and calls["n"] == 0:
            calls["n"] += 1
            raise RuntimeError("injected node failure")
    t.fault_hook = fault
    hist = t.run()
    assert hist[-1]["step"] == 12          # recovered and finished
    assert calls["n"] == 1


@pytest.mark.quick
def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0)
    for i in range(10):
        assert not m.record(i, 0.1)
    assert m.record(10, 1.0)               # 10x slower -> flagged
    assert len(m.events) == 1


_ELASTIC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
import numpy as np
import jax
sys.path.insert(0, "src")
from repro.configs import registry
from repro.launch.mesh import make_mesh
from repro.train import data as data_lib, optim, schedules
from repro.train.loop import Trainer, TrainerConfig

ckpt = sys.argv[1]
phase = sys.argv[2]
mesh = make_mesh((2, 4) if phase == "a" else (4, 2), ("data", "model"))
cfg = registry.get("granite-3-2b").smoke()
data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
    vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1))
opt = optim.adamw(schedules.constant(2e-3))
steps = 6 if phase == "a" else 12
tcfg = TrainerConfig(steps=steps, log_every=2, ckpt_every=6,
                     ckpt_dir=ckpt, resume=(phase == "b"))
t = Trainer(cfg, mesh, opt, data, tcfg)
hist = t.run()
print("RESULT", json.dumps(hist[-1]))
"""


@pytest.mark.slow
def test_elastic_remesh(tmp_path):
    """Train on (2,4) mesh, checkpoint, resume on (4,2): the checkpoint is
    resharded on load and training continues (loss stays finite+decreasing)."""
    ckpt = str(tmp_path / "ck")
    env = {**os.environ, "PYTHONPATH": "src"}
    r1 = subprocess.run([sys.executable, "-c", _ELASTIC, ckpt, "a"],
                        capture_output=True, text=True, cwd="/root/repo",
                        env=env, timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    l1 = json.loads(r1.stdout.split("RESULT", 1)[1])
    r2 = subprocess.run([sys.executable, "-c", _ELASTIC, ckpt, "b"],
                        capture_output=True, text=True, cwd="/root/repo",
                        env=env, timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    l2 = json.loads(r2.stdout.split("RESULT", 1)[1])
    assert l2["step"] == 12 and np.isfinite(l2["loss"])
    assert l2["loss"] < l1["loss"] + 0.5


@pytest.mark.quick
def test_wsd_schedule_shape():
    fn = schedules.wsd(1.0, warmup=10, stable=50, decay=40)
    s = lambda i: float(fn(jnp.int32(i)))
    assert s(0) < 0.2
    assert abs(s(30) - 1.0) < 1e-6          # stable plateau
    assert s(99) < 0.1                      # decayed


def test_adafactor_reduces_loss():
    cfg = registry.get("granite-3-2b").smoke()
    data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, seed=1))
    opt = optim.adafactor(schedules.constant(2e-2))
    tcfg = TrainerConfig(steps=16, log_every=4)
    t = Trainer(cfg, single_device_mesh(), opt, data, tcfg)
    hist = t.run()
    assert hist[-1]["loss"] < hist[0]["loss"]


@pytest.mark.quick
def test_adafactor_state_is_factored():
    cfg = registry.get("granite-3-2b").smoke()
    from repro.models import lm
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adafactor(schedules.constant(1e-2), min_dim_factored=32)
    st = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    n_state = sum(x.size for x in jax.tree.leaves(st))
    # factored second moments: far below Adam's 3x params (m+v+master);
    # small 3-d attention tensors stay unfactored in the smoke config
    assert n_state < 0.5 * n_params
