"""Property suite for the model-zoo frontend (`repro.neuromorphic.frontend`).

The compiler's contract is arithmetic: for every registry arch's smoke
config the compiled layer widths, parameter nnz and per-token MAC totals
must match the ``ModelCfg``/``EncDecCfg`` closed forms, and the compiled
network must inherit the simulator's bit-parity guarantees unchanged —
identical counters across ``compute="dense"``/``"event"`` (reusing the
harness from ``tests/test_compute_backends.py``) and across
``engine="batched"``/``"reference"``.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.models.common import BlockCfg, ModelCfg, MoECfg, SSDCfg
from repro.neuromorphic import (attention_probe, compile_network,
                                excluded_params, loihi2_like, lowering_spec,
                                minimal_partition, simulate)
from test_compute_backends import assert_backends_match

quick = pytest.mark.quick

ARCHS = registry.ARCH_IDS
PARITY_ARCHS = ["gemma2-2b", "mamba2-1.3b", "olmoe-1b-7b", "whisper-base"]


# ------------------------------------------------------ closed-form checks

class TestClosedForm:
    @quick
    @pytest.mark.parametrize("arch_id", ARCHS)
    def test_widths_chain_and_nnz(self, arch_id):
        """Layers chain d_model -> ... -> vocab; every built mask realizes
        exactly its spec's structural nnz."""
        cn = compile_network(arch_id)
        prev = cn.cfg.d_model
        assert cn.net.in_size == cn.cfg.d_model
        for spec, layer in zip(cn.specs, cn.net.layers):
            assert layer.kind == "fc"
            assert spec.fanin == prev == layer.weights.shape[0]
            assert spec.width == layer.weights.shape[1]
            assert layer.w_nnz == spec.nnz, spec.name
            prev = spec.width
        assert prev == cn.cfg.vocab_size          # head is last

    @quick
    @pytest.mark.parametrize("arch_id", ARCHS)
    def test_param_identity(self, arch_id):
        """sum(param nnz) + excluded_params == cfg.param_count(), exactly.
        param_count() is independent arithmetic in repro.models — this ties
        the lowering to the model stack's ground truth."""
        cn = compile_network(arch_id)
        assert (cn.param_layer_nnz() + excluded_params(cn.cfg)
                == cn.cfg.param_count())

    @quick
    @pytest.mark.parametrize("arch_id", ARCHS)
    def test_mac_closed_form(self, arch_id):
        """Simulated per-layer MAC counters == T * spec.macs_per_token for
        the dense-activity token pipeline."""
        cn = compile_network(arch_id, seed=1)
        T = 3
        xs = cn.inputs(T, seed=2)
        _, counters = cn.net.run_batch(xs)
        for spec, c in zip(cn.specs, counters):
            assert int(c.macs.sum()) == T * spec.macs_per_token, spec.name

    @quick
    def test_attention_context_window(self):
        """scores width = heads * min(window, seq_len); the window bounds
        the priced KV context."""
        cfg = registry.get("gemma2-2b").smoke()
        specs, attn = lowering_spec(cfg, seq_len=12)
        widths = {s.name: s.width for s in specs}
        assert widths["b0.attn.scores"] == cfg.n_heads * 8     # window=8
        assert widths["b1.attn.scores"] == cfg.n_heads * 12    # global
        assert attn[0].window == 8 and attn[1].window is None

    @quick
    def test_moe_router_topk_drives_density(self):
        """Only top_k + shared expert blocks (plus router logits) emit
        messages; the down projection's event MACs follow the active set."""
        cfg = registry.get("olmoe-1b-7b").smoke()
        moe = cfg.pattern[0].moe
        cn = compile_network(cfg, seed=4)
        up = next(l for l in cn.net.layers if l.name.endswith("experts_up"))
        f = moe.d_ff
        active = (moe.top_k + moe.n_shared_experts) * 2 * f + moe.n_experts
        assert int(up.msg_gate.sum()) == active
        xs = cn.inputs(2, seed=5)
        _, counters = cn.net.run_batch(xs)
        i_dn = next(i for i, l in enumerate(cn.net.layers)
                    if l.name.endswith("experts_down"))
        per_tok = (moe.top_k + moe.n_shared_experts) * f * cfg.d_model
        assert int(counters[i_dn].macs.sum()) == 2 * per_tok
        # MoE active-param arithmetic reproduced by counters: the inactive
        # experts' down weights are never fetched event-side
        assert int(counters[i_dn].macs.sum()) < 2 * cn.net.layers[i_dn].w_nnz

    @quick
    def test_flash_kernel_matches_oracle_at_lowered_shapes(self):
        """compile(verify_attention=True) runs the real Pallas kernel
        against its oracle at every lowered attention shape."""
        cn = compile_network("gemma2-2b", verify_attention=True)
        assert len(cn.attn_specs) == 4
        out, ref = attention_probe(cn.attn_specs[0], seed=3)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)


# -------------------------------------------------------- parity (reused)

class TestParity:
    @quick
    @pytest.mark.parametrize("arch_id", PARITY_ARCHS)
    def test_compute_backend_parity(self, arch_id):
        cn = compile_network(arch_id)
        xs = cn.inputs(5, seed=3)
        assert_backends_match(cn.net, xs)

    @quick
    @pytest.mark.parametrize("arch_id", PARITY_ARCHS)
    def test_engine_parity(self, arch_id):
        cn = compile_network(arch_id)
        xs = cn.inputs(4, seed=6)
        prof = loihi2_like()
        r_b = simulate(cn.net, xs, prof, engine="batched")
        r_r = simulate(cn.net, xs, prof, engine="reference")
        np.testing.assert_allclose(r_r.outputs, r_b.outputs,
                                   rtol=1e-6, atol=1e-6)
        assert np.array_equal(r_b.times, r_r.times)
        assert np.array_equal(r_b.energies, r_r.energies)

    @quick
    def test_partitionable_on_loihi2(self):
        prof = loihi2_like()
        for arch_id in PARITY_ARCHS:
            cn = compile_network(arch_id)
            part = minimal_partition(cn.net, prof)
            assert part.total_cores <= prof.n_cores

    def test_sigma_delta_recurrent_lowering(self):
        """recurrent_neuron="sd_relu" maps the state stream onto sigma-delta
        messaging; parity guarantees must survive the delta chain."""
        cn = compile_network("mamba2-1.3b", recurrent_neuron="sd_relu")
        state = [l for l in cn.net.layers if l.name.endswith(".state")]
        assert state and all(l.neuron_model == "sd_relu" and l.sends_deltas
                             for l in state)
        assert_backends_match(cn.net, cn.inputs(5, seed=7))

    def test_act_density_programs_message_sparsity(self):
        cn = compile_network("gemma2-2b", act_density=0.25, seed=8)
        xs = cn.inputs(3, seed=9)
        _, counters = cn.net.run_batch(xs)
        for layer, c in zip(cn.net.layers, counters):
            assert int(c.msgs_out.sum()) == \
                3 * int(round(0.25 * layer.n_neurons))
        assert_backends_match(cn.net, xs)


# ------------------------------------------------------ hypothesis sweeps

@given(st.integers(1, 2), st.integers(1, 2), st.sampled_from([4, 8]),
       st.integers(8, 24), st.sampled_from([0, 8, 16]),
       st.integers(0, 99))
@settings(max_examples=8, deadline=None)
def test_property_attn_block_lowering(kv, group, hd, d, d_ff, seed):
    """Arbitrary tiny attention configs: the identity and MAC closed forms
    hold for every (heads, kv_heads, head_dim, d_model, d_ff) draw."""
    cfg = ModelCfg(name="prop", d_model=d, n_heads=kv * group,
                   n_kv_heads=kv, head_dim=hd, vocab_size=32,
                   pattern=(BlockCfg(kind="attn", d_ff=d_ff),), n_repeats=1,
                   param_dtype="float32", compute_dtype="float32")
    cn = compile_network(cfg, seq_len=6, seed=seed)
    assert cn.param_layer_nnz() + excluded_params(cfg) == cfg.param_count()
    xs = cn.inputs(2, seed=seed + 1)
    _, counters = cn.net.run_batch(xs)
    for spec, c in zip(cn.specs, counters):
        assert int(c.macs.sum()) == 2 * spec.macs_per_token


@given(st.integers(1, 3), st.integers(0, 3), st.integers(0, 2),
       st.sampled_from([4, 8]), st.integers(0, 99))
@settings(max_examples=8, deadline=None)
def test_property_moe_lowering(top_k, extra, shared, d_ff, seed):
    """MoE draws: router top-k + shared experts set the active expert
    blocks; identity and event-MAC arithmetic hold for every draw."""
    moe = MoECfg(n_experts=top_k + extra, top_k=top_k, d_ff=d_ff or 4,
                 n_shared_experts=shared)
    cfg = ModelCfg(name="prop-moe", d_model=8, n_heads=2, n_kv_heads=1,
                   head_dim=4, vocab_size=16,
                   pattern=(BlockCfg(kind="attn", moe=moe),), n_repeats=1,
                   param_dtype="float32", compute_dtype="float32")
    cn = compile_network(cfg, seq_len=4, seed=seed)
    assert cn.param_layer_nnz() + excluded_params(cfg) == cfg.param_count()
    xs = cn.inputs(2, seed=seed)
    _, counters = cn.net.run_batch(xs)
    for spec, c in zip(cn.specs, counters):
        assert int(c.macs.sum()) == 2 * spec.macs_per_token


@given(st.sampled_from([(8, 4, 4, 1), (16, 4, 8, 2), (24, 8, 4, 1)]),
       st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_property_ssd_lowering(shape, seed):
    """SSD draws: the state layer wires 2*d_state + 2 taps per neuron and
    the in/out projections carry the exact SSD parameter arithmetic."""
    di, hd, stt, groups = shape
    ssd = SSDCfg(d_inner=di, head_dim=hd, d_state=stt, n_groups=groups,
                 chunk=4)
    cfg = ModelCfg(name="prop-ssd", d_model=8, n_heads=1, n_kv_heads=1,
                   head_dim=1, vocab_size=16,
                   pattern=(BlockCfg(kind="ssd", ssd=ssd),), n_repeats=1,
                   param_dtype="float32", compute_dtype="float32")
    cn = compile_network(cfg, seed=seed)
    assert cn.param_layer_nnz() + excluded_params(cfg) == cfg.param_count()
    state = next(s for s in cn.specs if s.name.endswith(".state"))
    assert state.nnz == di * (2 * stt + 2)
    assert_backends_match(cn.net, cn.inputs(3, seed=seed))
