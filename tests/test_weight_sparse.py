"""2-D (activation x weight tile) sparsity + temporal-tile sigma-delta.

Three layers of guarantees:

* kernel level — the joint-sparsity event matmul (`w_occ=`) matches its
  pure-jnp oracle and the dense contraction, including all-zero-weight-block
  edge cases, and the windowed delta reconstruction decomposes the dense
  time cumsum exactly (quiet windows produce exact-zero rows);
* backend level — dense / event-gather / event-pallas three-way parity over
  an (act_density, weight_density) grid: bit-identical counters, roundoff
  outputs.  Weight masks are *tile-structured* (whole (128, 128) blocks
  dead) so the tile-skip machinery actually engages, mirroring the paper's
  finding that structure is what converts weight sparsity into skipped
  fetches;
* cache level — every weight-derived structure (patch weights, block-CSR
  occupancy, w_mask) is keyed on the identity of the weights array, so
  rebinding ``layer.weights`` after a forward has run (the SparsityProfile
  staleness hazard) rebuilds instead of serving stale caches.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import (event_matmul, event_matmul_pair,
                           weight_block_occupancy, window_reconstruct)
from repro.kernels.event_matmul.ref import event_matmul2_ref
from repro.kernels.sigma_delta.ref import window_reconstruct_ref
from repro.neuromorphic import EventCompute, SimLayer, SimNetwork, fc_network, make_inputs
from repro.neuromorphic.compute import (_fc_weight_blocks, _patch_weights,
                                        _window_reconstruct_np,
                                        derived_from_weights)
from repro.neuromorphic.network import _exact_density_mask

from tests.test_compute_backends import (FLOAT_TOL, assert_backends_match,
                                         conv_stack)

quick = pytest.mark.quick


def tile_structured_weights(K, N, tile_density, rng, bk=128, bn=128):
    """(K, N) weights where whole (bk, bn) tiles are dead with exact tile
    density — the structured weight sparsity the block-CSR format prices."""
    w = rng.normal(0, 1.0 / np.sqrt(K), (K, N)).astype(np.float32)
    kb, nb = -(-K // bk), -(-N // bn)
    tmask = _exact_density_mask((kb, nb), tile_density, rng)
    w *= np.repeat(np.repeat(tmask, bk, axis=0), bn, axis=1)[:K, :N]
    return w


# ================================================================= kernels

class TestWeightSparseKernel:
    @quick
    def test_occupancy_map(self):
        w = np.zeros((256, 384), np.float32)
        w[10, 5] = 1.0          # tile (0, 0)
        w[200, 300] = -2.0      # tile (1, 2)
        occ = np.asarray(weight_block_occupancy(jnp.asarray(w)))
        expect = np.zeros((2, 3), bool)
        expect[0, 0] = expect[1, 2] = True
        assert np.array_equal(occ, expect)

    @quick
    def test_occupancy_pads_ragged_shapes(self):
        w = np.ones((130, 140), np.float32)
        occ = np.asarray(weight_block_occupancy(jnp.asarray(w)))
        assert occ.shape == (2, 2) and occ.all()

    @quick
    @pytest.mark.parametrize("act_d,w_d", [(0.1, 0.1), (0.5, 0.25),
                                           (1.0, 0.5), (0.25, 1.0)])
    def test_joint_matmul_matches_dense(self, act_d, w_d):
        rng = np.random.default_rng(int(act_d * 100 + w_d * 10))
        x = make_inputs(384, act_d, 256, seed=1)
        w = tile_structured_weights(384, 256, w_d, rng)
        occ = weight_block_occupancy(jnp.asarray(w))
        y = np.asarray(event_matmul(jnp.asarray(x), jnp.asarray(w), occ))
        # occupancy derived from w itself: skipped tiles are exact zeros,
        # so the joint kernel equals the dense contraction to roundoff
        np.testing.assert_allclose(y, x @ w, **FLOAT_TOL)
        yr = np.asarray(event_matmul2_ref(
            jnp.asarray(x), jnp.asarray(w), occ, threshold=0.0,
            bm=128, bk=128, bn=128))
        np.testing.assert_allclose(y, yr, **FLOAT_TOL)

    @quick
    def test_all_zero_weight_blocks(self):
        """Edge cases: a dead n-column of tiles, a dead k-row, and a fully
        dead weight matrix must all come out exact (zeros where dead)."""
        rng = np.random.default_rng(0)
        x = make_inputs(256, 0.5, 128, seed=2)
        w = rng.normal(size=(256, 256)).astype(np.float32)
        w[:, 128:] = 0.0         # dead n-column of tiles
        w[128:, :] = 0.0         # dead k-row of tiles
        occ = weight_block_occupancy(jnp.asarray(w))
        assert np.asarray(occ).sum() == 1
        y = np.asarray(event_matmul(jnp.asarray(x), jnp.asarray(w), occ))
        np.testing.assert_allclose(y, x @ w, **FLOAT_TOL)
        assert np.all(y[:, 128:] == 0.0)

        wz = np.zeros((256, 256), np.float32)
        yz = np.asarray(event_matmul(jnp.asarray(x), jnp.asarray(wz),
                                     weight_block_occupancy(jnp.asarray(wz))))
        assert np.all(yz == 0.0)

    @quick
    def test_overclaimed_occupancy_zeroes_tiles(self):
        """w_occ is the contract, not a hint: tiles declared dead are
        dropped even when the weights there are nonzero (the oracle defines
        this; it is what makes the counter matmul prices honest)."""
        rng = np.random.default_rng(3)
        x = rng.normal(size=(128, 256)).astype(np.float32)
        w = rng.normal(size=(256, 128)).astype(np.float32)
        occ = jnp.asarray(np.array([[True], [False]]))
        y = np.asarray(event_matmul(jnp.asarray(x), jnp.asarray(w), occ))
        np.testing.assert_allclose(y, x[:, :128] @ w[:128], **FLOAT_TOL)

    @quick
    def test_pair_counters_exact_under_weight_skipping(self):
        rng = np.random.default_rng(4)
        x = make_inputs(384, 0.2, 256, seed=5)
        m = (x != 0).astype(np.float32)
        w = tile_structured_weights(384, 256, 0.25, rng)
        wm = (w != 0).astype(np.float32)
        occ = weight_block_occupancy(jnp.asarray(w))
        y, macs = event_matmul_pair(jnp.asarray(x), jnp.asarray(m),
                                    jnp.asarray(w), jnp.asarray(wm), occ)
        assert np.array_equal(np.asarray(macs), m @ wm)
        np.testing.assert_allclose(np.asarray(y), x @ w, **FLOAT_TOL)


class TestWindowReconstruct:
    @quick
    @pytest.mark.parametrize("T,window", [(64, 16), (100, 16), (48, 8)])
    def test_decomposition_matches_cumsum(self, T, window):
        rng = np.random.default_rng(T)
        x = rng.normal(size=(T, 40)).astype(np.float32)
        acc = rng.normal(size=(40,)).astype(np.float32)
        x_eff = acc[None] + np.cumsum(x, axis=0)
        for impl in (window_reconstruct,
                     window_reconstruct_ref,
                     lambda a, b, window: _window_reconstruct_np(
                         np.asarray(a), np.asarray(b), window)):
            bases, xwin, new_acc = impl(jnp.asarray(x), jnp.asarray(acc),
                                        window=window)
            rec = (np.repeat(np.asarray(bases), window, axis=0)[:T]
                   + np.asarray(xwin))
            np.testing.assert_allclose(rec, x_eff, rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(np.asarray(new_acc), x_eff[-1],
                                       rtol=1e-5, atol=1e-5)

    @quick
    def test_quiet_windows_are_exact_zeros(self):
        """The temporal tile skip: a window with no deltas contributes
        exact-zero xwin rows (so the downstream event matmul compacts it
        away) in all three implementations."""
        x = make_inputs(32, 0.3, 64, seed=7)
        x[16:48] = 0.0           # two fully quiet 16-step windows
        acc = np.ones(32, np.float32)
        for impl in (window_reconstruct,
                     lambda a, b, window: _window_reconstruct_np(
                         np.asarray(a), np.asarray(b), window)):
            _, xwin, _ = impl(jnp.asarray(x), jnp.asarray(acc), window=16)
            assert np.all(np.asarray(xwin)[16:48] == 0.0)

    @quick
    def test_window_must_be_sublane_aligned(self):
        with pytest.raises(ValueError, match="multiple of 8"):
            window_reconstruct(jnp.zeros((16, 8)), jnp.zeros(8), window=5)


# ======================================================== backend parity

GRID = [(a, wd) for a in (0.05, 0.3) for wd in (0.1, 0.5, 1.0)]


class TestThreeWayParity:
    """dense / event-gather / event-pallas over the (act_d, w_d) grid."""

    def _net(self, w_d, seed=0):
        rng = np.random.default_rng(seed)
        sizes = [256, 256, 128]
        layers = []
        for i in range(len(sizes) - 1):
            w = tile_structured_weights(sizes[i], sizes[i + 1], w_d, rng)
            layers.append(SimLayer(name=f"fc{i}", kind="fc", weights=w))
        return SimNetwork(layers=layers, in_size=sizes[0])

    @quick
    @pytest.mark.parametrize("act_d,w_d", GRID)
    def test_fc_grid_gather(self, act_d, w_d):
        net = self._net(w_d)
        xs = make_inputs(256, act_d, 6, seed=1)
        assert_backends_match(net, xs, event=EventCompute(mode="gather"))

    @quick
    @pytest.mark.parametrize("act_d,w_d", [(0.05, 0.1), (0.3, 0.5)])
    def test_fc_grid_pallas(self, act_d, w_d):
        net = self._net(w_d)
        xs = make_inputs(256, act_d, 6, seed=2)
        assert_backends_match(net, xs, event=EventCompute(mode="pallas"))

    @quick
    def test_fc_dead_weight_matrix(self):
        """All-zero-weight-block edge through the full simulator: a layer
        whose weights are entirely dead must price zero MACs everywhere and
        still agree across all three backends."""
        net = self._net(0.5, seed=3)
        net.layers[1].weights = np.zeros_like(net.layers[1].weights)
        xs = make_inputs(256, 0.3, 4, seed=3)
        for ev in (EventCompute(mode="gather"), EventCompute(mode="pallas")):
            _, cnt = net.run_batch(xs, compute=ev)
            assert np.all(cnt[1].macs == 0)
        assert_backends_match(net, xs, event=EventCompute(mode="gather"))

    @quick
    @pytest.mark.parametrize("w_d", [0.2, 0.6])
    def test_conv_weight_masked(self, w_d):
        net = conv_stack(weight_density=w_d, seed=1)
        xs = make_inputs(net.in_size, 0.25, 6, seed=4)
        assert_backends_match(net, xs, event=EventCompute(mode="gather"))
        assert_backends_match(net, xs, event=EventCompute(mode="pallas"))

    @quick
    def test_conv_dead_input_channel_taps(self):
        """Conv weight rows dead for one input channel: CSR row skipping in
        the gather GEMM must not change the dense-fetch counter (fetches
        count every event once per output channel regardless of w_mask)."""
        net = conv_stack(weight_density=0.9, seed=2)
        net.layers[0].weights = net.layers[0].weights.copy()
        net.layers[0].weights[:, :, 1, :] = 0.0   # channel 1 taps all dead
        xs = make_inputs(net.in_size, 0.4, 5, seed=5)
        assert_backends_match(net, xs, event=EventCompute(mode="gather"))


class TestWindowedDeltaBackend:
    def _sd_net(self, seed=0):
        net = fc_network([64, 48, 32], weight_density=0.5, seed=seed,
                         neuron_model="sd_relu")
        for l in net.layers:
            l.threshold = 0.05
            l.sends_deltas = True
        return net

    @quick
    @pytest.mark.parametrize("event", [
        EventCompute(mode="gather", delta_window=16),
        EventCompute(mode="pallas", delta_window=16),
        EventCompute(mode="gather", delta_mode="cumsum"),
    ], ids=["gather-window", "pallas-window", "gather-cumsum"])
    def test_sd_chain_quiet_stretch(self, event):
        net = self._sd_net()
        xs = make_inputs(64, 0.3, 64, seed=9)
        xs[20:60] = 0.0          # quiet stretch spanning whole windows
        assert_backends_match(net, xs, event=event)

    @quick
    def test_window_path_engages(self):
        """The windowed path must actually run (not silently fall back):
        T > window with a nonzero accumulator through a quiet batch."""
        net = self._sd_net(seed=1)
        ev = EventCompute(mode="gather", delta_window=8)
        xs = make_inputs(64, 0.5, 40, seed=10)
        out_w, _ = net.run_batch(xs, compute=ev)
        out_d, _ = net.run_batch(xs, compute="dense")
        np.testing.assert_allclose(out_w, out_d, **FLOAT_TOL)

    @quick
    def test_conv_sd_chain_windowed(self):
        net = conv_stack(neuron_model="sd_relu", sends_deltas=True,
                         threshold=0.05, seed=3)
        xs = make_inputs(net.in_size, 0.3, 24, seed=11)
        xs[8:16] = 0.0
        assert_backends_match(
            net, xs, event=EventCompute(mode="gather", delta_window=8))


# ========================================================== cache staleness

class TestDerivedWeightCaches:
    @quick
    def test_derived_from_weights_invalidates_on_rebind(self):
        layer = SimLayer(name="l", kind="fc",
                         weights=np.ones((4, 4), np.float32))
        calls = []
        build = lambda l: calls.append(1) or l.weights.sum()
        assert derived_from_weights(layer, "_t", build) == 16.0
        assert derived_from_weights(layer, "_t", build) == 16.0
        assert len(calls) == 1                      # cached while same array
        layer.weights = np.zeros((4, 4), np.float32)
        assert derived_from_weights(layer, "_t", build) == 0.0
        assert len(calls) == 2                      # rebuilt on rebind

    @quick
    def test_patch_weights_staleness_regression(self):
        """The PR-10 satellite bug: run a conv forward (populating the
        patch-weight cache), then rewrite the weights in place as
        SparsityProfile.apply would on a live layer — the next forward must
        use the NEW weights on every backend."""
        rng = np.random.default_rng(0)
        net = conv_stack(seed=5)
        xs = make_inputs(net.in_size, 0.4, 4, seed=6)
        for compute in ("dense", EventCompute(mode="gather"),
                        EventCompute(mode="pallas")):
            net.run_batch(xs, compute=compute)      # warm every cache
        mask = _exact_density_mask(net.layers[0].weights.shape, 0.5, rng)
        net.layers[0].weights = (net.layers[0].weights * mask)

        fresh = conv_stack(seed=5)
        fresh.layers[0].weights = fresh.layers[0].weights * mask
        for compute in ("dense", EventCompute(mode="gather"),
                        EventCompute(mode="pallas")):
            out_stale, cnt_s = net.run_batch(xs, compute=compute)
            out_fresh, cnt_f = fresh.run_batch(xs, compute=compute)
            np.testing.assert_array_equal(out_stale, out_fresh)
            for a, b in zip(cnt_s, cnt_f):
                assert np.array_equal(a.macs, b.macs)

    @quick
    def test_fc_block_structure_invalidates(self):
        layer = SimLayer(name="l", kind="fc",
                         weights=np.ones((256, 256), np.float32))
        wb = _fc_weight_blocks(layer, 128, 128)
        assert wb.occ.all() and wb.live.all()
        w2 = layer.weights.copy()
        w2[:, 128:] = 0.0
        layer.weights = w2
        wb2 = _fc_weight_blocks(layer, 128, 128)
        assert wb2.occ.tolist() == [[True, False], [True, False]]
        assert layer.w_mask.sum() == 256 * 128      # w_mask rebuilt too
        assert layer.w_nnz == 256 * 128
