"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart, WSD schedule, and the framework's full training stack.

  PYTHONPATH=src python examples/train_sparse_lm.py [--steps 300]

The config is a scaled minicpm-family model (~100M params) on the synthetic
Markov LM task; loss drops from ~ln(V) toward the task entropy.  Training
checkpoints land in /tmp/repro_e2e and the run is resumable with --resume.
"""

import argparse

from repro.configs.shapes import sds  # noqa: F401  (import check)
from repro.launch.mesh import make_mesh
from repro.models.common import BlockCfg, ModelCfg
from repro.models.layers import single_device_mesh
from repro.train import data as data_lib
from repro.train import optim, schedules
from repro.train.loop import Trainer, TrainerConfig


def config_100m() -> ModelCfg:
    return ModelCfg(
        name="minicpm-100m",
        d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
        vocab_size=32_768,
        pattern=(BlockCfg(kind="attn", d_ff=1536),), n_repeats=10,
        act_fn="silu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e")
    args = ap.parse_args(argv)

    cfg = config_100m()
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")
    data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=0))
    opt = optim.adamw(schedules.wsd(3e-4, warmup=20,
                                    stable=int(args.steps * 0.7),
                                    decay=int(args.steps * 0.25)))
    tcfg = TrainerConfig(steps=args.steps, log_every=20, ckpt_every=100,
                         ckpt_dir=args.ckpt_dir, resume=args.resume)
    trainer = Trainer(cfg, single_device_mesh(), opt, data, tcfg)
    hist = trainer.run()
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} over "
          f"{args.steps} steps; straggler events: "
          f"{len(trainer.monitor.events)}")


if __name__ == "__main__":
    main()
