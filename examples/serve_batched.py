"""Batched serving demo: prefill + KV-cached decode on a reduced gemma-2
(alternating local/global attention exercises the ring-buffer cache).

  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.models.layers import single_device_mesh
from repro.serve.engine import Engine, ServeConfig


def main():
    cfg = registry.get("gemma2-2b").smoke()
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, single_device_mesh(),
                 ServeConfig(max_new_tokens=24, temperature=0.8, seed=1))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab_size, size=n))
               for n in (12, 12, 12, 12)]
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in out)
    print(f"batch={len(prompts)} generated {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s incl. compile)")
    for i, o in enumerate(out):
        print(f"  request {i}: {o[:12]}...")


if __name__ == "__main__":
    main()
