"""The paper's §VII pipeline end to end: sparsity-aware training (stage 1)
-> floorline-informed partitioning/mapping (stage 2) on the simulated
Loihi-2, reporting the combined runtime/energy improvement.

  PYTHONPATH=src python examples/two_stage_optimization.py
"""

import numpy as np

from benchmarks import stage1_sparsity as s1
from repro.core.partitioner import optimize_partitioning
from repro.neuromorphic.noc import ordered_mapping
from repro.neuromorphic.partition import minimal_partition
from repro.neuromorphic.platform import loihi2_like
from repro.neuromorphic.timestep import simulate
from repro.train.data import SyntheticDenoise


def main():
    print("stage 1: one-shot magnitude pruning + fine-tune sweep (S5)...")
    rows = s1.s5_pruning(quick=True)
    base = next(r for r in rows if r["baseline"])
    ok = [r for r in rows if not r["baseline"]
          and r["mse"] <= base["mse"] * 1.3]
    star = max(ok, key=lambda r: r["sparsity"]) if ok else rows[1]
    print(f"  baseline mse={base['mse']:.4f} time={base['time']:.0f}")
    print(f"  star: sparsity={star['sparsity']} mse={star['mse']:.4f} "
          f"time={star['time']:.0f} "
          f"({base['time'] / star['time']:.2f}x from sparsity)")

    print("stage 2: floorline-informed partitioning of the star network...")
    prof = loihi2_like()
    data = SyntheticDenoise(n_features=64, seq_len=24, global_batch=16,
                            seed=3)
    seq = np.asarray(data.batch(1234)["noisy"][0], np.float32)
    net = s1._deploy_fc([np.asarray(w) for w in star["tuned"]],
                        neuron_model="ssm")
    p0 = minimal_partition(net, prof)
    manual = simulate(net, seq, prof, p0, ordered_mapping(p0, prof))
    res = optimize_partitioning(
        net, prof, lambda pa, ma: simulate(net, seq, prof, pa, ma))
    for h in res.history:
        print(f"  it{h.iteration} [{h.assumption.value:7s}] {h.move:40s} "
              f"t={h.time:8.1f} e={h.energy:9.1f} "
              f"{'ACCEPT' if h.accepted else 'backtrack'}")
    print(f"stage-2 speedup: "
          f"{res.history[0].time / res.report.time_per_step:.2f}x")
    # combined vs the dense manually-placed baseline
    net_b = s1._deploy_fc([np.asarray(w) for w in base["tuned"]],
                          neuron_model="ssm")
    pb = minimal_partition(net_b, prof)
    dense_manual = simulate(net_b, seq, prof, pb, ordered_mapping(pb, prof))
    print(f"combined two-stage vs manual dense baseline: "
          f"{dense_manual.time_per_step / res.report.time_per_step:.2f}x "
          f"time, {dense_manual.energy_per_step / res.report.energy_per_step:.2f}x energy "
          "(paper: up to 3.86x / 3.38x)")


if __name__ == "__main__":
    main()
