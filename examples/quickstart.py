"""Quickstart: the paper's floorline analysis + two-stage optimization on a
simulated Loihi-2-like chip, end to end, in under a minute.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.floorline import WorkloadPoint, fit_floorline
from repro.core.partitioner import optimize_partitioning
from repro.neuromorphic.network import fc_network, make_inputs
from repro.neuromorphic.noc import ordered_mapping
from repro.neuromorphic.partition import minimal_partition
from repro.neuromorphic.platform import loihi2_like
from repro.neuromorphic.timestep import simulate


def main():
    prof = loihi2_like()

    # 1. a sparse 4-layer network on the simulated chip -------------------
    net = fc_network([128, 256, 256, 64], weight_density=0.5, seed=0)
    xs = make_inputs(128, density=0.3, steps=5, seed=1)
    part = minimal_partition(net, prof)
    base = simulate(net, xs, prof, part, ordered_mapping(part, prof))
    print("baseline:", base.summary())

    # 2. place it on the floorline ----------------------------------------
    pts = []
    for dens in (0.8, 0.5, 0.3, 0.1, 0.05):
        r = simulate(net, make_inputs(128, dens, 5, seed=2), prof)
        pts.append(WorkloadPoint(r.max_synops, r.max_acts, r.time_per_step,
                                 r.energy_per_step, label=f"d={dens}"))
    model = fit_floorline(pts)
    p = WorkloadPoint(base.max_synops, base.max_acts, base.time_per_step)
    print(f"floorline: state={model.classify(p).value}; "
          f"move: {model.recommend(p).action}")

    # 3. stage-2: floorline-informed partitioning/mapping ------------------
    res = optimize_partitioning(
        net, prof, lambda pa, ma: simulate(net, xs, prof, pa, ma))
    print(f"optimized: {res.report.summary()}")
    print(f"speedup vs baseline: "
          f"{base.time_per_step / res.report.time_per_step:.2f}x in "
          f"{len(res.history)} iterations "
          f"({sum(h.accepted for h in res.history)} accepted)")
    for h in res.history[:6]:
        print(f"  it{h.iteration} [{h.assumption.value:7s}] {h.move:42s} "
              f"t={h.time:9.1f} {'ACCEPT' if h.accepted else 'backtrack'}")


if __name__ == "__main__":
    main()
