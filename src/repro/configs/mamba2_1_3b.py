"""mamba2-1.3b [ssm] — 48L d_model=2048 attention-free, ssm_state=128.

[arXiv:2405.21060; unverified].  SSD (state-space duality) mixer in chunked
matmul form (MXU-friendly), d_inner=4096, 64 heads x head_dim 64, no MLP
(pure Mamba-2 block).  Logical vocab 50,280 padded to 50,432.
O(1) decode state -> long_500k RUNS for this arch.
"""

from repro.configs.shapes import SUBQUAD_SHAPES
from repro.models.common import BlockCfg, ModelCfg, SSDCfg

ARCH_ID = "mamba2-1.3b"
LOGICAL_VOCAB = 50_280

_SSD = SSDCfg(d_inner=4096, head_dim=64, d_state=128, n_groups=1, chunk=256)

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2048, n_heads=1, n_kv_heads=1, head_dim=1,    # attn-free
    vocab_size=50_432,
    pattern=(BlockCfg(kind="ssd", ssd=_SSD),), n_repeats=48,
    act_fn="silu",
)

SHAPES = SUBQUAD_SHAPES


def smoke() -> ModelCfg:
    ssd = SSDCfg(d_inner=64, head_dim=16, d_state=16, n_groups=1, chunk=8)
    return ModelCfg(
        name="mamba2-smoke", d_model=32, n_heads=1, n_kv_heads=1, head_dim=1,
        vocab_size=256,
        pattern=(BlockCfg(kind="ssd", ssd=ssd),), n_repeats=2,
        act_fn="silu", param_dtype="float32", compute_dtype="float32")
