"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192.

[hf:ibm-granite/granite-3.0-2b-base; hf].  Logical vocab 49,155 padded to
49,408 (multiple of 256) for even TP sharding.  Tied embeddings, SwiGLU.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg

ARCH_ID = "granite-3-2b"
LOGICAL_VOCAB = 49_155

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    vocab_size=49_408,
    pattern=(BlockCfg(kind="attn", d_ff=8192),), n_repeats=40,
    act_fn="silu", rope_theta=10_000.0, tie_embeddings=True,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    return ModelCfg(
        name="granite-smoke", d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab_size=512,
        pattern=(BlockCfg(kind="attn", d_ff=128),), n_repeats=2,
        act_fn="silu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32")
