"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each entry carries the exact assigned config, its shape set (with the
long_500k / decode skips already applied per family), a reduced smoke
config, and the abstract input-spec builder for the dry-run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

from repro.configs import (gemma2_2b, granite_3_2b, kimi_k2_1t_a32b,
                           mamba2_1_3b, minicpm_2b, olmoe_1b_7b,
                           phi3_medium_14b, pixtral_12b, recurrentgemma_2b,
                           whisper_base)
from repro.configs.shapes import (ShapeSpec, encdec_input_specs,
                                  lm_input_specs)
from repro.models.encdec import EncDecCfg


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    config: object                    # ModelCfg | EncDecCfg
    shapes: dict[str, ShapeSpec]
    smoke: Callable[[], object]
    family: str

    @property
    def is_encdec(self) -> bool:
        return isinstance(self.config, EncDecCfg)

    def input_specs(self, shape: ShapeSpec, microbatch: int | None = None,
                    cfg=None):
        fn = encdec_input_specs if self.is_encdec else lm_input_specs
        return fn(cfg if cfg is not None else self.config, shape, microbatch)


_MODULES = {
    "vlm": [pixtral_12b],
    "dense": [minicpm_2b, gemma2_2b, granite_3_2b, phi3_medium_14b],
    "moe": [kimi_k2_1t_a32b, olmoe_1b_7b],
    "audio": [whisper_base],
    "ssm": [mamba2_1_3b],
    "hybrid": [recurrentgemma_2b],
}

REGISTRY: dict[str, ArchEntry] = {}
for family, mods in _MODULES.items():
    for mod in mods:
        REGISTRY[mod.ARCH_ID] = ArchEntry(
            arch_id=mod.ARCH_ID, config=mod.CONFIG, shapes=dict(mod.SHAPES),
            smoke=mod.smoke, family=family)

ARCH_IDS = sorted(REGISTRY)


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    return REGISTRY[arch_id]


def all_cells() -> list[tuple[str, str]]:
    """Every assigned (arch, shape) pair, skips applied."""
    return [(a, s) for a in ARCH_IDS for s in REGISTRY[a].shapes]
