"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8), MoE 384e top-8.

[arXiv:2501.kimi2; unverified].  Trillion-parameter MoE (paper-table entry):
1 dense lead-in layer (d_ff=18432) + 60 MoE layers with 384 routed experts
(per-expert d_ff=2048, top-8) and 1 shared expert, vocab=163,840,
head_dim=112 (64x112=7168; 112 is 16-aligned so row-parallel decode
projections shard evenly).

Scale notes (why this fits 512 x 16GB, itself a floorline-informed,
memory-bound decision — see DESIGN.md):
  * experts shard over the `data` axis (EP=16, intra-pod), expert-FF over
    `model` (TP=16); pods replicate experts and carry pure DP;
  * the training launcher preset uses Adafactor (factored second moments) —
    Adam states for 1.04e12 params would exceed the fleet's HBM.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg, MoECfg

ARCH_ID = "kimi-k2-1t-a32b"

_MOE = MoECfg(n_experts=384, top_k=8, d_ff=2048, n_shared_experts=1,
              capacity_factor=1.25)

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    vocab_size=163_840,
    prefix=(BlockCfg(kind="attn", d_ff=18_432),),
    pattern=(BlockCfg(kind="attn", moe=_MOE),), n_repeats=60,
    act_fn="silu", rope_theta=50_000.0,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    moe = MoECfg(n_experts=8, top_k=2, d_ff=64, n_shared_experts=1,
                 capacity_factor=2.0)
    return ModelCfg(
        name="kimi-smoke", d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab_size=512,
        prefix=(BlockCfg(kind="attn", d_ff=128),),
        pattern=(BlockCfg(kind="attn", moe=moe),), n_repeats=2,
        act_fn="silu", param_dtype="float32", compute_dtype="float32")
