"""pixtral-12b [vlm] — Pixtral-ViT + Mistral-Nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, head_dim=128
[hf:mistralai/Pixtral-12B-2409; unverified].  The ViT patch frontend is a
STUB: input_specs provides 256 precomputed patch embeddings per sample that
are prepended to the token embeddings.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg

ARCH_ID = "pixtral-12b"

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    vocab_size=131_072,
    pattern=(BlockCfg(kind="attn", d_ff=14_336),), n_repeats=40,
    act_fn="silu", rope_theta=1e6,
    frontend="patches", frontend_tokens=256,
)

SHAPES = FULL_ATTN_SHAPES        # full attention: long_500k skipped (DESIGN.md)


def smoke() -> ModelCfg:
    return ModelCfg(
        name="pixtral-smoke", d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab_size=512,
        pattern=(BlockCfg(kind="attn", d_ff=128),), n_repeats=2,
        act_fn="silu", rope_theta=1e6, frontend="patches", frontend_tokens=4,
        param_dtype="float32", compute_dtype="float32")
