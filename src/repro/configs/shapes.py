"""Shape cells and abstract input specs for the dry-run.

Each architecture is paired with its own shape set (from the assignment):

  train_4k     seq_len=4096    global_batch=256   -> lowers train_step
  prefill_32k  seq_len=32768   global_batch=32    -> lowers prefill
  decode_32k   seq_len=32768   global_batch=128   -> lowers serve_step
                                                      (1 token, 32k KV cache)
  long_500k    seq_len=524288  global_batch=1     -> serve_step; run only for
                                                      sub-quadratic archs

``input_specs`` returns ShapeDtypeStruct stand-ins only — no allocation —
matching exactly what launch/dryrun.py lowers.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelCfg


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # "train" | "prefill" | "decode"


TRAIN_4K = ShapeSpec("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeSpec("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeSpec("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeSpec("long_500k", 524_288, 1, "decode")

FULL_ATTN_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K)}
SUBQUAD_SHAPES = {s.name: s
                  for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lm_input_specs(cfg: ModelCfg, shape: ShapeSpec,
                   microbatch: int | None = None) -> dict:
    """Abstract inputs for a decoder-only LM cell.

    train/prefill: {"tokens", "labels"[, "frontend_embeds"]}
    decode:        {"tokens" (B,1), "pos" scalar} (cache specs come from
                   jax.eval_shape(init_cache) in the launcher).
    """
    B = microbatch or shape.global_batch
    S = shape.seq_len
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32),
                "pos": sds((), jnp.int32)}
    specs = {}
    F = cfg.frontend_tokens if cfg.frontend != "none" else 0
    if F:
        specs["frontend_embeds"] = sds((B, F, cfg.d_model), jnp.bfloat16)
    specs["tokens"] = sds((B, S - F), jnp.int32)
    specs["labels"] = sds((B, S), jnp.int32)
    return specs


def encdec_input_specs(cfg, shape: ShapeSpec,
                       microbatch: int | None = None) -> dict:
    B = microbatch or shape.global_batch
    S = shape.seq_len
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32),
                "pos": sds((), jnp.int32)}
    return {"frontend_embeds": sds((B, cfg.n_frames, cfg.d_model),
                                   jnp.bfloat16),
            "tokens": sds((B, S), jnp.int32),
            "labels": sds((B, S), jnp.int32)}
