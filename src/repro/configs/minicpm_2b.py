"""minicpm-2b [dense] — 40L d_model=2304 36H (MHA kv=36) d_ff=5760.

[arXiv:2404.06395; hf].  Llama-like architecture; trained with the WSD
schedule (implemented in repro.train.schedules and used by its launcher
preset).  Logical vocab 122,753 padded to 122,880 (multiple of 256) for even
TP sharding — padded rows are never produced by the tokenizer.
36 heads do not divide the 16-way model axis -> attention runs in
context-parallel (sequence-sharded) mode automatically.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg

ARCH_ID = "minicpm-2b"
LOGICAL_VOCAB = 122_753

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2304, n_heads=36, n_kv_heads=36, head_dim=64,
    vocab_size=122_880,
    pattern=(BlockCfg(kind="attn", d_ff=5760),), n_repeats=40,
    act_fn="silu", rope_theta=10_000.0, tie_embeddings=True,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    return ModelCfg(
        name="minicpm-smoke", d_model=48, n_heads=6, n_kv_heads=6,
        head_dim=8, vocab_size=512,
        pattern=(BlockCfg(kind="attn", d_ff=96),), n_repeats=2,
        act_fn="silu", tie_embeddings=True,
        param_dtype="float32", compute_dtype="float32")
