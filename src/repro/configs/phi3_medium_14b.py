"""phi3-medium-14b [dense] — 40L d_model=5120 40H (GQA kv=10) d_ff=17920.

[arXiv:2404.14219; unverified].  RoPE + SwiGLU + GQA, vocab 100,352.
40 heads do not divide the 16-way model axis -> context-parallel attention.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg

ARCH_ID = "phi3-medium-14b"

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=5120, n_heads=40, n_kv_heads=10, head_dim=128,
    vocab_size=100_352,
    pattern=(BlockCfg(kind="attn", d_ff=17_920),), n_repeats=40,
    act_fn="silu", rope_theta=10_000.0,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    return ModelCfg(
        name="phi3-smoke", d_model=40, n_heads=5, n_kv_heads=5,
        head_dim=8, vocab_size=512,
        pattern=(BlockCfg(kind="attn", d_ff=96),), n_repeats=2,
        act_fn="silu", param_dtype="float32", compute_dtype="float32")
