"""whisper-base [audio] — enc-dec, 6+6L d_model=512 8H d_ff=2048.

[arXiv:2212.04356; unverified].  The conv/log-mel frontend is a STUB:
input_specs provides 1500 precomputed frame embeddings.  Logical vocab
51,865 padded to 52,224.  Shapes use the DECODER sequence; the encoder
context is the fixed 1500 frames.  long_500k skipped (full attention).
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.encdec import EncDecCfg

ARCH_ID = "whisper-base"
LOGICAL_VOCAB = 51_865

CONFIG = EncDecCfg(
    name=ARCH_ID,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    vocab_size=52_224, d_ff=2048,
    n_enc_layers=6, n_dec_layers=6, n_frames=1500,
    act_fn="gelu",
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> EncDecCfg:
    return EncDecCfg(
        name="whisper-smoke", d_model=32, n_heads=4, n_kv_heads=4,
        head_dim=8, vocab_size=256, d_ff=64,
        n_enc_layers=2, n_dec_layers=2, n_frames=12, act_fn="gelu",
        param_dtype="float32", compute_dtype="float32")
