"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

[arXiv:2408.00118; hf].  Local(4096-window)/global alternating attention,
attention-logit softcap 50, final-logit softcap 30, post-block RMSNorms,
tied embeddings, sqrt(d) embedding scaling, GeGLU MLP, head_dim=256.
long_500k is SKIPPED: the global layers attend over the full cache, so the
arch is not sub-quadratic (DESIGN.md §long_500k).
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg

ARCH_ID = "gemma2-2b"

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    vocab_size=256_000,
    pattern=(BlockCfg(kind="attn", d_ff=9216, window=4096, post_norms=True),
             BlockCfg(kind="attn", d_ff=9216, post_norms=True)),
    n_repeats=13,
    act_fn="gelu", rope_theta=10_000.0, tie_embeddings=True, emb_scale=True,
    attn_softcap=50.0, final_softcap=30.0,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    return ModelCfg(
        name="gemma2-smoke", d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, vocab_size=512,
        pattern=(BlockCfg(kind="attn", d_ff=128, window=8, post_norms=True),
                 BlockCfg(kind="attn", d_ff=128, post_norms=True)),
        n_repeats=2, act_fn="gelu", tie_embeddings=True, emb_scale=True,
        attn_softcap=50.0, final_softcap=30.0,
        param_dtype="float32", compute_dtype="float32")
