"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16), MoE 64e top-8.

[arXiv:2409.02060; hf].  64 experts top-8, per-expert d_ff=1024, QK-norm,
vocab 50,304 (already 16-divisible), head_dim=128.
"""

from repro.configs.shapes import FULL_ATTN_SHAPES
from repro.models.common import BlockCfg, ModelCfg, MoECfg

ARCH_ID = "olmoe-1b-7b"

_MOE = MoECfg(n_experts=64, top_k=8, d_ff=1024, capacity_factor=1.25)

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    vocab_size=50_304,
    pattern=(BlockCfg(kind="attn", moe=_MOE),), n_repeats=16,
    act_fn="silu", rope_theta=10_000.0, qk_norm=True,
)

SHAPES = FULL_ATTN_SHAPES


def smoke() -> ModelCfg:
    moe = MoECfg(n_experts=8, top_k=2, d_ff=64, capacity_factor=2.0)
    return ModelCfg(
        name="olmoe-smoke", d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        vocab_size=512,
        pattern=(BlockCfg(kind="attn", moe=moe),), n_repeats=2,
        act_fn="silu", qk_norm=True,
        param_dtype="float32", compute_dtype="float32")
