"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680.

[arXiv:2402.19427; hf].  Griffin-style: RG-LRU recurrent blocks + local
(2048-window) MQA attention in a 2:1 ratio — pattern (rec, rec, attn) x 8
with a (rec, rec) prefix = 26 layers.  head_dim=256, d_rnn=2560,
vocab=256,000, tied + scaled embeddings, GeGLU.
Bounded window + O(1) LRU state -> long_500k RUNS for this arch.
"""

from repro.configs.shapes import SUBQUAD_SHAPES
from repro.models.common import BlockCfg, ModelCfg, RGLRUCfg

ARCH_ID = "recurrentgemma-2b"

_RG = RGLRUCfg(d_rnn=2560, d_conv=4)

_REC = BlockCfg(kind="rglru", d_ff=7680, rglru=_RG)
_ATT = BlockCfg(kind="attn", d_ff=7680, window=2048)

CONFIG = ModelCfg(
    name=ARCH_ID,
    d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    vocab_size=256_000,
    prefix=(_REC, _REC),
    pattern=(_REC, _REC, _ATT), n_repeats=8,
    act_fn="gelu", rope_theta=10_000.0, tie_embeddings=True, emb_scale=True,
)

SHAPES = SUBQUAD_SHAPES


def smoke() -> ModelCfg:
    rg = RGLRUCfg(d_rnn=48, d_conv=4)
    rec = BlockCfg(kind="rglru", d_ff=96, rglru=rg)
    att = BlockCfg(kind="attn", d_ff=96, window=8)
    return ModelCfg(
        name="rg-smoke", d_model=48, n_heads=4, n_kv_heads=1, head_dim=12,
        vocab_size=256, prefix=(rec,), pattern=(rec, rec, att), n_repeats=2,
        act_fn="gelu", tie_embeddings=True, emb_scale=True,
        param_dtype="float32", compute_dtype="float32")
