"""JAX-based neuromorphic accelerator simulator.

Implements the macro-architecture of paper Fig. 1 — neurocores with co-located
synaptic memory / neuron state / compute, connected by a 2-D mesh NoC, running
barrier-synchronized timesteps — with per-platform cost profiles standing in
for the three real accelerators characterized in the paper (AKD1000, Speck,
Loihi 2).  Functional execution and event counters are exact; times/energies
come from the cost model (relative units, matching the paper's normalized
reporting).
"""

from repro.neuromorphic.platform import (ChipProfile, akd1000_like, loihi2_like,
                                         speck_like)
from repro.neuromorphic.compute import (DenseCompute, EventCompute,
                                        LayerCompute, get_compute,
                                        register_compute)
from repro.neuromorphic.frontend import (AttnSpec, CompiledNetwork,
                                         LayerSpec, attention_probe,
                                         compile_network, excluded_params,
                                         lowering_spec)
from repro.neuromorphic.network import (BatchCounters, SimLayer, SimNetwork,
                                        fc_network, make_inputs,
                                        programmed_fc_network)
from repro.neuromorphic.partition import Partition, minimal_partition
from repro.neuromorphic.noc import (Mapping, flow_matrix_population,
                                    flow_structures_rows, incidence_tables,
                                    ordered_mapping, random_mapping,
                                    route_batch,
                                    router_incidence_population,
                                    strided_mapping)
from repro.neuromorphic.timestep import (DevicePopulationPricer,
                                         LayerStageTimes,
                                         PopulationBatch, PricingCache,
                                         SimReport, build_population_batch,
                                         device_pricer, layer_stage_times,
                                         precompute_pricing,
                                         price_candidate,
                                         price_population_device,
                                         price_population_vmap, simulate,
                                         simulate_population)

__all__ = [
    "ChipProfile", "akd1000_like", "loihi2_like", "speck_like",
    "DenseCompute", "EventCompute", "LayerCompute", "get_compute",
    "register_compute",
    "AttnSpec", "CompiledNetwork", "LayerSpec", "attention_probe",
    "compile_network", "excluded_params", "lowering_spec",
    "BatchCounters", "SimLayer", "SimNetwork", "fc_network", "make_inputs",
    "programmed_fc_network",
    "Partition", "minimal_partition",
    "Mapping", "flow_matrix_population", "flow_structures_rows",
    "incidence_tables", "ordered_mapping", "random_mapping",
    "route_batch", "router_incidence_population", "strided_mapping",
    "DevicePopulationPricer", "LayerStageTimes", "PopulationBatch",
    "PricingCache", "SimReport",
    "build_population_batch", "device_pricer", "layer_stage_times",
    "precompute_pricing",
    "price_candidate", "price_population_device", "price_population_vmap",
    "simulate", "simulate_population",
]
