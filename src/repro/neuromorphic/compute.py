"""Pluggable per-layer synaptic-compute backends for the simulator.

The simulator's hot path is the per-layer synaptic forward: consume the
``(T, n_in)`` effective-activation block, produce the ``(T, n_out)``
pre-activations plus the exact MAC / dense-fetch counter maps the cost
model prices.  This module is the seam that makes that forward pluggable —
:class:`SimLayer` (``repro.neuromorphic.network``) delegates every
pre-activation GEMM / conv to a :class:`LayerCompute` backend instead of
hard-coding dense math:

* ``"dense"`` (:class:`DenseCompute`, the default) — the original jnp GEMM /
  ``conv_general_dilated`` path, moved here verbatim.  It is the bit-exact
  reference: every counter and every float op order is unchanged, so the
  engine-parity suites (``tests/test_sim_equivalence.py``) and the pricing
  caches are oblivious to the refactor.
* ``"event"`` (:class:`EventCompute`) — event-driven execution in the
  paper's sense: *"a message is only sent for a nonzero activation, and
  only its weights are fetched"*.  Work scales with the number of events
  instead of the dense shape.  Two kernel modes share one semantic
  contract (``y == x @ w`` exactly where skipped work is genuinely
  event-free, so outputs agree with dense to float roundoff and all
  integer counters agree exactly):

  - ``"pallas"`` — the block-sparse TPU kernel
    (:func:`repro.kernels.event_matmul.ops.event_matmul_pair`): (bm, bk)
    activation tiles with no events skip both the weight-tile DMA and the
    MXU issue.  Interpret mode is auto-selected on CPU backends, so CI
    executes the real kernel body on every push.
  - ``"gather"`` — the column-granular host expression of the same
    event contract: the time axis is cut into ``bm``-step tiles, each
    tile's *union of active input columns* is compacted, and only those
    columns' weight rows are fetched into one dense
    ``(bm, k_tile) @ (k_tile, n_out)`` contraction.  Weight fetches and
    MACs are proportional to activation density (the weight-row fetch is
    amortized over the whole tile) — the hardware-faithful fast path on
    hosts without an MXU.

  ``mode="auto"`` picks ``pallas`` on TPU/GPU backends and ``gather`` on
  CPU: the kernel where block-skipping pays, the density-proportional
  gather where interpret-mode overhead would bury it.

Conv layers run event-driven through an im2col view: a zero-copy
``sliding_window_view`` lowers the SAME-padded strided conv to a
``(T * oh * ow, cin * kh * kw)`` patch matrix, and the patch rows feed the
same event matmul as fc layers — window positions whose receptive field
holds no event fetch no weights, and input features (channel taps) that
are quiet across a tile are never contracted.  The conv win is therefore
largest for *structured* activation sparsity (quiet channels / feature
maps), mirroring the paper's CNN weight-format finding that structure is
what converts sparsity into skipped fetches.

Backends are selected per call (``compute=`` on ``simulate`` /
``precompute_pricing`` / ``SimEvaluator`` / ``SimNetwork.run_batch``) by
name, by instance, or by the process-wide :data:`DEFAULT_COMPUTE`
(``benchmarks/run.py --compute`` flips it globally, mirroring
``--engine``).  ``docs/kernels.md`` documents the kernel contracts;
``tests/test_compute_backends.py`` asserts the dense/event parity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.event_matmul.ops import (event_matmul_pair,
                                            weight_block_occupancy)
from repro.kernels.sigma_delta.ops import window_reconstruct

#: Backend used when a ``compute=`` argument is omitted.  ``"dense"`` is the
#: bit-exact reference; ``benchmarks/run.py --compute`` overrides this
#: module attribute globally, the supported way to flip every simulation in
#: a process (same contract as ``timestep.DEFAULT_ENGINE``).
DEFAULT_COMPUTE = "dense"


class LayerCompute:
    """Backend protocol: the per-layer synaptic forward over a time batch.

    Implementations provide :meth:`fc_forward` and :meth:`conv_forward`;
    both consume the full ``(T, n_in)`` effective-activation block plus the
    0/1 wire-event mask and per-step message counts, and return
    ``(pre, macs, fetches_dense)`` as ``(T, n_out)`` maps (channel-major
    flat for conv, so contiguous core ranges stay meaningful).  The
    single-step engine path is the same contract at ``T == 1``.

    Contract every backend must honor (``tests/test_compute_backends.py``):

    * ``macs`` and ``fetches_dense`` are exact event counts — integer-valued
      and bit-identical across backends (counter sums stay well below the
      2**24 float32 integer horizon);
    * ``pre`` equals the dense reference to float roundoff (backends may
      reassociate the contraction, so parity is rtol <= 1e-6, not bitwise).
    """

    name = "?"

    def fc_forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                   msgs_in: np.ndarray):
        raise NotImplementedError

    def conv_forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                     msgs_in: np.ndarray):
        raise NotImplementedError

    def forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                msgs_in: np.ndarray):
        """Dispatch on the layer kind; the one entry point SimLayer calls."""
        if layer.kind == "fc":
            return self.fc_forward(layer, x_eff, act_mask, msgs_in)
        return self.conv_forward(layer, x_eff, act_mask, msgs_in)

    def delta_forward(self, layer, x_in: np.ndarray, in_acc: np.ndarray,
                      act_mask: np.ndarray, msgs_in: np.ndarray):
        """Forward for a layer whose upstream sends deltas: reconstruct the
        effective activation from the carried accumulator, run the synaptic
        forward, and return ``(pre, macs, fetches_dense, new_acc)``.

        The base implementation is the bit-exact reference: a dense
        cumulative sum over the time axis (sequential ``np.add.accumulate``
        matches the step-major addition order bit for bit when the
        accumulator starts at zero, which :meth:`SimNetwork.init_accs`
        guarantees).  Event backends may override with temporal-tile
        reconstruction; counters never depend on the reconstruction (they
        derive from ``act_mask`` / ``msgs_in`` alone), so overrides change
        ``pre`` only within the float-reassociation tolerance.
        """
        if np.any(in_acc):
            x_eff = in_acc[None, :] + np.cumsum(x_in, axis=0)
        else:
            x_eff = np.cumsum(x_in, axis=0)
        new_acc = x_eff[-1].copy()
        pre, macs, fetches = self.forward(layer, x_eff, act_mask, msgs_in)
        return pre, macs, fetches, new_acc


# ------------------------------------------------------------------- dense

class DenseCompute(LayerCompute):
    """The original dense path: one GEMM / one batched conv per layer.

    Bit-exact reference — identical ops in identical order to the pre-seam
    ``SimLayer`` implementation, so every existing parity suite and every
    pricing cache sees unchanged numbers.
    """

    name = "dense"

    def fc_forward(self, layer, x_eff, act_mask, msgs_in):
        pre = x_eff @ layer.weights
        macs = act_mask @ layer.w_mask
        fetches = np.broadcast_to(msgs_in[:, None].astype(np.float32),
                                  macs.shape)
        return pre, macs, fetches

    def conv_forward(self, layer, x_eff, act_mask, msgs_in):
        """All-timesteps conv: one ``conv_general_dilated`` with batch = T
        per (values, mask, ones) kernel.  Flat boundaries are channel-major
        ((c, h, w)) on BOTH sides so conv->conv stacks keep consistent
        receptive fields."""
        T = x_eff.shape[0]
        h, w = layer.in_hw
        cin = layer.weights.shape[2]
        to_nhwc = lambda a: np.transpose(a.reshape(T, cin, h, w),
                                         (0, 2, 3, 1))
        x4 = jnp.asarray(to_nhwc(x_eff))
        m4 = jnp.asarray(to_nhwc(act_mask))
        wj, wmask, wones = layer._conv_kernels

        conv = lambda lhs, rhs: jax.lax.conv_general_dilated(
            lhs, rhs, window_strides=(layer.stride, layer.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        pre = np.asarray(conv(x4, wj))                 # (T, oh, ow, cout)
        macs = np.asarray(conv(m4, wmask))
        fetches = np.asarray(conv(m4, wones))
        to_flat = lambda a: np.transpose(a, (0, 3, 1, 2)).reshape(T, -1)
        return to_flat(pre), to_flat(macs), to_flat(fetches)


# ------------------------------------------------------------------- event

def derived_from_weights(layer, key: str, builder):
    """Per-layer cache of data derived from ``layer.weights``, keyed on the
    *identity of the weights array* rather than the layer object alone.

    The slot stores ``(weights_ref, value)``; a cached value is served only
    while ``layer.weights`` is still the same array object, so rebinding the
    weights (e.g. :meth:`SparsityProfile.apply` writing masked weights onto
    an already-simulated layer) invalidates every derived structure on the
    next access instead of serving stale caches.  ``builder(layer)`` runs on
    a miss.
    """
    slot = layer.__dict__.get(key)
    if slot is None or slot[0] is not layer.weights:
        slot = (layer.weights, builder(layer))
        layer.__dict__[key] = slot
    return slot[1]


def _patch_weights(layer) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-layer cache of the conv weights in im2col patch order:
    ``(kh, kw, cin, cout) -> (cin * kh * kw, cout)`` values + nnz mask +
    per-feature-row liveness (row has >= 1 nonzero tap), matching
    :func:`_im2col`'s (cin, kh, kw) feature layout.  Cached through
    :func:`derived_from_weights`, so rewriting ``layer.weights`` rebuilds
    the flattening instead of serving stale patch weights."""
    def build(layer):
        w = np.transpose(layer.weights, (2, 0, 1, 3))
        wf = np.ascontiguousarray(w.reshape(-1, layer.weights.shape[3]))
        return (wf, (wf != 0).astype(np.float32), (wf != 0).any(axis=1))
    return derived_from_weights(layer, "_patch_weights", build)


class _WeightBlocks:
    """Block-CSR weight-sparsity structure for one 2-D weight matrix.

    ``live`` (K,) bool marks weight rows with >= 1 nonzero (CSR row
    liveness — an input column whose row is dead fetches nothing);
    ``occ`` / ``occ_j`` are the (Kb, Nb) weight-tile occupancy map as a
    host array (gather mode) and device array (pallas scalar prefetch).
    Computed once per layer from the immutable post-mask weights and cached
    via :func:`derived_from_weights`.
    """

    __slots__ = ("live", "occ", "occ_j", "bk", "bn")

    def __init__(self, w2: np.ndarray, bk: int, bn: int):
        self.bk, self.bn = bk, bn
        nz = w2 != 0
        self.live = nz.any(axis=1)
        K, N = w2.shape
        kb, nb = -(-K // bk), -(-N // bn)
        pad = np.zeros((kb * bk, nb * bn), bool)
        pad[:K, :N] = nz
        self.occ = pad.reshape(kb, bk, nb, bn).any(axis=(1, 3))
        self.occ_j = jnp.asarray(self.occ)

    @classmethod
    def rows_only(cls, live: np.ndarray, bk: int, bn: int) -> "_WeightBlocks":
        """Row-liveness-only structure (conv gather, where the patch-weight
        feature axis is compacted per call so a tile map would not line up)."""
        wb = cls.__new__(cls)
        wb.live, wb.bk, wb.bn = live, bk, bn
        wb.occ = np.ones((1, 1), bool)
        wb.occ_j = None
        return wb


def _fc_weight_blocks(layer, bk: int, bn: int) -> _WeightBlocks:
    return derived_from_weights(
        layer, f"_fc_weight_blocks_{bk}x{bn}",
        lambda l: _WeightBlocks(np.asarray(l.weights), bk, bn))


def _conv_weight_blocks(layer, bk: int, bn: int) -> _WeightBlocks:
    return derived_from_weights(
        layer, f"_conv_weight_blocks_{bk}x{bn}",
        lambda l: _WeightBlocks(_patch_weights(l)[0], bk, bn))


def _im2col(x4: np.ndarray, kh: int, kw: int, stride: int,
            oh: int, ow: int) -> np.ndarray:
    """SAME-padded strided im2col: ``(T, cin, h, w) -> (T * oh * ow,
    cin * kh * kw)`` patch rows in (cin, kh, kw) feature order.

    Padding follows the XLA "SAME" split (``lo = total // 2``), so the
    extracted windows are exactly the receptive fields of the dense
    ``conv_general_dilated`` path.  The window view is zero-copy; the only
    copy is the final contiguous patch matrix (``T*oh*ow*F`` words — a
    ``1/cout`` fraction of the conv's MACs)."""
    T, cin, h, w = x4.shape
    pad_h = max(0, (oh - 1) * stride + kh - h)
    pad_w = max(0, (ow - 1) * stride + kw - w)
    x4 = np.pad(x4, ((0, 0), (0, 0),
                     (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2)))
    win = np.lib.stride_tricks.sliding_window_view(
        x4, (kh, kw), axis=(2, 3))[:, :, ::stride, ::stride]
    # (T, cin, oh, ow, kh, kw) -> (T, oh, ow, cin, kh, kw) -> rows
    return np.ascontiguousarray(
        win.transpose(0, 2, 3, 1, 4, 5).reshape(T * oh * ow, cin * kh * kw))


class EventCompute(LayerCompute):
    """Event-driven synaptic forward: skip all work for event-free inputs.

    ``threshold`` defines an event (``|x| > threshold``; 0.0 — the wire
    semantics of the simulator, where any nonzero message is an event —
    keeps both kernel modes *exactly* equal to the dense contraction, since
    skipped inputs contribute exact zeros).  ``bm``/``bk``/``bn`` are the
    pallas-mode tile sizes; ``mode`` picks the kernel path (see the module
    docstring).  Instances are stateless across calls and shared via
    :func:`get_compute`.
    """

    name = "event"

    def __init__(self, mode: str = "auto", threshold: float = 0.0,
                 bm: int = 128, bk: int = 128, bn: int = 128,
                 gather_bm: int = 32, delta_mode: str = "window",
                 delta_window: int | None = None):
        if mode not in ("auto", "pallas", "gather"):
            raise ValueError(f"unknown event kernel mode {mode!r}")
        if delta_mode not in ("window", "cumsum"):
            raise ValueError(f"unknown delta mode {delta_mode!r}")
        self.mode = mode
        self.threshold = float(threshold)
        self.bm, self.bk, self.bn = bm, bk, bn
        self.gather_bm = int(gather_bm)
        self.delta_mode = delta_mode
        self.delta_window = delta_window

    def _kernel_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        return "gather" if jax.default_backend() == "cpu" else "pallas"

    def _delta_window_size(self) -> int:
        """Temporal tile length for windowed delta reconstruction: match the
        kernel's time-tile (``bm``) in pallas mode so quiet windows line up
        with skippable activation tiles; a sublane-aligned multiple of the
        gather row tile otherwise."""
        if self.delta_window is not None:
            return int(self.delta_window)
        if self._kernel_mode() == "pallas":
            return self.bm
        return max(8, self.gather_bm)

    # ---------------------------------------------------- event contractions
    def _gather_matmul(self, x: np.ndarray, w: np.ndarray,
                       bm: int | None = None,
                       wb: "_WeightBlocks | None" = None) -> np.ndarray:
        """Column-granular event contraction: ``x @ w`` fetching only the
        weight rows of inputs active within each ``bm``-row tile
        (``gather_bm`` timesteps by default; conv passes a larger tile
        since its rows are window positions, not steps).

        For each tile of rows, the union of active columns is compacted
        (``k_tile`` of them) and one dense ``(bm, k_tile) @ (k_tile, n_out)``
        GEMM runs on the compacted operands.  Inactive columns contribute
        exact zeros, so the result equals the dense contraction up to float
        reassociation.  Weight fetches are ``k_tile * n_out`` words per tile
        (amortized over ``bm`` rows) and MACs ``bm * k_tile * n_out`` —
        both proportional to activation density, against the dense path's
        fixed ``n_in``-wide GEMM.

        With ``wb`` (the layer's :class:`_WeightBlocks`), sparsity goes 2-D
        — the CPU expression of the same block-CSR format the pallas kernel
        consumes: active columns whose weight row is all-zero are dropped
        from the union (CSR row skipping — a dead row fetches nothing), and
        output n-blocks whose occupancy is dead for every surviving k-tile
        skip their slice of the GEMM outright.  Both skips are exact: the
        dropped operand entries are exact zeros.
        """
        M, K = x.shape
        bm = max(1, bm or self.gather_bm)
        mask = np.abs(x) > self.threshold
        live = mask.any(axis=0)
        if wb is not None:
            live &= wb.live                  # CSR row skipping
        out = np.zeros((M, w.shape[1]), np.float32)
        for i0 in range(0, M, bm):
            i1 = min(i0 + bm, M)
            cols = np.flatnonzero(mask[i0:i1].any(axis=0) & live)
            if cols.size == 0:
                continue                     # event-free tile: no fetch
            if wb is not None and wb.occ.shape[1] > 1:
                nb_live = wb.occ[np.unique(cols // wb.bk)].any(axis=0)
                if not nb_live.all():        # block-CSR n-tile skipping
                    ncols = np.flatnonzero(
                        np.repeat(nb_live, wb.bn)[:w.shape[1]])
                    out[i0:i1, ncols] = x[i0:i1, cols] @ w[np.ix_(cols, ncols)]
                    continue
            if 2 * cols.size >= K:           # near-dense tile: the compacted
                out[i0:i1] = x[i0:i1] @ w    # GEMM wouldn't repay the copies
            else:
                out[i0:i1] = x[i0:i1, cols] @ w[cols]
        return out

    def _pair(self, x: np.ndarray, m: np.ndarray, w: np.ndarray,
              wm: np.ndarray, wb: "_WeightBlocks | None" = None
              ) -> tuple[np.ndarray, np.ndarray]:
        """(pre, macs) through the selected kernel mode.  ``wb`` threads the
        layer's block-CSR weight structure into both contractions: ``wm`` is
        the nnz mask of ``w``, so the two share one occupancy map and skip
        exactly the same tiles — which is what keeps the counter matmul
        bit-identical to the dense reference under weight skipping."""
        if self._kernel_mode() == "gather":
            return (self._gather_matmul(np.asarray(x, np.float32), w, wb=wb),
                    self._gather_matmul(np.asarray(m, np.float32), wm, wb=wb))
        y, macs = event_matmul_pair(
            jnp.asarray(x, jnp.float32), jnp.asarray(m, jnp.float32),
            jnp.asarray(w), jnp.asarray(wm),
            wb.occ_j if wb is not None else None, threshold=self.threshold,
            bm=self.bm, bk=self.bk, bn=self.bn)
        return np.asarray(y), np.asarray(macs)

    # ------------------------------------------------------------ layer kinds
    def fc_forward(self, layer, x_eff, act_mask, msgs_in):
        wb = _fc_weight_blocks(layer, self.bk, self.bn)
        pre, macs = self._pair(x_eff, act_mask, layer.weights, layer.w_mask,
                               wb)
        fetches = np.broadcast_to(msgs_in[:, None].astype(np.float32),
                                  macs.shape)
        return pre, macs, fetches

    def _conv_gather(self, a4: np.ndarray, wf: np.ndarray, layer,
                     wlive: np.ndarray | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Channel-compacted gather-mode conv: input channels with no event
        anywhere in the time batch are dropped *before* the im2col copy, so
        both the patch matrix and the weight fetch scale with structured
        (channel-level) activation density; the per-tile column union then
        harvests the remaining fine-grained sparsity.  Returns the
        ``(T * oh * ow, cout)`` result and the per-window event row sums
        (dropped channels are exact zeros, so both are unchanged).

        ``wlive`` (cin * kh * kw,) feeds CSR row skipping *inside the GEMM
        only*: feature taps whose weight row is all-zero fetch nothing, but
        the event row sums are taken before any weight-based dropping —
        dense fetch counts every event in the window regardless of the
        weight mask, and that counter contract must not move."""
        kh, kw = layer.weights.shape[:2]
        cin = a4.shape[1]
        oh, ow = layer.out_hw
        active_c = np.abs(a4).max(axis=(0, 2, 3)) > self.threshold
        k_c = int(active_c.sum())
        if k_c == 0:
            T = a4.shape[0]
            z = np.zeros((T * oh * ow, wf.shape[1]), np.float32)
            return z, np.zeros(T * oh * ow, np.float32)
        if 2 * k_c < cin:
            ch = np.flatnonzero(active_c)
            a4 = a4[:, ch]
            wf = np.ascontiguousarray(
                wf.reshape(cin, kh * kw, -1)[ch].reshape(k_c * kh * kw, -1))
            if wlive is not None:
                wlive = np.ascontiguousarray(
                    wlive.reshape(cin, kh * kw)[ch].reshape(-1))
        pat = _im2col(a4, kh, kw, layer.stride, oh, ow)
        rows = pat.sum(axis=1, dtype=np.float32)
        wb = None
        if wlive is not None and not wlive.all():
            wb = _WeightBlocks.rows_only(wlive, self.bk, self.bn)
        # conv rows are window positions (oh*ow of them per step): tile a
        # whole timestep's windows together so the per-tile overhead stays
        # per-step, like the fc path
        return self._gather_matmul(pat, wf, bm=max(self.gather_bm,
                                                   oh * ow), wb=wb), rows

    def conv_forward(self, layer, x_eff, act_mask, msgs_in):
        """Event-driven conv through the im2col view: each output position's
        receptive field is one patch row; windows without events fetch no
        weights.  Counter semantics match the dense conv bit for bit:
        ``macs`` sums the weight-nnz mask over each window's events and
        ``fetches_dense`` counts every event in the window once per output
        channel."""
        T = x_eff.shape[0]
        h, w = layer.in_hw
        cin = layer.weights.shape[2]
        kh, kw = layer.weights.shape[:2]
        oh, ow = layer.out_hw
        cout = layer.weights.shape[3]
        wf, wfm, wlive = _patch_weights(layer)
        x4 = np.asarray(x_eff, np.float32).reshape(T, cin, h, w)
        m4 = np.asarray(act_mask, np.float32).reshape(T, cin, h, w)
        if self._kernel_mode() == "gather":
            pre, _ = self._conv_gather(x4, wf, layer, wlive)
            macs, fetch_rows = self._conv_gather(m4, wfm, layer, wlive)
        else:
            xpat = _im2col(x4, kh, kw, layer.stride, oh, ow)
            mpat = _im2col(m4, kh, kw, layer.stride, oh, ow)
            pre, macs = self._pair(xpat, mpat, wf, wfm,
                                   _conv_weight_blocks(layer, self.bk,
                                                       self.bn))
            fetch_rows = mpat.sum(axis=1, dtype=np.float32)
        fetches = np.broadcast_to(fetch_rows[:, None], (T * oh * ow, cout))
        # (T*oh*ow, cout) -> channel-major (T, cout * oh * ow) flat maps
        to_flat = lambda a: np.transpose(
            a.reshape(T, oh, ow, cout), (0, 3, 1, 2)).reshape(T, -1)
        return to_flat(pre), to_flat(macs), to_flat(fetches)

    # --------------------------------------------- temporal-tile delta path
    def delta_forward(self, layer, x_in, in_acc, act_mask, msgs_in):
        """Windowed delta reconstruction: instead of materializing the full
        dense ``acc + cumsum(x_in)`` (which is dense in time even when the
        delta stream is almost silent), split time into ``window``-step
        tiles and exploit linearity of the synaptic forward:

            x_eff = repeat(bases, window) + xwin
            pre   = forward(bases) repeated + forward(xwin)

        ``xwin`` (the within-window cumsums) is exactly zero throughout
        quiet windows, so its event matmul skips them wholesale — temporal
        tile sparsity; the per-window base vectors pay one small dense
        contraction (``T / window`` rows).  Counters are computed on the
        unchanged ``act_mask`` / ``msgs_in``, hence bit-identical to the
        reference; ``pre`` differs only by float reassociation.
        """
        T = x_in.shape[0]
        window = self._delta_window_size()
        if self.delta_mode != "window" or T <= window:
            return super().delta_forward(layer, x_in, in_acc, act_mask,
                                         msgs_in)
        if self._kernel_mode() == "pallas":
            bases, xwin, new_acc = window_reconstruct(
                jnp.asarray(x_in, jnp.float32),
                jnp.asarray(in_acc, jnp.float32), window=window)
            bases, xwin = np.asarray(bases), np.asarray(xwin)
            new_acc = np.asarray(new_acc)
        else:
            bases, xwin, new_acc = _window_reconstruct_np(x_in, in_acc,
                                                          window)
        pre_w, macs, fetches = self.forward(layer, xwin, act_mask, msgs_in)
        # value-only pass over the base rows: a zero event mask yields zero
        # counters, which are discarded — only the contraction is kept
        zmask = np.zeros_like(bases)
        zmsgs = np.zeros(bases.shape[0], np.float32)
        pre_b, _, _ = self.forward(layer, bases, zmask, zmsgs)
        pre = pre_w + np.repeat(pre_b, window, axis=0)[:T]
        return pre, macs, fetches, new_acc


def _window_reconstruct_np(x_in: np.ndarray, acc: np.ndarray, window: int
                           ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Host fast path of :func:`repro.kernels.sigma_delta.ops.
    window_reconstruct` (same decomposition, same float op order per
    window): quiet windows are skipped outright — no cumsum rows are ever
    computed for them — which is where the gather backend's win over the
    dense time cumsum comes from."""
    T, n = x_in.shape
    pt = (-T) % window
    xp = x_in if pt == 0 else np.concatenate(
        [x_in, np.zeros((pt, n), np.float32)])
    xw = xp.reshape(-1, window, n)
    ws = xw.sum(axis=1)                        # per-window totals
    csum = np.cumsum(ws, axis=0)
    bases = np.empty_like(csum)
    bases[0] = acc
    bases[1:] = acc[None, :] + csum[:-1]
    new_acc = acc + csum[-1]
    live = np.flatnonzero((xw != 0).any(axis=(1, 2)))
    xwin = np.zeros_like(xw)
    if live.size:
        xwin[live] = np.cumsum(xw[live], axis=1)
    return bases, xwin.reshape(-1, n)[:T], new_acc


# ---------------------------------------------------------------- registry

_REGISTRY: dict[str, type[LayerCompute]] = {
    "dense": DenseCompute,
    "event": EventCompute,
}
_INSTANCES: dict[str, LayerCompute] = {}


def register_compute(name: str, factory: type[LayerCompute]) -> None:
    """Register a backend class under ``name`` (overwrites; the instance
    cache is invalidated so the next :func:`get_compute` rebuilds)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def get_compute(spec: "str | LayerCompute | None" = None) -> LayerCompute:
    """Resolve a ``compute=`` argument: None -> :data:`DEFAULT_COMPUTE`,
    a registered name -> its (shared) instance, an instance -> itself."""
    if spec is None:
        spec = DEFAULT_COMPUTE
    if isinstance(spec, LayerCompute):
        return spec
    if spec not in _REGISTRY:
        raise ValueError(f"unknown compute backend {spec!r}; registered: "
                         f"{sorted(_REGISTRY)}")
    if spec not in _INSTANCES:
        _INSTANCES[spec] = _REGISTRY[spec]()
    return _INSTANCES[spec]
