"""Pluggable per-layer synaptic-compute backends for the simulator.

The simulator's hot path is the per-layer synaptic forward: consume the
``(T, n_in)`` effective-activation block, produce the ``(T, n_out)``
pre-activations plus the exact MAC / dense-fetch counter maps the cost
model prices.  This module is the seam that makes that forward pluggable —
:class:`SimLayer` (``repro.neuromorphic.network``) delegates every
pre-activation GEMM / conv to a :class:`LayerCompute` backend instead of
hard-coding dense math:

* ``"dense"`` (:class:`DenseCompute`, the default) — the original jnp GEMM /
  ``conv_general_dilated`` path, moved here verbatim.  It is the bit-exact
  reference: every counter and every float op order is unchanged, so the
  engine-parity suites (``tests/test_sim_equivalence.py``) and the pricing
  caches are oblivious to the refactor.
* ``"event"`` (:class:`EventCompute`) — event-driven execution in the
  paper's sense: *"a message is only sent for a nonzero activation, and
  only its weights are fetched"*.  Work scales with the number of events
  instead of the dense shape.  Two kernel modes share one semantic
  contract (``y == x @ w`` exactly where skipped work is genuinely
  event-free, so outputs agree with dense to float roundoff and all
  integer counters agree exactly):

  - ``"pallas"`` — the block-sparse TPU kernel
    (:func:`repro.kernels.event_matmul.ops.event_matmul_pair`): (bm, bk)
    activation tiles with no events skip both the weight-tile DMA and the
    MXU issue.  Interpret mode is auto-selected on CPU backends, so CI
    executes the real kernel body on every push.
  - ``"gather"`` — the column-granular host expression of the same
    event contract: the time axis is cut into ``bm``-step tiles, each
    tile's *union of active input columns* is compacted, and only those
    columns' weight rows are fetched into one dense
    ``(bm, k_tile) @ (k_tile, n_out)`` contraction.  Weight fetches and
    MACs are proportional to activation density (the weight-row fetch is
    amortized over the whole tile) — the hardware-faithful fast path on
    hosts without an MXU.

  ``mode="auto"`` picks ``pallas`` on TPU/GPU backends and ``gather`` on
  CPU: the kernel where block-skipping pays, the density-proportional
  gather where interpret-mode overhead would bury it.

Conv layers run event-driven through an im2col view: a zero-copy
``sliding_window_view`` lowers the SAME-padded strided conv to a
``(T * oh * ow, cin * kh * kw)`` patch matrix, and the patch rows feed the
same event matmul as fc layers — window positions whose receptive field
holds no event fetch no weights, and input features (channel taps) that
are quiet across a tile are never contracted.  The conv win is therefore
largest for *structured* activation sparsity (quiet channels / feature
maps), mirroring the paper's CNN weight-format finding that structure is
what converts sparsity into skipped fetches.

Backends are selected per call (``compute=`` on ``simulate`` /
``precompute_pricing`` / ``SimEvaluator`` / ``SimNetwork.run_batch``) by
name, by instance, or by the process-wide :data:`DEFAULT_COMPUTE`
(``benchmarks/run.py --compute`` flips it globally, mirroring
``--engine``).  ``docs/kernels.md`` documents the kernel contracts;
``tests/test_compute_backends.py`` asserts the dense/event parity.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.event_matmul.ops import event_matmul_pair

#: Backend used when a ``compute=`` argument is omitted.  ``"dense"`` is the
#: bit-exact reference; ``benchmarks/run.py --compute`` overrides this
#: module attribute globally, the supported way to flip every simulation in
#: a process (same contract as ``timestep.DEFAULT_ENGINE``).
DEFAULT_COMPUTE = "dense"


class LayerCompute:
    """Backend protocol: the per-layer synaptic forward over a time batch.

    Implementations provide :meth:`fc_forward` and :meth:`conv_forward`;
    both consume the full ``(T, n_in)`` effective-activation block plus the
    0/1 wire-event mask and per-step message counts, and return
    ``(pre, macs, fetches_dense)`` as ``(T, n_out)`` maps (channel-major
    flat for conv, so contiguous core ranges stay meaningful).  The
    single-step engine path is the same contract at ``T == 1``.

    Contract every backend must honor (``tests/test_compute_backends.py``):

    * ``macs`` and ``fetches_dense`` are exact event counts — integer-valued
      and bit-identical across backends (counter sums stay well below the
      2**24 float32 integer horizon);
    * ``pre`` equals the dense reference to float roundoff (backends may
      reassociate the contraction, so parity is rtol <= 1e-6, not bitwise).
    """

    name = "?"

    def fc_forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                   msgs_in: np.ndarray):
        raise NotImplementedError

    def conv_forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                     msgs_in: np.ndarray):
        raise NotImplementedError

    def forward(self, layer, x_eff: np.ndarray, act_mask: np.ndarray,
                msgs_in: np.ndarray):
        """Dispatch on the layer kind; the one entry point SimLayer calls."""
        if layer.kind == "fc":
            return self.fc_forward(layer, x_eff, act_mask, msgs_in)
        return self.conv_forward(layer, x_eff, act_mask, msgs_in)


# ------------------------------------------------------------------- dense

class DenseCompute(LayerCompute):
    """The original dense path: one GEMM / one batched conv per layer.

    Bit-exact reference — identical ops in identical order to the pre-seam
    ``SimLayer`` implementation, so every existing parity suite and every
    pricing cache sees unchanged numbers.
    """

    name = "dense"

    def fc_forward(self, layer, x_eff, act_mask, msgs_in):
        pre = x_eff @ layer.weights
        macs = act_mask @ layer.w_mask
        fetches = np.broadcast_to(msgs_in[:, None].astype(np.float32),
                                  macs.shape)
        return pre, macs, fetches

    def conv_forward(self, layer, x_eff, act_mask, msgs_in):
        """All-timesteps conv: one ``conv_general_dilated`` with batch = T
        per (values, mask, ones) kernel.  Flat boundaries are channel-major
        ((c, h, w)) on BOTH sides so conv->conv stacks keep consistent
        receptive fields."""
        T = x_eff.shape[0]
        h, w = layer.in_hw
        cin = layer.weights.shape[2]
        to_nhwc = lambda a: np.transpose(a.reshape(T, cin, h, w),
                                         (0, 2, 3, 1))
        x4 = jnp.asarray(to_nhwc(x_eff))
        m4 = jnp.asarray(to_nhwc(act_mask))
        wj, wmask, wones = layer._conv_kernels

        conv = lambda lhs, rhs: jax.lax.conv_general_dilated(
            lhs, rhs, window_strides=(layer.stride, layer.stride),
            padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        pre = np.asarray(conv(x4, wj))                 # (T, oh, ow, cout)
        macs = np.asarray(conv(m4, wmask))
        fetches = np.asarray(conv(m4, wones))
        to_flat = lambda a: np.transpose(a, (0, 3, 1, 2)).reshape(T, -1)
        return to_flat(pre), to_flat(macs), to_flat(fetches)


# ------------------------------------------------------------------- event

def _patch_weights(layer) -> tuple[np.ndarray, np.ndarray]:
    """Per-layer cache of the conv weights in im2col patch order:
    ``(kh, kw, cin, cout) -> (cin * kh * kw, cout)`` values + nnz mask,
    matching :func:`_im2col`'s (cin, kh, kw) feature layout.  Weights are
    immutable after construction, so the flattening is computed once and
    stashed on the layer."""
    cached = layer.__dict__.get("_patch_weights")
    if cached is None:
        w = np.transpose(layer.weights, (2, 0, 1, 3))
        wf = np.ascontiguousarray(w.reshape(-1, layer.weights.shape[3]))
        cached = (wf, (wf != 0).astype(np.float32))
        layer.__dict__["_patch_weights"] = cached
    return cached


def _im2col(x4: np.ndarray, kh: int, kw: int, stride: int,
            oh: int, ow: int) -> np.ndarray:
    """SAME-padded strided im2col: ``(T, cin, h, w) -> (T * oh * ow,
    cin * kh * kw)`` patch rows in (cin, kh, kw) feature order.

    Padding follows the XLA "SAME" split (``lo = total // 2``), so the
    extracted windows are exactly the receptive fields of the dense
    ``conv_general_dilated`` path.  The window view is zero-copy; the only
    copy is the final contiguous patch matrix (``T*oh*ow*F`` words — a
    ``1/cout`` fraction of the conv's MACs)."""
    T, cin, h, w = x4.shape
    pad_h = max(0, (oh - 1) * stride + kh - h)
    pad_w = max(0, (ow - 1) * stride + kw - w)
    x4 = np.pad(x4, ((0, 0), (0, 0),
                     (pad_h // 2, pad_h - pad_h // 2),
                     (pad_w // 2, pad_w - pad_w // 2)))
    win = np.lib.stride_tricks.sliding_window_view(
        x4, (kh, kw), axis=(2, 3))[:, :, ::stride, ::stride]
    # (T, cin, oh, ow, kh, kw) -> (T, oh, ow, cin, kh, kw) -> rows
    return np.ascontiguousarray(
        win.transpose(0, 2, 3, 1, 4, 5).reshape(T * oh * ow, cin * kh * kw))


class EventCompute(LayerCompute):
    """Event-driven synaptic forward: skip all work for event-free inputs.

    ``threshold`` defines an event (``|x| > threshold``; 0.0 — the wire
    semantics of the simulator, where any nonzero message is an event —
    keeps both kernel modes *exactly* equal to the dense contraction, since
    skipped inputs contribute exact zeros).  ``bm``/``bk``/``bn`` are the
    pallas-mode tile sizes; ``mode`` picks the kernel path (see the module
    docstring).  Instances are stateless across calls and shared via
    :func:`get_compute`.
    """

    name = "event"

    def __init__(self, mode: str = "auto", threshold: float = 0.0,
                 bm: int = 128, bk: int = 128, bn: int = 128,
                 gather_bm: int = 32):
        if mode not in ("auto", "pallas", "gather"):
            raise ValueError(f"unknown event kernel mode {mode!r}")
        self.mode = mode
        self.threshold = float(threshold)
        self.bm, self.bk, self.bn = bm, bk, bn
        self.gather_bm = int(gather_bm)

    def _kernel_mode(self) -> str:
        if self.mode != "auto":
            return self.mode
        return "gather" if jax.default_backend() == "cpu" else "pallas"

    # ---------------------------------------------------- event contractions
    def _gather_matmul(self, x: np.ndarray, w: np.ndarray,
                       bm: int | None = None) -> np.ndarray:
        """Column-granular event contraction: ``x @ w`` fetching only the
        weight rows of inputs active within each ``bm``-row tile
        (``gather_bm`` timesteps by default; conv passes a larger tile
        since its rows are window positions, not steps).

        For each tile of rows, the union of active columns is compacted
        (``k_tile`` of them) and one dense ``(bm, k_tile) @ (k_tile, n_out)``
        GEMM runs on the compacted operands.  Inactive columns contribute
        exact zeros, so the result equals the dense contraction up to float
        reassociation.  Weight fetches are ``k_tile * n_out`` words per tile
        (amortized over ``bm`` rows) and MACs ``bm * k_tile * n_out`` —
        both proportional to activation density, against the dense path's
        fixed ``n_in``-wide GEMM.
        """
        M, K = x.shape
        bm = max(1, bm or self.gather_bm)
        mask = np.abs(x) > self.threshold
        out = np.zeros((M, w.shape[1]), np.float32)
        for i0 in range(0, M, bm):
            i1 = min(i0 + bm, M)
            cols = np.flatnonzero(mask[i0:i1].any(axis=0))
            if cols.size == 0:
                continue                     # event-free tile: no fetch
            if 2 * cols.size >= K:           # near-dense tile: the compacted
                out[i0:i1] = x[i0:i1] @ w    # GEMM wouldn't repay the copies
            else:
                out[i0:i1] = x[i0:i1, cols] @ w[cols]
        return out

    def _pair(self, x: np.ndarray, m: np.ndarray, w: np.ndarray,
              wm: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """(pre, macs) through the selected kernel mode."""
        if self._kernel_mode() == "gather":
            return (self._gather_matmul(np.asarray(x, np.float32), w),
                    self._gather_matmul(np.asarray(m, np.float32), wm))
        y, macs = event_matmul_pair(
            jnp.asarray(x, jnp.float32), jnp.asarray(m, jnp.float32),
            jnp.asarray(w), jnp.asarray(wm), threshold=self.threshold,
            bm=self.bm, bk=self.bk, bn=self.bn)
        return np.asarray(y), np.asarray(macs)

    # ------------------------------------------------------------ layer kinds
    def fc_forward(self, layer, x_eff, act_mask, msgs_in):
        pre, macs = self._pair(x_eff, act_mask, layer.weights, layer.w_mask)
        fetches = np.broadcast_to(msgs_in[:, None].astype(np.float32),
                                  macs.shape)
        return pre, macs, fetches

    def _conv_gather(self, a4: np.ndarray, wf: np.ndarray, layer
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Channel-compacted gather-mode conv: input channels with no event
        anywhere in the time batch are dropped *before* the im2col copy, so
        both the patch matrix and the weight fetch scale with structured
        (channel-level) activation density; the per-tile column union then
        harvests the remaining fine-grained sparsity.  Returns the
        ``(T * oh * ow, cout)`` result and the per-window event row sums
        (dropped channels are exact zeros, so both are unchanged)."""
        kh, kw = layer.weights.shape[:2]
        cin = a4.shape[1]
        oh, ow = layer.out_hw
        active_c = np.abs(a4).max(axis=(0, 2, 3)) > self.threshold
        k_c = int(active_c.sum())
        if k_c == 0:
            T = a4.shape[0]
            z = np.zeros((T * oh * ow, wf.shape[1]), np.float32)
            return z, np.zeros(T * oh * ow, np.float32)
        if 2 * k_c < cin:
            ch = np.flatnonzero(active_c)
            a4 = a4[:, ch]
            wf = np.ascontiguousarray(
                wf.reshape(cin, kh * kw, -1)[ch].reshape(k_c * kh * kw, -1))
        pat = _im2col(a4, kh, kw, layer.stride, oh, ow)
        rows = pat.sum(axis=1, dtype=np.float32)
        # conv rows are window positions (oh*ow of them per step): tile a
        # whole timestep's windows together so the per-tile overhead stays
        # per-step, like the fc path
        return self._gather_matmul(pat, wf, bm=max(self.gather_bm,
                                                   oh * ow)), rows

    def conv_forward(self, layer, x_eff, act_mask, msgs_in):
        """Event-driven conv through the im2col view: each output position's
        receptive field is one patch row; windows without events fetch no
        weights.  Counter semantics match the dense conv bit for bit:
        ``macs`` sums the weight-nnz mask over each window's events and
        ``fetches_dense`` counts every event in the window once per output
        channel."""
        T = x_eff.shape[0]
        h, w = layer.in_hw
        cin = layer.weights.shape[2]
        kh, kw = layer.weights.shape[:2]
        oh, ow = layer.out_hw
        cout = layer.weights.shape[3]
        wf, wfm = _patch_weights(layer)
        x4 = np.asarray(x_eff, np.float32).reshape(T, cin, h, w)
        m4 = np.asarray(act_mask, np.float32).reshape(T, cin, h, w)
        if self._kernel_mode() == "gather":
            pre, _ = self._conv_gather(x4, wf, layer)
            macs, fetch_rows = self._conv_gather(m4, wfm, layer)
        else:
            xpat = _im2col(x4, kh, kw, layer.stride, oh, ow)
            mpat = _im2col(m4, kh, kw, layer.stride, oh, ow)
            pre, macs = self._pair(xpat, mpat, wf, wfm)
            fetch_rows = mpat.sum(axis=1, dtype=np.float32)
        fetches = np.broadcast_to(fetch_rows[:, None], (T * oh * ow, cout))
        # (T*oh*ow, cout) -> channel-major (T, cout * oh * ow) flat maps
        to_flat = lambda a: np.transpose(
            a.reshape(T, oh, ow, cout), (0, 3, 1, 2)).reshape(T, -1)
        return to_flat(pre), to_flat(macs), to_flat(fetches)


# ---------------------------------------------------------------- registry

_REGISTRY: dict[str, type[LayerCompute]] = {
    "dense": DenseCompute,
    "event": EventCompute,
}
_INSTANCES: dict[str, LayerCompute] = {}


def register_compute(name: str, factory: type[LayerCompute]) -> None:
    """Register a backend class under ``name`` (overwrites; the instance
    cache is invalidated so the next :func:`get_compute` rebuilds)."""
    _REGISTRY[name] = factory
    _INSTANCES.pop(name, None)


def get_compute(spec: "str | LayerCompute | None" = None) -> LayerCompute:
    """Resolve a ``compute=`` argument: None -> :data:`DEFAULT_COMPUTE`,
    a registered name -> its (shared) instance, an instance -> itself."""
    if spec is None:
        spec = DEFAULT_COMPUTE
    if isinstance(spec, LayerCompute):
        return spec
    if spec not in _REGISTRY:
        raise ValueError(f"unknown compute backend {spec!r}; registered: "
                         f"{sorted(_REGISTRY)}")
    if spec not in _INSTANCES:
        _INSTANCES[spec] = _REGISTRY[spec]()
    return _INSTANCES[spec]
