"""Network-on-chip model: router-shared core placement + XY-routed congestion.

Mirrors the paper's §V-F traffic mechanism: several neurocores share each NoC
router tile (as on Loihi), so an *ordered* mapping that places a layer's
(equally busy) cores on consecutive slots concentrates its injection load on
a few routers — "the highest output neurocores ... are physically close to
one another and create congestion on their shared NoC routers".  A *strided*
mapping spreads same-layer cores across router paths (Fig. 8).

Messages from every core of layer l are duplicated (unicast per destination)
to every core of layer l+1 (broadcast, §III-C); the last layer's outputs
route to the chip I/O port at router 0.  Router load counts injections,
transits, and deliveries; dimension-ordered (X-then-Y) routing on the router
grid.  Per-pair router path incidence is precomputed per profile so a step's
congestion is two small matmuls.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import threading

import numpy as np

# jnp is only touched by the device-resident flow-structure path
# (:func:`flow_structures_rows`); the host paths below stay pure NumPy.
import jax.numpy as jnp

from repro.neuromorphic.partition import Partition
from repro.neuromorphic.platform import ChipProfile


@dataclasses.dataclass(frozen=True)
class Mapping:
    """logical core index -> physical core slot."""

    phys: tuple[int, ...]
    name: str = "custom"

    def __post_init__(self):
        if len(set(self.phys)) != len(self.phys):
            raise ValueError("mapping assigns two logical cores to one slot")


def ordered_mapping(part: Partition, profile: ChipProfile) -> Mapping:
    """Sequential placement — the congestion-prone Loihi-1 heuristic [27]."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    return Mapping(tuple(range(n)), name="ordered")


def strided_mapping(part: Partition, profile: ChipProfile) -> Mapping:
    """Strided placement: consecutive logical cores land on different
    routers, so same-layer cores use disjoint router paths."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    n_routers = n_router_tiles(profile)
    cpr = cores_per_router(profile)
    order = [r + n_routers * s for s in range(cpr) for r in range(n_routers)]
    return Mapping(tuple(int(_router_slot_to_core(o, profile)) for o in order[:n]),
                   name="strided")


def random_mapping(part: Partition, profile: ChipProfile,
                   rng: np.random.Generator) -> Mapping:
    """Uniform random placement — population-seeding diversity for the
    evolutionary mapping search (:mod:`repro.core.search`)."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    phys = rng.permutation(profile.n_cores)[:n]
    return Mapping(tuple(int(p) for p in phys), name="random")


def cores_per_router(profile: ChipProfile) -> int:
    rows, cols = profile.grid
    return max(1, profile.n_cores // (rows * cols))


def n_router_tiles(profile: ChipProfile) -> int:
    rows, cols = profile.grid
    return rows * cols


def core_router(core: int, profile: ChipProfile) -> int:
    return core // cores_per_router(profile)


def _router_slot_to_core(order_idx: int, profile: ChipProfile) -> int:
    """order_idx encodes (slot within router, router) -> physical core id."""
    n_routers = n_router_tiles(profile)
    slot, router = order_idx // n_routers, order_idx % n_routers
    return router * cores_per_router(profile) + slot


@functools.lru_cache(maxsize=16)
def _path_incidence(grid: tuple[int, int]) -> np.ndarray:
    """(R*R, R) matrix: entry[(src*R+dst), node] = 1 if the X-then-Y route
    from src to dst touches router ``node`` (inject/transit/deliver)."""
    rows, cols = grid
    R = rows * cols
    inc = np.zeros((R * R, R), np.float32)
    for s in range(R):
        r1, c1 = divmod(s, cols)
        for d in range(R):
            r2, c2 = divmod(d, cols)
            nodes = [s]
            step = 1 if c2 >= c1 else -1
            for c in range(c1 + step, c2 + step, step) if c1 != c2 else []:
                nodes.append(r1 * cols + c)
            step = 1 if r2 >= r1 else -1
            for r in range(r1 + step, r2 + step, step) if r1 != r2 else []:
                nodes.append(r * cols + c2)
            inc[s * R + d, nodes] = 1.0
    return inc


@functools.lru_cache(maxsize=16)
def _pair_hops(grid: tuple[int, int]) -> np.ndarray:
    """(R*R,) Manhattan hop counts between router pairs."""
    rows, cols = grid
    R = rows * cols
    r = np.arange(R)
    rr, cc = r // cols, r % cols
    return (np.abs(rr[:, None] - rr[None, :])
            + np.abs(cc[:, None] - cc[None, :])).astype(np.float32).reshape(-1)


@dataclasses.dataclass
class NocTraffic:
    """One timestep's routed traffic."""

    router_loads: np.ndarray      # packets touching each router
    total_hops: float             # link traversals (for hop energy)
    inject_per_core: np.ndarray   # packets injected by each logical core

    @property
    def max_router_load(self) -> float:
        return float(self.router_loads.max(initial=0.0))


@dataclasses.dataclass
class NocTrafficBatch:
    """Routed traffic for ALL timesteps at once (time-major)."""

    router_loads: np.ndarray      # (T, R) packets touching each router
    total_hops: np.ndarray        # (T,) link traversals
    inject_per_core: np.ndarray   # (T, n_logical) injected packets

    @property
    def max_router_load(self) -> np.ndarray:
        """(T,) busiest-router load per step."""
        return self.router_loads.max(axis=1, initial=0.0)


@functools.lru_cache(maxsize=64)
def _flow_matrix(cores: tuple[int, ...], phys: tuple[int, ...],
                 grid: tuple[int, int],
                 n_cores_phys: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-(partition, mapping) routing structure, independent of the
    per-step message counts.

    Returns ``(P, dup)`` where ``P`` is an (n_logical, R*R) matrix such that
    ``msgs @ P`` is the flattened router->router flow tensor (entry
    ``[core, src*R+dst]`` counts how many destination cores of the next
    layer sit on router ``dst``), and ``dup`` is the per-core unicast
    duplication factor (number of destination cores)."""
    rows, cols = grid
    R = rows * cols
    cpr = max(1, n_cores_phys // R)
    routers = np.asarray([p // cpr for p in phys])
    n_logical = int(sum(cores))
    P = np.zeros((n_logical, R * R), np.float64)
    dup = np.zeros(n_logical, np.float64)
    offsets = np.concatenate([[0], np.cumsum(cores)]).astype(int)
    n_layers = len(cores)
    for l in range(n_layers):
        src_idx = np.arange(offsets[l], offsets[l + 1])
        if l + 1 < n_layers:
            dst_routers = routers[offsets[l + 1]:offsets[l + 2]]
        else:
            dst_routers = np.asarray([0])        # chip I/O port
        dup[src_idx] = len(dst_routers)
        for g in src_idx:
            np.add.at(P[g], routers[g] * R + dst_routers, 1.0)
    return P, dup


# ---------------------------------------------------------------- population

#: Bytes-keyed LRU of per-candidate ``(P, dup)`` routing structures.  The
#: evolutionary search carries survivors between generations, so most of a
#: generation's genomes were already routed; keying by the raw genome bytes
#: (core counts + expressed physical slots) lets :func:`flow_matrix_population`
#: skip their scatter entirely.  Guarded by a lock so population pricing can
#: be driven from worker threads.
_FLOW_CACHE: collections.OrderedDict = collections.OrderedDict()
_FLOW_CACHE_MAX = 4096
_FLOW_CACHE_LOCK = threading.Lock()


def flow_cache_clear() -> None:
    """Drop the population flow-matrix cache (tests / memory pressure)."""
    with _FLOW_CACHE_LOCK:
        _FLOW_CACHE.clear()


def flow_matrix_population(cores_rows, phys_rows, grid: tuple[int, int],
                           n_cores_phys: int, n_pad: int, *,
                           cache: bool = True,
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Batched :func:`_flow_matrix`: all candidates' routing structures in
    one shot.

    Args:
      cores_rows: per-candidate layer core counts — sequence of K int
        sequences, each of length ``n_layers``.
      phys_rows: per-candidate *expressed* physical slot assignments —
        sequence of K int sequences, row k of length ``sum(cores_rows[k])``.
      n_pad: logical-core padding width (>= every candidate's total cores).

    Returns ``(P_stack, dup_stack)``: a ``(K, n_pad, R*R)`` float32 tensor
    whose k-th leading slice equals ``_flow_matrix``'s ``P`` for candidate k
    (zero rows beyond its ``n_logical``), and the ``(K, n_pad)`` float64
    duplication factors (zero on padding).  Cache misses are built with a
    single ``np.add.at`` scatter over the stacked tensor; hits are pasted
    from the bytes-keyed LRU.  Entries are exact small-integer counts, so
    float32 storage is lossless.  ``cache=False`` skips storing the raw
    matrices (:func:`router_incidence_population` only ever re-reads the
    much smaller folded form, so caching the dense ``P`` for it would
    waste most of the LRU's memory on dead entries).
    """
    rows, cols = grid
    R = rows * cols
    cpr = max(1, n_cores_phys // R)
    cores_rows = [np.asarray(c, np.int32) for c in cores_rows]
    phys_rows = [np.asarray(p, np.int32) for p in phys_rows]
    K = len(cores_rows)
    if K != len(phys_rows):
        raise ValueError("cores_rows and phys_rows disagree on K")

    P_stack = np.zeros((K, n_pad, R * R), np.float32)
    dup_stack = np.zeros((K, n_pad), np.float64)
    keys = []
    misses = []
    with _FLOW_CACHE_LOCK:
        for k, (cores, phys) in enumerate(zip(cores_rows, phys_rows)):
            key = (grid, n_cores_phys, cores.tobytes(), phys.tobytes())
            keys.append(key)
            hit = _FLOW_CACHE.get(key)
            if hit is not None:
                _FLOW_CACHE.move_to_end(key)
                P_k, dup_k = hit
                P_stack[k, :P_k.shape[0]] = P_k
                dup_stack[k, :dup_k.shape[0]] = dup_k
            else:
                misses.append(k)

    if misses:
        k_idx, core_idx, flat_idx = [], [], []
        for k in misses:
            cores, phys = cores_rows[k], phys_rows[k]
            routers = phys // cpr
            off = np.concatenate([[0], np.cumsum(cores)]).astype(int)
            n_layers = len(cores)
            for l in range(n_layers):
                src = np.arange(off[l], off[l + 1])
                if l + 1 < n_layers:
                    dst_r = routers[off[l + 1]:off[l + 2]]
                else:
                    dst_r = np.zeros(1, np.int32)     # chip I/O port
                dup_stack[k, off[l]:off[l + 1]] = len(dst_r)
                k_idx.append(np.full(src.size * dst_r.size, k, np.intp))
                core_idx.append(np.repeat(src, dst_r.size))
                flat_idx.append((routers[src][:, None] * R
                                 + dst_r[None, :]).reshape(-1))
        np.add.at(P_stack,
                  (np.concatenate(k_idx), np.concatenate(core_idx),
                   np.concatenate(flat_idx)), 1.0)
        if cache:
            with _FLOW_CACHE_LOCK:
                for k in misses:
                    n_logical = int(cores_rows[k].sum())
                    _FLOW_CACHE[keys[k]] = (P_stack[k, :n_logical].copy(),
                                            dup_stack[k, :n_logical].copy())
                    _FLOW_CACHE.move_to_end(keys[k])
                while len(_FLOW_CACHE) > _FLOW_CACHE_MAX:
                    _FLOW_CACHE.popitem(last=False)
    return P_stack, dup_stack


def router_incidence_population(cores_rows, phys_rows, grid: tuple[int, int],
                                n_cores_phys: int, n_pad: int,
                                ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Path-incidence-folded :func:`flow_matrix_population`.

    Returns ``(PL, ph, dup)``: ``PL`` is ``(K, n_pad, R)`` float64 with
    ``PL = P @ path_incidence`` (so a candidate's per-router loads are
    ``msgs @ PL`` — the ``(T, R*R)`` flow tensor never materializes), ``ph``
    is ``(K, n_pad)`` float64 with ``ph = P @ pair_hops`` (total hops are
    ``msgs @ ph``), and ``dup`` the duplication factors.  Because every
    entry of ``P``, the incidence, and the hop vector is a small exact
    integer, the fold is exact: ``msgs @ (P @ inc) == (msgs @ P) @ inc``
    bit-for-bit in float64.  Folded rows are LRU-cached by genome bytes
    alongside the raw flow matrices.
    """
    rows, cols = grid
    R = rows * cols
    cores_rows = [np.asarray(c, np.int32) for c in cores_rows]
    phys_rows = [np.asarray(p, np.int32) for p in phys_rows]
    K = len(cores_rows)
    PL = np.zeros((K, n_pad, R), np.float64)
    ph = np.zeros((K, n_pad), np.float64)
    dup = np.zeros((K, n_pad), np.float64)
    keys, misses = [], []
    with _FLOW_CACHE_LOCK:
        for k, (cores, phys) in enumerate(zip(cores_rows, phys_rows)):
            key = ("fold", grid, n_cores_phys, cores.tobytes(),
                   phys.tobytes())
            keys.append(key)
            hit = _FLOW_CACHE.get(key)
            if hit is not None:
                _FLOW_CACHE.move_to_end(key)
                PL_k, ph_k, dup_k = hit
                n = PL_k.shape[0]
                PL[k, :n], ph[k, :n], dup[k, :n] = PL_k, ph_k, dup_k
            else:
                misses.append(k)
    if misses:
        P_m, dup_m = flow_matrix_population(
            [cores_rows[k] for k in misses], [phys_rows[k] for k in misses],
            grid, n_cores_phys, n_pad, cache=False)
        inc = _path_incidence(grid).astype(np.float64)
        hops_vec = _pair_hops(grid).astype(np.float64)
        PL_m = P_m.astype(np.float64) @ inc           # (M, n_pad, R)
        ph_m = P_m.astype(np.float64) @ hops_vec      # (M, n_pad)
        with _FLOW_CACHE_LOCK:
            for j, k in enumerate(misses):
                n = int(cores_rows[k].sum())
                PL[k], ph[k], dup[k] = PL_m[j], ph_m[j], dup_m[j]
                _FLOW_CACHE[keys[k]] = (PL_m[j, :n].copy(),
                                        ph_m[j, :n].copy(),
                                        dup_m[j, :n].copy())
                _FLOW_CACHE.move_to_end(keys[k])
            while len(_FLOW_CACHE) > _FLOW_CACHE_MAX:
                _FLOW_CACHE.popitem(last=False)
    return PL, ph, dup


@functools.lru_cache(maxsize=16)
def incidence_tables(grid: tuple[int, int]) -> tuple[np.ndarray, np.ndarray]:
    """Per-grid routing geometry in the shapes the device path consumes:
    ``inc3[src, dst, node]`` is the (R, R, R) path-incidence tensor and
    ``hops2[src, dst]`` the (R, R) Manhattan hop matrix — float64 reshaped
    views of the lru-cached flat tables shared with :func:`route_batch`."""
    rows, cols = grid
    R = rows * cols
    inc3 = _path_incidence(grid).astype(np.float64).reshape(R, R, R)
    hops2 = _pair_hops(grid).astype(np.float64).reshape(R, R)
    return inc3, hops2


def flow_structures_rows(lid, router, alive, n_layers: int, inc3, hops2):
    """ONE candidate's routing structures, built entirely on device.

    The array-native analog of :func:`router_incidence_population` for a
    genome that never leaves the accelerator: given the candidate's padded
    per-core layer ids ``lid`` (Ncap,), router ids ``router`` (Ncap,), and
    float live-core mask ``alive`` (Ncap,), returns the same
    ``(PL, ph, dup)`` triple — per-core router-load incidence ``msgs @ PL``,
    hop factors ``msgs @ ph``, unicast duplication — as ``(Ncap, R)`` /
    ``(Ncap,)`` / ``(Ncap,)`` jnp arrays.  Pure jnp and shape-static, so it
    traces into the jitted population pricer and the device generation step
    (no host round-trip, no byte-keyed cache).

    Every intermediate is an exact small-integer count in float64 (layer
    destination-router counts folded through the integer incidence/hop
    tables), so the results are bit-identical to the host-built structures
    of :func:`router_incidence_population` — asserted by
    ``tests/test_device_search.py``.

    ``n_layers`` is static; ``inc3``/``hops2`` come from
    :func:`incidence_tables` (callers pass them so they become jit
    constants).  Dead slots must carry in-range ``lid``/``router`` values
    (the scatter adds their ``alive == 0`` contribution harmlessly); their
    output rows are zeroed.
    """
    R = inc3.shape[0]
    # cnt[l, r]: live cores of layer l sitting on router r
    cnt = jnp.zeros((n_layers, R), jnp.float64).at[lid, router].add(alive)
    io_row = jnp.zeros((1, R), jnp.float64).at[0, 0].set(1.0)
    # dest[l]: destination-router core counts for a source core of layer l
    # (next layer's placement; the last layer exits at the router-0 I/O port)
    dest = jnp.concatenate([cnt[1:], io_row], axis=0)            # (L, R)
    # fold per-layer dest counts through the geometry once: L x R x R work
    # instead of a per-core (Ncap, R, R) gather
    M = jnp.einsum("ld,sdr->lsr", dest, inc3)                    # (L, R, R)
    phL = dest @ hops2.T                                         # (L, R)
    PL = M[lid, router] * alive[:, None]                         # (Ncap, R)
    ph = phL[lid, router] * alive                                # (Ncap,)
    dup = dest.sum(axis=1)[lid] * alive                          # (Ncap,)
    return PL, ph, dup


def route_batch(part: Partition, mapping: Mapping, msgs_out: np.ndarray,
                profile: ChipProfile) -> NocTrafficBatch:
    """Route every timestep's messages at once.  ``msgs_out`` is the
    (T, n_logical) per-core message-count matrix in logical core order; the
    (T, R, R) flow tensor is one matmul against the cached per-core flow
    incidence, and router loads / hop counts are one matmul each against the
    cached path incidence.  Counts are integers in float64, so the results
    are bit-identical to T :func:`route_step` calls."""
    P, dup = _flow_matrix(part.cores, mapping.phys, profile.grid,
                          profile.n_cores)
    m = np.asarray(msgs_out, np.float64)
    flow_flat = m @ P                                   # (T, R*R)
    loads = flow_flat @ _path_incidence(profile.grid)   # (T, R)
    hops = flow_flat @ _pair_hops(profile.grid)         # (T,)
    return NocTrafficBatch(router_loads=loads, total_hops=hops,
                           inject_per_core=m * dup)


def route_step(part: Partition, mapping: Mapping,
               msgs_out_per_core: list[np.ndarray],
               profile: ChipProfile) -> NocTraffic:
    """Route one timestep's messages.  ``msgs_out_per_core[l]`` holds message
    counts per core of layer l; each message is unicast-duplicated to every
    core of layer l+1; the final layer exits at router 0."""
    grid = profile.grid
    R = n_router_tiles(profile)
    flow = np.zeros((R, R), np.float64)          # router -> router packets
    n_logical = part.total_cores
    inject = np.zeros(n_logical, np.float64)
    offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)
    routers = np.asarray([core_router(p, profile) for p in mapping.phys])

    n_layers = len(part.cores)
    for l in range(n_layers):
        src_idx = np.arange(offsets[l], offsets[l + 1])
        msgs = np.asarray(msgs_out_per_core[l], np.float64)
        if l + 1 < n_layers:
            dst_routers = routers[offsets[l + 1]:offsets[l + 2]]
        else:
            dst_routers = np.asarray([0])        # chip I/O port
        inject[src_idx] += msgs * len(dst_routers)
        src_routers = routers[src_idx]
        np.add.at(flow, (src_routers[:, None].repeat(len(dst_routers), 1),
                         np.broadcast_to(dst_routers, (len(src_idx),
                                                       len(dst_routers)))),
                  msgs[:, None])

    inc = _path_incidence(grid)
    loads = flow.reshape(-1) @ inc
    hops = float(flow.reshape(-1) @ _pair_hops(grid))
    return NocTraffic(router_loads=np.asarray(loads), total_hops=hops,
                      inject_per_core=inject)
