"""Network-on-chip model: router-shared core placement + XY-routed congestion.

Mirrors the paper's §V-F traffic mechanism: several neurocores share each NoC
router tile (as on Loihi), so an *ordered* mapping that places a layer's
(equally busy) cores on consecutive slots concentrates its injection load on
a few routers — "the highest output neurocores ... are physically close to
one another and create congestion on their shared NoC routers".  A *strided*
mapping spreads same-layer cores across router paths (Fig. 8).

Messages from every core of layer l are duplicated (unicast per destination)
to every core of layer l+1 (broadcast, §III-C); the last layer's outputs
route to the chip I/O port at router 0.  Router load counts injections,
transits, and deliveries; dimension-ordered (X-then-Y) routing on the router
grid.  Per-pair router path incidence is precomputed per profile so a step's
congestion is two small matmuls.
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.neuromorphic.partition import Partition
from repro.neuromorphic.platform import ChipProfile


@dataclasses.dataclass(frozen=True)
class Mapping:
    """logical core index -> physical core slot."""

    phys: tuple[int, ...]
    name: str = "custom"

    def __post_init__(self):
        if len(set(self.phys)) != len(self.phys):
            raise ValueError("mapping assigns two logical cores to one slot")


def ordered_mapping(part: Partition, profile: ChipProfile) -> Mapping:
    """Sequential placement — the congestion-prone Loihi-1 heuristic [27]."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    return Mapping(tuple(range(n)), name="ordered")


def strided_mapping(part: Partition, profile: ChipProfile) -> Mapping:
    """Strided placement: consecutive logical cores land on different
    routers, so same-layer cores use disjoint router paths."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    n_routers = n_router_tiles(profile)
    cpr = cores_per_router(profile)
    order = [r + n_routers * s for s in range(cpr) for r in range(n_routers)]
    return Mapping(tuple(int(_router_slot_to_core(o, profile)) for o in order[:n]),
                   name="strided")


def random_mapping(part: Partition, profile: ChipProfile,
                   rng: np.random.Generator) -> Mapping:
    """Uniform random placement — population-seeding diversity for the
    evolutionary mapping search (:mod:`repro.core.search`)."""
    n = part.total_cores
    if n > profile.n_cores:
        raise ValueError("partition exceeds physical cores")
    phys = rng.permutation(profile.n_cores)[:n]
    return Mapping(tuple(int(p) for p in phys), name="random")


def cores_per_router(profile: ChipProfile) -> int:
    rows, cols = profile.grid
    return max(1, profile.n_cores // (rows * cols))


def n_router_tiles(profile: ChipProfile) -> int:
    rows, cols = profile.grid
    return rows * cols


def core_router(core: int, profile: ChipProfile) -> int:
    return core // cores_per_router(profile)


def _router_slot_to_core(order_idx: int, profile: ChipProfile) -> int:
    """order_idx encodes (slot within router, router) -> physical core id."""
    n_routers = n_router_tiles(profile)
    slot, router = order_idx // n_routers, order_idx % n_routers
    return router * cores_per_router(profile) + slot


@functools.lru_cache(maxsize=16)
def _path_incidence(grid: tuple[int, int]) -> np.ndarray:
    """(R*R, R) matrix: entry[(src*R+dst), node] = 1 if the X-then-Y route
    from src to dst touches router ``node`` (inject/transit/deliver)."""
    rows, cols = grid
    R = rows * cols
    inc = np.zeros((R * R, R), np.float32)
    for s in range(R):
        r1, c1 = divmod(s, cols)
        for d in range(R):
            r2, c2 = divmod(d, cols)
            nodes = [s]
            step = 1 if c2 >= c1 else -1
            for c in range(c1 + step, c2 + step, step) if c1 != c2 else []:
                nodes.append(r1 * cols + c)
            step = 1 if r2 >= r1 else -1
            for r in range(r1 + step, r2 + step, step) if r1 != r2 else []:
                nodes.append(r * cols + c2)
            inc[s * R + d, nodes] = 1.0
    return inc


@functools.lru_cache(maxsize=16)
def _pair_hops(grid: tuple[int, int]) -> np.ndarray:
    """(R*R,) Manhattan hop counts between router pairs."""
    rows, cols = grid
    R = rows * cols
    r = np.arange(R)
    rr, cc = r // cols, r % cols
    return (np.abs(rr[:, None] - rr[None, :])
            + np.abs(cc[:, None] - cc[None, :])).astype(np.float32).reshape(-1)


@dataclasses.dataclass
class NocTraffic:
    """One timestep's routed traffic."""

    router_loads: np.ndarray      # packets touching each router
    total_hops: float             # link traversals (for hop energy)
    inject_per_core: np.ndarray   # packets injected by each logical core

    @property
    def max_router_load(self) -> float:
        return float(self.router_loads.max(initial=0.0))


@dataclasses.dataclass
class NocTrafficBatch:
    """Routed traffic for ALL timesteps at once (time-major)."""

    router_loads: np.ndarray      # (T, R) packets touching each router
    total_hops: np.ndarray        # (T,) link traversals
    inject_per_core: np.ndarray   # (T, n_logical) injected packets

    @property
    def max_router_load(self) -> np.ndarray:
        """(T,) busiest-router load per step."""
        return self.router_loads.max(axis=1, initial=0.0)


@functools.lru_cache(maxsize=64)
def _flow_matrix(cores: tuple[int, ...], phys: tuple[int, ...],
                 grid: tuple[int, int],
                 n_cores_phys: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-(partition, mapping) routing structure, independent of the
    per-step message counts.

    Returns ``(P, dup)`` where ``P`` is an (n_logical, R*R) matrix such that
    ``msgs @ P`` is the flattened router->router flow tensor (entry
    ``[core, src*R+dst]`` counts how many destination cores of the next
    layer sit on router ``dst``), and ``dup`` is the per-core unicast
    duplication factor (number of destination cores)."""
    rows, cols = grid
    R = rows * cols
    cpr = max(1, n_cores_phys // R)
    routers = np.asarray([p // cpr for p in phys])
    n_logical = int(sum(cores))
    P = np.zeros((n_logical, R * R), np.float64)
    dup = np.zeros(n_logical, np.float64)
    offsets = np.concatenate([[0], np.cumsum(cores)]).astype(int)
    n_layers = len(cores)
    for l in range(n_layers):
        src_idx = np.arange(offsets[l], offsets[l + 1])
        if l + 1 < n_layers:
            dst_routers = routers[offsets[l + 1]:offsets[l + 2]]
        else:
            dst_routers = np.asarray([0])        # chip I/O port
        dup[src_idx] = len(dst_routers)
        for g in src_idx:
            np.add.at(P[g], routers[g] * R + dst_routers, 1.0)
    return P, dup


def route_batch(part: Partition, mapping: Mapping, msgs_out: np.ndarray,
                profile: ChipProfile) -> NocTrafficBatch:
    """Route every timestep's messages at once.  ``msgs_out`` is the
    (T, n_logical) per-core message-count matrix in logical core order; the
    (T, R, R) flow tensor is one matmul against the cached per-core flow
    incidence, and router loads / hop counts are one matmul each against the
    cached path incidence.  Counts are integers in float64, so the results
    are bit-identical to T :func:`route_step` calls."""
    P, dup = _flow_matrix(part.cores, mapping.phys, profile.grid,
                          profile.n_cores)
    m = np.asarray(msgs_out, np.float64)
    flow_flat = m @ P                                   # (T, R*R)
    loads = flow_flat @ _path_incidence(profile.grid)   # (T, R)
    hops = flow_flat @ _pair_hops(profile.grid)         # (T,)
    return NocTrafficBatch(router_loads=loads, total_hops=hops,
                           inject_per_core=m * dup)


def route_step(part: Partition, mapping: Mapping,
               msgs_out_per_core: list[np.ndarray],
               profile: ChipProfile) -> NocTraffic:
    """Route one timestep's messages.  ``msgs_out_per_core[l]`` holds message
    counts per core of layer l; each message is unicast-duplicated to every
    core of layer l+1; the final layer exits at router 0."""
    grid = profile.grid
    R = n_router_tiles(profile)
    flow = np.zeros((R, R), np.float64)          # router -> router packets
    n_logical = part.total_cores
    inject = np.zeros(n_logical, np.float64)
    offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)
    routers = np.asarray([core_router(p, profile) for p in mapping.phys])

    n_layers = len(part.cores)
    for l in range(n_layers):
        src_idx = np.arange(offsets[l], offsets[l + 1])
        msgs = np.asarray(msgs_out_per_core[l], np.float64)
        if l + 1 < n_layers:
            dst_routers = routers[offsets[l + 1]:offsets[l + 2]]
        else:
            dst_routers = np.asarray([0])        # chip I/O port
        inject[src_idx] += msgs * len(dst_routers)
        src_routers = routers[src_idx]
        np.add.at(flow, (src_routers[:, None].repeat(len(dst_routers), 1),
                         np.broadcast_to(dst_routers, (len(src_idx),
                                                       len(dst_routers)))),
                  msgs[:, None])

    inc = _path_incidence(grid)
    loads = flow.reshape(-1) @ inc
    hops = float(flow.reshape(-1) @ _pair_hops(grid))
    return NocTraffic(router_loads=np.asarray(loads), total_hops=hops,
                      inject_per_core=inject)
