"""Network abstraction executed by the neuromorphic simulator.

A :class:`SimNetwork` is a feed-forward stack of :class:`SimLayer` s.  Each
layer owns its synaptic weights, neuron model (ReLU / IF-spiking / sigma-delta
ReLU / SSM state), optional message gate (used to *program* exact activation
sparsity, as the paper does in §V-A by "explicitly toggling neuron activation
messaging on and off"), and weight format (dense/sparse, Fig. 4).

``step`` executes one timestep functionally (exact values) and returns exact
event-counter maps per neuron; the cost model in :mod:`repro.neuromorphic.
timestep` turns those into per-core times and energies.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class CounterMaps:
    """Exact per-timestep event counts for one layer.

    Per-neuron maps are flattened in *partition order* (channel-major for
    conv layers) so contiguous core ranges are meaningful.
    """

    msgs_in: float                 # input messages arriving this step
    macs: np.ndarray               # nnz multiply-accumulates per neuron
    fetches_dense: np.ndarray      # dense-format weight fetches per neuron
    msgs_out: np.ndarray           # 0/1 message emitted per neuron
    acts_evented: np.ndarray       # 0/1 neuron received >= 1 synop


@dataclasses.dataclass
class SimLayer:
    """One layer mapped onto one-or-more neurocores."""

    name: str
    kind: str                       # 'fc' | 'conv'
    weights: np.ndarray             # fc: (fanin, nout); conv: (kh, kw, cin, cout)
    bias: np.ndarray | None = None
    neuron_model: str = "relu"      # 'relu' | 'if' | 'sd_relu' | 'ssm'
    weight_format: str | None = None   # None -> platform default
    msg_gate: np.ndarray | None = None # 0/1 per neuron; programs act sparsity
    threshold: float = 0.0          # IF spike / sigma-delta threshold
    decay: float = 0.9              # SSM state decay (diag A)
    stride: int = 1                 # conv only
    in_hw: tuple[int, int] | None = None   # conv only: input spatial dims
    force_active: bool = False      # characterization mode: all neurons emit
    sends_deltas: bool = False      # sigma-delta layers emit deltas

    # ------------------------------------------------------------------ sizes
    @property
    def n_neurons(self) -> int:
        if self.kind == "fc":
            return int(self.weights.shape[1])
        kh, kw, cin, cout = self.weights.shape
        oh, ow = self.out_hw
        return int(cout * oh * ow)

    @property
    def out_hw(self) -> tuple[int, int]:
        assert self.kind == "conv" and self.in_hw is not None
        h, w = self.in_hw
        return (h // self.stride, w // self.stride)   # SAME padding

    @property
    def n_weights(self) -> int:
        return int(np.prod(self.weights.shape))

    @property
    def fanin(self) -> int:
        if self.kind == "fc":
            return int(self.weights.shape[0])
        kh, kw, cin, _ = self.weights.shape
        return int(kh * kw * cin)

    def weights_per_core(self, n_cores: int) -> int:
        """Synaptic memory words needed per core under an n_cores split
        (fc: neuron ranges; conv: output-channel ranges)."""
        if self.kind == "fc":
            per = -(-self.weights.shape[1] // n_cores)
            return int(self.weights.shape[0] * per)
        kh, kw, cin, cout = self.weights.shape
        per = -(-cout // n_cores)
        return int(kh * kw * cin * per)

    def init_state(self) -> dict[str, np.ndarray]:
        n = self.n_neurons
        st: dict[str, Any] = {}
        if self.neuron_model == "if":
            st["v"] = np.zeros(n, np.float32)
        elif self.neuron_model == "sd_relu":
            st["y_sent"] = np.zeros(n, np.float32)
        elif self.neuron_model == "ssm":
            st["x"] = np.zeros(n, np.float32)
        if self.sends_deltas or self.neuron_model == "sd_relu":
            pass
        return st

    # ------------------------------------------------------------------ step
    def step(self, x_in: np.ndarray, state: dict[str, np.ndarray],
             in_acc: np.ndarray | None) -> tuple[np.ndarray, dict, CounterMaps,
                                                 np.ndarray | None]:
        """One timestep: consume input messages ``x_in``, produce output
        messages, update neuron state, and count events exactly.

        ``in_acc`` reconstructs the upstream activation when the upstream
        layer sends deltas (sigma-delta); otherwise it is None and the raw
        messages are the activation.
        """
        x_in = np.asarray(x_in, np.float32)
        if in_acc is not None:
            in_acc = in_acc + x_in          # delta reconstruction
            x_eff = in_acc
        else:
            x_eff = x_in

        act_mask = (x_in != 0).astype(np.float32)   # events on the wire
        msgs_in = float(act_mask.sum())

        if self.kind == "fc":
            pre = x_eff @ self.weights
            w_mask = (self.weights != 0).astype(np.float32)
            macs = act_mask @ w_mask
            fetches_dense = np.full(self.n_neurons, msgs_in, np.float32)
        else:
            pre, macs, fetches_dense = self._conv_forward(x_eff, act_mask)

        if self.bias is not None:
            pre = pre + self.bias

        y_msgs, state = self._neuron(pre, state)
        if self.msg_gate is not None:
            y_msgs = y_msgs * self.msg_gate
        msgs_out = (y_msgs != 0).astype(np.float32)

        counters = CounterMaps(
            msgs_in=msgs_in,
            macs=np.asarray(macs, np.float32).reshape(-1),
            fetches_dense=np.asarray(fetches_dense, np.float32).reshape(-1),
            msgs_out=msgs_out.reshape(-1),
            acts_evented=(np.asarray(macs).reshape(-1) > 0).astype(np.float32),
        )
        return y_msgs, state, counters, in_acc

    # ------------------------------------------------------------ neuron fns
    def _neuron(self, pre: np.ndarray, state: dict) -> tuple[np.ndarray, dict]:
        if self.neuron_model == "relu":
            y = np.maximum(pre, 0.0)
            if self.force_active:
                y = np.abs(pre) + 1.0
            return y, state
        if self.neuron_model == "if":
            v = state["v"] + pre
            thr = max(self.threshold, 1e-6)
            spikes = (v >= thr).astype(np.float32)
            state = dict(state, v=v - thr * spikes)
            return spikes, state
        if self.neuron_model == "sd_relu":
            y = np.maximum(pre, 0.0)
            delta = y - state["y_sent"]
            thr = max(self.threshold, 1e-9)
            q = np.where(np.abs(delta) >= thr,
                         np.round(delta / thr) * thr, 0.0).astype(np.float32)
            state = dict(state, y_sent=state["y_sent"] + q)
            return q, state
        if self.neuron_model == "ssm":
            x = self.decay * state["x"] + pre
            state = dict(state, x=x)
            y = np.abs(x) + 1.0 if self.force_active else x
            return y.astype(np.float32), state
        raise ValueError(f"unknown neuron model {self.neuron_model}")

    # ------------------------------------------------------------- conv math
    def _conv_forward(self, x_eff: np.ndarray, act_mask: np.ndarray):
        """SAME-padded strided conv + exact MAC / dense-fetch counting.

        Counter maps are returned channel-major ((cout, oh, ow) flattened) so
        output-channel core ranges are contiguous.
        """
        h, w = self.in_hw
        cin = self.weights.shape[2]
        # flat boundaries are channel-major ((c, h, w)) on BOTH sides so
        # conv->conv stacks keep consistent receptive fields
        to_hwc = lambda a: np.transpose(a.reshape(cin, h, w), (1, 2, 0))
        x4 = jnp.asarray(to_hwc(x_eff)[None])
        m4 = jnp.asarray(to_hwc(act_mask)[None])
        wj = jnp.asarray(self.weights)
        wmask = (wj != 0).astype(jnp.float32)
        wones = jnp.ones_like(wj)

        def conv(lhs, rhs):
            return jax.lax.conv_general_dilated(
                lhs, rhs, window_strides=(self.stride, self.stride),
                padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

        pre = np.asarray(conv(x4, wj))[0]                  # (oh, ow, cout)
        macs = np.asarray(conv(m4, wmask))[0]
        fetches = np.asarray(conv(m4, wones))[0]
        # channel-major flatten for contiguous channel partitions
        to_flat = lambda a: np.transpose(a, (2, 0, 1)).reshape(-1)
        pre_flat = to_flat(pre)
        return pre_flat, to_flat(macs), to_flat(fetches)


@dataclasses.dataclass
class SimNetwork:
    """Feed-forward stack of SimLayers with per-layer state threading."""

    layers: list[SimLayer]
    in_size: int

    def init_states(self) -> list[dict]:
        return [l.init_state() for l in self.layers]

    def init_accs(self) -> list[np.ndarray | None]:
        """Delta-reconstruction accumulators at each layer boundary: layer i
        needs one iff layer i-1 (or the network input) sends deltas."""
        accs: list[np.ndarray | None] = []
        prev_sends_deltas = False
        prev_n = self.in_size
        for l in self.layers:
            accs.append(np.zeros(prev_n, np.float32) if prev_sends_deltas else None)
            prev_sends_deltas = l.sends_deltas or l.neuron_model == "sd_relu"
            prev_n = l.n_neurons
        return accs

    def step(self, x: np.ndarray, states: list[dict],
             accs: list[np.ndarray | None]) -> tuple[np.ndarray, list, list,
                                                     list[CounterMaps]]:
        counters: list[CounterMaps] = []
        new_states, new_accs = [], []
        cur = np.asarray(x, np.float32)
        for layer, st, acc in zip(self.layers, states, accs):
            cur, st, cnt, acc = layer.step(cur, st, acc)
            counters.append(cnt)
            new_states.append(st)
            new_accs.append(acc)
        return cur, new_states, new_accs, counters

    def run(self, xs: np.ndarray) -> tuple[np.ndarray, list[list[CounterMaps]]]:
        """Run a (T, in_size)-shaped input sequence; return (T, out) outputs
        and per-timestep per-layer counters."""
        states, accs = self.init_states(), self.init_accs()
        outs, all_counters = [], []
        for t in range(xs.shape[0]):
            y, states, accs, counters = self.step(xs[t], states, accs)
            outs.append(np.asarray(y).reshape(-1))
            all_counters.append(counters)
        return np.stack(outs), all_counters


# ====================================================================== builders

def _exact_density_mask(shape: tuple[int, ...], density: float,
                        rng: np.random.Generator) -> np.ndarray:
    """0/1 mask with an exact (rounded) fraction of ones, uniformly placed."""
    n = int(np.prod(shape))
    k = int(round(density * n))
    flat = np.zeros(n, np.float32)
    if k > 0:
        flat[rng.choice(n, size=k, replace=False)] = 1.0
    return flat.reshape(shape)


def fc_network(sizes: list[int], *, weight_density: float | list[float] = 1.0,
               neuron_model: str = "relu", seed: int = 0,
               weight_format: str | None = None) -> SimNetwork:
    """Random fully-connected network with exact per-layer weight density."""
    rng = np.random.default_rng(seed)
    wd = ([weight_density] * (len(sizes) - 1)
          if np.isscalar(weight_density) else list(weight_density))
    layers = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 1.0 / np.sqrt(sizes[i]),
                       (sizes[i], sizes[i + 1])).astype(np.float32)
        w *= _exact_density_mask(w.shape, wd[i], rng)
        layers.append(SimLayer(name=f"fc{i}", kind="fc", weights=w,
                               neuron_model=neuron_model,
                               weight_format=weight_format))
    return SimNetwork(layers=layers, in_size=sizes[0])


def programmed_fc_network(sizes: list[int], *, weight_densities: list[float],
                          act_densities: list[float], seed: int = 0,
                          weight_format: str | None = None,
                          neuron_model: str = "relu") -> SimNetwork:
    """Characterization-mode network (§V-A): weight density exact per layer,
    activation (message) density exactly *programmed* via per-neuron message
    gates with all neurons forced active — the simulator analog of the
    paper's "explicitly toggling neuron activation messaging on and off"."""
    assert len(weight_densities) == len(sizes) - 1
    assert len(act_densities) == len(sizes) - 1
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 1.0 / np.sqrt(sizes[i]),
                       (sizes[i], sizes[i + 1])).astype(np.float32)
        w *= _exact_density_mask(w.shape, weight_densities[i], rng)
        gate = _exact_density_mask((sizes[i + 1],), act_densities[i], rng)
        layers.append(SimLayer(name=f"fc{i}", kind="fc", weights=w,
                               neuron_model=neuron_model, msg_gate=gate,
                               force_active=True, weight_format=weight_format))
    return SimNetwork(layers=layers, in_size=sizes[0])


def make_inputs(n: int, density: float, steps: int, seed: int = 0) -> np.ndarray:
    """(steps, n) inputs with exact per-step message density."""
    rng = np.random.default_rng(seed)
    return np.stack([np.abs(rng.normal(1.0, 0.2, n)).astype(np.float32)
                     * _exact_density_mask((n,), density, rng)
                     for _ in range(steps)])
