"""Network abstraction executed by the neuromorphic simulator.

A :class:`SimNetwork` is a feed-forward stack of :class:`SimLayer` s.  Each
layer owns its synaptic weights, neuron model (ReLU / IF-spiking / sigma-delta
ReLU / SSM state), optional message gate (used to *program* exact activation
sparsity, as the paper does in §V-A by "explicitly toggling neuron activation
messaging on and off"), and weight format (dense/sparse, Fig. 4).

Two execution engines produce identical event counts:

* **step-major** (``step`` / ``run``): one timestep at a time, layer by
  layer — the reference implementation, kept for parity checking.
* **layer-major, time-batched** (``step_batch`` / ``run_batch``): for each
  layer in order, the full ``(T, n_in)`` message matrix is consumed at once.
  This is *exact* for feed-forward stacks because within a timestep messages
  flow strictly downstream (layer ``l`` at step ``t`` sees only layer
  ``l-1``'s step-``t`` output), so the time axis of a stateless layer is
  embarrassingly parallel: ReLU layers become a single GEMM and conv layers
  a single batched ``conv_general_dilated`` with batch = T.  Stateful
  neurons (IF / sigma-delta / SSM) carry state only *along* time within one
  layer, so they reduce to a tight vectorized recurrence over T applied to
  the whole ``(T, n)`` pre-activation block.  Sigma-delta input
  reconstruction is a cumulative sum over the time axis.

The per-layer synaptic forward itself (the pre-activation GEMM / conv plus
the exact MAC / fetch counter maps) is pluggable: both engines delegate it
to a :class:`repro.neuromorphic.compute.LayerCompute` backend (``compute=``
on :meth:`SimLayer.step` / :meth:`SimLayer.step_batch` /
:meth:`SimNetwork.run` / :meth:`SimNetwork.run_batch`).  ``"dense"`` — the
original jnp GEMM / ``conv_general_dilated`` math, bit-exact — is the
default; ``"event"`` routes the forward through the event-driven Pallas
kernel path, where work scales with activation density.  Neuron-state
recurrences and message gating stay here: they are the neuron model, not
the synaptic compute.

The cost model in :mod:`repro.neuromorphic.timestep` turns the exact counter
maps of either engine into per-core times and energies.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.neuromorphic import compute as _compute


@dataclasses.dataclass
class CounterMaps:
    """Exact per-timestep event counts for one layer.

    Per-neuron maps are flattened in *partition order* (channel-major for
    conv layers) so contiguous core ranges are meaningful.
    """

    msgs_in: float                 # input messages arriving this step
    macs: np.ndarray               # nnz multiply-accumulates per neuron
    fetches_dense: np.ndarray      # dense-format weight fetches per neuron
    msgs_out: np.ndarray           # 0/1 message emitted per neuron
    acts_evented: np.ndarray       # 0/1 neuron received >= 1 synop


@dataclasses.dataclass
class BatchCounters:
    """Exact event counts for one layer over ALL timesteps (time-major).

    The layer-major engine's counterpart of :class:`CounterMaps`: per-neuron
    maps are ``(T, n_neurons)`` arrays in the same partition order, so one
    segment-sum per layer aggregates every timestep at once.
    """

    msgs_in: np.ndarray            # (T,) input messages per step
    macs: np.ndarray               # (T, n) nnz multiply-accumulates
    fetches_dense: np.ndarray      # (T, n) dense-format weight fetches
    msgs_out: np.ndarray           # (T, n) 0/1 message emitted
    acts_evented: np.ndarray       # (T, n) 0/1 neuron received >= 1 synop

    def step_view(self, t: int) -> CounterMaps:
        """Per-step view, for parity checks against the step-major engine."""
        return CounterMaps(
            msgs_in=float(self.msgs_in[t]), macs=self.macs[t],
            fetches_dense=self.fetches_dense[t], msgs_out=self.msgs_out[t],
            acts_evented=self.acts_evented[t])


@dataclasses.dataclass
class SimLayer:
    """One layer mapped onto one-or-more neurocores."""

    name: str
    kind: str                       # 'fc' | 'conv'
    weights: np.ndarray             # fc: (fanin, nout); conv: (kh, kw, cin, cout)
    bias: np.ndarray | None = None
    neuron_model: str = "relu"      # 'relu' | 'if' | 'sd_relu' | 'ssm'
    weight_format: str | None = None   # None -> platform default
    msg_gate: np.ndarray | None = None # 0/1 per neuron; programs act sparsity
    threshold: float = 0.0          # IF spike / sigma-delta threshold
    decay: float = 0.9              # SSM state decay (diag A)
    stride: int = 1                 # conv only
    in_hw: tuple[int, int] | None = None   # conv only: input spatial dims
    force_active: bool = False      # characterization mode: all neurons emit
    sends_deltas: bool = False      # sigma-delta layers emit deltas

    # ------------------------------------------------------------------ sizes
    @property
    def n_neurons(self) -> int:
        if self.kind == "fc":
            return int(self.weights.shape[1])
        kh, kw, cin, cout = self.weights.shape
        oh, ow = self.out_hw
        return int(cout * oh * ow)

    @property
    def out_hw(self) -> tuple[int, int]:
        assert self.kind == "conv" and self.in_hw is not None
        h, w = self.in_hw
        return (h // self.stride, w // self.stride)   # SAME padding

    @property
    def n_weights(self) -> int:
        return int(np.prod(self.weights.shape))

    @property
    def fanin(self) -> int:
        if self.kind == "fc":
            return int(self.weights.shape[0])
        kh, kw, cin, _ = self.weights.shape
        return int(kh * kw * cin)

    def weights_per_core(self, n_cores: int) -> int:
        """Synaptic memory words needed per core under an n_cores split
        (fc: neuron ranges; conv: output-channel ranges)."""
        if self.kind == "fc":
            per = -(-self.weights.shape[1] // n_cores)
            return int(self.weights.shape[0] * per)
        kh, kw, cin, cout = self.weights.shape
        per = -(-cout // n_cores)
        return int(kh * kw * cin * per)

    # --------------------------------------------- cached derived weight data
    # Caches are keyed on the identity of the weights array (not just the
    # layer object), so rebinding ``layer.weights`` — e.g. SparsityProfile
    # applying a mask to an already-simulated layer — invalidates every
    # derived structure instead of serving stale data.

    @property
    def w_mask(self) -> np.ndarray:
        """0/1 mask of nonzero weights (fc MAC counting)."""
        return _compute.derived_from_weights(
            self, "_w_mask", lambda l: (l.weights != 0).astype(np.float32))

    @property
    def w_nnz(self) -> int:
        """Number of nonzero synaptic weights."""
        return _compute.derived_from_weights(
            self, "_w_nnz", lambda l: int((l.weights != 0).sum()))

    @property
    def _conv_kernels(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Device-resident conv kernels: (weights, nnz mask, all-ones)."""
        def build(l):
            wj = jnp.asarray(l.weights)
            return wj, (wj != 0).astype(jnp.float32), jnp.ones_like(wj)
        return _compute.derived_from_weights(self, "_conv_kernels_cache",
                                             build)

    def init_state(self) -> dict[str, np.ndarray]:
        n = self.n_neurons
        st: dict[str, Any] = {}
        if self.neuron_model == "if":
            st["v"] = np.zeros(n, np.float32)
        elif self.neuron_model == "sd_relu":
            st["y_sent"] = np.zeros(n, np.float32)
        elif self.neuron_model == "ssm":
            st["x"] = np.zeros(n, np.float32)
        return st

    # ------------------------------------------------------------------ step
    def step(self, x_in: np.ndarray, state: dict[str, np.ndarray],
             in_acc: np.ndarray | None, *,
             compute=None) -> tuple[np.ndarray, dict, CounterMaps,
                                    np.ndarray | None]:
        """One timestep: consume input messages ``x_in``, produce output
        messages, update neuron state, and count events exactly.

        ``in_acc`` reconstructs the upstream activation when the upstream
        layer sends deltas (sigma-delta); otherwise it is None and the raw
        messages are the activation.  ``compute`` selects the synaptic
        backend (:func:`repro.neuromorphic.compute.get_compute`); the
        forward runs through the backend's batched contract at T = 1.
        """
        cc = _compute.get_compute(compute)
        x_in = np.asarray(x_in, np.float32)
        if in_acc is not None:
            in_acc = in_acc + x_in          # delta reconstruction
            x_eff = in_acc
        else:
            x_eff = x_in

        act_mask = (x_in != 0).astype(np.float32)   # events on the wire
        msgs_in = float(act_mask.sum())

        pre, macs, fetches_dense = cc.forward(
            self, x_eff[None, :], act_mask[None, :],
            np.asarray([msgs_in], np.float32))
        pre = pre[0]
        macs, fetches_dense = macs[0], fetches_dense[0]

        if self.bias is not None:
            pre = pre + self.bias

        y_msgs, state = self._neuron(pre, state)
        if self.msg_gate is not None:
            y_msgs = y_msgs * self.msg_gate
        msgs_out = (y_msgs != 0).astype(np.float32)

        counters = CounterMaps(
            msgs_in=msgs_in,
            macs=np.asarray(macs, np.float32).reshape(-1),
            fetches_dense=np.asarray(fetches_dense, np.float32).reshape(-1),
            msgs_out=msgs_out.reshape(-1),
            acts_evented=(np.asarray(macs).reshape(-1) > 0).astype(np.float32),
        )
        return y_msgs, state, counters, in_acc

    # ------------------------------------------------------- batched step
    def step_batch(self, x_in: np.ndarray, state: dict[str, np.ndarray],
                   in_acc: np.ndarray | None, *,
                   compute=None) -> tuple[np.ndarray, dict, BatchCounters,
                                          np.ndarray | None]:
        """All T timesteps at once: consume the full ``(T, n_in)`` message
        matrix, produce ``(T, n)`` output messages, and count events exactly.

        Equivalent to T calls of :meth:`step`: the input-side delta
        reconstruction is a cumulative sum over time, the synaptic forward is
        one GEMM / one batched conv (through the selected
        :class:`~repro.neuromorphic.compute.LayerCompute` backend), and
        neuron state advances in a vectorized recurrence over T.  Counters
        and neuron recurrences use the same float op order as the
        step-major path (bit-identical); the delta accumulator matches bit
        for bit when it starts at zero, which :meth:`SimNetwork.init_accs`
        guarantees for every run — a caller chaining ``step_batch`` from a
        *nonzero* accumulator gets ``acc + cumsum(x)``, equal to the
        step-major chain only to within float32 rounding.
        """
        cc = _compute.get_compute(compute)
        x_in = np.asarray(x_in, np.float32)
        if x_in.ndim != 2:
            raise ValueError(f"step_batch needs (T, n_in), got {x_in.shape}")

        act_mask = (x_in != 0).astype(np.float32)   # events on the wire
        msgs_in = act_mask.sum(axis=1)              # (T,)

        if in_acc is not None:
            # delta reconstruction (acc_t = acc_0 + sum_{k<=t} x_k) is the
            # backend's to own: the base implementation is the bit-exact
            # dense time cumsum; event backends reconstruct in temporal
            # tiles so quiet windows compact away before the matmul.
            pre, macs, fetches_dense, new_acc = cc.delta_forward(
                self, x_in, in_acc, act_mask, msgs_in)
        else:
            new_acc = None
            pre, macs, fetches_dense = cc.forward(self, x_in, act_mask,
                                                  msgs_in)

        if self.bias is not None:
            pre = pre + self.bias

        y_msgs, state = self._neuron_batch(pre, state)
        if self.msg_gate is not None:
            y_msgs = y_msgs * self.msg_gate
        msgs_out = (y_msgs != 0).astype(np.float32)

        counters = BatchCounters(
            msgs_in=msgs_in.astype(np.float64),
            macs=np.asarray(macs, np.float32),
            fetches_dense=np.asarray(fetches_dense, np.float32),
            msgs_out=msgs_out,
            acts_evented=(np.asarray(macs) > 0).astype(np.float32),
        )
        return y_msgs, state, counters, new_acc

    # ------------------------------------------------------------ neuron fns
    def _neuron(self, pre: np.ndarray, state: dict) -> tuple[np.ndarray, dict]:
        if self.neuron_model == "relu":
            y = np.maximum(pre, 0.0)
            if self.force_active:
                y = np.abs(pre) + 1.0
            return y, state
        if self.neuron_model == "if":
            v = state["v"] + pre
            thr = max(self.threshold, 1e-6)
            spikes = (v >= thr).astype(np.float32)
            state = dict(state, v=v - thr * spikes)
            return spikes, state
        if self.neuron_model == "sd_relu":
            y = np.maximum(pre, 0.0)
            delta = y - state["y_sent"]
            thr = max(self.threshold, 1e-9)
            q = np.where(np.abs(delta) >= thr,
                         np.round(delta / thr) * thr, 0.0).astype(np.float32)
            state = dict(state, y_sent=state["y_sent"] + q)
            return q, state
        if self.neuron_model == "ssm":
            x = self.decay * state["x"] + pre
            state = dict(state, x=x)
            y = np.abs(x) + 1.0 if self.force_active else x
            return y.astype(np.float32), state
        raise ValueError(f"unknown neuron model {self.neuron_model}")

    def _neuron_batch(self, pre: np.ndarray,
                      state: dict) -> tuple[np.ndarray, dict]:
        """Neuron update over the whole (T, n) pre-activation block.

        Stateless models vectorize fully; stateful models run a recurrence
        over T with every per-step operation vectorized across the n neurons
        (identical float op order to T sequential :meth:`_neuron` calls).
        """
        T = pre.shape[0]
        if self.neuron_model == "relu":
            y = np.maximum(pre, 0.0)
            if self.force_active:
                y = np.abs(pre) + 1.0
            return y, state
        if self.neuron_model == "if":
            thr = max(self.threshold, 1e-6)
            v = state["v"]
            y = np.empty_like(pre)
            for t in range(T):
                v = v + pre[t]
                spikes = (v >= thr).astype(np.float32)
                v = v - thr * spikes
                y[t] = spikes
            return y, dict(state, v=v)
        if self.neuron_model == "sd_relu":
            relu = np.maximum(pre, 0.0)
            thr = max(self.threshold, 1e-9)
            y_sent = state["y_sent"]
            y = np.empty_like(pre)
            for t in range(T):
                delta = relu[t] - y_sent
                q = np.where(np.abs(delta) >= thr,
                             np.round(delta / thr) * thr,
                             0.0).astype(np.float32)
                y_sent = y_sent + q
                y[t] = q
            return y, dict(state, y_sent=y_sent)
        if self.neuron_model == "ssm":
            x = state["x"]
            y = np.empty_like(pre)
            for t in range(T):
                x = self.decay * x + pre[t]
                y[t] = np.abs(x) + 1.0 if self.force_active else x
            return y, dict(state, x=x)
        raise ValueError(f"unknown neuron model {self.neuron_model}")

@dataclasses.dataclass
class SimNetwork:
    """Feed-forward stack of SimLayers with per-layer state threading."""

    layers: list[SimLayer]
    in_size: int

    def init_states(self) -> list[dict]:
        return [l.init_state() for l in self.layers]

    def init_accs(self) -> list[np.ndarray | None]:
        """Delta-reconstruction accumulators at each layer boundary: layer i
        needs one iff layer i-1 (or the network input) sends deltas."""
        accs: list[np.ndarray | None] = []
        prev_sends_deltas = False
        prev_n = self.in_size
        for l in self.layers:
            accs.append(np.zeros(prev_n, np.float32) if prev_sends_deltas else None)
            prev_sends_deltas = l.sends_deltas or l.neuron_model == "sd_relu"
            prev_n = l.n_neurons
        return accs

    def step(self, x: np.ndarray, states: list[dict],
             accs: list[np.ndarray | None], *,
             compute=None) -> tuple[np.ndarray, list, list,
                                    list[CounterMaps]]:
        cc = _compute.get_compute(compute)
        counters: list[CounterMaps] = []
        new_states, new_accs = [], []
        cur = np.asarray(x, np.float32)
        for layer, st, acc in zip(self.layers, states, accs):
            cur, st, cnt, acc = layer.step(cur, st, acc, compute=cc)
            counters.append(cnt)
            new_states.append(st)
            new_accs.append(acc)
        return cur, new_states, new_accs, counters

    def run(self, xs: np.ndarray, *,
            compute=None) -> tuple[np.ndarray, list[list[CounterMaps]]]:
        """Step-major reference run: (T, in_size) inputs -> (T, out) outputs
        and per-timestep per-layer counters."""
        cc = _compute.get_compute(compute)
        states, accs = self.init_states(), self.init_accs()
        outs, all_counters = [], []
        for t in range(xs.shape[0]):
            y, states, accs, counters = self.step(xs[t], states, accs,
                                                  compute=cc)
            outs.append(np.asarray(y).reshape(-1))
            all_counters.append(counters)
        return np.stack(outs), all_counters

    def run_batch(self, xs: np.ndarray, *,
                  compute=None) -> tuple[np.ndarray, list[BatchCounters]]:
        """Layer-major run: (T, in_size) inputs -> (T, out) outputs and one
        :class:`BatchCounters` per layer.  Exactly equivalent to :meth:`run`
        (see the module docstring) but visits each layer once with the full
        time batch instead of T times.  ``compute`` selects the synaptic
        backend for every layer (resolved once per run)."""
        cc = _compute.get_compute(compute)
        states, accs = self.init_states(), self.init_accs()
        cur = np.asarray(xs, np.float32)
        all_counters: list[BatchCounters] = []
        for i, layer in enumerate(self.layers):
            cur, states[i], cnt, accs[i] = layer.step_batch(
                cur, states[i], accs[i], compute=cc)
            all_counters.append(cnt)
        T = xs.shape[0]
        return np.asarray(cur).reshape(T, -1), all_counters


# ====================================================================== builders

def _exact_density_mask(shape: tuple[int, ...], density: float,
                        rng: np.random.Generator) -> np.ndarray:
    """0/1 mask with an exact (rounded) fraction of ones, uniformly placed."""
    n = int(np.prod(shape))
    k = int(round(density * n))
    flat = np.zeros(n, np.float32)
    if k > 0:
        flat[rng.choice(n, size=k, replace=False)] = 1.0
    return flat.reshape(shape)


def fc_network(sizes: list[int], *, weight_density: float | list[float] = 1.0,
               neuron_model: str = "relu", seed: int = 0,
               weight_format: str | None = None) -> SimNetwork:
    """Random fully-connected network with exact per-layer weight density."""
    rng = np.random.default_rng(seed)
    wd = ([weight_density] * (len(sizes) - 1)
          if np.isscalar(weight_density) else list(weight_density))
    layers = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 1.0 / np.sqrt(sizes[i]),
                       (sizes[i], sizes[i + 1])).astype(np.float32)
        w *= _exact_density_mask(w.shape, wd[i], rng)
        layers.append(SimLayer(name=f"fc{i}", kind="fc", weights=w,
                               neuron_model=neuron_model,
                               weight_format=weight_format))
    return SimNetwork(layers=layers, in_size=sizes[0])


def programmed_fc_network(sizes: list[int], *, weight_densities: list[float],
                          act_densities: list[float], seed: int = 0,
                          weight_format: str | None = None,
                          neuron_model: str = "relu") -> SimNetwork:
    """Characterization-mode network (§V-A): weight density exact per layer,
    activation (message) density exactly *programmed* via per-neuron message
    gates with all neurons forced active — the simulator analog of the
    paper's "explicitly toggling neuron activation messaging on and off"."""
    assert len(weight_densities) == len(sizes) - 1
    assert len(act_densities) == len(sizes) - 1
    rng = np.random.default_rng(seed)
    layers = []
    for i in range(len(sizes) - 1):
        w = rng.normal(0, 1.0 / np.sqrt(sizes[i]),
                       (sizes[i], sizes[i + 1])).astype(np.float32)
        w *= _exact_density_mask(w.shape, weight_densities[i], rng)
        gate = _exact_density_mask((sizes[i + 1],), act_densities[i], rng)
        layers.append(SimLayer(name=f"fc{i}", kind="fc", weights=w,
                               neuron_model=neuron_model, msg_gate=gate,
                               force_active=True, weight_format=weight_format))
    return SimNetwork(layers=layers, in_size=sizes[0])


def make_inputs(n: int, density: float, steps: int, seed: int = 0) -> np.ndarray:
    """(steps, n) inputs with exact per-step message density.

    One batched draw: values come from a single (steps, n) normal sample and
    the per-step masks from one row-wise argsort of uniform noise (each row
    keeps exactly ``round(density * n)`` ones, uniformly placed)."""
    rng = np.random.default_rng(seed)
    vals = np.abs(rng.normal(1.0, 0.2, (steps, n))).astype(np.float32)
    k = int(round(density * n))
    mask = np.zeros((steps, n), np.float32)
    if k > 0:
        order = rng.random((steps, n)).argsort(axis=1)
        np.put_along_axis(mask, order[:, :k], 1.0, axis=1)
    return vals * mask
