"""Partitioning: logical neuron->neurocore assignment (paper §II-A, §III-C/D).

A :class:`Partition` assigns each layer a number of neurocores; neurons are
split into contiguous equal ranges (output-channel ranges for conv layers, so
every core holds complete channels and — as on the real chips — every input
message must be delivered to every core of the layer).

``minimal_partition`` computes the 'involuntary' utilization forced by the
chip's per-core neuron-state and synaptic-memory limits (§III-D); splits on
top of that are the 'voluntary' partitioning of §III-C.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.neuromorphic.network import SimNetwork
from repro.neuromorphic.platform import ChipProfile


@dataclasses.dataclass(frozen=True)
class Partition:
    """Per-layer neurocore counts."""

    cores: tuple[int, ...]

    @property
    def total_cores(self) -> int:
        return int(sum(self.cores))

    def ranges(self, layer_idx: int, n_neurons: int) -> list[tuple[int, int]]:
        """Contiguous [start, end) neuron ranges for the layer's cores."""
        c = self.cores[layer_idx]
        bounds = np.linspace(0, n_neurons, c + 1).astype(int)
        return [(int(bounds[i]), int(bounds[i + 1])) for i in range(c)]

    def boundaries(self, layer_idx: int, n_neurons: int) -> np.ndarray:
        c = self.cores[layer_idx]
        return np.linspace(0, n_neurons, c + 1).astype(int)

    def split(self, layer_idx: int, by: int = 1) -> "Partition":
        """Grow a layer by ``by`` cores — the §VI-B memory/compute move."""
        cores = list(self.cores)
        cores[layer_idx] += by
        return Partition(tuple(cores))

    def merge(self, layer_idx: int, by: int = 1) -> "Partition":
        """Shrink a layer by ``by`` cores (coagulation, §VI-A move (c)):
        fewer cores per layer lowers NoC duplication and active power.  The
        inverse of :meth:`split`; callers must re-validate the result."""
        cores = list(self.cores)
        cores[layer_idx] = max(1, cores[layer_idx] - by)
        return Partition(tuple(cores))

    def with_layer(self, layer_idx: int, n_cores: int) -> "Partition":
        cores = list(self.cores)
        cores[layer_idx] = n_cores
        return Partition(tuple(cores))

    def core_layer_ids(self) -> np.ndarray:
        """layer index of each logical core, in global logical order."""
        return np.concatenate([np.full(c, i, np.int32)
                               for i, c in enumerate(self.cores)])


def max_cores_for_layer(net: SimNetwork, layer_idx: int) -> int:
    """Partitioning granularity limit: fc splits by neuron, conv by channel."""
    layer = net.layers[layer_idx]
    if layer.kind == "conv":
        return int(layer.weights.shape[3])
    return layer.n_neurons


def layer_fits(layer, n_cores: int, profile: ChipProfile) -> bool:
    """Per-core capacity predicate: ``n_cores`` cores satisfy the chip's
    neuron-state and synaptic-memory limits for this layer.  The single
    source of the capacity formulas — ``minimal_partition``,
    ``validate_partition``, and the search's feasibility tables
    (:func:`repro.core.search.move_tables`) all go through here."""
    return (-(-layer.n_neurons // n_cores) <= profile.neurons_per_core
            and layer.weights_per_core(n_cores) <= profile.synapses_per_core)


def _min_cores(net: SimNetwork, layer_idx: int, profile: ChipProfile) -> int:
    layer = net.layers[layer_idx]
    cap = max_cores_for_layer(net, layer_idx)
    for c in range(1, cap + 1):
        if layer_fits(layer, c, profile):
            return c
    raise ValueError(
        f"layer {layer.name} cannot fit on {profile.name} at any split")


def minimal_partition(net: SimNetwork, profile: ChipProfile) -> Partition:
    """Involuntary utilization (§III-D): fewest cores per layer that satisfy
    the chip's neuron and synaptic memory capacities."""
    if not profile.allow_partitioning:
        # e.g. Speck: exactly one core per layer; capacities must hold.
        for i, l in enumerate(net.layers):
            if (l.n_neurons > profile.neurons_per_core
                    or l.n_weights > profile.synapses_per_core):
                raise ValueError(
                    f"layer {l.name} exceeds {profile.name} per-core capacity "
                    "and the platform does not support partitioning")
        return Partition(tuple(1 for _ in net.layers))
    cores = tuple(_min_cores(net, i, profile) for i in range(len(net.layers)))
    part = Partition(cores)
    if part.total_cores > profile.n_cores:
        raise ValueError(
            f"network needs {part.total_cores} cores minimum; "
            f"{profile.name} has {profile.n_cores}")
    return part


def validate_partition(net: SimNetwork, part: Partition,
                       profile: ChipProfile) -> bool:
    """True iff the partition respects chip capacities and core budget."""
    if len(part.cores) != len(net.layers):
        return False
    if part.total_cores > profile.n_cores:
        return False
    if not profile.allow_partitioning and any(c != 1 for c in part.cores):
        return False
    for i, layer in enumerate(net.layers):
        c = part.cores[i]
        if c < 1 or c > max_cores_for_layer(net, i):
            return False
        if not layer_fits(layer, c, profile):
            return False
    return True
