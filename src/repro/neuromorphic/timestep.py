"""Barrier-synchronized timestep cost model + full simulation entry point.

Implements the paper's execution model (§II-A, Fig. 1 bottom): within a
timestep every neurocore (1) accumulates synops for each input message,
(2) computes activations, (3) emits activation messages, (4) barrier-syncs.
Per-core synop and activation stages are pipelined, so a core's time is the
max of its memory stage and compute stage (the floorline's straight-boundary
assumption, §VI-A); the timestep is set by the slowest core or by NoC
congestion, plus barrier overhead.

Asynchronous platforms (Speck) have no barrier: a sample's latency is the
pipeline sum over layers of event-driven core work, and idle cores consume
no active power.

Two engines price a workload:

* ``engine="batched"`` (default) — **layer-major, time-batched**: the
  functional network runs once per layer over the whole ``(T, n)`` block
  (:meth:`SimNetwork.run_batch`), counters are aggregated to cores with one
  segment-sum per layer over the ``(T, n_neurons)`` maps, NoC routing is one
  matmul against a cached flow incidence (:func:`route_batch`), and all
  per-step bookkeeping (times, energies, stage votes, max-per-core stats)
  is array ops over the time axis.  This is exact for feed-forward stacks:
  messages cross a layer boundary only within a step, and neuron state flows
  only along time *within* a layer, so reordering the (t, l) loop nest to
  layer-major changes no value.
* ``engine="reference"`` — the original step-major loop, kept so the batched
  engine's outputs and counters can be checked for exact parity
  (``tests/test_sim_equivalence.py``).

The batched engine is split into two phases so optimization loops can share
work across many candidates:

* :func:`precompute_pricing` runs the functional network once and reduces its
  ``(T, n_neurons)`` counter maps to per-layer neuron-axis cumulative sums —
  everything that is independent of (partition, mapping).
* :func:`price_candidate` prices one (partition, mapping) pair from a cache:
  per-core segment sums are O(cores) gathers into the cumsums, and the NoC
  matmuls run against the cached flow/path incidence of
  :mod:`repro.neuromorphic.noc`.
* :func:`simulate_population` prices a whole candidate population from one
  cache, gathering every candidate's segment sums in one stacked indexing
  operation per counter per layer (the population axis is the leading axis
  of the stacked boundary array).  Results are bit-identical to per-candidate
  :func:`simulate` calls — the same cumsums are indexed and the same float op
  order runs downstream — which :mod:`tests.test_search` asserts.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.metrics import LoadStats, WorkloadMetrics
from repro.neuromorphic.network import BatchCounters, CounterMaps, SimNetwork
from repro.neuromorphic.noc import (Mapping, NocTraffic, ordered_mapping,
                                    route_batch, route_step)
from repro.neuromorphic.partition import Partition, minimal_partition
from repro.neuromorphic.platform import ChipProfile

#: Engine used when :func:`simulate` is called without an explicit
#: ``engine=``.  ``"batched"`` is the layer-major, time-batched engine;
#: ``"reference"`` is the step-major loop kept for parity checking.
#: ``benchmarks/run.py --engine`` overrides this module attribute globally,
#: which is the supported way to flip every simulation in a process.
DEFAULT_ENGINE = "batched"


@dataclasses.dataclass
class CoreCounters:
    """Per-core event counts for one layer at one timestep."""

    msgs_in: np.ndarray        # input messages seen by each core (broadcast)
    synops: np.ndarray         # format-effective weight fetches per core
    macs: np.ndarray           # nnz multiply-accumulates per core
    acts: np.ndarray           # neuron updates per core
    msgs_out: np.ndarray       # messages emitted per core
    neurons: np.ndarray        # neurons mapped per core
    sparse_format: bool


@dataclasses.dataclass
class BatchCoreCounters:
    """Per-core event counts for one layer over ALL timesteps (time-major:
    every array is (T, cores) except ``neurons``)."""

    msgs_in: np.ndarray        # (T, cores) input messages (broadcast)
    synops: np.ndarray         # (T, cores)
    macs: np.ndarray           # (T, cores)
    acts: np.ndarray           # (T, cores)
    msgs_out: np.ndarray       # (T, cores)
    neurons: np.ndarray        # (cores,)
    sparse_format: bool


def _segment_sums(per_neuron: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    csum = np.concatenate([[0.0], np.cumsum(per_neuron, dtype=np.float64)])
    return csum[bounds[1:]] - csum[bounds[:-1]]


def _layer_format(layer, profile: ChipProfile) -> bool:
    fmt = layer.weight_format or (
        profile.default_format_conv if layer.kind == "conv"
        else profile.default_format_fc)
    return fmt == "sparse"


def aggregate_layer(counters: CounterMaps, layer_idx: int, part: Partition,
                    net: SimNetwork, profile: ChipProfile) -> CoreCounters:
    layer = net.layers[layer_idx]
    n = layer.n_neurons
    bounds = part.boundaries(layer_idx, n)
    sparse = _layer_format(layer, profile)
    macs = _segment_sums(counters.macs, bounds)
    fetches_dense = _segment_sums(counters.fetches_dense, bounds)
    synops = macs if sparse else fetches_dense
    acts_map = (counters.acts_evented if not profile.synchronous
                else np.ones_like(counters.macs))
    return CoreCounters(
        msgs_in=np.full(part.cores[layer_idx], counters.msgs_in, np.float64),
        synops=np.asarray(synops, np.float64),
        macs=np.asarray(macs, np.float64),
        acts=_segment_sums(acts_map, bounds),
        msgs_out=_segment_sums(counters.msgs_out, bounds),
        neurons=np.diff(bounds).astype(np.float64),
        sparse_format=sparse,
    )


def core_times(cc, neuron_model: str,
               profile: ChipProfile) -> tuple[np.ndarray, np.ndarray]:
    """(memory-stage, compute-stage) time per core of one layer.  Works on
    both per-step :class:`CoreCounters` and time-major
    :class:`BatchCoreCounters` (the formulas are elementwise)."""
    p = profile
    if cc.sparse_format:
        mem = (cc.msgs_in * (p.c_msg_recv + p.c_decode_msg)
               + cc.synops * (p.c_fetch + p.c_decode_word + p.c_mac))
    else:
        mem = cc.msgs_in * p.c_msg_recv + cc.synops * (p.c_fetch + p.c_mac)
    act = cc.acts * p.neuron_cost(neuron_model)
    return mem, act


@dataclasses.dataclass
class SimReport:
    """Simulation output: performance + M0 metrics + raw per-core arrays.

    ``time_per_step``/``energy_per_step`` are means over the per-step
    ``times``/``energies`` arrays (for asynchronous platforms a "step" is a
    sample and ``times`` holds pipeline latencies).  ``max_synops``,
    ``max_acts`` and ``max_link_load`` are the M0 neurocore-aware intensity
    metrics: per-step maxima over cores (routers for link load), averaged
    over steps — the x-axis / floor / traffic terms of the floorline model.
    The ``per_core_*`` arrays are per-logical-core means over steps in
    partition order; the §VI-B optimizer and the evolutionary search read
    them to locate bottleneck layers.  ``bottleneck_stage`` names the term
    ("memory" / "compute" / "traffic" / "barrier") that set the step time on
    a plurality of steps.
    """

    time_per_step: float            # mean over steps (timestep duration /
                                    # sample latency for async chips)
    energy_per_step: float
    times: np.ndarray               # per-step
    energies: np.ndarray
    metrics: WorkloadMetrics        # M0 (means over steps)
    max_synops: float               # mean over steps of max-per-core synops
    max_acts: float
    max_link_load: float
    n_cores_active: int
    outputs: np.ndarray             # functional network outputs (T, out)
    per_core_synops: np.ndarray     # (n_logical_cores,) mean over steps
    per_core_acts: np.ndarray
    per_core_msgs_out: np.ndarray
    bottleneck_stage: str           # which term set the mean step time

    def summary(self) -> str:
        return (f"time/step={self.time_per_step:.1f} "
                f"energy/step={self.energy_per_step:.1f} "
                f"max_synops={self.max_synops:.0f} "
                f"cores={self.n_cores_active} "
                f"bottleneck={self.bottleneck_stage}")


def simulate(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
             part: Partition | None = None,
             mapping: Mapping | None = None, *,
             engine: str | None = None,
             precomputed: tuple | None = None) -> SimReport:
    """Run the network on the simulated chip and price every timestep.

    Args:
      engine: "batched" (layer-major, default) or "reference" (step-major).
      precomputed: a cached ``net.run_batch(xs)`` result to reuse — the
        functional run is independent of partition/mapping/profile, so
        optimization loops that re-price many partitions of the same
        (net, xs) pair should compute it once.  Batched engine only: the
        reference engine ignores it and re-runs the network step-major.
    """
    engine = engine or DEFAULT_ENGINE
    part = part or minimal_partition(net, profile)
    mapping = mapping or ordered_mapping(part, profile)
    if engine == "batched":
        return _simulate_batched(net, xs, profile, part, mapping, precomputed)
    if engine == "reference":
        return _simulate_reference(net, xs, profile, part, mapping)
    raise ValueError(f"unknown engine {engine!r}")


def _finish_report(net, part, T, times, energies, outputs, mean_synops,
                   mean_acts, mean_msgs, max_synops_steps, max_acts_steps,
                   max_link_steps, total_msgs, total_neuron_steps,
                   stage_votes) -> SimReport:
    """Shared report assembly for both engines (identical float math)."""
    w_nnz = sum(l.w_nnz for l in net.layers)
    w_cap = sum(l.n_weights for l in net.layers)
    metrics = WorkloadMetrics(
        synops=LoadStats.of(mean_synops),
        acts=LoadStats.of(mean_acts),
        traffic=LoadStats.of(np.array([max_link_steps.mean()])),
        msgs_total=total_msgs / T,
        weight_density=w_nnz / max(w_cap, 1),
        act_density=(total_msgs / max(total_neuron_steps, 1.0)),
    )
    bottleneck = max(stage_votes.items(), key=lambda kv: kv[1])[0]
    return SimReport(
        time_per_step=float(times.mean()),
        energy_per_step=float(energies.mean()),
        times=times, energies=energies, metrics=metrics,
        max_synops=float(max_synops_steps.mean()),
        max_acts=float(max_acts_steps.mean()),
        max_link_load=float(max_link_steps.mean()),
        n_cores_active=part.total_cores,
        outputs=outputs,
        per_core_synops=mean_synops,
        per_core_acts=mean_acts,
        per_core_msgs_out=mean_msgs,
        bottleneck_stage=bottleneck,
    )


@dataclasses.dataclass
class LayerPricing:
    """Partition/mapping-independent pricing state for one layer: neuron-axis
    cumulative sums of every counter map, so any core boundary's segment sum
    is a 2-element gather (same cumulative-sum difference as the per-step
    :func:`_segment_sums`, identical bits for every partition — and, unlike
    ``np.add.reduceat``, an empty segment correctly sums to 0 when a
    partition holds more cores than the layer has neurons)."""

    msgs_in: np.ndarray        # (T,) float64
    csum_macs: np.ndarray      # (T, n_neurons + 1) float64
    csum_fetches: np.ndarray   # (T, n_neurons + 1)
    csum_acts: np.ndarray      # (T, n_neurons + 1) of the profile's acts map
    csum_msgs: np.ndarray      # (T, n_neurons + 1)
    n_neurons: int
    sparse: bool


@dataclasses.dataclass
class PricingCache:
    """Everything :func:`price_candidate` needs that does not depend on the
    candidate: the functional outputs plus per-layer :class:`LayerPricing`."""

    outputs: np.ndarray
    T: int
    layers: list[LayerPricing]


def _neuron_csum(per_neuron: np.ndarray) -> np.ndarray:
    """(T, n) -> (T, n+1) cumulative sum with a leading zero column; paired
    with :func:`_seg` it is the batched analog of :func:`_segment_sums`."""
    a = np.asarray(per_neuron, np.float64)
    return np.concatenate([np.zeros((a.shape[0], 1)),
                           np.cumsum(a, axis=1)], axis=1)


def precompute_pricing(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                       *, precomputed: tuple | None = None) -> PricingCache:
    """Run the functional network (or reuse a cached ``net.run_batch(xs)``
    result) and reduce its counter maps to per-layer cumsums.  One cache
    prices any number of (partition, mapping) candidates."""
    outputs, all_counters = precomputed or net.run_batch(xs)
    layers = []
    for l, counters in enumerate(all_counters):
        acts_map = (counters.acts_evented if not profile.synchronous
                    else np.ones_like(counters.macs))
        layers.append(LayerPricing(
            msgs_in=np.asarray(counters.msgs_in, np.float64),
            csum_macs=_neuron_csum(counters.macs),
            csum_fetches=_neuron_csum(counters.fetches_dense),
            csum_acts=_neuron_csum(acts_map),
            csum_msgs=_neuron_csum(counters.msgs_out),
            n_neurons=net.layers[l].n_neurons,
            sparse=_layer_format(net.layers[l], profile)))
    return PricingCache(outputs=outputs, T=int(xs.shape[0]), layers=layers)


def _seg(csum: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """(T, cores) segment sums from cached cumsums: a two-point gather and
    subtraction per core boundary."""
    return csum[:, bounds[1:]] - csum[:, bounds[:-1]]


def _seg_population(csum: np.ndarray, bounds_stack: np.ndarray) -> np.ndarray:
    """Stacked population gather: (T, n+1) cumsums x (K, C+1) padded
    per-candidate boundaries -> (K, T, C) segment sums for every candidate
    in one indexing operation.  Padded (repeated) boundaries yield empty
    zero segments that callers slice away; each candidate's slice carries
    exactly the bits :func:`_seg` would produce."""
    g = csum[:, bounds_stack]                       # (T, K, C+1)
    return np.moveaxis(g[:, :, 1:] - g[:, :, :-1], 1, 0)


def _cached_layer_counters(lp: LayerPricing, part: Partition, layer_idx: int,
                           T: int,
                           segments: tuple | None = None) -> BatchCoreCounters:
    """All-timesteps analog of :func:`aggregate_layer`, built from a
    :class:`LayerPricing` (and optionally pre-gathered
    ``(macs, fetches, acts, msgs_out)`` segment arrays from the population
    path)."""
    bounds = part.boundaries(layer_idx, lp.n_neurons)
    if segments is None:
        macs = _seg(lp.csum_macs, bounds)
        fetches_dense = _seg(lp.csum_fetches, bounds)
        acts = _seg(lp.csum_acts, bounds)
        msgs_out = _seg(lp.csum_msgs, bounds)
    else:
        macs, fetches_dense, acts, msgs_out = segments
    c = part.cores[layer_idx]
    return BatchCoreCounters(
        msgs_in=np.broadcast_to(lp.msgs_in[:, None], (T, c)),
        synops=macs if lp.sparse else fetches_dense,
        macs=macs,
        acts=acts,
        msgs_out=msgs_out,
        neurons=np.diff(bounds).astype(np.float64),
        sparse_format=lp.sparse,
    )


def simulate_population(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                        candidates, *, precomputed: tuple | None = None,
                        cache: PricingCache | None = None) -> list[SimReport]:
    """Price many (partition, mapping) candidates from ONE functional run.

    ``candidates`` is an iterable of ``(Partition, Mapping)`` pairs.  The
    expensive (T, n_neurons) work — the functional network run and the
    per-layer counter cumsums — happens once (or is reused from ``cache`` /
    ``precomputed``); each candidate's per-core segment sums are then
    gathered for the whole population at once (:func:`_seg_population`), and
    only the small (T, cores) stage/energy/NoC math runs per candidate.

    Every report is bit-identical to the corresponding single-candidate
    ``simulate(net, xs, profile, part, mapping)`` call with the batched
    engine: the same cumsums are indexed and the same float op order runs on
    the gathered segments (asserted by ``tests/test_search.py``).
    """
    cands = list(candidates)
    if not cands:
        return []
    cache = cache or precompute_pricing(net, xs, profile,
                                        precomputed=precomputed)
    n_layers = len(cache.layers)
    seg_by_cand: list[list[tuple]] = [[None] * n_layers for _ in cands]
    for l, lp in enumerate(cache.layers):
        all_bounds = [p.boundaries(l, lp.n_neurons) for p, _ in cands]
        c_max = max(len(b) - 1 for b in all_bounds)
        stack = np.stack([np.pad(b, (0, c_max + 1 - len(b)), mode="edge")
                          for b in all_bounds])          # (K, c_max + 1)
        pop_segs = tuple(_seg_population(csum, stack) for csum in
                         (lp.csum_macs, lp.csum_fetches,
                          lp.csum_acts, lp.csum_msgs))
        for k, b in enumerate(all_bounds):
            c = len(b) - 1
            seg_by_cand[k][l] = tuple(s[k, :, :c] for s in pop_segs)
    return [price_candidate(net, profile, cache, p, m,
                            layer_segments=seg_by_cand[k])
            for k, (p, m) in enumerate(cands)]


def _simulate_batched(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                      part: Partition, mapping: Mapping,
                      precomputed: tuple | None) -> SimReport:
    """Layer-major engine: one pricing-cache build + one candidate pricing."""
    cache = precompute_pricing(net, xs, profile, precomputed=precomputed)
    return price_candidate(net, profile, cache, part, mapping)


def price_candidate(net: SimNetwork, profile: ChipProfile,
                    cache: PricingCache, part: Partition, mapping: Mapping,
                    *, layer_segments: list[tuple] | None = None) -> SimReport:
    """Price one (partition, mapping) candidate from a pricing cache; every
    per-step quantity is a (T, ...) array."""
    outputs = cache.outputs
    T = cache.T
    n_layers = len(cache.layers)
    n_logical = part.total_cores

    layer_cc = [_cached_layer_counters(
                    cache.layers[l], part, l, T,
                    layer_segments[l] if layer_segments else None)
                for l in range(n_layers)]

    mem_all, act_all = [], []
    e_events = np.zeros(T, np.float64)
    total_msgs = 0.0
    total_neuron_steps = 0.0
    for l, cc in enumerate(layer_cc):
        mem, act = core_times(cc, net.layers[l].neuron_model, profile)
        mem_all.append(mem)
        act_all.append(act)
        # event energies: fetch every (format-effective) synop; MAC energy
        # only on nonzero weights (dense formats skip the multiply ->
        # the small Fig-2 energy benefit of CNN weight sparsity)
        e_events += (profile.e_fetch * cc.synops.sum(axis=1)
                     + profile.e_mac * cc.macs.sum(axis=1)
                     + (profile.e_decode * cc.synops.sum(axis=1)
                        if cc.sparse_format else 0.0)
                     + profile.e_act * cc.acts.sum(axis=1)
                     * (profile.neuron_cost(net.layers[l].neuron_model)
                        / profile.c_act))
        total_msgs += cc.msgs_out.sum()
        total_neuron_steps += T * cc.neurons.sum()

    synops_all = np.concatenate([cc.synops for cc in layer_cc], axis=1)
    acts_all = np.concatenate([cc.acts for cc in layer_cc], axis=1)
    msgs_all = np.concatenate([cc.msgs_out for cc in layer_cc], axis=1)

    traffic = route_batch(part, mapping, msgs_all, profile)
    mem_cat = np.concatenate(mem_all, axis=1)       # (T, n_logical)
    act_cat = np.concatenate(act_all, axis=1)
    core_time = np.maximum(mem_cat, act_cat) + profile.t_core_fixed
    # Congestion: the busiest router serializes every packet touching it;
    # cores also serialize their own (duplicated) injections.
    max_link_steps = traffic.max_router_load        # (T,)
    traffic_time = (profile.c_route * max_link_steps
                    + profile.c_inject
                    * traffic.inject_per_core.max(axis=1, initial=0.0))

    stage_votes = {"memory": 0, "compute": 0, "traffic": 0, "barrier": 0}
    if profile.synchronous:
        t_compute = core_time.max(axis=1, initial=0.0)
        times = np.maximum(t_compute, traffic_time) + profile.t_barrier
        traffic_bound = traffic_time > t_compute
        mem_bound = (mem_cat.max(axis=1, initial=0.0)
                     >= act_cat.max(axis=1, initial=0.0))
        stage_votes["traffic"] = int(traffic_bound.sum())
        stage_votes["memory"] = int((~traffic_bound & mem_bound).sum())
        stage_votes["compute"] = int((~traffic_bound & ~mem_bound).sum())
    else:
        # async pipeline: sample latency = sum over layers of the layer's
        # slowest event-driven core + NoC transit
        times = np.zeros(T, np.float64)
        for m, a in zip(mem_all, act_all):
            times = times + np.maximum(m, a).max(axis=1, initial=0.0)
        times = times + (profile.c_msg_hop * traffic.total_hops
                         / max(part.total_cores, 1))
        stage_votes["memory"] = T

    n_active = np.sum((synops_all + msgs_all) > 0, axis=1).astype(np.float64)
    n_active[n_active == 0] = n_logical
    e_hops = profile.e_msg_hop * traffic.total_hops
    energies = (times * (profile.p_idle + profile.p_core * n_active)
                + e_events + e_hops)

    mean_synops = synops_all.sum(axis=0) / T
    mean_acts = acts_all.sum(axis=0) / T
    mean_msgs = msgs_all.sum(axis=0) / T
    return _finish_report(
        net, part, T, times, energies, outputs, mean_synops, mean_acts,
        mean_msgs,
        max_synops_steps=synops_all.max(axis=1, initial=0.0),
        max_acts_steps=acts_all.max(axis=1, initial=0.0),
        max_link_steps=max_link_steps,
        total_msgs=total_msgs, total_neuron_steps=total_neuron_steps,
        stage_votes=stage_votes)


def _simulate_reference(net: SimNetwork, xs: np.ndarray,
                        profile: ChipProfile, part: Partition,
                        mapping: Mapping) -> SimReport:
    """Step-major reference engine (original implementation)."""
    outputs, all_counters = net.run(xs)

    T = xs.shape[0]
    n_layers = len(net.layers)
    n_logical = part.total_cores
    times = np.zeros(T)
    energies = np.zeros(T)
    sum_core_synops = np.zeros(n_logical)
    sum_core_acts = np.zeros(n_logical)
    sum_core_msgs = np.zeros(n_logical)
    max_synops_steps = np.zeros(T)
    max_acts_steps = np.zeros(T)
    max_link_steps = np.zeros(T)
    stage_votes = {"memory": 0, "compute": 0, "traffic": 0, "barrier": 0}
    total_msgs = 0.0
    total_neuron_steps = 0.0

    offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)

    for t in range(T):
        layer_cc = [aggregate_layer(all_counters[t][l], l, part, net, profile)
                    for l in range(n_layers)]
        mem_all, act_all = [], []
        msgs_out_per_core = []
        e_events = 0.0
        for l, cc in enumerate(layer_cc):
            mem, act = core_times(cc, net.layers[l].neuron_model, profile)
            mem_all.append(mem)
            act_all.append(act)
            msgs_out_per_core.append(cc.msgs_out)
            sl = slice(offsets[l], offsets[l + 1])
            sum_core_synops[sl] += cc.synops
            sum_core_acts[sl] += cc.acts
            sum_core_msgs[sl] += cc.msgs_out
            e_events += (profile.e_fetch * cc.synops.sum()
                         + profile.e_mac * cc.macs.sum()
                         + (profile.e_decode * cc.synops.sum()
                            if cc.sparse_format else 0.0)
                         + profile.e_act * cc.acts.sum()
                         * (profile.neuron_cost(net.layers[l].neuron_model)
                            / profile.c_act))
            total_msgs += cc.msgs_out.sum()
            total_neuron_steps += cc.neurons.sum()

        traffic = route_step(part, mapping, msgs_out_per_core, profile)
        mem_cat = np.concatenate(mem_all)
        act_cat = np.concatenate(act_all)
        core_time = np.maximum(mem_cat, act_cat) + profile.t_core_fixed
        traffic_time = (profile.c_route * traffic.max_router_load
                        + profile.c_inject
                        * float(traffic.inject_per_core.max(initial=0.0)))

        if profile.synchronous:
            t_compute = float(core_time.max(initial=0.0))
            t_step = max(t_compute, traffic_time) + profile.t_barrier
            which = ("traffic" if traffic_time > t_compute else
                     ("memory" if mem_cat.max(initial=0.0)
                      >= act_cat.max(initial=0.0) else "compute"))
        else:
            per_layer = [float(np.maximum(m, a).max(initial=0.0))
                         for m, a in zip(mem_all, act_all)]
            t_step = sum(per_layer) + profile.c_msg_hop * traffic.total_hops / max(
                part.total_cores, 1)
            which = "memory"

        n_active = int(np.sum(np.concatenate(
            [cc.synops + cc.msgs_out for cc in layer_cc]) > 0)) or n_logical
        e_hops = profile.e_msg_hop * traffic.total_hops
        energies[t] = (t_step * (profile.p_idle + profile.p_core * n_active)
                       + e_events + e_hops)
        times[t] = t_step
        stage_votes[which] += 1
        syn_step = np.concatenate([cc.synops for cc in layer_cc])
        acts_step = np.concatenate([cc.acts for cc in layer_cc])
        max_synops_steps[t] = syn_step.max(initial=0.0)
        max_acts_steps[t] = acts_step.max(initial=0.0)
        max_link_steps[t] = traffic.max_router_load

    return _finish_report(
        net, part, T, times, energies, outputs,
        mean_synops=sum_core_synops / T,
        mean_acts=sum_core_acts / T,
        mean_msgs=sum_core_msgs / T,
        max_synops_steps=max_synops_steps, max_acts_steps=max_acts_steps,
        max_link_steps=max_link_steps,
        total_msgs=total_msgs, total_neuron_steps=total_neuron_steps,
        stage_votes=stage_votes)
