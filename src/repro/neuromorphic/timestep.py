"""Barrier-synchronized timestep cost model + full simulation entry point.

Implements the paper's execution model (§II-A, Fig. 1 bottom): within a
timestep every neurocore (1) accumulates synops for each input message,
(2) computes activations, (3) emits activation messages, (4) barrier-syncs.
Per-core synop and activation stages are pipelined, so a core's time is the
max of its memory stage and compute stage (the floorline's straight-boundary
assumption, §VI-A); the timestep is set by the slowest core or by NoC
congestion, plus barrier overhead.

Asynchronous platforms (Speck) have no barrier: a sample's latency is the
pipeline sum over layers of event-driven core work, and idle cores consume
no active power.

Two engines price a workload:

* ``engine="batched"`` (default) — **layer-major, time-batched**: the
  functional network runs once per layer over the whole ``(T, n)`` block
  (:meth:`SimNetwork.run_batch`), counters are aggregated to cores with one
  segment-sum per layer over the ``(T, n_neurons)`` maps, NoC routing is one
  matmul against a cached flow incidence (:func:`route_batch`), and all
  per-step bookkeeping (times, energies, stage votes, max-per-core stats)
  is array ops over the time axis.  This is exact for feed-forward stacks:
  messages cross a layer boundary only within a step, and neuron state flows
  only along time *within* a layer, so reordering the (t, l) loop nest to
  layer-major changes no value.
* ``engine="reference"`` — the original step-major loop, kept so the batched
  engine's outputs and counters can be checked for exact parity
  (``tests/test_sim_equivalence.py``).

Orthogonal to the engine choice, ``compute=`` selects the per-layer
synaptic backend of the functional run (``"dense"`` GEMM/conv reference or
the event-driven ``"event"`` kernel path —
:mod:`repro.neuromorphic.compute`).  Counters are exact across backends,
so every pricing product (reports, caches, populations) is
backend-agnostic (``tests/test_compute_backends.py``).

The batched engine is split into two phases so optimization loops can share
work across many candidates:

* :func:`precompute_pricing` runs the functional network once and reduces its
  ``(T, n_neurons)`` counter maps to per-layer neuron-axis cumulative sums —
  everything that is independent of (partition, mapping).
* :func:`price_candidate` prices one (partition, mapping) pair from a cache:
  per-core segment sums are O(cores) gathers into the cumsums, and the NoC
  matmuls run against the cached flow/path incidence of
  :mod:`repro.neuromorphic.noc`.
* :func:`simulate_population` prices a whole candidate population from one
  cache, gathering every candidate's segment sums in one stacked indexing
  operation per counter per layer (the population axis is the leading axis
  of the stacked boundary array).  Results are bit-identical to per-candidate
  :func:`simulate` calls — the same cumsums are indexed and the same float op
  order runs downstream — which :mod:`tests.test_search` asserts.

Population pricing itself comes in three backends (``backend=`` on
:func:`simulate_population`): ``"numpy"`` — the bit-exact reference above;
``"vmap"`` — one jitted ``jax.vmap`` over the padded population axis with
host-assembled batch structures (:func:`price_population_vmap`); and
``"device"`` — the genome arrays are the program input and batch-structure
construction itself runs on device (:class:`DevicePopulationPricer`,
:func:`price_population_device`), which is what lets the evolutionary
search's ``engine="device"`` generation loop stay accelerator-resident.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.metrics import LoadStats, WorkloadMetrics
from repro.neuromorphic.network import BatchCounters, CounterMaps, SimNetwork
from repro.neuromorphic.noc import (Mapping, NocTraffic, flow_structures_rows,
                                    incidence_tables, ordered_mapping,
                                    route_batch, route_step,
                                    router_incidence_population)
from repro.neuromorphic.partition import (Partition, max_cores_for_layer,
                                          minimal_partition)
from repro.neuromorphic.platform import ChipProfile

# jax is a hard dependency of the functional engine (repro.neuromorphic.
# network) already; the vmap population backend additionally needs x64
# scoping for float64 parity with the NumPy pricing path.
import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

#: Engine used when :func:`simulate` is called without an explicit
#: ``engine=``.  ``"batched"`` is the layer-major, time-batched engine;
#: ``"reference"`` is the step-major loop kept for parity checking.
#: ``benchmarks/run.py --engine`` overrides this module attribute globally,
#: which is the supported way to flip every simulation in a process.
DEFAULT_ENGINE = "batched"


@dataclasses.dataclass
class CoreCounters:
    """Per-core event counts for one layer at one timestep."""

    msgs_in: np.ndarray        # input messages seen by each core (broadcast)
    synops: np.ndarray         # format-effective weight fetches per core
    macs: np.ndarray           # nnz multiply-accumulates per core
    acts: np.ndarray           # neuron updates per core
    msgs_out: np.ndarray       # messages emitted per core
    neurons: np.ndarray        # neurons mapped per core
    sparse_format: bool


@dataclasses.dataclass
class BatchCoreCounters:
    """Per-core event counts for one layer over ALL timesteps (time-major:
    every array is (T, cores) except ``neurons``)."""

    msgs_in: np.ndarray        # (T, cores) input messages (broadcast)
    synops: np.ndarray         # (T, cores)
    macs: np.ndarray           # (T, cores)
    acts: np.ndarray           # (T, cores)
    msgs_out: np.ndarray       # (T, cores)
    neurons: np.ndarray        # (cores,)
    sparse_format: bool


def _segment_sums(per_neuron: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    csum = np.concatenate([[0.0], np.cumsum(per_neuron, dtype=np.float64)])
    return csum[bounds[1:]] - csum[bounds[:-1]]


def _layer_format(layer, profile: ChipProfile) -> bool:
    fmt = layer.weight_format or (
        profile.default_format_conv if layer.kind == "conv"
        else profile.default_format_fc)
    return fmt == "sparse"


def aggregate_layer(counters: CounterMaps, layer_idx: int, part: Partition,
                    net: SimNetwork, profile: ChipProfile) -> CoreCounters:
    layer = net.layers[layer_idx]
    n = layer.n_neurons
    bounds = part.boundaries(layer_idx, n)
    sparse = _layer_format(layer, profile)
    macs = _segment_sums(counters.macs, bounds)
    fetches_dense = _segment_sums(counters.fetches_dense, bounds)
    synops = macs if sparse else fetches_dense
    acts_map = (counters.acts_evented if not profile.synchronous
                else np.ones_like(counters.macs))
    return CoreCounters(
        msgs_in=np.full(part.cores[layer_idx], counters.msgs_in, np.float64),
        synops=np.asarray(synops, np.float64),
        macs=np.asarray(macs, np.float64),
        acts=_segment_sums(acts_map, bounds),
        msgs_out=_segment_sums(counters.msgs_out, bounds),
        neurons=np.diff(bounds).astype(np.float64),
        sparse_format=sparse,
    )


def core_times(cc, neuron_model: str,
               profile: ChipProfile) -> tuple[np.ndarray, np.ndarray]:
    """(memory-stage, compute-stage) time per core of one layer.  Works on
    both per-step :class:`CoreCounters` and time-major
    :class:`BatchCoreCounters` (the formulas are elementwise)."""
    p = profile
    if cc.sparse_format:
        mem = (cc.msgs_in * (p.c_msg_recv + p.c_decode_msg)
               + cc.synops * (p.c_fetch + p.c_decode_word + p.c_mac))
    else:
        mem = cc.msgs_in * p.c_msg_recv + cc.synops * (p.c_fetch + p.c_mac)
    act = cc.acts * p.neuron_cost(neuron_model)
    return mem, act


@dataclasses.dataclass
class SimReport:
    """Simulation output: performance + M0 metrics + raw per-core arrays.

    ``time_per_step``/``energy_per_step`` are means over the per-step
    ``times``/``energies`` arrays (for asynchronous platforms a "step" is a
    sample and ``times`` holds pipeline latencies).  ``max_synops``,
    ``max_acts`` and ``max_link_load`` are the M0 neurocore-aware intensity
    metrics: per-step maxima over cores (routers for link load), averaged
    over steps — the x-axis / floor / traffic terms of the floorline model.
    The ``per_core_*`` arrays are per-logical-core means over steps in
    partition order; the §VI-B optimizer and the evolutionary search read
    them to locate bottleneck layers.  ``bottleneck_stage`` names the term
    ("memory" / "compute" / "traffic" / "barrier") that set the step time on
    a plurality of steps.
    """

    time_per_step: float            # mean over steps (timestep duration /
                                    # sample latency for async chips)
    energy_per_step: float
    times: np.ndarray               # per-step
    energies: np.ndarray
    metrics: WorkloadMetrics        # M0 (means over steps)
    max_synops: float               # mean over steps of max-per-core synops
    max_acts: float
    max_link_load: float
    n_cores_active: int
    outputs: np.ndarray             # functional network outputs (T, out)
    per_core_synops: np.ndarray     # (n_logical_cores,) mean over steps
    per_core_acts: np.ndarray
    per_core_msgs_out: np.ndarray
    bottleneck_stage: str           # which term set the mean step time

    def summary(self) -> str:
        return (f"time/step={self.time_per_step:.1f} "
                f"energy/step={self.energy_per_step:.1f} "
                f"max_synops={self.max_synops:.0f} "
                f"cores={self.n_cores_active} "
                f"bottleneck={self.bottleneck_stage}")


def simulate(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
             part: Partition | None = None,
             mapping: Mapping | None = None, *,
             engine: str | None = None,
             compute=None,
             precomputed: tuple | None = None,
             sparsity_profile=None) -> SimReport:
    """Run the network on the simulated chip and price every timestep.

    Args:
      engine: "batched" (layer-major, default) or "reference" (step-major).
      compute: per-layer synaptic backend — ``"dense"`` (default) or
        ``"event"``, a :class:`~repro.neuromorphic.compute.LayerCompute`
        instance, or None for
        :data:`repro.neuromorphic.compute.DEFAULT_COMPUTE`.  Both engines
        honor it; counters (and therefore the priced report) are exact
        across backends, outputs agree to float roundoff.
      precomputed: a cached ``net.run_batch(xs)`` result to reuse — the
        functional run is independent of partition/mapping/profile, so
        optimization loops that re-price many partitions of the same
        (net, xs) pair should compute it once.  Batched engine only: the
        reference engine ignores it and re-runs the network step-major.
        Takes precedence over ``compute`` (the run is already done).
      sparsity_profile: a trained
        :class:`~repro.sparsity.profile.SparsityProfile` to program onto
        ``net`` (via its ``apply``) before simulation — per-layer message
        gates + weight masks; the pricing math itself is untouched, so
        every engine/backend parity guarantee carries over.  Mutually
        exclusive with ``precomputed`` (a functional run is net-bound).
    """
    engine = engine or DEFAULT_ENGINE
    if sparsity_profile is not None:
        if precomputed is not None:
            raise ValueError("sparsity_profile cannot be combined with "
                             "precomputed: the cached run is bound to the "
                             "un-profiled network")
        net = sparsity_profile.apply(net)
    part = part or minimal_partition(net, profile)
    mapping = mapping or ordered_mapping(part, profile)
    if engine == "batched":
        return _simulate_batched(net, xs, profile, part, mapping, precomputed,
                                 compute)
    if engine == "reference":
        return _simulate_reference(net, xs, profile, part, mapping, compute)
    raise ValueError(f"unknown engine {engine!r}")


def _finish_report(net, part, T, times, energies, outputs, mean_synops,
                   mean_acts, mean_msgs, max_synops_steps, max_acts_steps,
                   max_link_steps, total_msgs, total_neuron_steps,
                   stage_votes) -> SimReport:
    """Shared report assembly for both engines (identical float math)."""
    w_nnz = sum(l.w_nnz for l in net.layers)
    w_cap = sum(l.n_weights for l in net.layers)
    metrics = WorkloadMetrics(
        synops=LoadStats.of(mean_synops),
        acts=LoadStats.of(mean_acts),
        traffic=LoadStats.of(np.array([max_link_steps.mean()])),
        msgs_total=total_msgs / T,
        weight_density=w_nnz / max(w_cap, 1),
        act_density=(total_msgs / max(total_neuron_steps, 1.0)),
    )
    bottleneck = max(stage_votes.items(), key=lambda kv: kv[1])[0]
    return SimReport(
        time_per_step=float(times.mean()),
        energy_per_step=float(energies.mean()),
        times=times, energies=energies, metrics=metrics,
        max_synops=float(max_synops_steps.mean()),
        max_acts=float(max_acts_steps.mean()),
        max_link_load=float(max_link_steps.mean()),
        n_cores_active=part.total_cores,
        outputs=outputs,
        per_core_synops=mean_synops,
        per_core_acts=mean_acts,
        per_core_msgs_out=mean_msgs,
        bottleneck_stage=bottleneck,
    )


@dataclasses.dataclass
class LayerPricing:
    """Partition/mapping-independent pricing state for one layer: neuron-axis
    cumulative sums of every counter map, so any core boundary's segment sum
    is a 2-element gather (same cumulative-sum difference as the per-step
    :func:`_segment_sums`, identical bits for every partition — and, unlike
    ``np.add.reduceat``, an empty segment correctly sums to 0 when a
    partition holds more cores than the layer has neurons)."""

    msgs_in: np.ndarray        # (T,) float64
    csum_macs: np.ndarray      # (T, n_neurons + 1) float64
    csum_fetches: np.ndarray   # (T, n_neurons + 1)
    csum_acts: np.ndarray      # (T, n_neurons + 1) of the profile's acts map
    csum_msgs: np.ndarray      # (T, n_neurons + 1)
    n_neurons: int
    sparse: bool


@dataclasses.dataclass
class PricingCache:
    """Everything :func:`price_candidate` needs that does not depend on the
    candidate: the functional outputs plus per-layer :class:`LayerPricing`.
    ``vmap_pricer`` lazily holds the compiled population pricer for the
    ``backend="vmap"`` path (one per cache — a cache is bound to one
    (net, xs, profile) workload)."""

    outputs: np.ndarray
    T: int
    layers: list[LayerPricing]
    vmap_pricer: object = dataclasses.field(default=None, repr=False,
                                            compare=False)
    #: lazily-built :class:`DevicePopulationPricer` for the ``device``
    #: backend / the device-resident search engine (one per cache)
    device_pricer_obj: object = dataclasses.field(default=None, repr=False,
                                                  compare=False)
    #: per-partition padded index rows, keyed by the cores tuple (see
    #: :func:`build_population_batch`)
    row_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                        compare=False)


def _neuron_csum(per_neuron: np.ndarray) -> np.ndarray:
    """(T, n) -> (T, n+1) cumulative sum with a leading zero column; paired
    with :func:`_seg` it is the batched analog of :func:`_segment_sums`."""
    a = np.asarray(per_neuron, np.float64)
    return np.concatenate([np.zeros((a.shape[0], 1)),
                           np.cumsum(a, axis=1)], axis=1)


def precompute_pricing(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                       *, precomputed: tuple | None = None,
                       compute=None, sparsity_profile=None) -> PricingCache:
    """Run the functional network (or reuse a cached ``net.run_batch(xs)``
    result) and reduce its counter maps to per-layer cumsums.  One cache
    prices any number of (partition, mapping) candidates.  ``compute``
    selects the synaptic backend of the functional run (counters — and so
    the cache — are exact across backends).  ``sparsity_profile`` programs
    a trained :class:`~repro.sparsity.profile.SparsityProfile` onto ``net``
    before the run (mutually exclusive with ``precomputed``)."""
    if sparsity_profile is not None:
        if precomputed is not None:
            raise ValueError("sparsity_profile cannot be combined with "
                             "precomputed: the cached run is bound to the "
                             "un-profiled network")
        net = sparsity_profile.apply(net)
    outputs, all_counters = precomputed or net.run_batch(xs, compute=compute)
    layers = []
    for l, counters in enumerate(all_counters):
        acts_map = (counters.acts_evented if not profile.synchronous
                    else np.ones_like(counters.macs))
        layers.append(LayerPricing(
            msgs_in=np.asarray(counters.msgs_in, np.float64),
            csum_macs=_neuron_csum(counters.macs),
            csum_fetches=_neuron_csum(counters.fetches_dense),
            csum_acts=_neuron_csum(acts_map),
            csum_msgs=_neuron_csum(counters.msgs_out),
            n_neurons=net.layers[l].n_neurons,
            sparse=_layer_format(net.layers[l], profile)))
    return PricingCache(outputs=outputs, T=int(xs.shape[0]), layers=layers)


def _seg(csum: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    """(T, cores) segment sums from cached cumsums: a two-point gather and
    subtraction per core boundary."""
    return csum[:, bounds[1:]] - csum[:, bounds[:-1]]


def _seg_population(csum: np.ndarray, bounds_stack: np.ndarray) -> np.ndarray:
    """Stacked population gather: (T, n+1) cumsums x (K, C+1) padded
    per-candidate boundaries -> (K, T, C) segment sums for every candidate
    in one indexing operation.  Padded (repeated) boundaries yield empty
    zero segments that callers slice away; each candidate's slice carries
    exactly the bits :func:`_seg` would produce."""
    g = csum[:, bounds_stack]                       # (T, K, C+1)
    return np.moveaxis(g[:, :, 1:] - g[:, :, :-1], 1, 0)


def _cached_layer_counters(lp: LayerPricing, part: Partition, layer_idx: int,
                           T: int,
                           segments: tuple | None = None) -> BatchCoreCounters:
    """All-timesteps analog of :func:`aggregate_layer`, built from a
    :class:`LayerPricing` (and optionally pre-gathered
    ``(macs, fetches, acts, msgs_out)`` segment arrays from the population
    path)."""
    bounds = part.boundaries(layer_idx, lp.n_neurons)
    if segments is None:
        macs = _seg(lp.csum_macs, bounds)
        fetches_dense = _seg(lp.csum_fetches, bounds)
        acts = _seg(lp.csum_acts, bounds)
        msgs_out = _seg(lp.csum_msgs, bounds)
    else:
        macs, fetches_dense, acts, msgs_out = segments
    c = part.cores[layer_idx]
    return BatchCoreCounters(
        msgs_in=np.broadcast_to(lp.msgs_in[:, None], (T, c)),
        synops=macs if lp.sparse else fetches_dense,
        macs=macs,
        acts=acts,
        msgs_out=msgs_out,
        neurons=np.diff(bounds).astype(np.float64),
        sparse_format=lp.sparse,
    )


def simulate_population(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                        candidates, *, precomputed: tuple | None = None,
                        cache: PricingCache | None = None,
                        backend: str = "numpy",
                        compute=None, sparsity_profile=None) -> list[SimReport]:
    """Price many (partition, mapping) candidates from ONE functional run.

    ``candidates`` is an iterable of ``(Partition, Mapping)`` pairs.  The
    expensive (T, n_neurons) work — the functional network run and the
    per-layer counter cumsums — happens once (or is reused from ``cache`` /
    ``precomputed``); each candidate's per-core segment sums are then
    gathered for the whole population at once (:func:`_seg_population`), and
    only the small (T, cores) stage/energy/NoC math runs per candidate.

    Three backends price the population (``docs/simulator.md`` has the
    full decision guide):

    * ``backend="numpy"`` (default) — stacked cumsum gathers plus
      per-candidate NumPy stage math.  Every report is bit-identical to the
      corresponding single-candidate ``simulate(net, xs, profile, part,
      mapping)`` call with the batched engine: the same cumsums are indexed
      and the same float op order runs on the gathered segments (asserted
      by ``tests/test_search.py``).  The reference the other two are
      checked against.
    * ``backend="vmap"`` — one jitted ``jax.vmap`` over the padded
      population axis (:func:`price_population_vmap`); the padded batch
      structures are still assembled on host.  Agrees with the NumPy path
      within float64 roundoff.
    * ``backend="device"`` — the genome rows themselves are the program
      input: candidates are encoded to stacked ``(K, n_layers)`` /
      ``(K, n_slots)`` arrays and everything downstream — segment
      boundaries, NoC flow structures, pricing — runs inside one jitted
      program (:func:`price_population_device`).  Same float64-roundoff
      parity as ``vmap``; this is the pricer the device-resident search
      engine (``repro.core.search``, ``engine="device"``) keeps entirely
      on the accelerator.
    * ``backend="sharded"`` — the device path with the K axis sharded over
      a 1-D ``("island",)`` device mesh (:func:`price_population_sharded`;
      every visible device prices its own block of rows).  Per-row parity
      with ``"device"`` to float64 roundoff; useful past pop ≈ 4k on a
      multi-device host (``docs/distributed.md``).

    ``sparsity_profile`` programs a trained
    :class:`~repro.sparsity.profile.SparsityProfile` onto ``net`` before
    the functional run — every backend then prices the profiled workload
    with its usual parity guarantee (mutually exclusive with ``cache`` /
    ``precomputed``, which are bound to the un-profiled network).
    """
    if sparsity_profile is not None:
        if cache is not None or precomputed is not None:
            raise ValueError("sparsity_profile cannot be combined with "
                             "cache/precomputed: both are bound to the "
                             "un-profiled network")
        net = sparsity_profile.apply(net)
    cands = list(candidates)
    if not cands:
        return []
    for k, (part, mapping) in enumerate(cands):
        if len(mapping.phys) != part.total_cores:
            raise ValueError(
                f"candidate {k}: mapping places {len(mapping.phys)} logical "
                f"cores but the partition allocates {part.total_cores} "
                f"(cores={tuple(part.cores)}); partition and mapping must "
                "agree before pricing")
    cache = cache or precompute_pricing(net, xs, profile,
                                        precomputed=precomputed,
                                        compute=compute)
    if backend == "vmap":
        return price_population_vmap(net, profile, cache, cands)
    if backend == "device":
        cores, perm = _pairs_to_rows(cands, len(cache.layers),
                                     profile.n_cores)
        return price_population_device(net, profile, cache, cores, perm)
    if backend == "sharded":
        cores, perm = _pairs_to_rows(cands, len(cache.layers),
                                     profile.n_cores)
        return price_population_sharded(net, profile, cache, cores, perm)
    if backend != "numpy":
        raise ValueError(f"unknown population backend {backend!r}")
    n_layers = len(cache.layers)
    seg_by_cand: list[list[tuple]] = [[None] * n_layers for _ in cands]
    for l, lp in enumerate(cache.layers):
        all_bounds = [p.boundaries(l, lp.n_neurons) for p, _ in cands]
        c_max = max(len(b) - 1 for b in all_bounds)
        stack = np.stack([np.pad(b, (0, c_max + 1 - len(b)), mode="edge")
                          for b in all_bounds])          # (K, c_max + 1)
        pop_segs = tuple(_seg_population(csum, stack) for csum in
                         (lp.csum_macs, lp.csum_fetches,
                          lp.csum_acts, lp.csum_msgs))
        for k, b in enumerate(all_bounds):
            c = len(b) - 1
            seg_by_cand[k][l] = tuple(s[k, :, :c] for s in pop_segs)
    return [price_candidate(net, profile, cache, p, m,
                            layer_segments=seg_by_cand[k])
            for k, (p, m) in enumerate(cands)]


def _simulate_batched(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                      part: Partition, mapping: Mapping,
                      precomputed: tuple | None, compute=None) -> SimReport:
    """Layer-major engine: one pricing-cache build + one candidate pricing."""
    cache = precompute_pricing(net, xs, profile, precomputed=precomputed,
                               compute=compute)
    return price_candidate(net, profile, cache, part, mapping)


def price_candidate(net: SimNetwork, profile: ChipProfile,
                    cache: PricingCache, part: Partition, mapping: Mapping,
                    *, layer_segments: list[tuple] | None = None) -> SimReport:
    """Price one (partition, mapping) candidate from a pricing cache; every
    per-step quantity is a (T, ...) array."""
    outputs = cache.outputs
    T = cache.T
    n_layers = len(cache.layers)
    n_logical = part.total_cores

    layer_cc = [_cached_layer_counters(
                    cache.layers[l], part, l, T,
                    layer_segments[l] if layer_segments else None)
                for l in range(n_layers)]

    mem_all, act_all = [], []
    e_events = np.zeros(T, np.float64)
    total_msgs = 0.0
    total_neuron_steps = 0.0
    for l, cc in enumerate(layer_cc):
        mem, act = core_times(cc, net.layers[l].neuron_model, profile)
        mem_all.append(mem)
        act_all.append(act)
        # event energies: fetch every (format-effective) synop; MAC energy
        # only on nonzero weights (dense formats skip the multiply ->
        # the small Fig-2 energy benefit of CNN weight sparsity)
        e_events += (profile.e_fetch * cc.synops.sum(axis=1)
                     + profile.e_mac * cc.macs.sum(axis=1)
                     + (profile.e_decode * cc.synops.sum(axis=1)
                        if cc.sparse_format else 0.0)
                     + profile.e_act * cc.acts.sum(axis=1)
                     * (profile.neuron_cost(net.layers[l].neuron_model)
                        / profile.c_act))
        total_msgs += cc.msgs_out.sum()
        total_neuron_steps += T * cc.neurons.sum()

    synops_all = np.concatenate([cc.synops for cc in layer_cc], axis=1)
    acts_all = np.concatenate([cc.acts for cc in layer_cc], axis=1)
    msgs_all = np.concatenate([cc.msgs_out for cc in layer_cc], axis=1)

    traffic = route_batch(part, mapping, msgs_all, profile)
    mem_cat = np.concatenate(mem_all, axis=1)       # (T, n_logical)
    act_cat = np.concatenate(act_all, axis=1)
    core_time = np.maximum(mem_cat, act_cat) + profile.t_core_fixed
    # Congestion: the busiest router serializes every packet touching it;
    # cores also serialize their own (duplicated) injections.
    max_link_steps = traffic.max_router_load        # (T,)
    traffic_time = (profile.c_route * max_link_steps
                    + profile.c_inject
                    * traffic.inject_per_core.max(axis=1, initial=0.0))

    stage_votes = {"memory": 0, "compute": 0, "traffic": 0, "barrier": 0}
    if profile.synchronous:
        t_compute = core_time.max(axis=1, initial=0.0)
        times = np.maximum(t_compute, traffic_time) + profile.t_barrier
        traffic_bound = traffic_time > t_compute
        mem_bound = (mem_cat.max(axis=1, initial=0.0)
                     >= act_cat.max(axis=1, initial=0.0))
        stage_votes["traffic"] = int(traffic_bound.sum())
        stage_votes["memory"] = int((~traffic_bound & mem_bound).sum())
        stage_votes["compute"] = int((~traffic_bound & ~mem_bound).sum())
    else:
        # async pipeline: sample latency = sum over layers of the layer's
        # slowest event-driven core + NoC transit
        times = np.zeros(T, np.float64)
        for m, a in zip(mem_all, act_all):
            times = times + np.maximum(m, a).max(axis=1, initial=0.0)
        times = times + (profile.c_msg_hop * traffic.total_hops
                         / max(part.total_cores, 1))
        stage_votes["memory"] = T

    n_active = np.sum((synops_all + msgs_all) > 0, axis=1).astype(np.float64)
    n_active[n_active == 0] = n_logical
    e_hops = profile.e_msg_hop * traffic.total_hops
    energies = (times * (profile.p_idle + profile.p_core * n_active)
                + e_events + e_hops)

    mean_synops = synops_all.sum(axis=0) / T
    mean_acts = acts_all.sum(axis=0) / T
    mean_msgs = msgs_all.sum(axis=0) / T
    return _finish_report(
        net, part, T, times, energies, outputs, mean_synops, mean_acts,
        mean_msgs,
        max_synops_steps=synops_all.max(axis=1, initial=0.0),
        max_acts_steps=acts_all.max(axis=1, initial=0.0),
        max_link_steps=max_link_steps,
        total_msgs=total_msgs, total_neuron_steps=total_neuron_steps,
        stage_votes=stage_votes)


@dataclasses.dataclass(frozen=True)
class LayerStageTimes:
    """Per-layer floorline coordinates (one row per network layer).

    ``mem_time`` / ``act_time`` are the mean-over-steps memory/compute stage
    times of the layer's slowest core (the same :func:`core_times` formulas
    the pricer uses); ``traffic_time`` is the layer's share of the NoC
    serialization time, apportioned by its message volume; ``msgs_out`` is
    its mean messages per step.  These are the coordinates
    :func:`repro.core.guidance.floorline_layer_guidance` classifies with
    the :class:`~repro.core.floorline.FloorlineModel`.
    """

    name: str
    mem_time: float
    act_time: float
    traffic_time: float
    msgs_out: float

    @property
    def total_time(self) -> float:
        return max(self.mem_time, self.act_time) + self.traffic_time


def layer_stage_times(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                      part: Partition | None = None,
                      mapping: Mapping | None = None, *,
                      cache: PricingCache | None = None
                      ) -> list[LayerStageTimes]:
    """Decompose a priced workload into per-layer stage times.

    The pricer's report localizes the bottleneck to a *stage*; this
    decomposes it to *layers*, using the identical counter segments and
    stage formulas (the per-layer maxima it reports are the terms whose
    global maxima set the report's step time).  This is the measurement the
    floorline-guided training loop weighs its regularizers with."""
    part = part or minimal_partition(net, profile)
    mapping = mapping or ordered_mapping(part, profile)
    cache = cache or precompute_pricing(net, xs, profile)
    T = cache.T
    layer_cc = [_cached_layer_counters(cache.layers[l], part, l, T)
                for l in range(len(cache.layers))]
    msgs_all = np.concatenate([cc.msgs_out for cc in layer_cc], axis=1)
    traffic = route_batch(part, mapping, msgs_all, profile)
    traffic_time = (profile.c_route * traffic.max_router_load
                    + profile.c_inject
                    * traffic.inject_per_core.max(axis=1, initial=0.0))
    layer_msgs = np.array([cc.msgs_out.sum() for cc in layer_cc], np.float64)
    share = layer_msgs / max(layer_msgs.sum(), 1.0)
    out = []
    for l, cc in enumerate(layer_cc):
        mem, act = core_times(cc, net.layers[l].neuron_model, profile)
        out.append(LayerStageTimes(
            name=net.layers[l].name,
            mem_time=float(mem.max(axis=1, initial=0.0).mean()),
            act_time=float(act.max(axis=1, initial=0.0).mean()),
            traffic_time=float(traffic_time.mean() * share[l]),
            msgs_out=float(layer_msgs[l] / T)))
    return out


# --------------------------------------------------------------- vmap backend
#
# The array-native population pricer: every candidate's (T, cores) stage
# reductions and NoC matmuls run as ONE jitted ``jax.vmap`` over the padded
# population axis.  Padding/masking contract:
#
# * logical cores are padded to a fixed width ``Ncap`` (the workload's
#   maximum feasible total cores, capped at ``profile.n_cores``) so the
#   compiled executable is reused across generations and population sizes;
# * a padded core has ``seg_lo == seg_hi == 0`` (its cumsum gather is an
#   empty segment -> exact 0 counters), ``mask == 0`` (its broadcast
#   ``msgs_in`` and fixed core overhead are zeroed before any max/sum), and
#   all-zero flow-matrix rows (it injects nothing into the NoC);
# * per-layer cost constants are folded into per-layer coefficient vectors in
#   float64 Python — the same constant folding as the NumPy path — and
#   gathered per core through the layer-id vector.
#
# Arithmetic runs in float64 (``jax.experimental.enable_x64`` scoped to this
# path), with the same elementwise formulas and reduction semantics as the
# NumPy path; XLA may reassociate/fuse (FMA), so results agree to float64
# roundoff rather than bit-for-bit — the parity suite asserts
# ``rtol=1e-9`` (``tests/test_population_pricing.py``).


@dataclasses.dataclass
class PopulationBatch:
    """Padded, stacked pricing inputs for one candidate population (the
    array-native genome view consumed by the jitted pricer).  ``PL``/``ph``
    carry the path-incidence-folded routing structures of
    :func:`repro.neuromorphic.noc.router_incidence_population`, so the NoC
    term is two tiny (T, cores) matmuls per candidate instead of a dense
    (T, R*R) flow-tensor build."""

    mask: np.ndarray       # (K, Ncap) float64; 1.0 on live cores
    lid: np.ndarray        # (K, Ncap) int32 layer id per core (0 on padding)
    seg_lo: np.ndarray     # (K, Ncap) int32 into the concatenated cumsums
    seg_hi: np.ndarray     # (K, Ncap) int32
    neurons: np.ndarray    # (K, Ncap) float64 neurons per core
    PL: np.ndarray         # (K, Ncap, R) float64 router-load incidence
    ph: np.ndarray         # (K, Ncap) float64 per-core hop factors
    dup: np.ndarray        # (K, Ncap) float64 unicast duplication factors
    n_logical: np.ndarray  # (K,) int


def population_pad_width(net: SimNetwork, profile: ChipProfile) -> int:
    """Fixed logical-core padding width for (net, profile): every feasible
    candidate fits, and the jitted pricer compiles exactly once."""
    cap = sum(min(max_cores_for_layer(net, l), profile.n_cores)
              for l in range(len(net.layers)))
    return min(cap, profile.n_cores)


#: Per-partition index rows (seg_lo/seg_hi/lid/neurons) are mapping- and
#: population-independent; survivors carried between generations reuse them.
_ROW_CACHE_MAX = 8192


def build_population_batch(cache: PricingCache, net: SimNetwork,
                           profile: ChipProfile, pairs,
                           n_pad: int | None = None) -> PopulationBatch:
    """(Partition, Mapping) pairs -> padded stacked arrays.  Boundaries come
    from the same :meth:`Partition.boundaries` the scalar path uses, so the
    gathered segments index identical cumsum entries."""
    pairs = list(pairs)
    K = len(pairs)
    n_pad = n_pad or population_pad_width(net, profile)
    lo = np.zeros((K, n_pad), np.int32)
    hi = np.zeros((K, n_pad), np.int32)
    lid = np.zeros((K, n_pad), np.int32)
    mask = np.zeros((K, n_pad), np.float64)
    neurons = np.zeros((K, n_pad), np.float64)
    n_logical = np.zeros(K, int)
    # offsets of each layer's (n_neurons + 1)-wide cumsum block in the
    # concatenated cumsum arrays
    widths = [lp.n_neurons + 1 for lp in cache.layers]
    block_off = np.concatenate([[0], np.cumsum(widths)]).astype(np.int32)
    rows = cache.row_cache
    for k, (part, _) in enumerate(pairs):
        if part.total_cores > n_pad:
            raise ValueError(
                f"candidate uses {part.total_cores} cores > pad width {n_pad}")
        hit = rows.get(part.cores)
        if hit is None:
            lo_k, hi_k, lid_k, neu_k = [], [], [], []
            for l, lp in enumerate(cache.layers):
                b = part.boundaries(l, lp.n_neurons).astype(np.int32)
                lo_k.append(block_off[l] + b[:-1])
                hi_k.append(block_off[l] + b[1:])
                lid_k.append(np.full(len(b) - 1, l, np.int32))
                neu_k.append(np.diff(b).astype(np.float64))
            hit = (np.concatenate(lo_k), np.concatenate(hi_k),
                   np.concatenate(lid_k), np.concatenate(neu_k))
            if len(rows) >= _ROW_CACHE_MAX:
                rows.clear()
            rows[part.cores] = hit
        n = hit[0].shape[0]
        lo[k, :n], hi[k, :n], lid[k, :n], neurons[k, :n] = hit
        mask[k, :n] = 1.0
        n_logical[k] = n
    PL, ph, dup = router_incidence_population(
        [p.cores for p, _ in pairs],
        [m.phys[:p.total_cores] for p, m in pairs],
        profile.grid, profile.n_cores, n_pad)
    return PopulationBatch(mask=mask, lid=lid, seg_lo=lo, seg_hi=hi,
                           neurons=neurons, PL=PL, ph=ph, dup=dup,
                           n_logical=n_logical)


class _VmapPricer:
    """Compiled population pricer bound to one :class:`PricingCache`.

    Holds the device-resident workload constants (concatenated counter
    cumsums, per-layer coefficient vectors, NoC path incidence — reusing the
    per-grid lru caches of :mod:`repro.neuromorphic.noc`) and the jitted
    vmapped pricing function.  Shapes are fixed by ``Ncap``; the population
    axis K is the vmap axis, so a new population size only re-traces, it
    does not rebuild the constants.
    """

    def __init__(self, net: SimNetwork, profile: ChipProfile,
                 cache: PricingCache):
        self.profile = profile
        self.synchronous = profile.synchronous
        self.T = cache.T
        self.n_layers = len(cache.layers)
        w_nnz = sum(l.w_nnz for l in net.layers)
        w_cap = sum(l.n_weights for l in net.layers)
        self.weight_density = w_nnz / max(w_cap, 1)
        p = profile
        # per-layer coefficient vectors, folded with the SAME Python-float
        # constant arithmetic as core_times()/price_candidate()
        mem_msg, mem_syn, ncost, sparse_f, e_act_c = [], [], [], [], []
        for l, lp in enumerate(cache.layers):
            model = net.layers[l].neuron_model
            if lp.sparse:
                mem_msg.append(p.c_msg_recv + p.c_decode_msg)
                mem_syn.append(p.c_fetch + p.c_decode_word + p.c_mac)
            else:
                mem_msg.append(p.c_msg_recv)
                mem_syn.append(p.c_fetch + p.c_mac)
            ncost.append(p.neuron_cost(model))
            sparse_f.append(1.0 if lp.sparse else 0.0)
            e_act_c.append(p.e_act * (p.neuron_cost(model) / p.c_act))
        with enable_x64():
            self.csums = tuple(
                jnp.asarray(np.concatenate([getattr(lp, f) for lp in
                                            cache.layers], axis=1))
                for f in ("csum_macs", "csum_fetches", "csum_acts",
                          "csum_msgs"))
            self.msgs_in_all = jnp.asarray(
                np.stack([lp.msgs_in for lp in cache.layers], axis=1))
            self.coefs = tuple(jnp.asarray(np.asarray(v, np.float64))
                               for v in (mem_msg, mem_syn, ncost, sparse_f,
                                         e_act_c))
        self._fn = jax.jit(jax.vmap(
            self._price_one, in_axes=(0, 0, 0, 0, 0, 0, 0, 0)))

    # ---- the per-candidate pricing program (vmapped over axis 0) --------
    def _price_one(self, mask, lid, seg_lo, seg_hi, neurons, PL, ph, dup):
        p = self.profile
        T = self.T
        csum_macs, csum_fetches, csum_acts, csum_msgs = self.csums
        mem_msg, mem_syn, ncost, sparse_f, e_act_c = self.coefs

        macs = csum_macs[:, seg_hi] - csum_macs[:, seg_lo]        # (T, Ncap)
        fetches = csum_fetches[:, seg_hi] - csum_fetches[:, seg_lo]
        acts = csum_acts[:, seg_hi] - csum_acts[:, seg_lo]
        msgs = csum_msgs[:, seg_hi] - csum_msgs[:, seg_lo]

        sp_c = sparse_f[lid]                                      # (Ncap,)
        synops = jnp.where(sp_c > 0, macs, fetches)
        msgs_in_c = self.msgs_in_all[:, lid] * mask               # (T, Ncap)
        mem = msgs_in_c * mem_msg[lid] + synops * mem_syn[lid]
        act = acts * ncost[lid]
        core_time = (jnp.maximum(mem, act) + p.t_core_fixed) * mask

        e_events = (p.e_fetch * synops.sum(axis=1)
                    + p.e_mac * macs.sum(axis=1)
                    + p.e_decode * (synops * sp_c).sum(axis=1)
                    + (acts * e_act_c[lid]).sum(axis=1))

        loads = msgs @ PL                                         # (T, R)
        hops = msgs @ ph                                          # (T,)
        inject = msgs * dup
        max_link = loads.max(axis=1)
        traffic_time = (p.c_route * max_link
                        + p.c_inject * inject.max(axis=1))

        n_logical = mask.sum().astype(jnp.int32)
        if self.synchronous:
            t_compute = core_time.max(axis=1)
            times = jnp.maximum(t_compute, traffic_time) + p.t_barrier
            tb = traffic_time > t_compute
            mb = mem.max(axis=1) >= act.max(axis=1)
            votes = jnp.stack([(~tb & mb).sum(), (~tb & ~mb).sum(),
                               tb.sum(), jnp.zeros((), jnp.int32)])
        else:
            val = jnp.maximum(mem, act)                           # (T, Ncap)
            per_layer = jax.ops.segment_max(
                (val * mask).T, lid, num_segments=self.n_layers)  # (L, T)
            times = (jnp.maximum(per_layer, 0.0).sum(axis=0)
                     + p.c_msg_hop * hops / jnp.maximum(n_logical, 1))
            votes = jnp.stack([jnp.full((), T, jnp.int32)] +
                              [jnp.zeros((), jnp.int32)] * 3)

        n_active = (((synops + msgs) > 0) & (mask > 0)).sum(axis=1)
        n_active = jnp.where(n_active == 0, n_logical, n_active)
        energies = (times * (p.p_idle + p.p_core * n_active)
                    + e_events + p.e_msg_hop * hops)

        mean_synops = synops.sum(axis=0) / T
        mean_acts = acts.sum(axis=0) / T
        mean_msgs = msgs.sum(axis=0) / T
        total_msgs = msgs.sum()
        return dict(
            times=times, energies=energies,
            time_per_step=times.mean(), energy_per_step=energies.mean(),
            max_synops=synops.max(axis=1).mean(),
            max_acts=acts.max(axis=1).mean(),
            max_link_load=max_link.mean(),
            mean_synops=mean_synops, mean_acts=mean_acts,
            mean_msgs=mean_msgs,
            # LoadStats ingredients (pads are exact zeros -> don't count)
            syn_total=mean_synops.sum(), syn_max=mean_synops.max(),
            syn_nact=(mean_synops > 0).sum(),
            act_total=mean_acts.sum(), act_max=mean_acts.max(),
            act_nact=(mean_acts > 0).sum(),
            votes=votes,
            total_msgs=total_msgs,
            total_neuron_steps=T * neurons.sum(),
        )

    def price(self, batch: PopulationBatch) -> dict:
        """Run the jitted pricer; returns host NumPy arrays with a leading
        population axis."""
        with enable_x64():
            out = self._fn(jnp.asarray(batch.mask), jnp.asarray(batch.lid),
                           jnp.asarray(batch.seg_lo),
                           jnp.asarray(batch.seg_hi),
                           jnp.asarray(batch.neurons), jnp.asarray(batch.PL),
                           jnp.asarray(batch.ph), jnp.asarray(batch.dup))
        return jax.device_get(out)


def price_population_vmap(net: SimNetwork, profile: ChipProfile,
                          cache: PricingCache, pairs) -> list[SimReport]:
    """Price a candidate population with the jitted ``jax.vmap`` pipeline.

    Functionally equivalent to the NumPy :func:`simulate_population` path
    (same cumsums, same boundaries, same cost formulas) within float64
    roundoff; ~an order of magnitude higher pricing throughput at
    population >= 64 because the per-candidate Python/NumPy dispatch
    collapses into one compiled program (``BENCH_search.json``).
    """
    pairs = list(pairs)
    if not pairs:
        return []
    if cache.vmap_pricer is None:
        cache.vmap_pricer = _VmapPricer(net, profile, cache)
    pricer: _VmapPricer = cache.vmap_pricer
    batch = build_population_batch(cache, net, profile, pairs)
    out = pricer.price(batch)
    return _assemble_reports(out, batch.n_logical, cache,
                             pricer.weight_density)


def _assemble_reports(out, n_logical, cache: PricingCache,
                      w_density: float) -> list[SimReport]:
    """Host-side :class:`SimReport` assembly shared by the vmap and device
    population backends: ``out`` is the pricer's host dict with a leading
    population axis, ``n_logical`` the (K,) live-core counts."""
    T = cache.T
    outputs = cache.outputs
    stage_names = ("memory", "compute", "traffic", "barrier")
    reports = []
    for k in range(len(n_logical)):
        n = int(n_logical[k])
        votes = out["votes"][k]

        def _stats(total, mx, n_act):
            total, mx, n_act = float(total), float(mx), int(n_act)
            mean = total / max(n_act, 1)
            return LoadStats(total=total, max=mx, mean=mean,
                             imbalance=(mx / mean) if mean > 0 else 1.0,
                             n_units=int(n), n_active=n_act)

        link_mean = float(out["max_link_load"][k])
        total_msgs = float(out["total_msgs"][k])
        metrics = WorkloadMetrics(
            synops=_stats(out["syn_total"][k], out["syn_max"][k],
                          out["syn_nact"][k]),
            acts=_stats(out["act_total"][k], out["act_max"][k],
                        out["act_nact"][k]),
            traffic=LoadStats(
                total=link_mean, max=link_mean,
                mean=link_mean if link_mean > 0 else 0.0, imbalance=1.0,
                n_units=1, n_active=int(link_mean > 0)),
            msgs_total=total_msgs / T,
            weight_density=w_density,
            act_density=(total_msgs
                         / max(float(out["total_neuron_steps"][k]), 1.0)),
        )
        reports.append(SimReport(
            time_per_step=float(out["time_per_step"][k]),
            energy_per_step=float(out["energy_per_step"][k]),
            times=out["times"][k], energies=out["energies"][k],
            metrics=metrics,
            max_synops=float(out["max_synops"][k]),
            max_acts=float(out["max_acts"][k]),
            max_link_load=link_mean,
            n_cores_active=n,
            outputs=outputs,
            per_core_synops=out["mean_synops"][k, :n],
            per_core_acts=out["mean_acts"][k, :n],
            per_core_msgs_out=out["mean_msgs"][k, :n],
            bottleneck_stage=stage_names[int(np.argmax(votes))],
        ))
    return reports


# ------------------------------------------------------------- device backend
#
# The device-resident population pricer: where the vmap backend still
# assembles its padded batch structures (segment boundaries, flow matrices)
# on host per generation, this path takes the raw genome arrays —
# (K, n_layers) core counts + (K, n_slots) slot permutations — as the
# program input and derives EVERYTHING on device: per-core layer ids and
# cumsum gather indices from an integer decode of the core-count rows, and
# the NoC (PL, ph, dup) structures from a pure-jnp scatter/fold
# (:func:`repro.neuromorphic.noc.flow_structures_rows`).  Because the
# decode is shape-static it traces into larger jitted programs — the
# device-resident evolutionary search keeps survivor genomes on the
# accelerator across generations and re-prices them without any host sync.
#
# Boundary parity: ``Partition.boundaries`` is ``np.linspace(0, n, c+1)
# .astype(int)`` = ``int(i * (n/c))`` with the endpoint pinned to ``n``;
# the decode reproduces exactly that float64 arithmetic, so the gathered
# cumsum indices are identical to the host paths' and pricing agrees with
# the vmap backend bit-for-bit (and with NumPy to float64 roundoff).


class DevicePopulationPricer:
    """Genome-array population pricer bound to one :class:`PricingCache`.

    ``price(cores, perm)`` accepts already-on-device (or host) stacked
    genome rows and returns the pricing dict; :meth:`price_row` is the
    traced single-genome program for composition into larger jitted
    functions (the device search engine vmaps it inside its generation
    step).  Beyond the :class:`_VmapPricer` outputs it adds the
    mutation-policy fields the search consumes on device: ``stage``
    (argmax of the bottleneck votes, memory/compute/traffic/barrier order)
    and ``hot_mem``/``hot_act`` (layer of the max-loaded core).
    """

    def __init__(self, net: SimNetwork, profile: ChipProfile,
                 cache: PricingCache):
        if cache.vmap_pricer is None:
            cache.vmap_pricer = _VmapPricer(net, profile, cache)
        self.base: _VmapPricer = cache.vmap_pricer
        self.profile = profile
        self.n_layers = len(cache.layers)
        self.n_pad = population_pad_width(net, profile)
        rows, cols = profile.grid
        self.cpr = max(1, profile.n_cores // (rows * cols))
        widths = np.asarray([lp.n_neurons + 1 for lp in cache.layers])
        with enable_x64():
            self.block_off = jnp.asarray(
                np.concatenate([[0], np.cumsum(widths)])[:-1]
                .astype(np.int32))
            self.n_neurons_vec = jnp.asarray(
                np.asarray([lp.n_neurons for lp in cache.layers], np.int32))
            inc3, hops2 = incidence_tables(profile.grid)
            self.inc3 = jnp.asarray(inc3)
            self.hops2 = jnp.asarray(hops2)
        self._fn = jax.jit(jax.vmap(self.price_row))

    def structures_row(self, cores_row, perm_row):
        """(n_layers,) cores + (n_slots,) perm -> the padded per-core
        pricing structures of :class:`PopulationBatch`, all on device."""
        L, ncap = self.n_layers, self.n_pad
        csum = jnp.cumsum(cores_row)                        # (L,)
        total = csum[-1]
        j = jnp.arange(ncap)
        alive = j < total
        lid = jnp.minimum(jnp.searchsorted(csum, j, side="right"),
                          L - 1).astype(jnp.int32)
        within = j - (csum - cores_row)[lid]                # index in layer
        n_l = self.n_neurons_vec[lid]
        c_l = cores_row[lid]
        # same float64 arithmetic as np.linspace(0, n, c+1).astype(int)
        step = n_l.astype(jnp.float64) / c_l.astype(jnp.float64)
        lo_loc = (within.astype(jnp.float64) * step).astype(jnp.int32)
        hi_loc = jnp.where(within + 1 == c_l, n_l,
                           ((within + 1).astype(jnp.float64) * step)
                           .astype(jnp.int32))
        lid = jnp.where(alive, lid, 0)
        seg_lo = jnp.where(alive, self.block_off[lid] + lo_loc, 0) \
            .astype(jnp.int32)
        seg_hi = jnp.where(alive, self.block_off[lid] + hi_loc, 0) \
            .astype(jnp.int32)
        neurons = jnp.where(alive, hi_loc - lo_loc, 0).astype(jnp.float64)
        mask = alive.astype(jnp.float64)
        router = jnp.where(alive, perm_row[:ncap] // self.cpr, 0) \
            .astype(jnp.int32)
        PL, ph, dup = flow_structures_rows(lid, router, mask, L,
                                           self.inc3, self.hops2)
        return mask, lid, seg_lo, seg_hi, neurons, PL, ph, dup

    def price_row(self, cores_row, perm_row):
        """The traced per-genome pricing program (vmap/jit composable)."""
        mask, lid, seg_lo, seg_hi, neurons, PL, ph, dup = \
            self.structures_row(cores_row, perm_row)
        out = self.base._price_one(mask, lid, seg_lo, seg_hi, neurons,
                                   PL, ph, dup)
        out["stage"] = jnp.argmax(out["votes"]).astype(jnp.int32)
        out["hot_mem"] = lid[jnp.argmax(out["mean_synops"])]
        out["hot_act"] = lid[jnp.argmax(out["mean_acts"])]
        return out

    def price(self, cores, perm, *, device: bool = False) -> dict:
        """Price stacked genome rows (host or device arrays).  Returns the
        pricing dict on host (``device=False``, default) or device-resident
        (``device=True`` — no transfer, for callers that keep going on
        device)."""
        with enable_x64():
            out = self._fn(jnp.asarray(cores, jnp.int32),
                           jnp.asarray(perm, jnp.int32))
        return out if device else jax.device_get(out)


def device_pricer(net: SimNetwork, profile: ChipProfile,
                  cache: PricingCache) -> DevicePopulationPricer:
    """The cache's :class:`DevicePopulationPricer` (built on first use; a
    cache is bound to one (net, xs, profile) workload, so one pricer —
    and its compiled programs — serves every population it prices)."""
    if cache.device_pricer_obj is None:
        cache.device_pricer_obj = DevicePopulationPricer(net, profile, cache)
    return cache.device_pricer_obj


def _pairs_to_rows(pairs, n_layers: int,
                   n_slots: int) -> tuple[np.ndarray, np.ndarray]:
    """(Partition, Mapping) pairs -> stacked fixed-shape genome rows; the
    permutation tail (unexpressed slots) is filled ascending, mirroring
    ``repro.core.search.encode``."""
    K = len(pairs)
    cores = np.zeros((K, n_layers), np.int32)
    perm = np.zeros((K, n_slots), np.int32)
    for k, (part, mapping) in enumerate(pairs):
        cores[k] = part.cores
        used = [int(p) for p in mapping.phys]
        taken = set(used)
        perm[k] = used + [s for s in range(n_slots) if s not in taken]
    return cores, perm


def price_population_device(net: SimNetwork, profile: ChipProfile,
                            cache: PricingCache, cores,
                            perm) -> list[SimReport]:
    """Device-resident re-pricing entry point: price already-stacked (and
    possibly already-on-device) genome rows — ``cores`` (K, n_layers),
    ``perm`` (K, n_slots) — and assemble host :class:`SimReport`\\ s.

    This is the report-producing wrapper over
    :meth:`DevicePopulationPricer.price`; loops that stay on device (the
    ``engine="device"`` search) skip it and compose
    :meth:`DevicePopulationPricer.price_row` into their own jitted step,
    only materializing reports for the candidates they return.
    """
    pricer = device_pricer(net, profile, cache)
    n_layers, n_slots = len(cache.layers), int(profile.n_cores)
    if (np.ndim(cores) != 2 or np.ndim(perm) != 2
            or cores.shape[1] != n_layers or perm.shape[1] != n_slots
            or cores.shape[0] != perm.shape[0]):
        raise ValueError(
            f"genome rows must be cores (K, {n_layers}) and perm "
            f"(K, {n_slots}) for this (network, profile); got "
            f"cores {np.shape(cores)} and perm {np.shape(perm)}")
    out = pricer.price(cores, perm)
    n_logical = np.asarray(jax.device_get(cores), np.int64).sum(axis=1)
    return _assemble_reports(out, n_logical, cache,
                             pricer.base.weight_density)


def price_population_sharded(net: SimNetwork, profile: ChipProfile,
                             cache: PricingCache, cores, perm, *,
                             mesh=None) -> list[SimReport]:
    """Mesh-aware population pricing: the K axis sharded over a 1-D
    ``("island",)`` device mesh.

    Each device prices its own block of genome rows with the same traced
    :meth:`DevicePopulationPricer.price_row` program the single-device
    backend vmaps, inside one ``shard_map``; per-row outputs are therefore
    within float64 roundoff of ``backend="device"`` (pricing is row-
    independent).  ``mesh`` defaults to
    :func:`repro.distributed.sharding.island_mesh` over every visible
    device; K is padded up to a multiple of the island count with copies
    of row 0 and the padding is dropped from the returned reports.

    This is the report-producing wrapper; the sharded evolutionary search
    (``engine="sharded"``) composes ``price_row`` directly into its own
    per-island generation step instead (``repro.core.device_search``).
    """
    from jax.sharding import PartitionSpec
    from repro.distributed.compat import shard_map
    pricer = device_pricer(net, profile, cache)
    n_layers, n_slots = len(cache.layers), int(profile.n_cores)
    if (np.ndim(cores) != 2 or np.ndim(perm) != 2
            or cores.shape[1] != n_layers or perm.shape[1] != n_slots
            or cores.shape[0] != perm.shape[0]):
        raise ValueError(
            f"genome rows must be cores (K, {n_layers}) and perm "
            f"(K, {n_slots}) for this (network, profile); got "
            f"cores {np.shape(cores)} and perm {np.shape(perm)}")
    if mesh is None:
        from repro.distributed.sharding import island_mesh
        mesh = island_mesh()
    n_islands = int(mesh.shape["island"])
    K = int(np.shape(cores)[0])
    pad = (-K) % n_islands
    cores_h = np.asarray(jax.device_get(cores), np.int32)
    perm_h = np.asarray(jax.device_get(perm), np.int32)
    if pad:
        cores_h = np.concatenate([cores_h, np.repeat(cores_h[:1], pad, 0)])
        perm_h = np.concatenate([perm_h, np.repeat(perm_h[:1], pad, 0)])
    fns = pricer.__dict__.setdefault("_sharded_price_fns", {})
    mesh_key = (n_islands, tuple(d.id for d in mesh.devices.flat))
    if mesh_key not in fns:
        spec = PartitionSpec("island")
        fns[mesh_key] = jax.jit(shard_map(
            jax.vmap(pricer.price_row), mesh=mesh,
            in_specs=(spec, spec), out_specs=spec, check_vma=False))
    with enable_x64():
        out = jax.device_get(fns[mesh_key](jnp.asarray(cores_h),
                                           jnp.asarray(perm_h)))
    if pad:
        out = {k: v[:K] for k, v in out.items()}
    n_logical = cores_h[:K].astype(np.int64).sum(axis=1)
    return _assemble_reports(out, n_logical, cache,
                             pricer.base.weight_density)


def _simulate_reference(net: SimNetwork, xs: np.ndarray,
                        profile: ChipProfile, part: Partition,
                        mapping: Mapping, compute=None) -> SimReport:
    """Step-major reference engine (original implementation)."""
    outputs, all_counters = net.run(xs, compute=compute)

    T = xs.shape[0]
    n_layers = len(net.layers)
    n_logical = part.total_cores
    times = np.zeros(T)
    energies = np.zeros(T)
    sum_core_synops = np.zeros(n_logical)
    sum_core_acts = np.zeros(n_logical)
    sum_core_msgs = np.zeros(n_logical)
    max_synops_steps = np.zeros(T)
    max_acts_steps = np.zeros(T)
    max_link_steps = np.zeros(T)
    stage_votes = {"memory": 0, "compute": 0, "traffic": 0, "barrier": 0}
    total_msgs = 0.0
    total_neuron_steps = 0.0

    offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)

    for t in range(T):
        layer_cc = [aggregate_layer(all_counters[t][l], l, part, net, profile)
                    for l in range(n_layers)]
        mem_all, act_all = [], []
        msgs_out_per_core = []
        e_events = 0.0
        for l, cc in enumerate(layer_cc):
            mem, act = core_times(cc, net.layers[l].neuron_model, profile)
            mem_all.append(mem)
            act_all.append(act)
            msgs_out_per_core.append(cc.msgs_out)
            sl = slice(offsets[l], offsets[l + 1])
            sum_core_synops[sl] += cc.synops
            sum_core_acts[sl] += cc.acts
            sum_core_msgs[sl] += cc.msgs_out
            e_events += (profile.e_fetch * cc.synops.sum()
                         + profile.e_mac * cc.macs.sum()
                         + (profile.e_decode * cc.synops.sum()
                            if cc.sparse_format else 0.0)
                         + profile.e_act * cc.acts.sum()
                         * (profile.neuron_cost(net.layers[l].neuron_model)
                            / profile.c_act))
            total_msgs += cc.msgs_out.sum()
            total_neuron_steps += cc.neurons.sum()

        traffic = route_step(part, mapping, msgs_out_per_core, profile)
        mem_cat = np.concatenate(mem_all)
        act_cat = np.concatenate(act_all)
        core_time = np.maximum(mem_cat, act_cat) + profile.t_core_fixed
        traffic_time = (profile.c_route * traffic.max_router_load
                        + profile.c_inject
                        * float(traffic.inject_per_core.max(initial=0.0)))

        if profile.synchronous:
            t_compute = float(core_time.max(initial=0.0))
            t_step = max(t_compute, traffic_time) + profile.t_barrier
            which = ("traffic" if traffic_time > t_compute else
                     ("memory" if mem_cat.max(initial=0.0)
                      >= act_cat.max(initial=0.0) else "compute"))
        else:
            per_layer = [float(np.maximum(m, a).max(initial=0.0))
                         for m, a in zip(mem_all, act_all)]
            t_step = sum(per_layer) + profile.c_msg_hop * traffic.total_hops / max(
                part.total_cores, 1)
            which = "memory"

        n_active = int(np.sum(np.concatenate(
            [cc.synops + cc.msgs_out for cc in layer_cc]) > 0)) or n_logical
        e_hops = profile.e_msg_hop * traffic.total_hops
        energies[t] = (t_step * (profile.p_idle + profile.p_core * n_active)
                       + e_events + e_hops)
        times[t] = t_step
        stage_votes[which] += 1
        syn_step = np.concatenate([cc.synops for cc in layer_cc])
        acts_step = np.concatenate([cc.acts for cc in layer_cc])
        max_synops_steps[t] = syn_step.max(initial=0.0)
        max_acts_steps[t] = acts_step.max(initial=0.0)
        max_link_steps[t] = traffic.max_router_load

    return _finish_report(
        net, part, T, times, energies, outputs,
        mean_synops=sum_core_synops / T,
        mean_acts=sum_core_acts / T,
        mean_msgs=sum_core_msgs / T,
        max_synops_steps=max_synops_steps, max_acts_steps=max_acts_steps,
        max_link_steps=max_link_steps,
        total_msgs=total_msgs, total_neuron_steps=total_neuron_steps,
        stage_votes=stage_votes)
