"""Barrier-synchronized timestep cost model + full simulation entry point.

Implements the paper's execution model (§II-A, Fig. 1 bottom): within a
timestep every neurocore (1) accumulates synops for each input message,
(2) computes activations, (3) emits activation messages, (4) barrier-syncs.
Per-core synop and activation stages are pipelined, so a core's time is the
max of its memory stage and compute stage (the floorline's straight-boundary
assumption, §VI-A); the timestep is set by the slowest core or by NoC
congestion, plus barrier overhead.

Asynchronous platforms (Speck) have no barrier: a sample's latency is the
pipeline sum over layers of event-driven core work, and idle cores consume
no active power.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.metrics import LoadStats, WorkloadMetrics
from repro.neuromorphic.network import CounterMaps, SimNetwork
from repro.neuromorphic.noc import Mapping, NocTraffic, ordered_mapping, route_step
from repro.neuromorphic.partition import Partition, minimal_partition
from repro.neuromorphic.platform import ChipProfile


@dataclasses.dataclass
class CoreCounters:
    """Per-core event counts for one layer at one timestep."""

    msgs_in: np.ndarray        # input messages seen by each core (broadcast)
    synops: np.ndarray         # format-effective weight fetches per core
    macs: np.ndarray           # nnz multiply-accumulates per core
    acts: np.ndarray           # neuron updates per core
    msgs_out: np.ndarray       # messages emitted per core
    neurons: np.ndarray        # neurons mapped per core
    sparse_format: bool


def _segment_sums(per_neuron: np.ndarray, bounds: np.ndarray) -> np.ndarray:
    csum = np.concatenate([[0.0], np.cumsum(per_neuron, dtype=np.float64)])
    return csum[bounds[1:]] - csum[bounds[:-1]]


def aggregate_layer(counters: CounterMaps, layer_idx: int, part: Partition,
                    net: SimNetwork, profile: ChipProfile) -> CoreCounters:
    layer = net.layers[layer_idx]
    n = layer.n_neurons
    bounds = part.boundaries(layer_idx, n)
    fmt = layer.weight_format or (
        profile.default_format_conv if layer.kind == "conv"
        else profile.default_format_fc)
    sparse = fmt == "sparse"
    macs = _segment_sums(counters.macs, bounds)
    fetches_dense = _segment_sums(counters.fetches_dense, bounds)
    synops = macs if sparse else fetches_dense
    acts_map = (counters.acts_evented if not profile.synchronous
                else np.ones_like(counters.macs))
    return CoreCounters(
        msgs_in=np.full(part.cores[layer_idx], counters.msgs_in, np.float64),
        synops=np.asarray(synops, np.float64),
        macs=np.asarray(macs, np.float64),
        acts=_segment_sums(acts_map, bounds),
        msgs_out=_segment_sums(counters.msgs_out, bounds),
        neurons=np.diff(bounds).astype(np.float64),
        sparse_format=sparse,
    )


def core_times(cc: CoreCounters, neuron_model: str,
               profile: ChipProfile) -> tuple[np.ndarray, np.ndarray]:
    """(memory-stage, compute-stage) time per core of one layer."""
    p = profile
    if cc.sparse_format:
        mem = (cc.msgs_in * (p.c_msg_recv + p.c_decode_msg)
               + cc.synops * (p.c_fetch + p.c_decode_word + p.c_mac))
    else:
        mem = cc.msgs_in * p.c_msg_recv + cc.synops * (p.c_fetch + p.c_mac)
    act = cc.acts * p.neuron_cost(neuron_model)
    return mem, act


@dataclasses.dataclass
class SimReport:
    """Simulation output: performance + M0 metrics + raw per-core arrays."""

    time_per_step: float            # mean over steps (timestep duration /
                                    # sample latency for async chips)
    energy_per_step: float
    times: np.ndarray               # per-step
    energies: np.ndarray
    metrics: WorkloadMetrics        # M0 (means over steps)
    max_synops: float               # mean over steps of max-per-core synops
    max_acts: float
    max_link_load: float
    n_cores_active: int
    outputs: np.ndarray             # functional network outputs (T, out)
    per_core_synops: np.ndarray     # (n_logical_cores,) mean over steps
    per_core_acts: np.ndarray
    per_core_msgs_out: np.ndarray
    bottleneck_stage: str           # which term set the mean step time

    def summary(self) -> str:
        return (f"time/step={self.time_per_step:.1f} "
                f"energy/step={self.energy_per_step:.1f} "
                f"max_synops={self.max_synops:.0f} "
                f"cores={self.n_cores_active} "
                f"bottleneck={self.bottleneck_stage}")


def simulate(net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
             part: Partition | None = None,
             mapping: Mapping | None = None) -> SimReport:
    """Run the network on the simulated chip and price every timestep."""
    part = part or minimal_partition(net, profile)
    mapping = mapping or ordered_mapping(part, profile)
    outputs, all_counters = net.run(xs)

    T = xs.shape[0]
    n_layers = len(net.layers)
    n_logical = part.total_cores
    times = np.zeros(T)
    energies = np.zeros(T)
    sum_core_synops = np.zeros(n_logical)
    sum_core_acts = np.zeros(n_logical)
    sum_core_msgs = np.zeros(n_logical)
    max_synops_steps = np.zeros(T)
    max_acts_steps = np.zeros(T)
    max_link_steps = np.zeros(T)
    stage_votes = {"memory": 0, "compute": 0, "traffic": 0, "barrier": 0}
    total_msgs = 0.0
    total_neuron_steps = 0.0

    offsets = np.concatenate([[0], np.cumsum(part.cores)]).astype(int)

    for t in range(T):
        layer_cc = [aggregate_layer(all_counters[t][l], l, part, net, profile)
                    for l in range(n_layers)]
        mem_all, act_all = [], []
        msgs_out_per_core = []
        e_events = 0.0
        for l, cc in enumerate(layer_cc):
            mem, act = core_times(cc, net.layers[l].neuron_model, profile)
            mem_all.append(mem)
            act_all.append(act)
            msgs_out_per_core.append(cc.msgs_out)
            sl = slice(offsets[l], offsets[l + 1])
            sum_core_synops[sl] += cc.synops
            sum_core_acts[sl] += cc.acts
            sum_core_msgs[sl] += cc.msgs_out
            # event energies: fetch every (format-effective) synop; MAC energy
            # only on nonzero weights (dense formats skip the multiply ->
            # the small Fig-2 energy benefit of CNN weight sparsity)
            e_events += (profile.e_fetch * cc.synops.sum()
                         + profile.e_mac * cc.macs.sum()
                         + (profile.e_decode * cc.synops.sum()
                            if cc.sparse_format else 0.0)
                         + profile.e_act * cc.acts.sum()
                         * (profile.neuron_cost(net.layers[l].neuron_model)
                            / profile.c_act))
            total_msgs += cc.msgs_out.sum()
            total_neuron_steps += cc.neurons.sum()

        traffic = route_step(part, mapping, msgs_out_per_core, profile)
        mem_cat = np.concatenate(mem_all)
        act_cat = np.concatenate(act_all)
        core_time = np.maximum(mem_cat, act_cat) + profile.t_core_fixed
        # Congestion: the busiest router serializes every packet touching it;
        # cores also serialize their own (duplicated) injections.
        traffic_time = (profile.c_route * traffic.max_router_load
                        + profile.c_inject
                        * float(traffic.inject_per_core.max(initial=0.0)))

        if profile.synchronous:
            t_compute = float(core_time.max(initial=0.0))
            t_step = max(t_compute, traffic_time) + profile.t_barrier
            which = ("traffic" if traffic_time > t_compute else
                     ("memory" if mem_cat.max(initial=0.0)
                      >= act_cat.max(initial=0.0) else "compute"))
        else:
            # async pipeline: sample latency = sum over layers of the layer's
            # slowest event-driven core + NoC transit
            per_layer = [float(np.maximum(m, a).max(initial=0.0))
                         for m, a in zip(mem_all, act_all)]
            t_step = sum(per_layer) + profile.c_msg_hop * traffic.total_hops / max(
                part.total_cores, 1)
            which = "memory"

        n_active = int(np.sum(np.concatenate(
            [cc.synops + cc.msgs_out for cc in layer_cc]) > 0)) or n_logical
        e_hops = profile.e_msg_hop * traffic.total_hops
        energies[t] = (t_step * (profile.p_idle + profile.p_core * n_active)
                       + e_events + e_hops)
        times[t] = t_step
        stage_votes[which] += 1
        syn_step = np.concatenate([cc.synops for cc in layer_cc])
        acts_step = np.concatenate([cc.acts for cc in layer_cc])
        max_synops_steps[t] = syn_step.max(initial=0.0)
        max_acts_steps[t] = acts_step.max(initial=0.0)
        max_link_steps[t] = traffic.max_router_load

    mean_synops = sum_core_synops / T
    mean_acts = sum_core_acts / T
    mean_msgs = sum_core_msgs / T

    w_nnz = sum(float((l.weights != 0).sum()) for l in net.layers)
    w_cap = sum(l.n_weights for l in net.layers)
    metrics = WorkloadMetrics(
        synops=LoadStats.of(mean_synops),
        acts=LoadStats.of(mean_acts),
        traffic=LoadStats.of(np.array([max_link_steps.mean()])),
        msgs_total=total_msgs / T,
        weight_density=w_nnz / max(w_cap, 1),
        act_density=(total_msgs / max(total_neuron_steps, 1.0)),
    )
    bottleneck = max(stage_votes.items(), key=lambda kv: kv[1])[0]
    return SimReport(
        time_per_step=float(times.mean()),
        energy_per_step=float(energies.mean()),
        times=times, energies=energies, metrics=metrics,
        max_synops=float(max_synops_steps.mean()),
        max_acts=float(max_acts_steps.mean()),
        max_link_load=float(max_link_steps.mean()),
        n_cores_active=n_logical,
        outputs=outputs,
        per_core_synops=mean_synops,
        per_core_acts=mean_acts,
        per_core_msgs_out=mean_msgs,
        bottleneck_stage=bottleneck,
    )
