"""Neuromorphic chip profiles (paper §IV).

Cost constants are *relative units* calibrated so that synop memory access,
activation compute, and NoC hop costs sit within one order of magnitude of
each other, per the circuit-level analyses the paper builds on ([12], [52]).
The paper reports normalized performance; we do the same — trends, crossovers
and ratios are the validation target, not absolute seconds/joules.
"""

from __future__ import annotations

import dataclasses


# Per-neuron-update instruction-cost multipliers (relative to plain ReLU).
# SD-ReLU keeps sigma-delta state (reconstruct + threshold + quantize);
# SSM neurons update recurrent state (complex diag A -> 2 real MACs + IO).
NEURON_COST = {
    "relu": 1.0,
    "if": 1.2,        # integrate-and-fire: accumulate, compare, reset
    "sd_relu": 2.5,   # sigma-delta ReLU [34]
    "ssm": 6.0,       # S5-style state update [38], [47]
}


@dataclasses.dataclass(frozen=True)
class ChipProfile:
    """One neuromorphic accelerator's architecture + cost model."""

    name: str
    n_cores: int
    grid: tuple[int, int]               # NoC *router* grid (rows, cols); several
                                        # cores share each router tile
    neurons_per_core: int               # neuron-state memory limit
    synapses_per_core: int              # synaptic weight memory limit (words)
    synchronous: bool = True            # barrier-synchronized timesteps
    allow_partitioning: bool = True     # Speck: one layer per core, no splits

    # --- timing costs (relative time units) -------------------------------
    c_fetch: float = 1.0        # fetch one synaptic weight word
    c_mac: float = 0.25         # multiply-accumulate one fetched weight
    c_decode_word: float = 0.25 # sparse-format per-word decode overhead
    c_decode_msg: float = 8.0   # sparse-format fixed per-message decode setup
    c_msg_recv: float = 2.0     # receive/enqueue one input message
    c_act: float = 4.0          # one neuron update (x NEURON_COST multiplier)
    c_msg_hop: float = 1.5      # one message crossing one NoC link
    c_route: float = 1.0        # router service time per packet touching it
    c_inject: float = 0.5       # per-packet injection serialization at a core
    t_barrier: float = 100.0    # barrier sync + timestep bookkeeping
    t_core_fixed: float = 20.0  # per-active-core fixed timestep overhead

    # --- energy costs (relative energy units) -----------------------------
    e_fetch: float = 1.0
    e_mac: float = 0.8          # skipped for zero weights (dense format)
    e_decode: float = 0.2
    e_act: float = 2.0
    e_msg_hop: float = 1.2
    p_idle: float = 0.05        # static power (energy per time unit)
    p_core: float = 0.02        # per-active-core power (energy per time unit)

    # Default weight format per layer kind; Fig. 4: Loihi 2 defaults to dense
    # for CNNs and sparse for linearly-connected layers.
    default_format_fc: str = "sparse"
    default_format_conv: str = "dense"

    def neuron_cost(self, neuron_model: str) -> float:
        return self.c_act * NEURON_COST[neuron_model]


def loihi2_like(**overrides) -> ChipProfile:
    """Research-class chip: 120 programmable cores, arbitrary partitioning,
    selectable weight formats (paper §IV-3)."""
    return ChipProfile(
        name="loihi2_like", n_cores=120, grid=(5, 6),   # 30 routers x 4 cores
        neurons_per_core=8192, synapses_per_core=64 * 1024,
        synchronous=True, allow_partitioning=True,
        **overrides,
    )


def akd1000_like(**overrides) -> ChipProfile:
    """Edge CNN accelerator: 80 cores, dense CNN weight formatting only
    (paper §IV-1 — explains the Fig. 2 weight-sparsity non-result)."""
    return ChipProfile(
        name="akd1000_like", n_cores=80, grid=(4, 5),   # 20 routers x 4 cores
        neurons_per_core=8192, synapses_per_core=128 * 1024,
        synchronous=True, allow_partitioning=True,
        default_format_fc="dense", default_format_conv="dense",
        **overrides,
    )


def speck_like(**overrides) -> ChipProfile:
    """Micro-edge event-camera chip: 9 cores, fully asynchronous, one layer
    per core, IF neurons (paper §IV-2).  Async => no barrier; cores idle when
    no events are present, and sample latency is the pipeline sum."""
    return ChipProfile(
        name="speck_like", n_cores=9, grid=(3, 3),
        neurons_per_core=128 * 1024, synapses_per_core=256 * 1024,
        synchronous=False, allow_partitioning=False,
        default_format_fc="dense", default_format_conv="dense",
        t_barrier=0.0, p_idle=0.002,   # near-zero static draw when idle
        **overrides,
    )


PROFILES = {
    "loihi2": loihi2_like,
    "akd1000": akd1000_like,
    "speck": speck_like,
}
