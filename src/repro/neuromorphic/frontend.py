"""Model-zoo workload frontend: compile a model config into a priceable
:class:`~repro.neuromorphic.network.SimNetwork`.

Every number the floorline produces is a function of exact event counters
(MACs / weight fetches / NoC messages), so "running a real model" on the
simulator means emitting a layer stack whose *counters* reproduce the
per-token cost arithmetic of the architecture — not its floating-point
function.  :func:`compile_network` takes any
:class:`repro.configs.registry.ArchEntry` id (or a raw
:class:`~repro.models.common.ModelCfg` / :class:`~repro.models.encdec.EncDecCfg`)
and lowers it block by block onto the existing ``SimLayer`` vocabulary:

**Execution model.**  One simulator timestep = one decoded token at steady
state.  The residual stream (width ``d_model``) is the feed-forward chain
backbone; each block becomes a short chain of ``fc`` layers mapping
``d_model -> ... -> d_model``.  The embedding lookup is the network input
(its fetch cost rides on the first layer's input messages) and RMSNorm
scales fold into the adjacent projection (``diag(g) @ W`` — exact linear
algebra, no extra fetches), so norms/embeddings appear only in
:func:`excluded_params`, the documented remainder that makes
``sum(param nnz) + excluded_params(cfg) == cfg.param_count()`` an identity.

**Attention** lowers through the flash-attention kernel contract
(:mod:`repro.kernels.flash_attn`) into an fc-equivalent counter map over a
steady-state context of ``S = min(window, seq_len)`` positions:

* ``qkv``    ``(d, q+2kv)`` dense — the per-token Q/K/V projections; the
  K/V output messages are real NoC traffic (they leave for the KV cache).
* ``scores`` ``(q+2kv, H*S)`` block-sparse — score neuron ``(h, s)`` reads
  exactly its head's ``head_dim`` query lanes: ``H*S*head_dim`` MACs/token,
  the exact ``q . k`` cost of one decode step.
* ``values`` ``(H*S, q)`` block-sparse — output lane ``(h, j)`` reads its
  head's ``S`` score neurons: ``q*S`` MACs/token, the exact ``a . v`` cost.
* ``out``    ``(q, d)`` dense.

The ``scores``/``values`` weights are stand-ins for cache contents (role
``"kv"``, zero parameter nnz); each lowering site is recorded as an
:class:`AttnSpec` so :func:`attention_probe` can execute the *real* Pallas
kernel against its jnp oracle at exactly the lowered (heads, head_dim, seq)
shape (``compile_network(verify_attention=True)`` does this inline).

**SSD / RG-LRU** mixers put their recurrence on the simulator's stateful
neuron models (``"ssm"`` by default, ``recurrent_neuron="sd_relu"`` maps the
state stream onto sigma-delta messaging instead): ``in -> state -> out``
with the state layer's fanin wired per head/group (x channel + B/C group
taps + dt), ``2*d_state + 2`` synapses per state neuron.

**MoE** blocks emit each expert as a contiguous column block (a natural
partition unit) plus ``n_experts`` router-logit columns; a static
``msg_gate`` keeps exactly ``top_k + n_shared`` expert blocks messaging, so
the router's top-k drives per-expert activation density and the down
projection's event-driven MACs are ``(top_k + n_shared) * d_ff * d`` —
:meth:`ModelCfg.active_param_count` arithmetic, produced by counters.

All emitted layers are ``kind="fc"`` with static gates, so the compiled
network inherits every existing guarantee unchanged: bit-identical counters
across the two engines (batched/reference) and compute backends
(dense/event), pricing caches, population backends and the evolutionary
search all accept it like any hand-built network.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.common import BlockCfg, ModelCfg, MoECfg, RGLRUCfg, SSDCfg
from repro.models.encdec import EncDecCfg
from repro.neuromorphic.network import SimLayer, SimNetwork, make_inputs

DEFAULT_SEQ_LEN = 16        # steady-state decode context for smoke pricing
_RECURRENT_NEURONS = ("ssm", "sd_relu")


# ===================================================================== specs

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """One attention lowering site == one flash_attn kernel instance."""

    name: str
    heads: int
    kv_heads: int
    head_dim: int
    seq: int                        # steady-state context length S
    causal: bool = True
    window: int | None = None
    softcap: float | None = None
    cross: bool = False             # encoder-decoder cross attention


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Closed-form description of one emitted fc layer.

    ``nnz``/``macs_per_token`` are *arithmetic* (derived from the config,
    not from built weights); compile asserts the built mask reproduces them
    and the property suite asserts the simulator's counters do too.
    ``macs_per_token`` assumes the dense-activity token pipeline (every
    ungated neuron messaging, the compile default).
    """

    name: str
    fanin: int
    width: int
    structure: tuple                # mask family, see _structure_mask
    role: str                       # "param" | "kv" | "state" | "head"
    nnz: int                        # structural nonzero synapses
    param_nnz: int                  # contribution to cfg.param_count()
    macs_per_token: int             # exact MACs per timestep
    neuron_model: str = "relu"
    gate: tuple | None = None       # ("moe", E, shared, top_k, d_ff)


# ----------------------------------------------------------- mask structures

def _structure_nnz(structure: tuple, fanin: int, width: int) -> int:
    kind = structure[0]
    if kind == "dense":
        return fanin * width
    if kind == "first_rows":
        return structure[1] * width
    if kind in ("attn_scores", "attn_values"):
        _, heads, seq, head_dim = structure
        return heads * seq * head_dim
    if kind == "moe_down":
        _, n_experts_total, n_router, d_ff = structure
        return n_experts_total * d_ff * width
    if kind == "ssd_state":
        _, d_inner, head_dim, n_groups, d_state = structure
        return d_inner * (2 * d_state + 2)
    raise ValueError(f"unknown structure {structure!r}")


def _structure_mask(spec: LayerSpec) -> np.ndarray:
    """0/1 synapse mask (fanin, width) realizing ``spec.structure``."""
    kind = spec.structure[0]
    m = np.zeros((spec.fanin, spec.width), np.float32)
    if kind == "dense":
        m[:] = 1.0
    elif kind == "first_rows":
        m[: spec.structure[1], :] = 1.0
    elif kind == "attn_scores":
        # fanin layout [q | k | v]; neuron (h, s) reads head h's query lanes
        _, heads, seq, hd = spec.structure
        for h in range(heads):
            m[h * hd:(h + 1) * hd, h * seq:(h + 1) * seq] = 1.0
    elif kind == "attn_values":
        # fanin = H*S score lanes; output lane (h, j) reads head h's scores
        _, heads, seq, hd = spec.structure
        for h in range(heads):
            m[h * seq:(h + 1) * seq, h * hd:(h + 1) * hd] = 1.0
    elif kind == "moe_down":
        # fanin layout [expert 0 (wi|wg) .. expert n-1 (wi|wg) | router];
        # only the wi half of each expert projects down
        _, n_tot, n_router, f = spec.structure
        for e in range(n_tot):
            m[e * 2 * f: e * 2 * f + f, :] = 1.0
    elif kind == "ssd_state":
        # fanin layout [x (di) | z (di) | B (G*st) | C (G*st) | dt (h)]
        _, di, hd, groups, st = spec.structure
        n_heads = di // hd
        heads_per_group = n_heads // groups
        for j in range(di):
            head = j // hd
            g = head // heads_per_group
            m[j, j] = 1.0                                        # x channel
            m[2 * di + g * st: 2 * di + (g + 1) * st, j] = 1.0   # B taps
            b0 = 2 * di + groups * st
            m[b0 + g * st: b0 + (g + 1) * st, j] = 1.0           # C taps
            m[2 * di + 2 * groups * st + head, j] = 1.0          # dt
    else:
        raise ValueError(f"unknown structure {spec.structure!r}")
    assert int(m.sum()) == spec.nnz, (spec.name, int(m.sum()), spec.nnz)
    return m


def _structure_gate(spec: LayerSpec) -> np.ndarray | None:
    """Static per-neuron message gate (MoE expert activation)."""
    if spec.gate is None:
        return None
    tag, n_experts, n_shared, top_k, f = spec.gate
    assert tag == "moe"
    g = np.zeros(spec.width, np.float32)
    for e in range(top_k):                       # routed experts kept live
        g[e * 2 * f:(e + 1) * 2 * f] = 1.0
    for e in range(n_experts, n_experts + n_shared):   # always-on experts
        g[e * 2 * f:(e + 1) * 2 * f] = 1.0
    g[-n_experts:] = 1.0                         # router logits always emit
    return g


# ================================================================= lowering

class _Lowering:
    """Accumulates LayerSpecs; tracks the previous layer's gate so per-token
    MAC arithmetic stays exact across gated boundaries."""

    def __init__(self, seq_len: int, recurrent_neuron: str):
        if recurrent_neuron not in _RECURRENT_NEURONS:
            raise ValueError(f"recurrent_neuron must be one of "
                             f"{_RECURRENT_NEURONS}, got {recurrent_neuron!r}")
        self.seq_len = seq_len
        self.recurrent_neuron = recurrent_neuron
        self.specs: list[LayerSpec] = []
        self.attn_specs: list[AttnSpec] = []
        self._prev_gate: tuple | None = None

    def add(self, name: str, fanin: int, width: int, structure: tuple,
            role: str, *, param_nnz: int = 0, neuron_model: str = "relu",
            gate: tuple | None = None) -> None:
        nnz = _structure_nnz(structure, fanin, width)
        if self._prev_gate is None:
            macs = nnz                       # dense input activity
        else:
            # Input messages are gated by the previous layer's static MoE
            # gate: only live expert blocks' wi rows reach nonzero weights.
            tag, n_experts, n_shared, top_k, f = self._prev_gate
            assert structure[0] == "moe_down", \
                "only moe_up -> moe_down gating is lowered"
            macs = (top_k + n_shared) * f * width
        self.specs.append(LayerSpec(
            name=name, fanin=fanin, width=width, structure=structure,
            role=role, nnz=nnz, param_nnz=param_nnz,
            macs_per_token=macs, neuron_model=neuron_model, gate=gate))
        self._prev_gate = gate

    # -------------------------------------------------------------- blocks
    def attn(self, prefix: str, d: int, heads: int, kv_heads: int,
             head_dim: int, *, seq: int, causal: bool = True,
             window: int | None = None, softcap: float | None = None,
             cross: bool = False) -> None:
        q, kv = heads * head_dim, kv_heads * head_dim
        self.add(f"{prefix}.qkv", d, q + 2 * kv, ("dense",), "param",
                 param_nnz=d * (q + 2 * kv))
        self.add(f"{prefix}.scores", q + 2 * kv, heads * seq,
                 ("attn_scores", heads, seq, head_dim), "kv")
        self.add(f"{prefix}.values", heads * seq, q,
                 ("attn_values", heads, seq, head_dim), "kv")
        self.add(f"{prefix}.out", q, d, ("dense",), "param",
                 param_nnz=q * d)
        self.attn_specs.append(AttnSpec(
            name=prefix, heads=heads, kv_heads=kv_heads, head_dim=head_dim,
            seq=seq, causal=causal, window=window, softcap=softcap,
            cross=cross))

    def mlp(self, prefix: str, d: int, d_ff: int) -> None:
        # SwiGLU/GeGLU: wi|wg fused up, gate half carries no down weights
        self.add(f"{prefix}.in", d, 2 * d_ff, ("dense",), "param",
                 param_nnz=2 * d * d_ff)
        self.add(f"{prefix}.out", 2 * d_ff, d, ("first_rows", d_ff),
                 "param", param_nnz=d_ff * d)

    def moe(self, prefix: str, d: int, m: MoECfg) -> None:
        n_tot = m.n_experts + m.n_shared_experts
        f = m.d_ff
        width = n_tot * 2 * f + m.n_experts
        self.add(f"{prefix}.experts_up", d, width, ("dense",), "param",
                 param_nnz=d * width,
                 gate=("moe", m.n_experts, m.n_shared_experts, m.top_k, f))
        self.add(f"{prefix}.experts_down", width, d,
                 ("moe_down", n_tot, m.n_experts, f), "param",
                 param_nnz=n_tot * f * d)

    def ssd(self, prefix: str, d: int, s: SSDCfg) -> None:
        di, st, groups = s.d_inner, s.d_state, s.n_groups
        n_heads = di // s.head_dim
        fan = 2 * di + 2 * groups * st + n_heads
        self.add(f"{prefix}.in", d, fan, ("dense",), "param",
                 param_nnz=d * fan)
        self.add(f"{prefix}.state", fan, di,
                 ("ssd_state", di, s.head_dim, groups, st), "state",
                 neuron_model=self.recurrent_neuron)
        self.add(f"{prefix}.out", di, d, ("dense",), "param",
                 param_nnz=di * d)

    def rglru(self, prefix: str, d: int, r: RGLRUCfg) -> None:
        dr = r.d_rnn
        self.add(f"{prefix}.in", d, 2 * dr, ("dense",), "param",
                 param_nnz=2 * d * dr)
        # r,i gates are two (dr, dr) maps of the x half: lowered as one
        # dense (2dr, dr) recurrence layer — 2*dr^2 params exactly
        self.add(f"{prefix}.gates", 2 * dr, dr, ("dense",), "state",
                 param_nnz=2 * dr * dr, neuron_model=self.recurrent_neuron)
        self.add(f"{prefix}.out", dr, d, ("dense",), "param",
                 param_nnz=dr * d)

    def head(self, d: int, vocab: int) -> None:
        self.add("head", d, vocab, ("dense",), "head", param_nnz=vocab * d)


def _attn_context(window: int | None, seq_len: int) -> int:
    return min(window, seq_len) if window else seq_len


def lowering_spec(cfg, *, seq_len: int = DEFAULT_SEQ_LEN,
                  recurrent_neuron: str = "ssm"
                  ) -> tuple[list[LayerSpec], list[AttnSpec]]:
    """Pure-arithmetic lowering plan for ``cfg`` (no weights built)."""
    lo = _Lowering(seq_len, recurrent_neuron)
    if isinstance(cfg, EncDecCfg):
        d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        for i in range(cfg.n_enc_layers):
            # streaming encoder: one new frame per step, full-frame context
            lo.attn(f"enc{i}.attn", d, H, K, hd, seq=cfg.n_frames,
                    causal=False)
            lo.mlp(f"enc{i}.mlp", d, cfg.d_ff)
        for i in range(cfg.n_dec_layers):
            lo.attn(f"dec{i}.attn", d, H, K, hd, seq=seq_len, causal=True)
            lo.attn(f"dec{i}.xattn", d, H, K, hd, seq=cfg.n_frames,
                    causal=False, cross=True)
            lo.mlp(f"dec{i}.mlp", d, cfg.d_ff)
        lo.head(d, cfg.vocab_size)
        return lo.specs, lo.attn_specs
    if not isinstance(cfg, ModelCfg):
        raise TypeError(f"cannot lower {type(cfg).__name__}; expected "
                        "ModelCfg, EncDecCfg, or a registry arch id")
    d = cfg.d_model
    for bi, blk in enumerate(cfg.all_blocks()):
        prefix = f"b{bi}"
        if blk.kind == "attn":
            lo.attn(f"{prefix}.attn", d, cfg.n_heads, cfg.n_kv_heads,
                    cfg.head_dim, seq=_attn_context(blk.window, seq_len),
                    window=blk.window, softcap=cfg.attn_softcap)
        elif blk.kind == "ssd":
            lo.ssd(f"{prefix}.ssd", d, blk.ssd)
        elif blk.kind == "rglru":
            lo.rglru(f"{prefix}.rglru", d, blk.rglru)
        else:
            raise ValueError(f"unknown block kind {blk.kind!r}")
        if blk.moe is not None:
            lo.moe(f"{prefix}.moe", d, blk.moe)
        elif blk.d_ff:
            lo.mlp(f"{prefix}.mlp", d, blk.d_ff)
    lo.head(d, cfg.vocab_size)
    return lo.specs, lo.attn_specs


def excluded_params(cfg) -> int:
    """Parameters the lowering folds away (norms, convs, scalar gains) or
    absorbs into the network input (untied embeddings).  The frontend
    identity — asserted by the property suite — is::

        sum(spec.param_nnz) + excluded_params(cfg) == cfg.param_count()
    """
    d = cfg.d_model
    if isinstance(cfg, EncDecCfg):
        # per-layer norms (enc 2, dec 3) + enc/dec final norms; embeddings
        # are tied to the lowered head
        return cfg.n_enc_layers * 2 * d + cfg.n_dec_layers * 3 * d + 2 * d
    total = d                                       # final norm
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * d                 # input embedding table
    for blk in cfg.all_blocks():
        total += d                                  # mixer pre-norm
        if blk.moe is not None or blk.d_ff:
            total += d                              # mlp pre-norm
        if blk.post_norms:
            total += 2 * d
        if blk.kind == "attn":
            if cfg.qk_norm:
                total += 2 * cfg.head_dim
        elif blk.kind == "ssd":
            s = blk.ssd
            h = s.d_inner // s.head_dim
            total += s.d_conv * (s.d_inner + 2 * s.n_groups * s.d_state)
            total += 3 * h + s.d_inner              # A_log/D/dt_bias + norm
        elif blk.kind == "rglru":
            total += blk.rglru.d_conv * blk.rglru.d_rnn + blk.rglru.d_rnn
    return total


# ================================================================== compile

@dataclasses.dataclass
class CompiledNetwork:
    """A priceable SimNetwork plus the arithmetic it was compiled from."""

    net: SimNetwork
    cfg: object                     # ModelCfg | EncDecCfg
    name: str
    arch_id: str | None
    family: str | None
    seq_len: int
    specs: list[LayerSpec]
    attn_specs: list[AttnSpec]

    @property
    def d_model(self) -> int:
        return self.net.in_size

    def param_layer_nnz(self) -> int:
        """Total parameter-bearing synapses (== param_count - excluded)."""
        return sum(s.param_nnz for s in self.specs)

    def macs_per_token(self) -> int:
        """Exact per-timestep MAC total of the dense-activity pipeline."""
        return sum(s.macs_per_token for s in self.specs)

    def inputs(self, steps: int, *, density: float = 1.0,
               seed: int = 0) -> np.ndarray:
        """(steps, d_model) embedded-token stream for the compiled net."""
        return make_inputs(self.net.in_size, density, steps, seed)


def _resolve(arch, smoke: bool):
    """(cfg, name, arch_id, family) from an arch id or a raw config."""
    if isinstance(arch, str):
        from repro.configs import registry
        entry = registry.get(arch)
        cfg = entry.smoke() if smoke else entry.config
        return cfg, cfg.name, entry.arch_id, entry.family
    return arch, arch.name, None, None


def _resolve_densities(act_density, n_layers: int) -> list[float | None]:
    """Per-layer message densities from a scalar, a per-layer schedule (any
    length — resampled over normalized depth, the trained analog of
    ``benchmarks.workloads.schedule``), or a
    :class:`~repro.sparsity.profile.SparsityProfile`."""
    if act_density is None:
        return [None] * n_layers
    if hasattr(act_density, "densities_for"):          # SparsityProfile
        return [float(d) for d in act_density.densities_for(n_layers)]
    if isinstance(act_density, (int, float)):
        return [float(act_density)] * n_layers
    seq = np.asarray(act_density, np.float64)
    if seq.ndim != 1 or seq.size == 0:
        raise ValueError("act_density schedule must be a non-empty 1-D "
                         f"sequence; got shape {seq.shape}")
    if seq.size == n_layers:
        return [float(d) for d in seq]
    if seq.size == 1:
        return [float(seq[0])] * n_layers
    src = np.linspace(0.0, 1.0, seq.size)
    dst = np.linspace(0.0, 1.0, n_layers)
    return [float(d) for d in np.interp(dst, src, seq)]


def _build_layer(spec: LayerSpec, rng: np.random.Generator,
                 act_density: float | None) -> SimLayer:
    mask = _structure_mask(spec)
    # weight magnitudes bounded away from zero so nnz (hence every counter)
    # is exactly the structural count; scale keeps the forced-active
    # message magnitudes stable across deep stacks
    scale = 0.5 / np.sqrt(max(1.0, spec.nnz / spec.width))
    vals = rng.normal(0.0, 1.0, (spec.fanin, spec.width))
    w = np.where(vals >= 0, 1.0, -1.0) * (0.5 + np.abs(vals)) * scale
    w = (w * mask).astype(np.float32)
    gate = _structure_gate(spec)
    if act_density is not None:
        live = np.nonzero(gate)[0] if gate is not None \
            else np.arange(spec.width)
        keep = int(round(act_density * live.size))
        g = np.zeros(spec.width, np.float32)
        if keep > 0:
            g[rng.choice(live, size=keep, replace=False)] = 1.0
        gate = g
    sd = spec.neuron_model == "sd_relu"
    return SimLayer(
        name=spec.name, kind="fc", weights=w,
        neuron_model=spec.neuron_model, msg_gate=gate,
        force_active=not sd, decay=0.5,
        threshold=0.05 if sd else 0.0, sends_deltas=sd)


def compile_network(arch, *, seq_len: int = DEFAULT_SEQ_LEN,
                    smoke: bool = True, seed: int = 0,
                    act_density=None,
                    recurrent_neuron: str = "ssm",
                    verify_attention: bool = False) -> CompiledNetwork:
    """Compile a registry arch id (or raw config) into a CompiledNetwork.

    ``arch``: a ``repro.configs.registry`` id (``smoke=True`` selects the
    arch's smoke config, ``False`` the full assigned config) or a
    ``ModelCfg`` / ``EncDecCfg`` instance.  ``seq_len`` sets the
    steady-state decode context (attention layers price
    ``min(window, seq_len)`` cache positions).  ``act_density`` programs an
    exact message density on top of the structural gates (None = the dense
    token pipeline, the counter-exact default); it accepts a scalar, a
    per-layer density schedule (any length — resampled over normalized
    depth), or a trained :class:`~repro.sparsity.profile.SparsityProfile`
    (its measured densities drive the lowered layers — the trained
    replacement for synthetic schedules).  ``verify_attention`` runs
    the real flash_attn kernel against its oracle at every lowered
    attention shape before returning.
    """
    cfg, name, arch_id, family = _resolve(arch, smoke)
    specs, attn_specs = lowering_spec(cfg, seq_len=seq_len,
                                      recurrent_neuron=recurrent_neuron)
    rng = np.random.default_rng(seed)
    dens = _resolve_densities(act_density, len(specs))
    layers = [_build_layer(s, rng, d) for s, d in zip(specs, dens)]
    net = SimNetwork(layers=layers, in_size=cfg.d_model)
    compiled = CompiledNetwork(
        net=net, cfg=cfg, name=name, arch_id=arch_id, family=family,
        seq_len=seq_len, specs=specs, attn_specs=attn_specs)
    if verify_attention:
        for spec in attn_specs:
            out, ref = attention_probe(spec, seed=seed)
            err = float(np.max(np.abs(out - ref))) if out.size else 0.0
            if err > 2e-4:
                raise ValueError(
                    f"flash_attn kernel diverged from oracle at {spec} "
                    f"(max err {err:.2e})")
    return compiled


def attention_probe(spec: AttnSpec, *, seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Run the Pallas flash_attn kernel and its jnp oracle at exactly the
    (heads, head_dim, seq) shape ``spec`` was lowered for; returns
    ``(kernel_out, oracle_out)`` as float32 arrays."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attn import flash_attention, flash_attention_ref

    kq, kk, kv = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(kq, (1, spec.seq, spec.heads, spec.head_dim),
                          jnp.float32)
    k = jax.random.normal(kk, (1, spec.seq, spec.kv_heads, spec.head_dim),
                          jnp.float32)
    v = jax.random.normal(kv, (1, spec.seq, spec.kv_heads, spec.head_dim),
                          jnp.float32)
    kw = dict(causal=spec.causal, window=spec.window, softcap=spec.softcap)
    out = np.asarray(flash_attention(q, k, v, interpret=True, **kw),
                     np.float32)
    ref = np.asarray(flash_attention_ref(q, k, v, **kw), np.float32)
    return out, ref
