"""Batched serving engine: prefill + decode with a preallocated KV cache.

Production layout: the cache is allocated once at ``max_len`` (sequence-
sharded over `model` — flash-decoding), prefill writes the prompt K/V into
it, and decode_step appends one token per call.  Batched requests of uneven
prompt length are left-padded to the batch max (per-slot ``start`` offsets
keep positions correct); finished slots keep decoding into a scratch column
(fixed-shape step, no recompilation).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed import sharding
from repro.models import lm
from repro.models.common import ModelCfg
from repro.models.layers import ShardCtx


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0         # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelCfg, params, mesh, scfg: ServeConfig):
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.ctx = sharding.make_ctx(mesh)
        self.mesh = mesh
        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, self.ctx))
        self._decode = jax.jit(
            lambda p, c, t, pos: lm.decode_step(p, t, c, pos, cfg,
                                                self.ctx))

    @staticmethod
    def _seq_axis(x, prompt_len: int) -> int | None:
        """KV seq axis: 1 for per-layer (B,S,K,hd), 2 for pattern-stacked
        (R,B,S,K,hd). Recurrent-state leaves have no such axis -> None."""
        for ax in (1, 2):
            if x.ndim > ax + 1 and x.shape[ax] == prompt_len:
                return ax
        return None

    def _pad_cache(self, cache, prompt_len: int, max_len: int):
        """Grow the prefill cache (length prompt_len) to max_len slots."""
        def grow(x):
            ax = self._seq_axis(x, prompt_len)
            if ax is None:
                return x
            pad = [(0, 0)] * x.ndim
            pad[ax] = (0, max_len - prompt_len)
            return jnp.pad(x, pad)
        return jax.tree.map(grow, cache)

    def _roll_windows(self, cache, prompt_len: int, windows: set[int]):
        """Ring caches from prefill hold positions [S-W, S) at slots
        [0, W); decode writes slot pos % W.  Roll so position p sits at
        slot p % W."""
        def roll(x):
            for ax in (1, 2):
                if (x.ndim > ax + 1 and x.shape[ax] in windows
                        and x.shape[ax] < prompt_len):
                    W = x.shape[ax]
                    shift = (prompt_len - W) % W
                    return jnp.roll(x, shift, axis=ax)
            return x
        return jax.tree.map(roll, cache)

    def generate(self, prompts: list[list[int]],
                 max_new_tokens: Optional[int] = None) -> list[list[int]]:
        """Batched greedy/temperature generation."""
        cfg, scfg = self.cfg, self.scfg
        new_toks = max_new_tokens or scfg.max_new_tokens
        B = len(prompts)
        S = max(len(p) for p in prompts)
        toks = np.zeros((B, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, S - len(p):] = p                     # left-pad
        max_len = S + new_toks

        logits, cache = self._prefill(self.params, jnp.asarray(toks))
        windows = {b.window for b in cfg.all_blocks()
                   if b.window is not None and b.window < S}
        if windows:
            cache = self._roll_windows(cache, S, windows)
        cache = self._pad_cache(cache, S, max_len)

        key = jax.random.PRNGKey(scfg.seed)
        out = [[] for _ in range(B)]
        cur = self._sample(logits, key)
        for i in range(B):
            out[i].append(int(cur[i]))
        for t in range(1, new_toks):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(self.params, cache,
                                         cur[:, None], jnp.int32(S + t - 1))
            cur = self._sample(logits, sub)
            for i in range(B):
                out[i].append(int(cur[i]))
        return out

    def _sample(self, logits, key):
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1).astype(jnp.int32)
