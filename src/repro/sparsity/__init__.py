from repro.sparsity.regularizers import (synops_loss, tl1_regularizer,
                                         activation_density)
from repro.sparsity.pruning import (apply_masks, magnitude_prune_masks,
                                    prune_and_finetune_sweep)
from repro.sparsity.sigma_delta import calibrate_thresholds

__all__ = ["synops_loss", "tl1_regularizer", "activation_density",
           "apply_masks", "magnitude_prune_masks",
           "prune_and_finetune_sweep", "calibrate_thresholds"]
