from repro.sparsity.regularizers import (synops_loss, tl1_regularizer,
                                         activation_density)
from repro.sparsity.pruning import (apply_masks, magnitude_prune_masks,
                                    prune_and_finetune_sweep, weight_sparsity)
from repro.sparsity.sigma_delta import (calibrate_thresholds,
                                        delta_sparsity,
                                        sigma_delta_densities,
                                        sigma_delta_messages)
from repro.sparsity.profile import SparsityProfile

__all__ = ["synops_loss", "tl1_regularizer", "activation_density",
           "apply_masks", "magnitude_prune_masks",
           "prune_and_finetune_sweep", "weight_sparsity",
           "calibrate_thresholds", "delta_sparsity",
           "sigma_delta_densities", "sigma_delta_messages",
           "SparsityProfile"]
