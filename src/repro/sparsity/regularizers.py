"""Stage-1 sparsity-aware training losses (paper §VI-B / §VII-A).

* ``tl1_regularizer``  — transformed-L1 activation penalty [63]:
  rho_a(x) = (a+1)|x| / (a + |x|): near-L0 for small a, used to induce ReLU
  activation sparsity on AKD1000-style CNNs (applied to the pre-trained
  baseline, then fine-tuned).
* ``synops_loss``      — Sorbaro et al. [50] synaptic-operation loss: the
  expected downstream synops of each layer's activations (activation count
  weighted by fan-out), matching the paper's Speck training setup.  This is
  the neurocore-aware (M0) training signal: per-LAYER sums are returned so
  imbalanced layers can be targeted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tl1_regularizer(acts: list[jax.Array], a: float = 1.0,
                    weights=None) -> jax.Array:
    """Transformed-L1 penalty over a list of (post-ReLU) activations.

    ``weights`` — optional per-layer multipliers (e.g. the floorline-guided
    weights of :func:`repro.core.guidance.floorline_layer_weights`): layer
    ``l``'s mean penalty is scaled by ``weights[l]`` so bottleneck layers
    are pushed toward sparsity hardest.  ``None`` keeps the unweighted
    element-mean (exact historical behavior)."""
    if weights is None:
        total = jnp.float32(0.0)
        count = 0
        for x in acts:
            ax = jnp.abs(x.astype(jnp.float32))
            total = total + jnp.sum((a + 1.0) * ax / (a + ax))
            count += x.size
        return total / max(count, 1)
    total = jnp.float32(0.0)
    for x, w in zip(acts, weights):
        ax = jnp.abs(x.astype(jnp.float32))
        total = total + w * jnp.mean((a + 1.0) * ax / (a + ax))
    return total / max(len(acts), 1)


def activation_density(acts: list[jax.Array], thresh: float = 0.0):
    """Per-layer and total activation density (fraction > thresh)."""
    per_layer = [jnp.mean((x > thresh).astype(jnp.float32)) for x in acts]
    total = sum(jnp.sum((x > thresh).astype(jnp.float32)) for x in acts) / \
        max(sum(x.size for x in acts), 1)
    return per_layer, total


def synops_loss(acts: list[jax.Array], fanouts: list[int],
                surrogate: str = "abs", weights=None) -> jax.Array:
    """Expected synops: sum_l weight_l * fanout_l * E[activity_l].

    ``surrogate``: 'abs' uses |a| (differentiable proxy for spike counts /
    message magnitude); 'count' uses a straight-through 0/1 estimate.
    ``weights`` — optional per-layer multipliers (floorline guidance);
    ``None`` is the unweighted loss (exact historical behavior)."""
    if weights is None:
        weights = [1.0] * len(acts)
    total = jnp.float32(0.0)
    norm = 0.0
    for x, f, w in zip(acts, fanouts, weights):
        xf = x.astype(jnp.float32)
        if surrogate == "abs":
            act = jnp.abs(xf)
        else:
            hard = (xf > 0).astype(jnp.float32)
            act = hard + xf - jax.lax.stop_gradient(xf)   # straight-through
        total = total + w * f * jnp.mean(act)
        norm += f
    return total / max(norm, 1.0)
