"""Stage-1 sparsity-aware training losses (paper §VI-B / §VII-A).

* ``tl1_regularizer``  — transformed-L1 activation penalty [63]:
  rho_a(x) = (a+1)|x| / (a + |x|): near-L0 for small a, used to induce ReLU
  activation sparsity on AKD1000-style CNNs (applied to the pre-trained
  baseline, then fine-tuned).
* ``synops_loss``      — Sorbaro et al. [50] synaptic-operation loss: the
  expected downstream synops of each layer's activations (activation count
  weighted by fan-out), matching the paper's Speck training setup.  This is
  the neurocore-aware (M0) training signal: per-LAYER sums are returned so
  imbalanced layers can be targeted.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tl1_regularizer(acts: list[jax.Array], a: float = 1.0) -> jax.Array:
    """Transformed-L1 penalty over a list of (post-ReLU) activations."""
    total = jnp.float32(0.0)
    count = 0
    for x in acts:
        ax = jnp.abs(x.astype(jnp.float32))
        total = total + jnp.sum((a + 1.0) * ax / (a + ax))
        count += x.size
    return total / max(count, 1)


def activation_density(acts: list[jax.Array], thresh: float = 0.0):
    """Per-layer and total activation density (fraction > thresh)."""
    per_layer = [jnp.mean((x > thresh).astype(jnp.float32)) for x in acts]
    total = sum(jnp.sum((x > thresh).astype(jnp.float32)) for x in acts) / \
        max(sum(x.size for x in acts), 1)
    return per_layer, total


def synops_loss(acts: list[jax.Array], fanouts: list[int],
                surrogate: str = "abs") -> jax.Array:
    """Expected synops: sum_l fanout_l * E[activity_l].

    ``surrogate``: 'abs' uses |a| (differentiable proxy for spike counts /
    message magnitude); 'count' uses a straight-through 0/1 estimate."""
    total = jnp.float32(0.0)
    norm = 0.0
    for x, f in zip(acts, fanouts):
        xf = x.astype(jnp.float32)
        if surrogate == "abs":
            act = jnp.abs(xf)
        else:
            hard = (xf > 0).astype(jnp.float32)
            act = hard + xf - jax.lax.stop_gradient(xf)   # straight-through
        total = total + f * jnp.mean(act)
        norm += f
    return total / max(norm, 1.0)
