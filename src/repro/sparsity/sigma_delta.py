"""Per-layer sigma-delta threshold calibration (paper §VII-A, PilotNet).

The paper's baseline uses one uniform Σ-Δ threshold; their improved recipe
assigns thresholds per layer to hit per-layer sparsity TARGETS, which
load-balances the deployed network (M0).  ``calibrate_thresholds`` solves
each layer's threshold by bisection on sample activation deltas.
"""

from __future__ import annotations

import numpy as np


def delta_sparsity(deltas: np.ndarray, theta: float) -> float:
    """Fraction of suppressed (|delta| <= theta) messages."""
    return float(np.mean(np.abs(deltas) <= theta))


def calibrate_thresholds(layer_deltas: list[np.ndarray],
                         target_sparsity: list[float] | float,
                         iters: int = 40) -> list[float]:
    """Bisection per layer: smallest theta with sparsity >= target."""
    if isinstance(target_sparsity, float):
        target_sparsity = [target_sparsity] * len(layer_deltas)
    thetas = []
    for deltas, tgt in zip(layer_deltas, target_sparsity):
        lo, hi = 0.0, float(np.max(np.abs(deltas)) + 1e-9)
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            if delta_sparsity(deltas, mid) >= tgt:
                hi = mid
            else:
                lo = mid
        thetas.append(hi)
    return thetas


def sigma_delta_densities(layer_acts_seq: list[np.ndarray],
                          thetas: list[float]) -> list[float]:
    """Message density per layer under calibrated thresholds: run the Σ-Δ
    encoder over each layer's (T, n) activation sequence and count firing
    messages — the measured-density column of a sigma-delta
    :class:`~repro.sparsity.profile.SparsityProfile`."""
    dens = []
    for acts, theta in zip(layer_acts_seq, thetas):
        acts = np.asarray(acts, np.float64)
        ref = np.zeros_like(acts[0])
        fired = 0
        for t in range(acts.shape[0]):
            q, ref = sigma_delta_messages(acts[t], ref, theta)
            fired += int(np.count_nonzero(q))
        dens.append(fired / max(acts.size, 1))
    return dens


def sigma_delta_messages(acts_t: np.ndarray, acts_prev: np.ndarray,
                         theta: float):
    """Quantized Σ-Δ messaging for one step: (messages, new_reference).
    Mirrors kernels/sigma_delta/ref.py in numpy for calibration use."""
    delta = acts_t - acts_prev
    fire = np.abs(delta) > theta
    q = np.where(fire, delta, 0.0)
    return q, acts_prev + q
