"""Trained sparsity profiles — the artifact that closes the paper's loop.

A :class:`SparsityProfile` captures what sparsity-aware training actually
produced: per-layer activation (message) densities, per-layer weight
densities (and optionally the exact 0/1 weight masks), and — for sigma-delta
recipes — the calibrated per-layer thresholds.  It is the hand-off between
the training side (``repro.train.sparse``) and the pricing/search side
(``simulate`` / ``simulate_population`` / the evolutionary search engines):
instead of the synthetic density schedules in ``benchmarks/act_schedules.py``,
the mapping optimizer prices the densities a real training run achieved.

Two consumption modes:

* **exact deployment** — the trained ``SimNetwork`` (trained weights, real
  activations) is priced directly; the profile just *records* its measured
  statistics for reporting and floorline guidance;
* **density injection** — :meth:`SparsityProfile.apply` programs the
  profile's densities onto an arbitrary ``SimNetwork`` (msg gates + exact
  weight masks), and ``compile_network(..., act_density=profile)`` injects
  them at model-zoo lowering time.  Because injection only rewrites the
  *network* (never the pricing math), every pricing backend — numpy / vmap /
  device population — prices a profiled workload with its usual parity
  guarantees.

Profiles serialize to a single ``.npz`` (arrays + a JSON header), atomically.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np


@dataclasses.dataclass
class SparsityProfile:
    """Per-layer trained sparsity statistics (one entry per network layer).

    ``act_density[l]`` — fraction of layer ``l``'s neurons that emit a
    message per timestep (post-training, measured on an eval batch);
    ``weight_density[l]`` — fraction of nonzero weights;
    ``weight_masks`` — optional exact 0/1 masks (same shapes as the trained
    weight tensors) from magnitude pruning;
    ``thresholds`` — optional per-layer sigma-delta thetas from
    :func:`repro.sparsity.sigma_delta.calibrate_thresholds`;
    ``input_density`` — message density of the input stream;
    ``meta`` — free-form provenance (recipe, accuracy, step count, ...).
    """

    layer_names: tuple[str, ...]
    act_density: np.ndarray
    weight_density: np.ndarray
    weight_masks: tuple[np.ndarray, ...] | None = None
    thresholds: tuple[float, ...] | None = None
    input_density: float = 1.0
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.layer_names = tuple(self.layer_names)
        self.act_density = np.asarray(self.act_density, np.float64)
        self.weight_density = np.asarray(self.weight_density, np.float64)
        n = len(self.layer_names)
        if self.act_density.shape != (n,) or self.weight_density.shape != (n,):
            raise ValueError(
                f"profile arrays must be ({n},) to match layer_names; got "
                f"act {self.act_density.shape}, w {self.weight_density.shape}")
        if self.weight_masks is not None:
            self.weight_masks = tuple(
                np.asarray(m, np.float32) for m in self.weight_masks)
        if self.thresholds is not None:
            self.thresholds = tuple(float(t) for t in self.thresholds)

    @property
    def n_layers(self) -> int:
        return len(self.layer_names)

    # ------------------------------------------------------------- builders
    @classmethod
    def from_activations(cls, layer_names, acts, *, weights=None,
                         masks=None, thresholds=None, input_density=1.0,
                         thresh=0.0, meta=None) -> "SparsityProfile":
        """Measure a profile from per-layer activation arrays (any shapes:
        density is the fraction of entries ``> thresh``).  ``weights`` (or
        ``masks``) provide the weight-density column; masks are kept as the
        exact artifact when given."""
        act_d = np.array([float(np.mean(np.asarray(a) > thresh))
                          for a in acts], np.float64)
        src = masks if masks is not None else weights
        if src is not None:
            w_d = np.array([float(np.mean(np.asarray(w) != 0)) for w in src],
                           np.float64)
        else:
            w_d = np.ones(len(layer_names), np.float64)
        return cls(layer_names=tuple(layer_names), act_density=act_d,
                   weight_density=w_d,
                   weight_masks=None if masks is None else tuple(masks),
                   thresholds=thresholds, input_density=float(input_density),
                   meta=dict(meta or {}))

    # --------------------------------------------------------- resampling
    def densities_for(self, n_layers: int) -> np.ndarray:
        """Resample the per-layer activation densities to ``n_layers`` by
        linear interpolation over normalized depth — how a profile trained
        on an L-layer workload programs an M-layer one (the trained analog
        of ``benchmarks.workloads.schedule``)."""
        if n_layers == self.n_layers:
            return self.act_density.copy()
        if self.n_layers == 1:
            return np.full(n_layers, float(self.act_density[0]))
        src = np.linspace(0.0, 1.0, self.n_layers)
        dst = np.linspace(0.0, 1.0, n_layers)
        return np.interp(dst, src, self.act_density)

    # ---------------------------------------------------------- injection
    def apply(self, net, *, seed: int = 0):
        """Program this profile onto ``net``: per-layer msg gates at the
        profile's activation densities (composed with any structural gates)
        and weight masks — the exact trained masks when shapes match, an
        exact-density random mask otherwise.  Returns a new ``SimNetwork``;
        ``net`` is untouched.  On ``force_active`` (characterization-mode)
        layers the gates program the message counters *exactly*; on
        functional layers they are an upper bound (real activations still
        gate messages)."""
        from repro.neuromorphic.network import (SimNetwork,
                                                _exact_density_mask)
        dens = self.densities_for(len(net.layers))
        layers = []
        for i, lay in enumerate(net.layers):
            rng = np.random.default_rng(seed * 100003 + i)
            w = np.asarray(lay.weights, np.float32)
            if (self.weight_masks is not None and i < len(self.weight_masks)
                    and self.weight_masks[i].shape == w.shape):
                w = w * self.weight_masks[i]
            elif self.weight_density[min(i, self.n_layers - 1)] < 1.0:
                wd = float(self.weight_density[min(i, self.n_layers - 1)])
                w = w * _exact_density_mask(w.shape, wd, rng)
            gate = None
            if lay.kind == "fc":
                old = lay.msg_gate
                live = (np.nonzero(np.asarray(old))[0] if old is not None
                        else np.arange(lay.n_neurons))
                keep = int(round(float(dens[i]) * live.size))
                gate = np.zeros(lay.n_neurons, np.float32)
                if keep > 0:
                    gate[rng.choice(live, size=keep, replace=False)] = 1.0
            thr = lay.threshold
            if (self.thresholds is not None and lay.sends_deltas
                    and i < len(self.thresholds)):
                thr = float(self.thresholds[i])
            layers.append(dataclasses.replace(
                lay, weights=w,
                msg_gate=gate if gate is not None else lay.msg_gate,
                threshold=thr))
        return SimNetwork(layers=layers, in_size=net.in_size)

    # -------------------------------------------------------------- persist
    def save(self, path: str) -> str:
        """Atomic single-file ``.npz`` (same torn-write discipline as
        ``repro.train.checkpoint``)."""
        arrays = {"act_density": self.act_density,
                  "weight_density": self.weight_density}
        if self.weight_masks is not None:
            for i, m in enumerate(self.weight_masks):
                arrays[f"mask_{i}"] = m
        if self.thresholds is not None:
            arrays["thresholds"] = np.asarray(self.thresholds, np.float64)
        header = {"layer_names": list(self.layer_names),
                  "input_density": self.input_density,
                  "n_masks": 0 if self.weight_masks is None
                  else len(self.weight_masks),
                  "has_thresholds": self.thresholds is not None,
                  "meta": self.meta}
        arrays["header"] = np.frombuffer(
            json.dumps(header).encode(), np.uint8)
        path = os.fspath(path)
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        os.replace(tmp, path)
        return path

    @classmethod
    def load(cls, path: str) -> "SparsityProfile":
        data = np.load(path)
        header = json.loads(bytes(data["header"]).decode())
        masks = None
        if header["n_masks"]:
            masks = tuple(data[f"mask_{i}"]
                          for i in range(header["n_masks"]))
        thresholds = (tuple(float(t) for t in data["thresholds"])
                      if header["has_thresholds"] else None)
        return cls(layer_names=tuple(header["layer_names"]),
                   act_density=data["act_density"],
                   weight_density=data["weight_density"],
                   weight_masks=masks, thresholds=thresholds,
                   input_density=float(header["input_density"]),
                   meta=header.get("meta", {}))
