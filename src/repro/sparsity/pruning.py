"""Magnitude pruning + fine-tune (paper §VII-A, S5 workload).

One-shot global or per-tensor magnitude pruning to a target weight sparsity
followed by masked fine-tuning — the S5 stage-1 recipe ("prune the smallest
0.1..0.9 of weights away in one shot, and fine-tune").
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def magnitude_prune_masks(params, sparsity, *, min_size: int = 64):
    """0/1 masks keeping the largest-|w| (1-sparsity) fraction per tensor.
    Tensors smaller than min_size (biases, norms) and vectors are never
    pruned.

    jit-safe and exact: ``sparsity`` may be a traced scalar (only tensor
    *shapes* — static under jit — steer the per-tensor branching), and each
    mask keeps exactly ``round(size * (1 - sparsity))`` entries via a stable
    descending argsort, so value ties break deterministically toward the
    lowest flat index and jitted and eager masks are bit-identical."""
    def one(p):
        if p.size < min_size or p.ndim < 2:        # static: shape-only
            return jnp.ones(p.shape, dtype=jnp.float32)
        flat = jnp.abs(p.astype(jnp.float32)).reshape(-1)
        n = flat.size
        k = jnp.round(n * (1.0 - jnp.asarray(sparsity, jnp.float32)))
        k = jnp.clip(k, 0, n).astype(jnp.int32)
        order = jnp.argsort(-flat, stable=True)    # ties -> lowest index
        keep = (jnp.arange(n, dtype=jnp.int32) < k).astype(jnp.float32)
        mask = jnp.zeros(n, jnp.float32).at[order].set(keep)
        return mask.reshape(p.shape)
    return jax.tree.map(one, params)


def apply_masks(params, masks):
    return jax.tree.map(lambda p, m: (p.astype(jnp.float32) * m
                                      ).astype(p.dtype), params, masks)


def weight_sparsity(params, masks=None) -> float:
    leaves = jax.tree.leaves(masks if masks is not None else params)
    nz = sum(float(jnp.sum(m != 0)) for m in leaves)
    tot = sum(m.size for m in leaves)
    return 1.0 - nz / max(tot, 1)


def prune_and_finetune_sweep(params, train_steps: Callable,
                             sparsities: list[float],
                             finetune_steps: int = 50):
    """For each target sparsity: one-shot prune -> masked fine-tune.
    ``train_steps(params, masks, n)`` must return (params, final_metrics).
    Returns [(sparsity, params, metrics), ...] — the Fig. 10 Pareto sweep."""
    out = []
    for s in sparsities:
        masks = magnitude_prune_masks(params, s)
        pruned = apply_masks(params, masks)
        tuned, metrics = train_steps(pruned, masks, finetune_steps)
        tuned = apply_masks(tuned, masks)        # keep exactly masked
        out.append((s, tuned, metrics))
    return out
