"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck [--resume]

``--smoke`` uses the reduced per-family config on the local device(s);
full configs target the production mesh (run under the dry-run env or a
real fleet).  The minicpm preset uses the WSD schedule per its paper.
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import registry
from repro.launch.mesh import make_mesh
from repro.models.layers import single_device_mesh
from repro.train import data as data_lib
from repro.train import optim, schedules
from repro.train.loop import Trainer, TrainerConfig


def lr_for(arch_id: str, lr: float, steps: int):
    if arch_id.startswith("minicpm"):
        return schedules.wsd(lr, max(steps // 20, 1),
                             int(steps * 0.7), int(steps * 0.25))
    return schedules.cosine(lr, max(steps // 20, 1), steps)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--mesh-shape", default=None,
                    help="e.g. 2,4 -> (data,model); default 1-device")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = registry.get(args.arch)
    cfg = entry.smoke() if args.smoke else entry.config
    if entry.is_encdec:
        raise SystemExit("use examples/train_whisper.py for enc-dec smoke")

    mesh = (make_mesh(tuple(int(x) for x in args.mesh_shape.split(",")),
                      ("data", "model")) if args.mesh_shape
            else single_device_mesh())
    opt = optim.for_arch(cfg.param_count(), lr_for(args.arch, args.lr,
                                                   args.steps))
    data = data_lib.SyntheticLM(data_lib.LMTaskConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, seed=args.seed))
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, resume=args.resume,
        num_microbatches=args.microbatches,
        compress_grads=args.compress_grads, seed=args.seed)
    trainer = Trainer(cfg, mesh, opt, data, tcfg)
    hist = trainer.run()
    print(f"final loss: {hist[-1]['loss']:.4f} "
          f"(straggler events: {len(trainer.monitor.events)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
