"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device.

Mesh axes:
  single pod:  (16, 16)      ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips

`model` carries TP/SP (and MoE expert-FF); `data` carries DP and MoE EP
(expert parallelism stays on intra-pod ICI); `pod` is pure DP over the
inter-pod links (DCI), which only see gradient reduce-scatters.
"""

from __future__ import annotations

import numpy as np

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            "visible — launch via repro.launch.dryrun (it sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax)")
    return jax.make_mesh(shape, axes,
                         devices=devices[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic restarts (e.g. (2,4) on 8 CPU
    placeholder devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"mesh {shape} needs {n} devices, "
                           f"have {len(devices)}")
    return jax.make_mesh(shape, axes,
                         devices=devices[:n])
