"""Production mesh builders + the pre-import host-device-count switch.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — and since the
``--devices`` flag landed, this module does not even import jax at module
scope: :func:`force_host_device_count` must run *before* the first jax
import anywhere in the process (XLA reads
``--xla_force_host_platform_device_count`` exactly once, at backend init),
so the benchmark drivers import ``repro.launch.mesh`` alone, apply the
flag, and only then import the jax-heavy modules.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` the same way;
smoke tests and benchmarks see the real single CPU device.

Mesh axes:
  single pod:  (16, 16)      ("data", "model")   = 256 chips (one v5e pod)
  multi-pod:   (2, 16, 16)   ("pod", "data", "model") = 512 chips

`model` carries TP/SP (and MoE expert-FF); `data` carries DP and MoE EP
(expert parallelism stays on intra-pod ICI); `pod` is pure DP over the
inter-pod links (DCI), which only see gradient reduce-scatters.

The sharded evolutionary search uses the separate 1-D ``("island",)`` mesh
of :func:`repro.distributed.sharding.island_mesh` (``docs/distributed.md``).
"""

from __future__ import annotations

import os
import sys

import numpy as np

_FORCE_FLAG = "--xla_force_host_platform_device_count"


def forced_host_device_count() -> int | None:
    """The count currently requested via XLA_FLAGS, or None."""
    for tok in os.environ.get("XLA_FLAGS", "").split():
        if tok.startswith(_FORCE_FLAG + "="):
            try:
                return int(tok.split("=", 1)[1])
            except ValueError:
                return None
    return None


def force_host_device_count(n: int) -> None:
    """Request ``n`` CPU placeholder devices for this process, BEFORE jax.

    Rewrites ``XLA_FLAGS`` (replacing any prior
    ``--xla_force_host_platform_device_count``).  XLA reads the flag once,
    when the backend initializes on first jax import — so this raises a
    clear :class:`RuntimeError` if jax is already in ``sys.modules`` and
    the flag would silently not take effect.  Idempotent: a repeated call
    with the count already in force is a no-op (so module-level pre-parse
    hooks and argparse handlers can both call it).
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"device count must be >= 1, got {n}")
    if forced_host_device_count() == n:
        return
    if any(m == "jax" or m.startswith("jax.") for m in sys.modules):
        raise RuntimeError(
            f"force_host_device_count({n}) must run before jax is first "
            "imported: XLA reads --xla_force_host_platform_device_count "
            "once, at backend init, so setting it now would have no "
            "effect.  Pass --devices N to `python -m benchmarks.run` / "
            "`python -m benchmarks.search_mapping` (they apply it before "
            "importing jax), or export XLA_FLAGS="
            f"'{_FORCE_FLAG}={n}' before starting python.")
    flags = [t for t in os.environ.get("XLA_FLAGS", "").split()
             if not t.startswith(_FORCE_FLAG)]
    flags.append(f"{_FORCE_FLAG}={n}")
    os.environ["XLA_FLAGS"] = " ".join(flags)


def apply_devices_flag(argv) -> int | None:
    """Pre-argparse scan of ``argv`` for ``--devices N`` / ``--devices=N``.

    Benchmark entry points call this at module import time (before their
    jax-importing imports run) so the flag can take effect; the later
    argparse pass keeps ``--devices`` for ``--help`` and validation.
    Returns the applied count, or None when the flag is absent."""
    n = None
    for i, tok in enumerate(argv):
        if tok == "--devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif tok.startswith("--devices="):
            n = tok.split("=", 1)[1]
    if n is None:
        return None
    try:
        count = int(n)
    except ValueError:
        raise SystemExit(f"--devices expects an integer, got {n!r}")
    force_host_device_count(count)
    return count


def make_production_mesh(*, multi_pod: bool = False):
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices but only {len(devices)} are "
            "visible — launch via repro.launch.dryrun (it sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax)")
    return jax.make_mesh(shape, axes,
                         devices=devices[:n])


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic restarts (e.g. (2,4) on 8 CPU
    placeholder devices)."""
    import jax
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"mesh {shape} needs {n} devices, "
                           f"have {len(devices)}")
    return jax.make_mesh(shape, axes,
                         devices=devices[:n])
