import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count on first backend init): the dry-run — and only the dry-run — sees 512
placeholder CPU devices so `jax.make_mesh` can build the production meshes.

Per cell this lowers the real step function (train_step for train_4k,
prefill for prefill_32k, serve_step for decode_*) with ShapeDtypeStruct
inputs (zero allocation), compiles it, prints memory_analysis() (proves the
cell fits) and cost_analysis() (FLOPs/bytes for EXPERIMENTS.md §Roofline),
parses collective bytes from the compiled HLO, and writes a JSON artifact.

  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
  python -m repro.launch.dryrun --arch kimi-k2-1t-a32b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all --out experiments/dryrun -j 6
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import ShapeSpec
from repro.core import tpu_floorline as tfl
from repro.distributed import sharding
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.models import encdec, lm
from repro.models.encdec import EncDecCfg
from repro.train import optim, schedules, step as step_lib


def _shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def _mem_analysis(compiled) -> dict:
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        return {
            "argument_bytes": int(getattr(m, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(m, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(m, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(m, "alias_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(m, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:                      # pragma: no cover
        return {"error": str(e)}


def _spec_bytes(abstract_tree, spec_tree, mesh) -> int:
    """Per-device bytes of a sharded pytree (fallback accounting)."""
    import numpy as np
    total = 0
    for x, s in zip(jax.tree.leaves(abstract_tree),
                    jax.tree.leaves(spec_tree,
                                    is_leaf=lambda t: isinstance(t, P))):
        shards = 1
        for entry in tuple(s):
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax is not None:
                    shards *= mesh.shape[ax]
        total += int(np.prod(x.shape)) * x.dtype.itemsize // shards
    return total


def build_cell(arch_id: str, shape_name: str, mesh, *, smoke: bool = False,
               microbatches: int | None = None, flags=None,
               remat: str | None = None):
    """Returns (fn, args, in_shardings, out_shardings, donate, meta)."""
    import dataclasses as _dc
    entry = registry.get(arch_id)
    cfg = entry.smoke() if smoke else entry.config
    if remat is not None:
        cfg = _dc.replace(cfg, remat=remat)
    shape = entry.shapes[shape_name]
    if smoke:
        shape = ShapeSpec(shape.name, seq_len=32,
                          global_batch=max(8, 2 * mesh.devices.size),
                          kind=shape.kind)
    ctx = sharding.make_ctx(mesh, batch_size=shape.global_batch)
    if flags is not None:
        ctx = _dc.replace(ctx, flags=flags)
    pspecs = sharding.param_specs(cfg, ctx)
    init_p = encdec.init_params if entry.is_encdec else lm.init_params
    aparams = jax.eval_shape(lambda: init_p(cfg, jax.random.PRNGKey(0)))
    meta = {"arch": arch_id, "shape": shape_name, "kind": shape.kind,
            "seq_len": shape.seq_len, "global_batch": shape.global_batch,
            "n_chips": int(mesh.devices.size),
            "params": int(cfg.param_count())}

    if shape.kind == "train":
        dp = ctx.dp_size
        M = microbatches or max(1, shape.global_batch // dp)
        lr = schedules.cosine(3e-4, 100, 10_000)
        opt = optim.for_arch(cfg.param_count(), lr)
        gspecs = sharding.grad_specs(aparams, pspecs, ctx)
        accum_dt = ("bfloat16" if cfg.param_count() > 100e9 else "float32")
        fn = step_lib.make_train_step(
            cfg, ctx, opt, num_microbatches=M, grad_accum_dtype=accum_dt,
            grad_spec_tree=gspecs)
        astate = {
            "params": aparams,
            "opt": jax.eval_shape(opt.init, aparams),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        sspecs = step_lib.state_spec_tree(cfg, ctx, opt, aparams)
        inputs = entry.input_specs(shape, cfg=cfg)
        bspecs = sharding.batch_specs(inputs, ctx)
        in_sh = (_shardings(sspecs, mesh), _shardings(bspecs, mesh))
        mspec = jax.tree.map(lambda _: P(), jax.eval_shape(
            lambda s, b: fn(s, b)[1], astate, inputs))
        out_sh = (_shardings(sspecs, mesh), _shardings(mspec, mesh))
        meta["microbatches"] = M
        meta["optimizer"] = opt.name
        meta["state_bytes_per_device"] = (
            _spec_bytes(aparams, pspecs, mesh)
            + _spec_bytes(astate["opt"],
                          opt.state_specs(aparams, pspecs, ctx), mesh))
        return fn, (astate, inputs), in_sh, out_sh, (0,), meta, cfg, shape

    if shape.kind == "prefill":
        fn = step_lib.make_prefill_step(cfg, ctx)
        inputs = entry.input_specs(shape, cfg=cfg)
        bspecs = sharding.batch_specs(inputs, ctx)
        in_sh = (_shardings(pspecs, mesh), _shardings(bspecs, mesh))
        meta["state_bytes_per_device"] = _spec_bytes(aparams, pspecs, mesh)
        return (fn, (aparams, inputs), in_sh, None, (), meta, cfg, shape)

    # decode: serve_step(params, cache, tokens, pos)
    B = shape.global_batch
    init_c = encdec.init_cache if entry.is_encdec else lm.init_cache
    acache = jax.eval_shape(lambda: init_c(cfg, B, shape.seq_len))
    cspec = (encdec.cache_spec(cfg, ctx) if entry.is_encdec
             else lm.cache_spec(cfg, ctx))
    fn = step_lib.make_serve_step(cfg, ctx)
    toks = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    in_sh = (_shardings(pspecs, mesh), _shardings(cspec, mesh),
             NamedSharding(mesh, P(ctx.dp_spec, None)),
             NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(ctx.dp_spec, None)),
              _shardings(cspec, mesh))
    meta["state_bytes_per_device"] = (
        _spec_bytes(aparams, pspecs, mesh)
        + _spec_bytes(acache, cspec, mesh))
    meta["cache_bytes_per_device"] = _spec_bytes(acache, cspec, mesh)
    return fn, (aparams, acache, toks, pos), in_sh, out_sh, (1,), meta, cfg, shape


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             out_dir: str | None, smoke: bool = False,
             mesh_shape: tuple[int, ...] | None = None,
             microbatches: int | None = None, flags=None,
             remat: str | None = None, tag: str = "") -> dict:
    if mesh_shape is not None:
        axes = (("pod", "data", "model") if len(mesh_shape) == 3
                else ("data", "model"))
        mesh = make_mesh(mesh_shape, axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = ("multipod" if multi_pod else "pod") if mesh_shape is None \
        else "x".join(map(str, mesh_shape))

    if tag:
        mesh_name = f"{mesh_name}__{tag}"
    t0 = time.time()
    fn, args, in_sh, out_sh, donate, meta, cfg, shape = build_cell(
        arch_id, shape_name, mesh, smoke=smoke, microbatches=microbatches,
        flags=flags, remat=remat)
    with mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = _mem_analysis(compiled)
    print(f"[{arch_id} x {shape_name} x {mesh_name}] memory_analysis:",
          json.dumps(mem))
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    cost_small = {k: float(v) for k, v in cost.items()
                  if isinstance(v, (int, float)) and
                  k in ("flops", "bytes accessed", "transcendentals",
                        "optimal_seconds")}
    print(f"[{arch_id} x {shape_name} x {mesh_name}] cost_analysis:",
          json.dumps(cost_small))

    from repro.core import hlo_cost
    hlo_text = compiled.as_text()
    hc = hlo_cost.analyze(hlo_text)
    if out_dir:
        import gzip
        os.makedirs(out_dir, exist_ok=True)
        with gzip.open(os.path.join(
                out_dir, f"{arch_id}__{shape_name}__{mesh_name}.hlo.gz"),
                "wt") as zf:
            zf.write(hlo_text)
    mf = tfl.model_flops_for(cfg, shape.kind, shape.seq_len,
                             shape.global_batch)
    # memory term: flash-adjusted — attention score tensors are VMEM-
    # resident on the TPU target (kernels/flash_attn); the raw CPU-fusion
    # number is recorded alongside.
    terms = tfl.RooflineTerms(
        flops_per_chip=hc.flops,
        hbm_bytes_per_chip=hc.hbm_bytes - hc.score_bytes,
        collective_bytes_per_chip=hc.collective_bytes,
        model_flops=mf, n_chips=meta["n_chips"],
        label=f"{arch_id}|{shape_name}|{mesh_name}")

    record = {
        **meta,
        "mesh": mesh_name,
        "mesh_shape": [int(mesh.shape[a]) for a in mesh.axis_names],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "xla_cost_analysis": cost_small,   # raw (scan bodies counted once)
        "hlo_cost": {
            "flops": hc.flops, "hbm_bytes": hc.hbm_bytes,
            "score_bytes_vmem_resident": hc.score_bytes,
            "collective_bytes": hc.collective_bytes,
            "bytes_by_kind": hc.bytes_by_kind,
            "count_by_kind": hc.count_by_kind,
            "while_trips": hc.while_trips,
            "top_collectives": hc.top_collectives[:8],
            "top_dots": hc.top_dots[:8],
            "top_hbm": hc.top_hbm[:8],
        },
        "roofline": terms.row(),
        "ok": True,
    }
    print(f"[{arch_id} x {shape_name} x {mesh_name}] dominant="
          f"{terms.dominant.value} bound={terms.bound:.4f}s "
          f"useful_ratio={terms.useful_flops_ratio:.3f} "
          f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch_id}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
    return record


def _sweep(args):
    """Run every cell x {pod, multipod} in parallel worker subprocesses."""
    import subprocess
    cells = [(a, s, mp) for a, s in registry.all_cells()
             for mp in (False, True)]
    procs: list[tuple[tuple, subprocess.Popen]] = []
    results = {}

    def launch(cell):
        a, s, mp = cell
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", a, "--shape", s, "--out", args.out]
        if mp:
            cmd.append("--multi-pod")
        return subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                stderr=subprocess.STDOUT, text=True)

    pending = list(cells)
    while pending or procs:
        while pending and len(procs) < args.jobs:
            c = pending.pop(0)
            procs.append((c, launch(c)))
            print(f"launched {c}", flush=True)
        done = [(c, p) for c, p in procs if p.poll() is not None]
        for c, p in done:
            procs.remove((c, p))
            out = p.stdout.read()
            ok = p.returncode == 0
            results[c] = ok
            tag = "OK " if ok else "FAIL"
            print(f"[{tag}] {c}")
            if not ok:
                print(out[-4000:])
        time.sleep(2)
    n_ok = sum(results.values())
    print(f"\nsweep: {n_ok}/{len(results)} cells passed")
    return 0 if n_ok == len(results) else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + tiny shape (subprocess tests)")
    ap.add_argument("--mesh-shape", type=str, default=None,
                    help="e.g. 2,4 (data,model) or 2,2,4 (pod,data,model)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--flag", action="append", default=[],
                    help="PerfFlags field to enable (repeatable)")
    ap.add_argument("--remat", default=None, choices=["none", "block"])
    ap.add_argument("--tag", default="", help="artifact suffix")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("-j", "--jobs", type=int, default=4)
    args = ap.parse_args(argv)

    if args.all:
        return _sweep(args)

    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split(","))
                  if args.mesh_shape else None)
    flags = None
    if args.flag:
        from repro.models.layers import PerfFlags
        flags = PerfFlags(**{f: True for f in args.flag})
    try:
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 out_dir=args.out, smoke=args.smoke, mesh_shape=mesh_shape,
                 microbatches=args.microbatches, flags=flags,
                 remat=args.remat, tag=args.tag)
        return 0
    except Exception:
        traceback.print_exc()
        return 1


if __name__ == "__main__":
    sys.exit(main())
