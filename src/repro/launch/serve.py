"""Serving launcher: batched generation demo.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import lm
from repro.models.layers import single_device_mesh
from repro.serve.engine import Engine, ServeConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    entry = registry.get(args.arch)
    if entry.is_encdec:
        raise SystemExit("enc-dec serving: see examples/serve_batched.py")
    cfg = entry.smoke() if args.smoke else entry.config
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = Engine(cfg, params, single_device_mesh(),
                 ServeConfig(max_new_tokens=args.new_tokens,
                             temperature=args.temperature, seed=args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = [list(rng.integers(1, cfg.vocab_size,
                                 size=args.prompt_len))
               for _ in range(args.batch)]
    t0 = time.time()
    out = eng.generate(prompts)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s batched)")
    for i, o in enumerate(out[:2]):
        print(f"  sample {i}: {o}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
