"""Neurocore-aware workload metrics (paper insight M0).

The paper's central measurement finding: *network-wide* sparsity / op totals
are unreliable performance predictors on barrier-synchronized parallel
hardware — the **maximum per-unit** load governs the step time.  This module
computes both views from per-unit counters so the gap itself is reportable.

The same metrics apply unchanged to the TPU adaptation where the "unit" is a
chip, an MoE expert, or a sequence shard (see ``repro.distributed``).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LoadStats:
    """Aggregate vs per-unit view of one counter (M0)."""

    total: float
    max: float
    mean: float
    imbalance: float        # max / mean over *active* units; 1.0 = balanced
    n_units: int
    n_active: int

    @staticmethod
    def of(per_unit: np.ndarray) -> "LoadStats":
        per_unit = np.asarray(per_unit, dtype=np.float64).ravel()
        active = per_unit > 0
        n_active = int(np.sum(active))
        total = float(np.sum(per_unit))
        mx = float(np.max(per_unit)) if per_unit.size else 0.0
        mean = total / max(n_active, 1)
        return LoadStats(total=total, max=mx, mean=mean,
                         imbalance=(mx / mean) if mean > 0 else 1.0,
                         n_units=int(per_unit.size), n_active=n_active)


@dataclasses.dataclass(frozen=True)
class WorkloadMetrics:
    """Full M0 metric set for one workload configuration / step."""

    synops: LoadStats          # per-neurocore synop accumulations
    acts: LoadStats            # per-neurocore activation computes
    traffic: LoadStats         # per-NoC-link message loads
    msgs_total: float          # total activation messages emitted
    weight_density: float      # network-wide (the "conventional proxy")
    act_density: float         # network-wide (the "conventional proxy")

    @property
    def max_synops(self) -> float:
        return self.synops.max

    @property
    def max_acts(self) -> float:
        return self.acts.max

    @property
    def max_link_load(self) -> float:
        return self.traffic.max


def network_wide_density(nnz: float, capacity: float) -> float:
    """The conventional aggregate proxy the paper shows to be insufficient."""
    return float(nnz) / max(float(capacity), 1.0)


def proxy_gap(metrics: WorkloadMetrics) -> float:
    """How much the aggregate proxy under-states the true bottleneck:
    max-per-core synops vs what a perfectly balanced network would give.
    1.0 = aggregate proxy is exact; >1 = load imbalance invalidates it."""
    return metrics.synops.imbalance
