"""Floorline-style three-term bound analysis of compiled XLA programs.

The paper's floorline places a neuromorphic workload by (max per-core
synops, max per-core activation computes, NoC traffic).  A pjit-SPMD TPU
step is the same shape of machine — barrier-synchronized units where the
slowest term bounds the step — with the terms:

    compute term    = HLO_FLOPs_per_chip   / peak_FLOPs/s
    memory term     = HLO_bytes_per_chip   / HBM_bandwidth
    collective term = collective_operand_bytes_per_chip / link_bandwidth

``cost_analysis()`` of the SPMD-partitioned executable reports *per-chip*
flops/bytes (each chip runs the same partitioned program).  Collective bytes
are NOT in cost_analysis: we parse the compiled HLO text and sum operand
sizes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (matching the assignment's definition).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  The dominant term is the workload's bottleneck state, exactly like a
position on the floorline; `recommendation()` mirrors the paper's (a)/(b)/(c)
optimization moves.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

from repro.core.analytical import Bottleneck

PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)\s+)?\S*?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int]
    count_by_kind: dict[str, int]
    ops: list[dict]                      # per-op detail (kind, bytes, groups)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of every collective in (post-SPMD) HLO text.

    The per-device module's operand shapes are per-shard, so the totals are
    bytes-per-chip.  `-done` ops are skipped (they alias their `-start`).
    """
    bytes_by: dict[str, int] = {}
    count_by: dict[str, int] = {}
    ops: list[dict] = []
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand shapes: everything inside the call parens
        call = line[m.end() - 1:]
        operand_bytes = sum(_shape_bytes(d, s)
                            for d, s in _SHAPE_RE.findall(
                                call.split("),", 1)[0] + ")")
                            )
        g = _GROUPS_RE.search(line)
        group = int(g.group(2)) if g else None
        bytes_by[kind] = bytes_by.get(kind, 0) + operand_bytes
        count_by[kind] = count_by.get(kind, 0) + 1
        ops.append({"kind": kind, "bytes": operand_bytes, "group": group})
    return CollectiveStats(bytes_by, count_by, ops)


@dataclasses.dataclass
class RooflineTerms:
    """The three floorline terms for one compiled (arch x shape x mesh)."""

    flops_per_chip: float
    hbm_bytes_per_chip: float
    collective_bytes_per_chip: float
    model_flops: float = 0.0             # 6*N*D (dense) / 6*N_active*D (MoE)
    n_chips: int = 1
    label: str = ""

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / LINK_BW

    @property
    def bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def dominant(self) -> Bottleneck:
        terms = {Bottleneck.COMPUTE: self.t_compute,
                 Bottleneck.MEMORY: self.t_memory,
                 Bottleneck.TRAFFIC: self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much compiled compute is
        'useful' — catches remat/redundancy waste (and, when > 1, flops the
        HLO cost model does not see, e.g. inside custom ops)."""
        total = self.flops_per_chip * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of the compute roofline if the program hit
        its bound: useful-compute-time / bound-time."""
        useful_t = (self.model_flops / self.n_chips) / PEAK_FLOPS
        return useful_t / self.bound if self.bound else 0.0

    def recommendation(self) -> str:
        d = self.dominant
        if d == Bottleneck.MEMORY:
            return ("memory-bound: cut HBM traffic — fuse/remat less, "
                    "larger microbatch, bf16/f8 buffers, better layouts")
        if d == Bottleneck.COMPUTE:
            return ("compute-bound: cut redundant FLOPs (remat policy, "
                    "duplicated projections) or accept — at the roofline")
        return ("collective-bound: re-shard to shrink collective bytes "
                "(SP dispatch, reduce-scatter instead of all-reduce, "
                "overlap via microbatch pipelining)")

    def row(self) -> dict:
        return {
            "label": self.label,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bound_s": self.bound,
            "dominant": self.dominant.value,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_for(cfg, shape_kind: str, seq_len: int, batch: int,
                    n_new_tokens: int = 1) -> float:
    """6*N*D rule (forward+backward for train; 2*N*D forward-only for
    prefill/decode), N = active params."""
    active = (cfg.active_param_count()
              if hasattr(cfg, "active_param_count") else cfg.param_count())
    if shape_kind == "train":
        return 6.0 * active * seq_len * batch
    if shape_kind == "prefill":
        return 2.0 * active * seq_len * batch
    return 2.0 * active * batch * n_new_tokens


def terms_from_compiled(compiled, *, model_flops: float, n_chips: int,
                        label: str = "") -> RooflineTerms:
    """Three terms from a compiled executable.

    Uses the trip-count-aware HLO analyzer (repro.core.hlo_cost) — XLA's
    built-in cost_analysis() counts scan bodies once and would under-report
    every scanned program (verified; see EXPERIMENTS.md)."""
    from repro.core import hlo_cost
    c = hlo_cost.analyze(compiled.as_text())
    return RooflineTerms(
        flops_per_chip=c.flops, hbm_bytes_per_chip=c.hbm_bytes,
        collective_bytes_per_chip=c.collective_bytes,
        model_flops=model_flops, n_chips=n_chips, label=label)
