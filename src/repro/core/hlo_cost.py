"""Trip-count-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts each ``while`` body ONCE,
regardless of trip count (verified empirically — see EXPERIMENTS.md §Dry-run
notes).  Framework code built on ``lax.scan`` (layer stacking, microbatch
gradient accumulation, chunked attention) is therefore massively
under-counted.  This module re-derives per-chip costs from the HLO text:

  1. split the module into named computations and build a per-computation
     SSA symbol table (instruction -> result shape),
  2. find every `while`, resolve its condition computation's loop bound
     (compare-against-constant pattern) -> trip count,
  3. propagate multipliers entry->leaves: while/call bodies scale by trips;
     fusion sub-computations inherit the FLOP multiplier but contribute no
     HBM bytes (fused intermediates never touch HBM),
  4. per op: dot FLOPs = 2 * prod(result) * contraction_extent;
     HBM bytes = operand + result bytes of material ops;
     collective bytes = operand bytes by kind.

Shapes in the partitioned module are per-shard, so every number is
per-chip.  This is the "profile" the §Perf hillclimb reads (the dry-run
equivalent of a wall-clock trace).  Elementwise FLOPs are not counted (dots
dominate every cell by construction; transcendentals are visible in XLA's
own cost_analysis for cross-checking).
"""

from __future__ import annotations

import collections
import dataclasses
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0, "s4": 1, "u4": 1,
}

_NAME_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s([a-z][\w\-]*)\(")
_WHILE_ATTRS = re.compile(r"(condition|body)=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_NOBYTE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "get-dimension-size", "domain", "opt-barrier", "while",
               "conditional", "call"}


def _shape_list(type_str: str) -> list[tuple[str, str]]:
    return _SHAPE_RE.findall(type_str)


def _bytes_of(shapes: list[tuple[str, str]]) -> int:
    total = 0
    for dtype, dims in shapes:
        b = _DTYPE_BYTES.get(dtype)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


def _args_segment(line: str, op_end: int) -> str:
    """Text inside the op's balanced call parens."""
    depth = 0
    start = None
    for i in range(op_end - 1, len(line)):
        c = line[i]
        if c == "(":
            if depth == 0:
                start = i + 1
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[start:i]
    return line[op_end:]


def _split_computations(text: str):
    comps: dict[str, list[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in text.splitlines():
        if line and not line[0].isspace() and "{" in line and "->" in line:
            m = _NAME_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        elif cur is not None:
            s = line.strip()
            if s == "}":
                cur = None
            else:
                comps[cur].append(s)
    return comps, entry


def _trip_count(cond_lines: list[str]) -> int:
    consts = []
    for line in cond_lines:
        if "compare(" in line or "constant(" in line:
            consts += [int(c) for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


_PARAM_RE = re.compile(r"parameter\((\d+)\)")
_SLICERS = {"dynamic-slice", "slice", "gather"}


def _fusion_io_bytes(sub: str, parsed, symtab, operands, tab,
                     rbytes: int) -> int:
    """Bytes a fusion moves: operands consumed only by slice/gather ops
    inside the fused computation contribute their slice-result bytes, not
    the full operand (XLA reads just the window); a fusion whose ROOT is a
    dynamic-update-slice writes only the update window (the big buffer is
    aliased in place), so the result contributes 2x update bytes."""
    instrs = parsed.get(sub)
    if instrs is None:
        return rbytes + _bytes_of([s for o in operands
                                   for s in tab.get(o, [])])
    stab = symtab[sub]
    param_name: dict[int, str] = {}
    consumers: dict[str, list[tuple[str, str]]] = {}
    dus_updates = 0
    for name, op, ops_, line in instrs:
        pm = _PARAM_RE.search(line)
        if op == "parameter" and pm:
            param_name[int(pm.group(1))] = name
        if op == "dynamic-update-slice" and len(ops_) > 1:
            dus_updates += _bytes_of(stab.get(ops_[1], []))
        for o in ops_:
            consumers.setdefault(o, []).append((op, name))
    total = 0
    for i, o in enumerate(operands):
        full = _bytes_of(tab.get(o, []))
        pname = param_name.get(i)
        cons = consumers.get(pname, []) if pname else []
        if cons and all(c_op in _SLICERS for c_op, _ in cons):
            total += sum(_bytes_of(stab.get(c_name, []))
                         for _, c_name in cons)
        elif (cons and dus_updates
              and all(c_op == "dynamic-update-slice" for c_op, _ in cons)):
            total += dus_updates        # in-place buffer: read ~update only
        else:
            total += full
    if dus_updates and dus_updates < rbytes:
        total += dus_updates            # write = update window, not buffer
    else:
        total += rbytes
    return total


def _is_score_like(shapes: list[tuple[str, str]]) -> bool:
    """Attention-score-shaped: the two trailing dims are both >= 512 and
    the tensor is >= 4 Mi elements (S x S or S x kv_chunk blocks)."""
    for _, dims in shapes:
        d = [int(x) for x in dims.split(",") if x]
        if len(d) >= 2 and d[-1] >= 512 and d[-2] >= 512 \
                and math.prod(d) >= 4 * 2**20:
            return True
    return False


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    score_bytes: float = 0.0        # subset of hbm_bytes: VMEM-resident on
                                    # TPU under kernels/flash_attn
    collective_bytes: float = 0.0
    bytes_by_kind: dict = dataclasses.field(default_factory=dict)
    count_by_kind: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)
    top_collectives: list = dataclasses.field(default_factory=list)
    top_dots: list = dataclasses.field(default_factory=list)
    top_hbm: list = dataclasses.field(default_factory=list)


def analyze(text: str) -> HloCost:
    comps, entry = _split_computations(text)
    cost = HloCost()

    parsed: dict[str, list[tuple[str, str, list[str], str]]] = {}
    symtab: dict[str, dict[str, list[tuple[str, str]]]] = {}
    edges: dict[str, list[tuple[str, float, bool]]] = {c: [] for c in comps}

    for cname, lines in comps.items():
        tab: dict[str, list[tuple[str, str]]] = {}
        instrs = []
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, op = m.groups()
            tab[name] = _shape_list(type_str)
            args = _args_segment(line, m.end())
            operands = re.findall(r"%([\w\.\-]+)", args)
            instrs.append((name, op, operands, line))
            if op == "while":
                attrs = dict(_WHILE_ATTRS.findall(line))
                body, cond = attrs.get("body"), attrs.get("condition")
                if body and cond:
                    t = _trip_count(comps.get(cond, []))
                    cost.while_trips[body] = t
                    edges[cname].append((body, float(t), False))
                continue
            for sub in _CALLS_RE.findall(line):
                if sub in comps:
                    edges[cname].append((sub, 1.0, op == "fusion"))
        parsed[cname] = instrs
        symtab[cname] = tab

    m_flops: dict[str, float] = collections.defaultdict(float)
    m_bytes: dict[str, float] = collections.defaultdict(float)
    roots = [entry] if entry in comps else []
    if not roots:
        called = {s for subs in edges.values() for s, _, _ in subs}
        roots = [c for c in comps if c not in called]
    queue = collections.deque((r, 1.0, 1.0) for r in roots)
    budget = 5_000_000
    while queue and budget > 0:
        budget -= 1
        cname, mf, mb = queue.popleft()
        m_flops[cname] += mf
        m_bytes[cname] += mb
        for sub, t, is_fusion in edges.get(cname, []):
            if sub != cname:
                queue.append((sub, mf * t, 0.0 if is_fusion else mb * t))

    coll_sizes: list[tuple[str, float]] = []
    dot_sizes: list[tuple[str, float]] = []
    hbm_sizes: list[tuple[str, float]] = []
    for cname, instrs in parsed.items():
        mf, mb = m_flops.get(cname, 0.0), m_bytes.get(cname, 0.0)
        if mf <= 0 and mb <= 0:
            continue
        tab = symtab[cname]
        for name, op, operands, line in instrs:
            rshapes = tab.get(name, [])
            if op == "dot" and mf > 0:
                rsize = 0
                for dt, dims in rshapes:
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    rsize += n
                lhs = tab.get(operands[0], []) if operands else []
                contract = 1
                cm = _CONTRACT_RE.search(line)
                if cm and cm.group(1) and lhs:
                    ldims = [int(d) for d in lhs[0][1].split(",") if d]
                    for i in cm.group(1).split(","):
                        contract *= ldims[int(i)]
                f = 2.0 * rsize * contract
                cost.flops += mf * f
                dot_sizes.append((f"x{mf:.0f} {line[:110]}", mf * f))
            if op in _NOBYTE_OPS or mb <= 0:
                continue
            rbytes = _bytes_of(rshapes)
            # slice-type ops touch only the moved window, not the operand
            if op in ("dynamic-slice", "slice"):
                bytes_touched = 2 * rbytes
            elif op == "dynamic-update-slice":
                upd = (_bytes_of(tab.get(operands[1], []))
                       if len(operands) > 1 else rbytes)
                bytes_touched = 2 * upd
            elif op == "gather":
                idx = (_bytes_of(tab.get(operands[1], []))
                       if len(operands) > 1 else 0)
                bytes_touched = 2 * rbytes + idx
            elif op == "scatter":
                upd = (_bytes_of(tab.get(operands[2], []))
                       if len(operands) > 2 else rbytes)
                bytes_touched = 2 * upd
            elif op == "broadcast":
                bytes_touched = rbytes
            elif op == "fusion":
                subs = _CALLS_RE.findall(line)
                bytes_touched = (
                    _fusion_io_bytes(subs[0], parsed, symtab, operands, tab,
                                     rbytes)
                    if subs else
                    rbytes + _bytes_of([s for o in operands
                                        for s in tab.get(o, [])]))
            else:
                obytes = _bytes_of([s for o in operands
                                    for s in tab.get(o, [])])
                bytes_touched = obytes + rbytes
            cost.hbm_bytes += mb * bytes_touched
            # score-like tensors (two trailing seq dims): on the TPU target
            # these stay in VMEM inside the flash-attention Pallas kernel
            # (kernels/flash_attn); CPU fusion boundaries materialize them.
            if _is_score_like(rshapes) or any(
                    _is_score_like(tab.get(o, [])) for o in operands):
                cost.score_bytes += mb * bytes_touched
            hbm_sizes.append((f"x{mb:.0f} {line[:110]}", mb * bytes_touched))
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                obytes = _bytes_of([s for o in operands
                                    for s in tab.get(o, [])])
                cbytes = obytes if obytes else rbytes
                cost.collective_bytes += mb * cbytes
                cost.bytes_by_kind[base] = (cost.bytes_by_kind.get(base, 0)
                                            + mb * cbytes)
                cost.count_by_kind[base] = (cost.count_by_kind.get(base, 0)
                                            + mb)
                coll_sizes.append((f"{base} x{mb:.0f} {line[:100]}",
                                   mb * cbytes))
    coll_sizes.sort(key=lambda x: -x[1])
    dot_sizes.sort(key=lambda x: -x[1])
    hbm_sizes.sort(key=lambda x: -x[1])
    cost.top_collectives = coll_sizes[:12]
    cost.top_dots = dot_sizes[:12]
    cost.top_hbm = hbm_sizes[:12]
    return cost
