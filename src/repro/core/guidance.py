"""Floorline-guided per-layer training guidance (closing the §VII loop).

The floorline model (§VI-A) classifies a *workload*; sparsity-aware
training (§VII-A) needs that verdict per *layer*: which layers should the
activation/weight regularizers push hardest?  This module prices the
workload once, decomposes the step time into per-layer stage times
(:func:`repro.neuromorphic.timestep.layer_stage_times`), places each layer
on the floorline with :meth:`FloorlineModel.classify`, and turns the
per-layer bottleneck states into regularizer weights:

* **traffic-bound** layers get the largest weight — sparsifying their
  messages attacks the term *above* the floorline (§VI-A move (c));
* **memory-bound** layers come next — fewer synops slides them down-left
  along the memory slope (move (a));
* **compute-bound** layers get the smallest weight — activation sparsity
  barely moves an act-latency floor (move (b) wants partitioning, not
  sparsity).

Within a state, hotter layers (larger stage time) are weighted harder, so
the training signal concentrates on the layers that actually set the step
time.  The weights feed ``tl1_regularizer(..., weights=)`` /
``synops_loss(..., weights=)`` in :mod:`repro.train.sparse`.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.analytical import Bottleneck
from repro.core.floorline import FloorlineModel, WorkloadPoint

#: per-state base multipliers (traffic > memory > compute, see module doc)
DEFAULT_STATE_WEIGHTS = {
    Bottleneck.TRAFFIC: 3.0,
    Bottleneck.MEMORY: 2.0,
    Bottleneck.COMPUTE: 1.0,
}


@dataclasses.dataclass(frozen=True)
class LayerGuidance:
    """One layer's floorline placement + the training weight derived from
    it.  ``stage`` carries the raw per-layer stage times."""

    name: str
    state: Bottleneck
    weight: float
    stage: object                     # LayerStageTimes


def floorline_layer_guidance(net, xs, profile, part=None, mapping=None, *,
                             cache=None, state_weights=None,
                             traffic_tol: float = 0.25
                             ) -> list[LayerGuidance]:
    """Classify every layer's bottleneck state and derive its regularizer
    weight.  Each layer is placed on a normalized floorline (unit
    latencies) at its stage-time coordinates — ``classify`` then reads
    TRAFFIC when the layer's NoC share exceeds ``traffic_tol`` of its
    pipeline bound, MEMORY/COMPUTE by the dominant stage — exactly the
    §VI-A (a)/(b)/(c) decision at layer granularity.  Weights are
    state-base times the layer's relative heat, normalized to mean 1 so
    the regularizer strength ``lam`` keeps its meaning."""
    from repro.neuromorphic.timestep import layer_stage_times

    stages = layer_stage_times(net, xs, profile, part, mapping, cache=cache)
    state_weights = state_weights or DEFAULT_STATE_WEIGHTS
    model = FloorlineModel(mem_latency=1.0, act_latency=1.0, t0=0.0,
                           traffic_tol=traffic_tol)
    totals = np.array([s.total_time for s in stages], np.float64)
    hot = totals / max(float(totals.max()), 1e-30)
    out = []
    raw = []
    for s, h in zip(stages, hot):
        point = WorkloadPoint(max_synops=s.mem_time, max_acts=s.act_time,
                              time=s.total_time, label=s.name)
        state = model.classify(point)
        raw.append(state_weights[state] * float(h))
        out.append((s, state))
    mean = max(float(np.mean(raw)), 1e-30)
    return [LayerGuidance(name=s.name, state=state, weight=w / mean, stage=s)
            for (s, state), w in zip(out, raw)]


def floorline_layer_weights(net, xs, profile, part=None, mapping=None, *,
                            cache=None, state_weights=None,
                            traffic_tol: float = 0.25) -> np.ndarray:
    """Just the per-layer weight vector (mean 1.0), ready for
    ``tl1_regularizer`` / ``synops_loss``."""
    gs = floorline_layer_guidance(net, xs, profile, part, mapping,
                                  cache=cache, state_weights=state_weights,
                                  traffic_tol=traffic_tol)
    return np.array([g.weight for g in gs], np.float64)
