"""Device-resident evolutionary generation engines (``engine="device"``
and the island-model ``engine="sharded"``).

The numpy engine in :mod:`repro.core.search` prices generations through the
stacked population backends, but its generation *loop* — tournament draws,
the per-offspring split/merge/swap mutation chain, phenotype dedup, elitist
survival — still runs as per-offspring Python over host NumPy rows, forcing
a host↔device round-trip every generation.  This module compiles the ENTIRE
generation step into one jitted program over the stacked ``(K, n_layers)``
core-count and ``(K, n_slots)`` permutation matrices:

1. **tournament selection** — a row-min over the draw matrix (survivors are
   kept (rank, time, energy)-sorted, so fitness order == index order);
2. **table-gated mutation** (:func:`mutate_rows_array`) — the bottleneck
   stage picks split/merge/swap per offspring, feasibility is a gather into
   the :class:`~repro.core.search.MoveTables` matrix, and the fallback chain
   is a deterministic masked cascade (split → merge → swap; a swap of two
   permutation genes is always valid and always changes the row);
3. **pricing** — :meth:`DevicePopulationPricer.price_row` vmapped over the
   offspring axis (segment boundaries and NoC flow structures are derived
   from the genome rows on device, no host-side batch assembly);
4. **survival** (:func:`survival_order_array` + :func:`pareto_ranks_array`)
   — nondomination ranking, ``(rank, time, energy, index)`` lexsort, and a
   sort-based phenotype dedup, keeping the ``population_size`` best unique
   rows.

Survivor batches (genomes, objectives, bottleneck stages, hot layers) stay
device-resident between generations; the only per-generation host traffic
is the 3-scalar :class:`~repro.core.search.GenStats` record and the
offspring (times, energies, genomes) fed to the epsilon-Pareto archive.

**The PRNG-key contract.**  All randomness in a run derives from
``jax.random.PRNGKey(seed)``: generation ``g`` consumes exactly the draws
of :func:`generation_draws` under ``fold_in(key, g)`` — fixed shapes,
fixed split order, explicit dtypes.  Because ``jax.random`` is
deterministic regardless of jit/eager and of backend, a host NumPy mirror
(``reference=True``) can consume the *identical* draw tensors and replay
the identical decisions: :func:`evolutionary_search_device` with
``reference=True`` runs the same algorithm with ``xp=numpy`` host ops and
the bit-exact numpy pricing backend.  ``tests/test_device_search.py``
asserts selection/mutation/survival parity exactly and the full fitness
trajectory to float64 roundoff.

Two deliberate, documented deviations from the numpy engine (same
*algorithm family*, different micro-policy — the numpy engine remains the
reference for its own path, not for this one):

* no ``tried``-set resampling of duplicate offspring (a host-side hash
  set); duplicates are simply removed at survival, and
* the population size is fixed at the seeded size: when fewer than
  ``population_size`` unique rows exist the best rows are duplicated
  rather than shrinking the batch (shapes must be static on device).

**The sharded island engine** (:class:`ShardedSearchEngine`,
``engine="sharded"``) scales this loop across a 1-D ``("island",)`` device
mesh: the population's K axis is sharded so every device runs the SAME
:func:`_generation_step` on its own subpopulation (an island), with elites
rotating one island around a ``ppermute`` ring every ``migrate_every``
generations and global stats assembled in-program via
``all_gather``/``psum``.  Its PRNG contract extends the device engine's:
island ``i`` of generation ``g`` draws under
``fold_in(key, g * n_islands + i)`` (:func:`island_keys`), which for a
single island reduces exactly to ``fold_in(key, g)`` — so a mesh of one
reproduces ``engine="device"`` trajectories bit-identically, and
:class:`_ShardedHostMirror` replays migration semantics on host NumPy
(``docs/distributed.md``; parity asserted by
``tests/test_sharded_search.py``).
"""

from __future__ import annotations

import dataclasses
import logging
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64
from jax.sharding import PartitionSpec

from repro.core.resilience import (Demotion, FaultPlan, RetryPolicy,
                                   SearchCheckpointer, finite_mean,
                                   quarantine_rows, validate_resume_meta)
from repro.distributed.collectives import gather_islands, ring_shift
from repro.distributed.compat import shard_map
from repro.core.search import (Candidate, EpsParetoArchive, GenStats,
                               MoveTables, Population, SearchResult,
                               _validate_search_args, decode, move_tables,
                               pareto_ranks, seeded_population)
from repro.neuromorphic.timestep import (device_pricer, precompute_pricing,
                                         price_candidate,
                                         simulate_population)

log = logging.getLogger("repro.resilience")

#: bottleneck-stage ids, in the (first-max-wins) vote order shared with
#: ``SimReport.bottleneck_stage`` / ``_VmapPricer`` votes
STAGE_ID = {"memory": 0, "compute": 1, "traffic": 2, "barrier": 3}


# ----------------------------------------------------------- PRNG contract

def generation_draws(key, *, n_off: int, n_pop: int, n_layers: int,
                     n_slots: int, tournament_k: int) -> dict:
    """One generation's complete randomness, from one key.

    This function IS the PRNG-key contract: a fixed 8-way split consumed in
    a fixed order with explicit dtypes, so the jitted device step and the
    eager NumPy mirror draw identical tensors.  Keys: ``tourn`` (n_off, k)
    parent indices; ``explore_u`` / ``stage_r`` exploration coin and
    replacement stage; ``traffic_u`` the merge-vs-swap coin of the traffic
    move; ``split_pri`` / ``merge_pri`` (n_off, n_layers) random priorities
    that pick among feasible layers; ``swap_iu`` / ``swap_ju`` the swap
    gene positions.  Requires an enabled-x64 scope (float64 draws).
    """
    ks = jax.random.split(key, 8)
    kt = max(1, int(tournament_k))
    return dict(
        tourn=jax.random.randint(ks[0], (n_off, kt), 0, n_pop,
                                 dtype=jnp.int32),
        explore_u=jax.random.uniform(ks[1], (n_off,), dtype=jnp.float64),
        stage_r=jax.random.randint(ks[2], (n_off,), 0, 3, dtype=jnp.int32),
        traffic_u=jax.random.uniform(ks[3], (n_off,), dtype=jnp.float64),
        split_pri=jax.random.uniform(ks[4], (n_off, n_layers),
                                     dtype=jnp.float64),
        merge_pri=jax.random.uniform(ks[5], (n_off, n_layers),
                                     dtype=jnp.float64),
        swap_iu=jax.random.uniform(ks[6], (n_off,), dtype=jnp.float64),
        swap_ju=jax.random.uniform(ks[7], (n_off,), dtype=jnp.float64),
    )


def island_keys(base_key, gen: int, n_islands: int):
    """The sharded engine's per-island PRNG-key contract.

    Island ``i`` of generation ``g`` consumes :func:`generation_draws`
    under ``fold_in(base_key, g * n_islands + i)`` — the ``(gen, island)``
    pair packed into a single fold so that with ``n_islands == 1`` the
    stream reduces EXACTLY to the device engine's ``fold_in(base_key, g)``
    (the mesh-size-1 bit-parity contract).  Returns the stacked
    ``(n_islands, key_size)`` keys; the sharded step's ``in_specs`` shard
    them over the island axis, so each island reads row 0 of its block.
    Derivation stays on host — the jitted step never folds keys itself, so
    the host mirror consumes the identical key rows."""
    g, n = int(gen), int(n_islands)
    return jnp.stack([jax.random.fold_in(base_key, g * n + i)
                      for i in range(n)])


# ------------------------------------------------------- array-native moves

def mutate_rows_array(xp, pc, pp, pstage, phot_mem, phot_act, draws,
                      feasible, n_phys: int, explore_prob: float):
    """Stacked table-gated mutation: parent rows -> offspring rows.

    Pure array program over the offspring axis, written against the shared
    numpy/jax.numpy API surface: ``xp=jnp`` is the device path (traced into
    the jitted generation step), ``xp=numpy`` the host mirror — identical
    semantics op for op, which the parity suite asserts exactly.

    Per offspring: the parent's bottleneck stage (or, with probability
    ``explore_prob`` — and always on a "barrier" stage — a uniformly random
    stage) picks the move family.  memory/compute want a split of the hot
    layer (falling back to the feasible layer of max random priority);
    traffic flips a coin between merge and swap.  The fallback cascade is
    deterministic: an infeasible split falls to merge, an infeasible merge
    to swap.  A swap exchanges one *expressed* gene with any other gene —
    permutation entries are distinct, so it always changes the mapping and
    is always valid.
    """
    n_off, n_layers = pc.shape
    n_slots = pp.shape[1]
    lrange = xp.arange(n_layers)

    explore = (draws["explore_u"] < explore_prob) | (pstage >= 3)
    s_eff = xp.where(explore, draws["stage_r"], pstage)

    total = pc.sum(axis=1)
    split_feas = (feasible[lrange[None, :], pc + 1]
                  & ((total + 1) <= n_phys)[:, None])
    merge_feas = (pc > 1) & feasible[lrange[None, :], pc - 1]

    hot = xp.where(s_eff == 0, phot_mem, phot_act)
    hot_ok = xp.take_along_axis(split_feas, hot[:, None], axis=1)[:, 0]
    rand_split = xp.argmax(xp.where(split_feas, draws["split_pri"], -1.0),
                           axis=1).astype(xp.int32)
    split_l = xp.where(hot_ok, hot, rand_split)
    any_split = split_feas.any(axis=1)
    merge_l = xp.argmax(xp.where(merge_feas, draws["merge_pri"], -1.0),
                        axis=1).astype(xp.int32)
    any_merge = merge_feas.any(axis=1)

    want_split = s_eff <= 1
    traffic_merge = (s_eff == 2) & (draws["traffic_u"] < 0.5)
    do_split = want_split & any_split
    do_merge = ~do_split & any_merge & (traffic_merge | want_split)
    do_swap = ~(do_split | do_merge)

    oh_split = (lrange[None, :] == split_l[:, None]) & do_split[:, None]
    oh_merge = (lrange[None, :] == merge_l[:, None]) & do_merge[:, None]
    cores = pc + oh_split.astype(pc.dtype) - oh_merge.astype(pc.dtype)

    # swap: i an expressed gene, j any gene (i != j); clamps guard the
    # u -> index map against u*total rounding up to total
    i = xp.minimum((draws["swap_iu"] * total).astype(xp.int32), total - 1)
    j = xp.minimum((draws["swap_ju"] * n_slots).astype(xp.int32),
                   n_slots - 1)
    j = xp.where(i == j, (j + 1) % n_slots, j)
    pi = xp.take_along_axis(pp, i[:, None], axis=1)
    pj = xp.take_along_axis(pp, j[:, None], axis=1)
    srange = xp.arange(n_slots)
    swapped = xp.where(srange[None, :] == i[:, None], pj,
                       xp.where(srange[None, :] == j[:, None], pi, pp))
    perm = xp.where(do_swap[:, None], swapped, pp)
    return cores.astype(xp.int32), perm.astype(xp.int32)


def pareto_ranks_array(t, e, n_keep: int | None = None):
    """jnp nondomination ranks — the jittable (lax.while_loop) counterpart
    of :func:`repro.core.search.pareto_ranks`, same peeling algorithm.

    ``n_keep`` (a static Python int) caps the peeling for survival
    selection: the while_loop stops once at least ``n_keep`` rows are
    ranked — enough to fill every survivor slot — instead of running the
    O(K^2)-per-front peel over all K rows (the cost that dominated
    generations at population >= 1k).  Unpeeled rows carry the sentinel
    rank ``K``, which sorts after every real rank, so the
    ``(rank, time, energy, index)`` survival order is unchanged below the
    cutoff, and host and device agree rank-for-rank everywhere
    (``tests/test_device_search.py``).  Documented deviation from
    uncapped ranking: among the unpeeled (sentinel) rows the order falls
    back to (time, energy), so when phenotype dedup pushes survival past
    the cutoff — duplicate-heavy converged populations — the survivor
    tail may differ from the uncapped engine's; elitism is unaffected
    (rank 0 is always peeled first)."""
    dominated_by = ((t[None, :] <= t[:, None]) & (e[None, :] <= e[:, None])
                    & ((t[None, :] < t[:, None]) | (e[None, :] < e[:, None])))
    n = t.shape[0]
    cap = n if n_keep is None else min(int(n_keep), n)

    def body(state):
        ranks, remaining, r, peeled = state
        dom = (dominated_by & remaining[None, :]).sum(axis=1)
        frontier = remaining & (dom == 0)
        return (jnp.where(frontier, r, ranks), remaining & ~frontier,
                r + 1, peeled + frontier.sum().astype(jnp.int32))

    ranks, _, _, _ = jax.lax.while_loop(
        lambda s: s[1].any() & (s[3] < cap), body,
        (jnp.full(n, n, jnp.int32), jnp.ones(n, bool), jnp.int32(0),
         jnp.int32(0)))
    return ranks


def survival_order_array(xp, cores, perm, times, energies, ranks,
                         n_keep: int):
    """Elitist survival on stacked rows: indices of the ``n_keep`` best
    phenotype-unique rows under the total order (rank, time, energy,
    index).

    Dedup is sort-based (no O(K^2 * genes) equality tensor): rows are
    lexsorted by their genome columns with survival position as the final
    tie-break, so equal phenotypes are adjacent and ordered by fitness; a
    row equal to its sorted predecessor is a duplicate.  Unexpressed
    permutation genes are masked to -1 first — two genomes differing only
    in the dead tail are the same phenotype (the array analog of
    ``Population.row_key``).  If fewer than ``n_keep`` unique rows exist,
    the best duplicates pad the batch (static shapes).
    """
    n = cores.shape[0]
    idx = xp.arange(n)
    # total order is unique (index is the last key), so numpy and jax
    # agree independent of sort-stability implementation details
    order = xp.lexsort((idx, energies, times, ranks))
    oc, op = cores[order], perm[order]
    n_log = oc.sum(axis=1)
    pm = xp.where(xp.arange(perm.shape[1])[None, :] < n_log[:, None], op, -1)
    genome = xp.concatenate([oc, pm], axis=1)           # (n, L + S)
    gsort = xp.lexsort((idx,) + tuple(genome[:, c]
                                      for c in range(genome.shape[1])))
    gg = genome[gsort]
    eq_prev = xp.concatenate(
        [xp.zeros(1, bool), (gg[1:] == gg[:-1]).all(axis=1)])
    if xp is np:
        dup = np.zeros(n, bool)
        dup[gsort] = eq_prev
        sel = np.argsort(dup, kind="stable")
    else:
        dup = jnp.zeros(n, bool).at[gsort].set(eq_prev)
        sel = jnp.argsort(dup, stable=True)
    return order[sel[:n_keep]]


# ------------------------------------------------- shared step bookkeeping
#
# The generation-step skeleton is written ONCE, parameterized by the array
# namespace, the pricing function and the ranking function; the jitted
# device engine and the host mirror differ only in what they inject
# (jnp + vmapped device pricer + while_loop ranks vs numpy + the bit-exact
# numpy backend + host ranks).  What the parity suite then actually tests
# is the real divergence surface: XLA-vs-NumPy numerics of the same array
# program, and the two pricing paths.

def _sorted_state(xp, rank_fn, cores, perm, out, idx_n):
    """Price-output dict + genome rows -> survival-sorted state dict.
    Ranking is capped at the survivor count ``idx_n`` — rows beyond the
    cutoff only need a rank larger than every kept one.

    Objectives are quarantined first: NaN/inf rows take the sentinel
    ``(+inf, +inf)`` fitness, so they are dominated by every finite row
    and sort last, instead of poisoning the nondomination ranks (NaN
    comparisons are all False — an unscreened NaN row is never dominated
    and would rank 0).  Finite rows pass through bit-unchanged, on both
    the jitted and the mirror path (same ``where`` masking)."""
    t, e, _ = quarantine_rows(xp, out["times"], out["energies"])
    ranks = rank_fn(t, e, n_keep=idx_n)
    idx = survival_order_array(xp, cores, perm, t, e, ranks, idx_n)
    return dict(cores=cores[idx], perm=perm[idx], times=t[idx],
                energies=e[idx], stage=out["stage"][idx],
                hot_mem=out["hot_mem"][idx], hot_act=out["hot_act"][idx])


def _generation_step(xp, price_fn, rank_fn, feasible, n_phys, explore_prob,
                     state, draws):
    """One (mu + lambda) generation on stacked rows: select, mutate, price,
    concatenate with the survivors, rank, survive.  Returns (new state,
    offspring dict, stats dict)."""
    parents = draws["tourn"].min(axis=1)
    oc, op = mutate_rows_array(
        xp, state["cores"][parents], state["perm"][parents],
        state["stage"][parents], state["hot_mem"][parents],
        state["hot_act"][parents], draws, feasible, n_phys, explore_prob)
    out = price_fn(oc, op)
    all_c = xp.concatenate([state["cores"], oc])
    all_p = xp.concatenate([state["perm"], op])
    all_out = {k: xp.concatenate([state[k], out[k]])
               for k in ("times", "energies", "stage", "hot_mem", "hot_act")}
    new = _sorted_state(xp, rank_fn, all_c, all_p, all_out,
                        state["cores"].shape[0])
    off = dict(cores=oc, perm=op, times=out["times"],
               energies=out["energies"])
    n_quar = (~(xp.isfinite(out["times"])
                & xp.isfinite(out["energies"]))).sum()
    stats = dict(best_time=new["times"][0], best_energy=new["energies"][0],
                 mean_time=finite_mean(xp, new["times"]),
                 n_quarantined=n_quar)
    return new, off, stats


# ----------------------------------------------------------------- engine

class DeviceSearchEngine:
    """One workload's compiled generation machinery.

    Owns the jitted ``init`` (price + sort the seed population) and
    ``step`` (the full generation described in the module docstring)
    programs, both closed over the cache-bound
    :class:`~repro.neuromorphic.timestep.DevicePopulationPricer` and the
    feasibility table.  State is a dict of device arrays
    ``{cores, perm, times, energies, stage, hot_mem, hot_act}`` kept
    (rank, time, energy)-sorted; nothing in it touches the host between
    :meth:`step` calls.
    """

    def __init__(self, net, profile, cache, tables: MoveTables, *,
                 explore_prob: float, tournament_k: int):
        self.pricer = device_pricer(net, profile, cache)
        self.explore_prob = float(explore_prob)
        self.tournament_k = int(tournament_k)
        self.n_layers = len(cache.layers)
        self.n_slots = int(profile.n_cores)
        self.n_phys = int(tables.n_cores_phys)
        with enable_x64():
            self.feasible = jnp.asarray(tables.feasible)
        self._init_fn = jax.jit(self._init_impl)
        self._step_fn = jax.jit(self._step_impl, static_argnames=("n_off",))

    def _price(self, cores, perm):
        """Vmapped device pricing, normalized to the step-skeleton keys
        (``times``/``energies`` are the per-candidate objectives)."""
        o = jax.vmap(self.pricer.price_row)(cores, perm)
        return dict(times=o["time_per_step"], energies=o["energy_per_step"],
                    stage=o["stage"], hot_mem=o["hot_mem"],
                    hot_act=o["hot_act"])

    def _init_impl(self, cores, perm):
        out = self._price(cores, perm)
        state = _sorted_state(jnp, pareto_ranks_array, cores, perm, out,
                              cores.shape[0])
        return state, dict(times=out["times"], energies=out["energies"])

    def _step_impl(self, state, key, n_off: int):
        draws = generation_draws(key, n_off=n_off,
                                 n_pop=state["cores"].shape[0],
                                 n_layers=self.n_layers,
                                 n_slots=self.n_slots,
                                 tournament_k=self.tournament_k)
        return _generation_step(jnp, self._price, pareto_ranks_array,
                                self.feasible, self.n_phys,
                                self.explore_prob, state, draws)

    def init(self, cores, perm):
        with enable_x64():
            return self._init_fn(jnp.asarray(cores, jnp.int32),
                                 jnp.asarray(perm, jnp.int32))

    def step(self, state, key, n_off: int):
        with enable_x64():
            return self._step_fn(state, key, n_off=n_off)


def _engine_for(net, profile, cache, tables, *, explore_prob,
                tournament_k) -> DeviceSearchEngine:
    """Engines (and their compiled programs) are cached on the workload's
    device pricer, keyed by the mutation hyper-parameters, so repeated
    searches over one cache never re-jit."""
    pricer = device_pricer(net, profile, cache)
    engines = pricer.__dict__.setdefault("_search_engines", {})
    key = (float(explore_prob), int(tournament_k))
    if key not in engines:
        engines[key] = DeviceSearchEngine(net, profile, cache, tables,
                                          explore_prob=explore_prob,
                                          tournament_k=tournament_k)
    return engines[key]


# ---------------------------------------------------------- sharded engine

class ShardedSearchEngine:
    """Island-model generation machinery over a 1-D ``("island",)`` mesh.

    The population's K axis is sharded over the mesh: each device owns one
    island's ``local_pop`` rows and runs the SAME :func:`_generation_step`
    as :class:`DeviceSearchEngine` on them inside a jitted
    ``shard_map`` program — selection, mutation and pricing never cross
    islands, so generation throughput scales with the mesh while
    per-island semantics stay identical to the single-device engine.
    Collectives appear at exactly two points of the step:

    * **migration** (the static ``migrate=True`` compile variant): each
      island's elite block (rows ``[0:n_migrants]`` — state is kept
      survival-sorted) is *rotated* one island forward around a
      ``ppermute`` ring and replaces the recipient's elite block, after
      which each island re-sorts locally.  A rotation moves rows — it
      never copies or drops them — so the global genome multiset is
      preserved exactly (property-tested in
      ``tests/test_sharded_search.py``).
    * **global stats**: the generation's best/mean objectives are reduced
      in-program (``all_gather`` of the per-island leaders + ``psum`` of
      the finite sums/counts, the :func:`finite_mean` formula) and
      emitted once per island as ``(1,)`` slices; the host reads island
      0's copy.  Per-generation host traffic therefore stays O(offspring)
      and mesh-independent.

    Host-side array layouts (checkpoints, the mirror, ``init`` inputs)
    use island-block order: global row ``i * local_pop + r`` is island
    ``i``'s row ``r``.  With one island every collective degenerates to
    the identity and no ``migrate`` variant is ever compiled, so the
    trajectory is bit-identical to :class:`DeviceSearchEngine` under the
    :func:`island_keys` contract.
    """

    def __init__(self, net, profile, cache, tables: MoveTables, *, mesh,
                 local_pop: int, n_migrants: int, explore_prob: float,
                 tournament_k: int):
        self.pricer = device_pricer(net, profile, cache)
        self.mesh = mesh
        self.n_islands = int(mesh.shape["island"])
        self.local_pop = int(local_pop)
        self.n_migrants = int(n_migrants)
        self.explore_prob = float(explore_prob)
        self.tournament_k = int(tournament_k)
        self.n_layers = len(cache.layers)
        self.n_slots = int(profile.n_cores)
        self.n_phys = int(tables.n_cores_phys)
        with enable_x64():
            self.feasible = jnp.asarray(tables.feasible)
        spec = PartitionSpec("island")
        self._init_fn = self._wrap(self._init_impl, n_in=2,
                                   out_specs=(spec, spec))
        self._migrate_fn = self._wrap(self._migrate_impl, n_in=1,
                                      out_specs=spec)
        self._step_fns: dict = {}

    def _wrap(self, f, *, n_in: int, out_specs):
        """jit(shard_map(f)) with every input sharded over the island
        axis (a spec is a pytree *prefix*, so one P("island") covers a
        whole state dict)."""
        spec = PartitionSpec("island")
        return jax.jit(shard_map(f, mesh=self.mesh,
                                 in_specs=(spec,) * n_in,
                                 out_specs=out_specs, check_vma=False))

    def _price(self, cores, perm):
        o = jax.vmap(self.pricer.price_row)(cores, perm)
        return dict(times=o["time_per_step"], energies=o["energy_per_step"],
                    stage=o["stage"], hot_mem=o["hot_mem"],
                    hot_act=o["hot_act"])

    def _init_impl(self, cores, perm):
        out = self._price(cores, perm)
        state = _sorted_state(jnp, pareto_ranks_array, cores, perm, out,
                              self.local_pop)
        return state, dict(times=out["times"], energies=out["energies"])

    def _migrate_impl(self, state):
        m = self.n_migrants
        inc = ring_shift({k: v[:m] for k, v in state.items()},
                         size=self.n_islands)
        merged = {k: state[k].at[:m].set(inc[k]) for k in state}
        return _sorted_state(jnp, pareto_ranks_array, merged["cores"],
                             merged["perm"], merged, self.local_pop)

    def _global_stats(self, new, n_quar):
        """Globally-reduced GenStats scalars, computed inside the sharded
        program.  Every op sequence mirrors the single-device stats
        (``new[...][0]`` leaders, the :func:`finite_mean` formula) with the
        cross-island reduction spliced in — at one island the ``psum`` /
        ``all_gather`` are identities, preserving bit parity."""
        lead = gather_islands(dict(t=new["times"][0], e=new["energies"][0]))
        tmin = lead["t"].min()
        emin = jnp.where(lead["t"] == tmin, lead["e"], jnp.inf).min()
        ok = jnp.isfinite(new["times"])
        n_ok = jax.lax.psum(ok.sum(), "island")
        total = jax.lax.psum(jnp.where(ok, new["times"], 0.0).sum(),
                             "island")
        mean = jnp.where(n_ok > 0, total / jnp.maximum(n_ok, 1),
                         jnp.asarray(np.inf, dtype=total.dtype))
        n_quar = jax.lax.psum(n_quar, "island")
        return dict(best_time=tmin[None], best_energy=emin[None],
                    mean_time=mean[None], n_quarantined=n_quar[None])

    def _step_for(self, n_off: int, migrate: bool):
        sig = (int(n_off), bool(migrate))
        fn = self._step_fns.get(sig)
        if fn is None:
            spec = PartitionSpec("island")

            def body(state, keys):
                draws = generation_draws(keys[0], n_off=sig[0],
                                         n_pop=self.local_pop,
                                         n_layers=self.n_layers,
                                         n_slots=self.n_slots,
                                         tournament_k=self.tournament_k)
                new, off, st = _generation_step(
                    jnp, self._price, pareto_ranks_array, self.feasible,
                    self.n_phys, self.explore_prob, state, draws)
                if sig[1]:
                    new = self._migrate_impl(new)
                return new, off, self._global_stats(new,
                                                    st["n_quarantined"])

            fn = self._wrap(body, n_in=2, out_specs=(spec, spec, spec))
            self._step_fns[sig] = fn
        return fn

    def init(self, cores, perm):
        with enable_x64():
            return self._init_fn(jnp.asarray(cores, jnp.int32),
                                 jnp.asarray(perm, jnp.int32))

    def step(self, state, keys, n_off: int, migrate: bool = False):
        """One generation on every island from the stacked per-island
        ``keys`` (:func:`island_keys`); ``n_off`` is the per-island
        offspring count."""
        with enable_x64():
            return self._step_for(n_off, migrate)(state, jnp.asarray(keys))

    def migrate(self, state):
        """The migration collective alone (jitted) — the unit the
        multiset-preservation property test drives directly."""
        with enable_x64():
            return self._migrate_fn(state)


def _sharded_engine_for(net, profile, cache, tables, *, mesh, local_pop,
                        n_migrants, explore_prob,
                        tournament_k) -> ShardedSearchEngine:
    """Sharded engines are cached on the workload's device pricer like the
    single-device ones, additionally keyed by the island geometry and the
    exact device assignment (a different mesh must recompile)."""
    pricer = device_pricer(net, profile, cache)
    engines = pricer.__dict__.setdefault("_sharded_engines", {})
    key = (float(explore_prob), int(tournament_k), int(local_pop),
           int(n_migrants), tuple(d.id for d in mesh.devices.flat))
    if key not in engines:
        engines[key] = ShardedSearchEngine(net, profile, cache, tables,
                                           mesh=mesh, local_pop=local_pop,
                                           n_migrants=n_migrants,
                                           explore_prob=explore_prob,
                                           tournament_k=tournament_k)
    return engines[key]


# -------------------------------------------------------- reference mirror

class _NumpyMirror:
    """Host replay of the device engine under the shared PRNG-key contract.

    Prices with the bit-exact numpy population backend and runs
    selection/mutation/survival through the very same array programs with
    ``xp=numpy``.  This is the semantic specification the jitted engine is
    tested against — not a production path (use the numpy engine of
    :func:`repro.core.search.evolutionary_search` for host-only runs).
    """

    #: state handed to this engine must be fetched to host first
    host_state = True

    def __init__(self, net, xs, profile, cache, tables, *, explore_prob,
                 tournament_k, fault_plan: FaultPlan | None = None):
        self.net, self.xs, self.profile, self.cache = net, xs, profile, cache
        self.feasible = np.asarray(tables.feasible)
        self.n_phys = int(tables.n_cores_phys)
        self.n_layers = len(cache.layers)
        self.n_slots = int(profile.n_cores)
        self.explore_prob = float(explore_prob)
        self.tournament_k = int(tournament_k)
        #: fault-injection hook: scripted NaN pricing rows land here (the
        #: jitted engine's pricing cannot be corrupted per-call without a
        #: recompile, so the harness exercises quarantine via the mirror)
        self.fault_plan = fault_plan

    def _price(self, cores, perm):
        pairs = Population(cores, perm).pairs()
        reports = simulate_population(self.net, self.xs, self.profile,
                                      pairs, cache=self.cache)
        t = np.asarray([r.time_per_step for r in reports])
        e = np.asarray([r.energy_per_step for r in reports])
        if self.fault_plan is not None:
            t, e = self.fault_plan.corrupt_arrays(t, e)
        stage = np.asarray([STAGE_ID[r.bottleneck_stage] for r in reports],
                           np.int32)
        hot_mem = np.empty(len(reports), np.int32)
        hot_act = np.empty(len(reports), np.int32)
        for k, r in enumerate(reports):
            lids = np.repeat(np.arange(self.n_layers), cores[k])
            hot_mem[k] = lids[int(np.argmax(r.per_core_synops))]
            hot_act[k] = lids[int(np.argmax(r.per_core_acts))]
        return dict(times=t, energies=e, stage=stage, hot_mem=hot_mem,
                    hot_act=hot_act)

    def init(self, cores, perm):
        out = self._price(cores, perm)
        state = _sorted_state(np, pareto_ranks, cores, perm, out,
                              cores.shape[0])
        return state, dict(times=out["times"], energies=out["energies"])

    def step(self, state, key, n_off: int):
        with enable_x64():
            draws = jax.device_get(generation_draws(
                key, n_off=n_off, n_pop=state["cores"].shape[0],
                n_layers=self.n_layers, n_slots=self.n_slots,
                tournament_k=self.tournament_k))
        return _generation_step(np, self._price, pareto_ranks,
                                self.feasible, self.n_phys,
                                self.explore_prob, state, draws)


class _ShardedHostMirror:
    """Host NumPy replay of the island engine — migration's semantic spec.

    Wraps one :class:`_NumpyMirror` for pricing and runs each island's
    generation sequentially over its block of the (island-block-ordered)
    global host state, consuming row ``i`` of the same :func:`island_keys`
    stack the sharded step shards.  Migration is the same elite-block
    rotation in list form: island ``i`` receives island ``i-1``'s elites
    (``ppermute`` ring direction), then re-sorts locally.  Doubles as the
    demotion target of the sharded :class:`_ResilientEngine` — a mid-run
    demotion continues the same trajectory to float64 roundoff.
    """

    host_state = True

    def __init__(self, net, xs, profile, cache, tables, *, n_islands,
                 local_pop, n_migrants, explore_prob, tournament_k,
                 fault_plan: FaultPlan | None = None):
        self.base = _NumpyMirror(net, xs, profile, cache, tables,
                                 explore_prob=explore_prob,
                                 tournament_k=tournament_k,
                                 fault_plan=fault_plan)
        self.n_islands = int(n_islands)
        self.local_pop = int(local_pop)
        self.n_migrants = int(n_migrants)

    def _blocks(self, state):
        L = self.local_pop
        return [{k: np.asarray(state[k])[i * L:(i + 1) * L] for k in state}
                for i in range(self.n_islands)]

    def _stats(self, blocks, n_quar):
        ts = np.asarray([b["times"][0] for b in blocks])
        es = np.asarray([b["energies"][0] for b in blocks])
        tmin = ts.min()
        emin = np.where(ts == tmin, es, np.inf).min()
        ok = [np.isfinite(b["times"]) for b in blocks]
        n_ok = np.sum([m.sum() for m in ok])
        total = np.sum([np.where(m, b["times"], 0.0).sum()
                        for b, m in zip(blocks, ok)])
        mean = total / max(n_ok, 1) if n_ok > 0 else np.inf
        n = self.n_islands
        return dict(best_time=np.full(n, tmin),
                    best_energy=np.full(n, emin),
                    mean_time=np.full(n, mean, np.float64),
                    n_quarantined=np.full(n, n_quar, np.int64))

    def _cat(self, blocks):
        return {k: np.concatenate([b[k] for b in blocks])
                for k in blocks[0]}

    def init(self, cores, perm):
        outs = []
        for blk in self._blocks(dict(cores=np.asarray(cores),
                                     perm=np.asarray(perm))):
            out = self.base._price(blk["cores"], blk["perm"])
            outs.append((blk, out))
        states = [_sorted_state(np, pareto_ranks, b["cores"], b["perm"],
                                o, self.local_pop) for b, o in outs]
        init_out = dict(
            times=np.concatenate([o["times"] for _, o in outs]),
            energies=np.concatenate([o["energies"] for _, o in outs]))
        return self._cat(states), init_out

    def migrate(self, state):
        blocks = self._migrate(self._blocks(state))
        return self._cat(blocks)

    def _migrate(self, blocks):
        m = self.n_migrants
        elites = [{k: b[k][:m] for k in b} for b in blocks]
        incoming = elites[-1:] + elites[:-1]
        out = []
        for b, e in zip(blocks, incoming):
            merged = {k: np.concatenate([e[k], b[k][m:]]) for k in b}
            out.append(_sorted_state(np, pareto_ranks, merged["cores"],
                                     merged["perm"], merged,
                                     self.local_pop))
        return out

    def step(self, state, keys, n_off: int, migrate: bool = False):
        keys = np.asarray(jax.device_get(keys))
        new_blocks, offs = [], []
        n_quar = 0
        for i, blk in enumerate(self._blocks(state)):
            with enable_x64():
                draws = jax.device_get(generation_draws(
                    jnp.asarray(keys[i]), n_off=n_off,
                    n_pop=self.local_pop, n_layers=self.base.n_layers,
                    n_slots=self.base.n_slots,
                    tournament_k=self.base.tournament_k))
            nb, off, st = _generation_step(
                np, self.base._price, pareto_ranks, self.base.feasible,
                self.base.n_phys, self.base.explore_prob, blk, draws)
            new_blocks.append(nb)
            offs.append(off)
            n_quar += int(st["n_quarantined"])
        if migrate:
            new_blocks = self._migrate(new_blocks)
        return (self._cat(new_blocks), self._cat(offs),
                self._stats(new_blocks, n_quar))


# ------------------------------------------------------ degradation shell

class _ResilientEngine:
    """Graceful-degradation shell around a jitted generation engine.

    A failed ``init``/``step`` (compile error, device OOM, runtime fault —
    or an injected one at the engine's :class:`FaultPlan` site,
    ``"device"`` or ``"sharded"``) is retried per the
    :class:`RetryPolicy`; when the retries are exhausted the engine
    demotes **permanently** to its host NumPy mirror (a failed compile
    fails again — flapping back is pointless).  The mirror consumes the
    identical :func:`generation_draws` under the same key contract
    (``fold_in(key, gen)``, or the :func:`island_keys` stack for the
    sharded engine), so a mid-run demotion continues the same trajectory
    to float64 roundoff; a mirror failure propagates."""

    def __init__(self, primary, mirror_factory, *,
                 retry: RetryPolicy | None = None,
                 fault_plan: FaultPlan | None = None,
                 backend: str = "device"):
        self.engine = primary
        self._mirror_factory = mirror_factory
        self.retry = retry or RetryPolicy()
        self.fault_plan = fault_plan
        self._primary = str(backend)
        self.backend = self._primary
        self.demotions: list[Demotion] = []

    def _run(self, call, site: str):
        while True:
            delay = self.retry.backoff_s
            last = None
            for a in range(self.retry.max_retries + 1):
                if a and delay > 0:
                    time.sleep(delay)
                    delay *= self.retry.multiplier
                try:
                    if self.fault_plan is not None:
                        self.fault_plan.check(self.backend)
                    return call(self.engine)
                except Exception as e:          # SimulatedCrash passes:
                    last = e                    # it is a BaseException
            if self.backend != self._primary:
                raise last                      # mirror failed: no net left
            d = Demotion(site=site, frm=self._primary, to="numpy-mirror",
                         error=repr(last), retries=self.retry.max_retries)
            self.demotions.append(d)
            log.warning("%s search engine failed %s after %d retries "
                        "(%s); demoting to the host numpy mirror",
                        self._primary, site, d.retries, d.error)
            self.engine = self._mirror_factory()
            self.backend = "numpy-mirror"

    def init(self, cores, perm):
        return self._run(lambda e: e.init(cores, perm), "init")

    def step(self, state, key, *args, **kw):
        def call(e):
            st = jax.device_get(state) if getattr(e, "host_state", False) \
                else state
            return e.step(st, key, *args, **kw)
        return self._run(call, "step")


# ----------------------------------------------------------------- driver

#: the engine's device-resident state dict, in checkpoint order
_STATE_KEYS = ("cores", "perm", "times", "energies", "stage", "hot_mem",
               "hot_act")


def evolutionary_search_device(
    net,
    profile,
    evaluator,
    *,
    population_size: int = 24,
    generations: int = 16,
    tournament_k: int = 3,
    explore_prob: float = 0.25,
    seed: int = 0,
    max_evaluations: int | None = None,
    seed_candidates=None,
    greedy=None,
    pareto_eps: float = 0.01,
    reference: bool = False,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 3,
    resume: bool = False,
    fault_plan: FaultPlan | None = None,
    retry: RetryPolicy | None = None,
) -> SearchResult:
    """Run the device-resident (mu + lambda) search (the ``engine="device"``
    path of :func:`repro.core.search.evolutionary_search`).

    ``evaluator`` must be :class:`~repro.core.partitioner.SimEvaluator`-like
    (expose ``net`` / ``xs`` / ``profile`` and ideally a ``cache``): the
    device engine prices inside its own jitted step, so the evaluator is
    the source of the pricing cache and the evaluation-count ledger
    (``n_evals`` is charged per generation to keep iso-budget comparisons
    with the other engines honest).  The final best-candidate
    ``SearchResult.report`` and the archive's ``front_reports`` are
    re-priced once at the end through the bit-exact numpy backend — a
    stats-only materialization that is *not* charged as search
    evaluations.  ``reference=True`` swaps the jitted step for the host
    NumPy mirror (the parity harness; same PRNG-key contract, same
    trajectory to float64 roundoff).

    Fault tolerance (``docs/robustness.md``): ``checkpoint_dir`` /
    ``checkpoint_every`` / ``checkpoint_keep`` / ``resume`` snapshot and
    restore the engine's device state dict — resume is bit-identical
    because each generation is a pure function of ``(key, gen,
    survivors)`` under the PRNG-key contract.  A failed jitted
    ``init``/``step`` is retried per ``retry`` and then demoted
    permanently to the host mirror (logged; recorded in
    ``SearchResult.demotions``).  ``fault_plan`` scripts deterministic
    faults: ``fail={"device": n}`` makes the next ``n`` jitted calls
    raise, ``nan_rows`` corrupts mirror pricing rows, ``kill_after_gen``
    simulates a crash after that generation's checkpoint.
    """
    for attr in ("net", "xs", "profile"):
        if not hasattr(evaluator, attr):
            raise TypeError(
                "engine='device' needs a SimEvaluator-like evaluator "
                f"(missing .{attr}); plain callables can only drive the "
                "numpy engine")
    _validate_search_args(net, profile, population_size=population_size,
                          generations=generations,
                          seed_candidates=seed_candidates)
    xs = evaluator.xs
    cache = getattr(evaluator, "cache", None) \
        or precompute_pricing(net, xs, profile)

    ckpt = (SearchCheckpointer(checkpoint_dir, every=checkpoint_every,
                               keep=checkpoint_keep)
            if checkpoint_dir else None)
    restored = ckpt.restore() if (ckpt is not None and resume) else None

    tables = move_tables(net, profile)
    n_layers = len(cache.layers)
    n_slots = int(profile.n_cores)

    def _mirror():
        return _NumpyMirror(net, xs, profile, cache, tables,
                            explore_prob=explore_prob,
                            tournament_k=tournament_k,
                            fault_plan=fault_plan)

    if reference:
        engine = _mirror()
    else:
        engine = _ResilientEngine(
            _engine_for(net, profile, cache, tables,
                        explore_prob=explore_prob,
                        tournament_k=tournament_k),
            _mirror, retry=retry, fault_plan=fault_plan)
    base_key = jax.random.PRNGKey(seed)
    archive = EpsParetoArchive(pareto_eps)

    if restored is not None:
        arrays, gen0, meta = restored
        validate_resume_meta(meta, engine="device",
                             checkpoint_dir=checkpoint_dir)
        state = {k: np.asarray(arrays[k]) for k in _STATE_KEYS}
        archive.load_state(arrays)
        history = [GenStats(**h) for h in meta["history"]]
        evals_used = int(meta["evals_used"])
        seed_best_time = float(meta["seed_best_time"])
        n_pop = int(state["cores"].shape[0])
        start_gen = gen0 + 1
    else:
        rng = np.random.default_rng(seed)
        cands = list(seed_candidates if seed_candidates is not None else
                     seeded_population(net, profile, size=population_size,
                                       rng=rng, greedy=greedy))
        if not cands:
            raise ValueError("empty initial population")
        if max_evaluations is not None:
            cands = cands[:max(1, max_evaluations)]
        pop = Population.from_candidates(cands)

        state, init_out = engine.init(pop.cores, pop.perm)
        evals_used = len(pop)
        _charge(evaluator, len(pop))
        init_host = jax.device_get(init_out)
        # screen the raw seed objectives before they reach host stats or
        # the archive (the archive rejects non-finite points itself; the
        # sentinel keeps the min() below NaN-safe)
        it, ie, _ = quarantine_rows(
            np, np.asarray(init_host["times"], np.float64),
            np.asarray(init_host["energies"], np.float64))
        seed_best_time = float(np.min(it))
        archive.update_batch(it, ie, pop.cores, pop.perm)

        first = jax.device_get({k: state[k] for k in ("times", "energies")})
        history = [GenStats(generation=0,
                            best_time=float(first["times"][0]),
                            best_energy=float(first["energies"][0]),
                            mean_time=float(finite_mean(np, first["times"])),
                            n_evals=evals_used,
                            front_size=len(archive))]
        n_pop = len(pop)
        start_gen = 1

    def _snapshot(gen: int) -> None:
        host_state = jax.device_get(state)
        arrays = {k: np.asarray(host_state[k]) for k in _STATE_KEYS}
        arrays.update(archive.state_arrays(n_layers, n_slots))
        meta = dict(engine="device", evals_used=int(evals_used),
                    seed_best_time=float(seed_best_time),
                    history=[dataclasses.asdict(g) for g in history])
        ckpt.save(gen, arrays, meta)

    if restored is None:
        if ckpt is not None:
            _snapshot(0)
        if fault_plan is not None:
            fault_plan.after_generation(0)

    for gen in range(start_gen, generations + 1):
        n_off = n_pop
        if max_evaluations is not None:
            n_off = min(n_off, max_evaluations - evals_used)
        if n_off <= 0:
            break
        key = jax.random.fold_in(base_key, gen)
        state, off, stats = engine.step(state, key, n_off)
        evals_used += n_off
        _charge(evaluator, n_off)
        # the only per-generation host sync: tiny stats + the offspring
        # batch, absorbed by the epsilon-Pareto archive in ONE vectorized
        # update (no per-offspring host Python anywhere in this loop)
        host = jax.device_get(dict(off=off, stats=stats))
        off_h, stats_h = host["off"], host["stats"]
        archive.update_batch(off_h["times"], off_h["energies"],
                             off_h["cores"], off_h["perm"])
        history.append(GenStats(
            generation=gen,
            best_time=float(stats_h["best_time"]),
            best_energy=float(stats_h["best_energy"]),
            mean_time=float(stats_h["mean_time"]),
            n_evals=evals_used,
            front_size=len(archive),
            n_quarantined=int(stats_h.get("n_quarantined", 0))))
        if ckpt is not None and ckpt.due(gen, generations):
            _snapshot(gen)
        if fault_plan is not None:
            fault_plan.after_generation(gen)

    final = jax.device_get({k: state[k] for k in ("cores", "perm")})
    best = Candidate(tuple(int(x) for x in final["cores"][0]),
                     tuple(int(x) for x in final["perm"][0]))
    part, mapping = decode(best)
    # stats-only materialization through the bit-exact path (uncharged)
    best_report = price_candidate(net, profile, cache, part, mapping)
    front, _ = archive.front()
    front_reports = simulate_population(net, xs, profile,
                                        [decode(c) for c in front],
                                        cache=cache) if front else []
    return SearchResult(candidate=best, partition=part, mapping=mapping,
                        report=best_report, history=history,
                        n_evals=evals_used, seed_best_time=seed_best_time,
                        front=front, front_reports=front_reports,
                        demotions=list(getattr(engine, "demotions", ())))


def _charge(evaluator, n: int) -> None:
    """Record ``n`` candidate pricings on the evaluator's ledger (the
    iso-budget currency shared with the greedy walk and the numpy engine);
    evaluators without a counter are left alone."""
    if hasattr(evaluator, "n_evals"):
        evaluator.n_evals += int(n)


def evolutionary_search_sharded(
    net,
    profile,
    evaluator,
    *,
    population_size: int = 24,
    generations: int = 16,
    tournament_k: int = 3,
    explore_prob: float = 0.25,
    seed: int = 0,
    max_evaluations: int | None = None,
    seed_candidates=None,
    greedy=None,
    pareto_eps: float = 0.01,
    n_islands: int | None = None,
    migrate_every: int = 5,
    n_migrants: int | None = None,
    mesh=None,
    reference: bool = False,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 3,
    resume: bool = False,
    fault_plan: FaultPlan | None = None,
    retry: RetryPolicy | None = None,
) -> SearchResult:
    """Run the island-model sharded search (the ``engine="sharded"`` path
    of :func:`repro.core.search.evolutionary_search`).

    The population is split into ``n_islands`` equal islands (default: one
    per visible device; ``population_size`` must divide evenly and leave
    at least 2 rows per island), the K axis is sharded over the 1-D
    ``("island",)`` mesh, and every device runs the jitted device-engine
    generation on its own island.  Every ``migrate_every`` generations
    (0 disables) each island's top ``n_migrants`` rows (default
    ``local_pop // 8``, at least 1) rotate one island around the ring.
    Randomness follows :func:`island_keys`; with ``n_islands=1`` the run
    is bit-identical to :func:`evolutionary_search_device`.

    Checkpointing reuses the device engine's self-contained ``.npz``
    layout — island state is gathered to host in island-block order, and
    resume validates the island geometry via
    :func:`~repro.core.resilience.validate_resume_meta` (a checkpoint is
    only bit-identical under the configuration that wrote it).
    ``reference=True`` swaps the jitted program for
    :class:`_ShardedHostMirror`; a failed jitted call demotes to the same
    mirror through :class:`_ResilientEngine` (``fail={"sharded": n}`` of a
    :class:`FaultPlan` injects such failures).  See
    ``docs/distributed.md``.
    """
    for attr in ("net", "xs", "profile"):
        if not hasattr(evaluator, attr):
            raise TypeError(
                "engine='sharded' needs a SimEvaluator-like evaluator "
                f"(missing .{attr}); plain callables can only drive the "
                "numpy engine")
    _validate_search_args(net, profile, population_size=population_size,
                          generations=generations,
                          seed_candidates=seed_candidates)
    if mesh is None:
        from repro.distributed.sharding import island_mesh
        mesh = island_mesh(n_islands)
    if "island" not in mesh.axis_names:
        raise ValueError(f"engine='sharded' needs a 1-D ('island',) mesh, "
                         f"got axes {mesh.axis_names}")
    n_islands = int(mesh.shape["island"])
    if population_size % n_islands:
        raise ValueError(
            f"population_size={population_size} does not divide evenly "
            f"over {n_islands} islands — pick a multiple of {n_islands} "
            "or pass n_islands explicitly")
    local_pop = population_size // n_islands
    if local_pop < 2:
        raise ValueError(
            f"population_size={population_size} over {n_islands} islands "
            f"leaves {local_pop} row(s) per island; tournament selection "
            "needs at least 2 — lower n_islands or grow the population")
    migrate_every = int(migrate_every)
    if n_migrants is None:
        n_migrants = max(1, local_pop // 8)
    n_migrants = int(n_migrants)
    if not 1 <= n_migrants <= local_pop:
        raise ValueError(f"n_migrants={n_migrants} must be in "
                         f"[1, {local_pop}] (the island size)")

    xs = evaluator.xs
    cache = getattr(evaluator, "cache", None) \
        or precompute_pricing(net, xs, profile)

    ckpt = (SearchCheckpointer(checkpoint_dir, every=checkpoint_every,
                               keep=checkpoint_keep)
            if checkpoint_dir else None)
    restored = ckpt.restore() if (ckpt is not None and resume) else None

    tables = move_tables(net, profile)
    n_layers = len(cache.layers)
    n_slots = int(profile.n_cores)

    def _mirror():
        return _ShardedHostMirror(net, xs, profile, cache, tables,
                                  n_islands=n_islands, local_pop=local_pop,
                                  n_migrants=n_migrants,
                                  explore_prob=explore_prob,
                                  tournament_k=tournament_k,
                                  fault_plan=fault_plan)

    if reference:
        engine = _mirror()
    else:
        engine = _ResilientEngine(
            _sharded_engine_for(net, profile, cache, tables, mesh=mesh,
                                local_pop=local_pop, n_migrants=n_migrants,
                                explore_prob=explore_prob,
                                tournament_k=tournament_k),
            _mirror, retry=retry, fault_plan=fault_plan, backend="sharded")
    base_key = jax.random.PRNGKey(seed)
    archive = EpsParetoArchive(pareto_eps)

    if restored is not None:
        arrays, gen0, meta = restored
        validate_resume_meta(meta, engine="sharded",
                             checkpoint_dir=checkpoint_dir,
                             expect=dict(population_size=population_size,
                                         n_islands=n_islands,
                                         migrate_every=migrate_every,
                                         n_migrants=n_migrants))
        state = {k: np.asarray(arrays[k]) for k in _STATE_KEYS}
        archive.load_state(arrays)
        history = [GenStats(**h) for h in meta["history"]]
        evals_used = int(meta["evals_used"])
        seed_best_time = float(meta["seed_best_time"])
        start_gen = gen0 + 1
    else:
        rng = np.random.default_rng(seed)
        cands = list(seed_candidates if seed_candidates is not None else
                     seeded_population(net, profile, size=population_size,
                                       rng=rng, greedy=greedy))
        if not cands:
            raise ValueError("empty initial population")
        if len(cands) != population_size:
            raise ValueError(
                f"{len(cands)} seed candidates do not fill "
                f"population_size={population_size} (the sharded engine "
                "needs full equal islands)")
        pop = Population.from_candidates(cands)

        state, init_out = engine.init(pop.cores, pop.perm)
        evals_used = len(pop)
        _charge(evaluator, len(pop))
        init_host = jax.device_get(init_out)
        it, ie, _ = quarantine_rows(
            np, np.asarray(init_host["times"], np.float64),
            np.asarray(init_host["energies"], np.float64))
        seed_best_time = float(np.min(it))
        archive.update_batch(it, ie, pop.cores, pop.perm)

        # gen-0 stats on host, with the same ops as the device driver at
        # one island (bit parity); islands contribute their sorted leaders
        first = jax.device_get({k: state[k] for k in ("times", "energies")})
        ft = np.asarray(first["times"]).reshape(n_islands, local_pop)
        fe = np.asarray(first["energies"]).reshape(n_islands, local_pop)
        tmin = float(np.min(ft[:, 0]))
        emin = float(np.min(np.where(ft[:, 0] == tmin, fe[:, 0], np.inf)))
        history = [GenStats(generation=0,
                            best_time=tmin,
                            best_energy=emin,
                            mean_time=float(finite_mean(
                                np, np.asarray(first["times"]))),
                            n_evals=evals_used,
                            front_size=len(archive))]
        start_gen = 1

    def _snapshot(gen: int) -> None:
        host_state = jax.device_get(state)
        arrays = {k: np.asarray(host_state[k]) for k in _STATE_KEYS}
        arrays.update(archive.state_arrays(n_layers, n_slots))
        meta = dict(engine="sharded", population_size=int(population_size),
                    n_islands=int(n_islands),
                    migrate_every=int(migrate_every),
                    n_migrants=int(n_migrants),
                    evals_used=int(evals_used),
                    seed_best_time=float(seed_best_time),
                    history=[dataclasses.asdict(g) for g in history])
        ckpt.save(gen, arrays, meta)

    if restored is None:
        if ckpt is not None:
            _snapshot(0)
        if fault_plan is not None:
            fault_plan.after_generation(0)

    for gen in range(start_gen, generations + 1):
        n_off_total = population_size
        if max_evaluations is not None:
            n_off_total = min(n_off_total, max_evaluations - evals_used)
        local_off = n_off_total // n_islands
        if local_off <= 0:
            break
        migrate = (n_islands > 1 and migrate_every > 0
                   and gen % migrate_every == 0)
        keys = island_keys(base_key, gen, n_islands)
        state, off, stats = engine.step(state, keys, n_off=local_off,
                                        migrate=migrate)
        evals_used += local_off * n_islands
        _charge(evaluator, local_off * n_islands)
        host = jax.device_get(dict(off=off, stats=stats))
        off_h, stats_h = host["off"], host["stats"]
        archive.update_batch(off_h["times"], off_h["energies"],
                             off_h["cores"], off_h["perm"])
        history.append(GenStats(
            generation=gen,
            best_time=float(np.asarray(stats_h["best_time"])[0]),
            best_energy=float(np.asarray(stats_h["best_energy"])[0]),
            mean_time=float(np.asarray(stats_h["mean_time"])[0]),
            n_evals=evals_used,
            front_size=len(archive),
            n_quarantined=int(np.asarray(stats_h["n_quarantined"])[0])))
        if ckpt is not None and ckpt.due(gen, generations):
            _snapshot(gen)
        if fault_plan is not None:
            fault_plan.after_generation(gen)

    final = jax.device_get({k: state[k] for k in
                            ("cores", "perm", "times", "energies")})
    ft = np.asarray(final["times"]).reshape(n_islands, local_pop)
    fe = np.asarray(final["energies"]).reshape(n_islands, local_pop)
    t0 = ft[:, 0]
    best_i = int(np.argmin(np.where(t0 == t0.min(), fe[:, 0], np.inf)))
    row = best_i * local_pop
    best = Candidate(tuple(int(x) for x in np.asarray(final["cores"])[row]),
                     tuple(int(x) for x in np.asarray(final["perm"])[row]))
    part, mapping = decode(best)
    best_report = price_candidate(net, profile, cache, part, mapping)
    front, _ = archive.front()
    front_reports = simulate_population(net, xs, profile,
                                        [decode(c) for c in front],
                                        cache=cache) if front else []
    return SearchResult(candidate=best, partition=part, mapping=mapping,
                        report=best_report, history=history,
                        n_evals=evals_used, seed_best_time=seed_best_time,
                        front=front, front_reports=front_reports,
                        demotions=list(getattr(engine, "demotions", ())))
