"""Floorline-informed partitioning & mapping optimization (paper §VI-B).

The paper's stage-2 procedure, verbatim in structure:

1. Initialize at the minimum neurocore utilization with a good heuristic
   (strided) mapping — likely memory-bound.
2. **Memory assumption**: find the core with the most synops, partition its
   layer further.  If the step helps, keep tracing down the memory slope;
   if not, *backtrack* (greater utilization without synop improvement costs
   power).
3. **Compute assumption**: same loop keyed on max activation computes.
4. **Traffic assumption**: improve the mapping (move the highest-output
   cores onto separate router paths — here: re-stride / traffic-greedy map).
5. Cycle through the assumptions; stop when out of cores, when energy
   worsens without timing benefit, or when no assumption yields improvement
   (the workload hit its true boundary for its sparsity dynamics).

The evaluator is any callable (partition, mapping) -> SimReport, so the same
optimizer drives the neuromorphic simulator and, through an adapter, the TPU
sharding hillclimb in :mod:`repro.distributed.autoshard`.  The canonical
implementation is :class:`SimEvaluator`: it builds the batched engine's
pricing cache once, prices every candidate from it (single candidates and
whole populations), and counts evaluations — the shared currency that makes
the greedy walk here and the evolutionary search in
:mod:`repro.core.search` comparable at iso-evaluations.  The move vocabulary
(:meth:`Partition.split` / :meth:`Partition.merge` plus a re-mapping of the
logical->physical placement, gated by :func:`can_split` /
``validate_partition``) is likewise shared by both optimizers.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.analytical import Bottleneck
from repro.neuromorphic.network import SimNetwork
from repro.neuromorphic.noc import Mapping, strided_mapping
from repro.neuromorphic.partition import (Partition, max_cores_for_layer,
                                          minimal_partition, validate_partition)
from repro.neuromorphic.platform import ChipProfile
from repro.neuromorphic.timestep import (SimReport, precompute_pricing,
                                         price_candidate, simulate,
                                         simulate_population)

#: Anything that prices a (partition, mapping) candidate.  Both optimizers
#: (greedy §VI-B and the evolutionary search) accept any such callable;
#: :class:`SimEvaluator` is the standard one.
Evaluator = Callable[[Partition, Mapping], SimReport]


class SimEvaluator:
    """Evaluation-counting pricing gateway shared by both optimizers.

    Wraps one (net, xs, profile) workload: the functional run and per-layer
    counter cumsums are computed once (``engine="batched"``), after which
    every candidate — single or population — is priced counter-free from the
    cache.  ``n_evals`` counts priced candidates, the budget unit for
    greedy-vs-evolutionary comparisons (``benchmarks/search_mapping.py``).

    With ``engine="reference"`` candidates are priced by the step-major
    engine (no cache); results are identical, just slower — useful for
    auditing the cache path at small scale.

    ``population_backend`` selects how :meth:`evaluate_population` prices a
    generation — one of the three population backends of
    :func:`~repro.neuromorphic.timestep.simulate_population`: ``"numpy"``
    (stacked gathers + per-candidate NumPy math, bit-identical to
    ``simulate`` — the reference), ``"vmap"`` (one jitted ``jax.vmap`` over
    the padded population axis, host-built batch structures,
    float64-roundoff-identical, several times the pricing throughput at
    population >= 64), or ``"device"`` (the genome rows are the program
    input and structure construction runs on device too — same parity as
    vmap; see ``BENCH_search.json`` and ``docs/simulator.md``).

    The evaluator is also the pricing-cache and evaluation-ledger host for
    the device-resident search (``evolutionary_search(...,
    engine="device")``), which prices inside its own jitted generation
    step and charges ``n_evals`` here per generation.

    Population pricing degrades gracefully (``docs/robustness.md``): a
    backend failure — compile error, device OOM, runtime fault, or an
    injected one — is retried per ``retry`` and then demoted down the
    ``device -> vmap -> numpy`` chain (sticky; logged; recorded in
    :attr:`demotions`).  The backends agree at float64 roundoff, so a
    mid-run demotion perturbs a search trajectory by at most rtol=1e-9
    against a numpy-only run.  ``fallback=False`` restores fail-fast
    behavior.  ``fault_plan`` is the deterministic fault-injection hook
    (:class:`repro.core.resilience.FaultPlan`): scripted backend failures
    and NaN pricing rows for the robustness suite.
    """

    def __init__(self, net: SimNetwork, xs: np.ndarray, profile: ChipProfile,
                 *, engine: str | None = None, cache=None,
                 population_backend: str = "numpy", compute=None,
                 fault_plan=None, fallback: bool = True, retry=None,
                 sparsity_profile=None):
        from repro.core.resilience import FallbackChain
        from repro.neuromorphic import timestep
        # A trained SparsityProfile is programmed onto the network ONCE,
        # here — every candidate, backend, and search engine (the device/
        # sharded engines build their pricers from this evaluator's cache)
        # then prices the profiled workload with unchanged parity.
        if sparsity_profile is not None:
            if cache is not None:
                raise ValueError("sparsity_profile cannot be combined with "
                                 "a shared cache: the cache is bound to the "
                                 "un-profiled network")
            net = sparsity_profile.apply(net)
        self.sparsity_profile = sparsity_profile
        self.net, self.xs, self.profile = net, xs, profile
        self.engine = engine or timestep.DEFAULT_ENGINE
        self.population_backend = population_backend
        #: per-layer synaptic compute backend of the functional run
        #: ("dense" / "event" / a LayerCompute instance; None -> the
        #: process default) — counters are exact across backends, so the
        #: cache and every report it prices are backend-agnostic
        self.compute = compute
        # ``cache=`` shares one PricingCache between evaluators that only
        # differ in their evaluation counters (e.g. benchmark arms)
        self.cache = (cache or precompute_pricing(net, xs, profile,
                                                  compute=compute)
                      if self.engine == "batched" else None)
        self.n_evals = 0
        self.fault_plan = fault_plan
        self._chain = (FallbackChain(population_backend, retry=retry)
                       if fallback else None)

    @property
    def demotions(self) -> list:
        """Fallback-chain demotion records, oldest first (empty when the
        chain is disabled or never fired)."""
        return self._chain.demotions if self._chain is not None else []

    @property
    def active_backend(self) -> str:
        """The population backend currently in use (differs from
        ``population_backend`` after a demotion)."""
        return (self._chain.backend if self._chain is not None
                else self.population_backend)

    def __call__(self, part: Partition, mapping: Mapping) -> SimReport:
        self.n_evals += 1
        if self.cache is not None:
            return price_candidate(self.net, self.profile, self.cache,
                                   part, mapping)
        return simulate(self.net, self.xs, self.profile, part, mapping,
                        engine=self.engine, compute=self.compute)

    def evaluate_population(self, candidates) -> list[SimReport]:
        """Price a list of (partition, mapping) pairs; one stacked gather
        per layer (or one jitted program — ``population_backend="vmap"`` /
        ``"device"``) when the pricing cache is live.  Backend failures
        retry, then demote down the fallback chain (see the class
        docstring); scripted :class:`FaultPlan` faults inject here."""
        cands = list(candidates)
        self.n_evals += len(cands)
        if self.cache is not None:
            def attempt(backend):
                if self.fault_plan is not None:
                    self.fault_plan.check(backend)
                return simulate_population(self.net, self.xs, self.profile,
                                           cands, cache=self.cache,
                                           backend=backend)
            if self._chain is not None:
                reports = self._chain.run(attempt)
            else:
                reports = attempt(self.population_backend)
        else:
            reports = [simulate(self.net, self.xs, self.profile, p, m,
                                engine=self.engine, compute=self.compute)
                       for p, m in cands]
        if self.fault_plan is not None:
            reports = self.fault_plan.corrupt(reports)
        return reports


@dataclasses.dataclass
class OptStep:
    """One accepted/rejected move in the iteration log (EXPERIMENTS §Perf
    mirrors this structure for the TPU hillclimb)."""

    iteration: int
    assumption: Bottleneck
    move: str
    partition: Partition
    time: float
    energy: float
    max_synops: float
    accepted: bool
    note: str = ""


@dataclasses.dataclass
class OptimizationResult:
    partition: Partition
    mapping: Mapping
    report: SimReport
    history: list[OptStep]

    @property
    def trace(self) -> list[tuple[float, float]]:
        """(max_synops, time) path of accepted steps — the floorline trace."""
        pts = [(s.max_synops, s.time) for s in self.history if s.accepted]
        return pts


def _argmax_layer(per_core: np.ndarray, part: Partition) -> int:
    """Layer owning the max-loaded core (the M0 bottleneck unit)."""
    core_layers = part.core_layer_ids()
    return int(core_layers[int(np.argmax(per_core))])


def _bottleneck_layers(per_core: np.ndarray, part: Partition,
                       tie_tol: float = 0.05) -> list[int]:
    """All layers owning a core within ``tie_tol`` of the max load.  The
    paper splits the single argmax layer; when several layers tie (uniform
    workloads) a single split cannot move the global max, so we split the
    tied set together — a strict generalization that reduces to the paper's
    move when the max is unique."""
    core_layers = part.core_layer_ids()
    mx = float(np.max(per_core))
    hot = np.asarray(per_core) >= (1.0 - tie_tol) * mx
    return sorted({int(l) for l in core_layers[hot]})


def can_split(net: SimNetwork, part: Partition, layer: int,
              profile: ChipProfile) -> bool:
    """True iff the split move is legal for ``layer``: granularity, chip
    core budget, and per-core capacities all hold after the split.  Shared
    gate for the greedy optimizer's and the evolutionary search's split
    moves."""
    if part.cores[layer] >= max_cores_for_layer(net, layer):
        return False
    if part.total_cores + 1 > profile.n_cores:
        return False
    return validate_partition(net, part.split(layer), profile)


def optimize_partitioning(
    net: SimNetwork,
    profile: ChipProfile,
    evaluate: Evaluator,
    *,
    max_iters: int = 64,
    time_improvement_tol: float = 0.01,
    energy_guard: bool = True,
    make_mapping: Callable[[Partition, ChipProfile], Mapping] = strided_mapping,
) -> OptimizationResult:
    """Run the §VI-B iterative backtracking procedure.

    ``evaluate`` is any :data:`Evaluator` — a callable
    ``(Partition, Mapping) -> SimReport`` — typically a
    :class:`SimEvaluator` so evaluations are counted and priced from one
    shared functional run.  Moves are accepted only when time improves by
    more than ``time_improvement_tol`` (relative) and, under
    ``energy_guard``, energy does not regress without a timing benefit.
    Returns the best (partition, mapping, report) plus the full accept /
    backtrack history, whose accepted prefix traces the floorline.
    """
    part = minimal_partition(net, profile)
    mapping = make_mapping(part, profile)
    best = evaluate(part, mapping)
    history: list[OptStep] = [OptStep(
        iteration=0, assumption=Bottleneck.MEMORY, move="init:minimal+strided",
        partition=part, time=best.time_per_step, energy=best.energy_per_step,
        max_synops=best.max_synops, accepted=True, note="baseline")]

    assumptions = [Bottleneck.MEMORY, Bottleneck.COMPUTE, Bottleneck.TRAFFIC]
    a_idx = 0
    stale = 0          # consecutive assumptions with no accepted move
    it = 0
    while it < max_iters and stale < len(assumptions):
        it += 1
        assumption = assumptions[a_idx]
        accepted = False
        if assumption in (Bottleneck.MEMORY, Bottleneck.COMPUTE):
            per_core = (best.per_core_synops if assumption is Bottleneck.MEMORY
                        else best.per_core_acts)
            layers = [l for l in _bottleneck_layers(per_core, part)
                      if can_split(net, part, l, profile)]
            cand_part = part
            for l in layers:
                if validate_partition(net, cand_part.split(l), profile):
                    cand_part = cand_part.split(l)
            if cand_part.cores != part.cores:
                cand_map = make_mapping(cand_part, profile)
                rep = evaluate(cand_part, cand_map)
                time_gain = (best.time_per_step - rep.time_per_step) \
                    / max(best.time_per_step, 1e-30)
                energy_ok = (not energy_guard
                             or rep.energy_per_step <= best.energy_per_step
                             or time_gain > time_improvement_tol)
                if time_gain > time_improvement_tol and energy_ok:
                    part, mapping, best = cand_part, cand_map, rep
                    accepted = True
                history.append(OptStep(
                    iteration=it, assumption=assumption,
                    move=(f"split layers {layers} -> "
                          f"{[cand_part.cores[l] for l in layers]} cores"),
                    partition=cand_part, time=rep.time_per_step,
                    energy=rep.energy_per_step, max_synops=rep.max_synops,
                    accepted=accepted,
                    note="" if accepted else "backtracked (no benefit)"))
            else:
                history.append(OptStep(
                    iteration=it, assumption=assumption, move="no split available",
                    partition=part, time=best.time_per_step,
                    energy=best.energy_per_step, max_synops=best.max_synops,
                    accepted=False, note="out of cores / granularity"))
        else:   # TRAFFIC: optimize the mapping only (synops intensity fixed)
            cand_map = _traffic_greedy_mapping(part, profile, best)
            if tuple(cand_map.phys) != tuple(mapping.phys):
                rep = evaluate(part, cand_map)
                gain = (best.time_per_step - rep.time_per_step) \
                    / max(best.time_per_step, 1e-30)
                if gain > time_improvement_tol:
                    mapping, best = cand_map, rep
                    accepted = True
                history.append(OptStep(
                    iteration=it, assumption=assumption,
                    move=f"remap ({cand_map.name})", partition=part,
                    time=rep.time_per_step, energy=rep.energy_per_step,
                    max_synops=rep.max_synops, accepted=accepted,
                    note="" if accepted else "backtracked"))
            else:
                history.append(OptStep(
                    iteration=it, assumption=assumption, move="mapping unchanged",
                    partition=part, time=best.time_per_step,
                    energy=best.energy_per_step, max_synops=best.max_synops,
                    accepted=False))
        if accepted:
            stale = 0            # keep working the same assumption
        else:
            stale += 1
            a_idx = (a_idx + 1) % len(assumptions)

    return OptimizationResult(partition=part, mapping=mapping, report=best,
                              history=history)


def _traffic_greedy_mapping(part: Partition, profile: ChipProfile,
                            report: SimReport) -> Mapping:
    """Traffic move (§VI-B): place the highest-output cores onto separate
    router paths — greedy round-robin over router tiles by descending
    message count, so hot cores never share a router's injection port."""
    from repro.neuromorphic.noc import cores_per_router, n_router_tiles

    n = part.total_cores
    cpr = cores_per_router(profile)
    n_routers = n_router_tiles(profile)
    order = np.argsort(-report.per_core_msgs_out)      # busiest first
    slots_by_router = [[r * cpr + s for s in range(cpr)]
                       for r in range(n_routers)]
    phys = [0] * n
    r = 0
    for logical in order:
        placed = False
        for _ in range(n_routers):
            if slots_by_router[r]:
                phys[int(logical)] = slots_by_router[r].pop(0)
                r = (r + 1) % n_routers
                placed = True
                break
            r = (r + 1) % n_routers
        if not placed:
            raise RuntimeError("ran out of physical slots")
    return Mapping(tuple(phys), name="traffic_greedy")
