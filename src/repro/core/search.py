"""Vectorized evolutionary search over (partition, mapping) candidates.

The paper's stage-2 optimizer (§VI-B, :mod:`repro.core.partitioner`) walks
one candidate at a time: split the bottleneck layer, re-price, backtrack.
That is cheap but easily trapped — a split that only pays off together with
a re-mapping is never found, and the walk prices exactly one candidate per
step.  Population-based search over accelerator mappings (cf. "Evolutionary
Mapping of Neural Networks to Spatial Accelerators") dominates greedy
hillclimbing on this problem precisely because it holds many (partition,
mapping) hypotheses at once; what made it affordable *here* is the batched
engine's pricing split: one functional run + per-layer counter cumsums
(:func:`repro.neuromorphic.timestep.precompute_pricing`) price an entire
generation with one stacked gather per layer
(:func:`repro.neuromorphic.timestep.simulate_population`) — or, with the
``vmap`` backend, as one jitted ``jax.vmap`` over the padded population axis
(:func:`repro.neuromorphic.timestep.price_population_vmap`).

The genome representation is **tensor-first**: a generation lives in a
:class:`Population` — a ``(K, n_layers)`` core-count matrix plus a
``(K, n_slots)`` permutation matrix — and mutation, tournament selection,
nondomination ranking, and elitist survival all operate on the stacked
arrays (feasibility checks are table lookups into a precomputed
:class:`MoveTables`, not per-candidate ``validate_partition`` walks).
:class:`Candidate` remains the per-individual view:

* ``cores`` — per-layer core counts, shape ``(n_layers,)``;
* ``perm``  — a permutation of ALL physical core slots, shape
  ``(profile.n_cores,)``.  The decoded mapping is ``perm[:total_cores]``:
  a split simply pulls the next gene into use, a merge releases one, and a
  gene swap is always a valid mapping move.  ``encode``/``decode`` round-trip
  the partition and physical placement exactly (``tests/test_search.py``).

The generation loop is (mu + lambda) elitist: tournament parent selection,
floorline-guided mutation (the parent's bottleneck stage picks the move —
memory/compute -> split the hot layer, traffic -> re-map or coagulate, with
an exploration probability of a uniformly random move), then survival of the
``population_size`` best unique candidates ordered by **(nondomination rank,
time, energy)**.  The rank ordering replaces the PR-2 lexicographic
tie-break: equal-time candidates trade off against energy on a (time,
energy) Pareto front, maintained across the whole run by an
epsilon-dominance archive (:class:`EpsParetoArchive`) and returned as
``SearchResult.front``; :func:`knee_point` names its best balanced point.
Because the lexicographic (time, energy) minimum is always nondominated, the
rank ordering preserves PR 2's guarantees: elitism plus floorline-informed
seeding (the greedy optimizer's accepted moves are injected into the initial
population) still guarantee the search never returns a candidate worse than
its best seed — and never worse than the greedy result when seeded from it.

Two generation engines drive the loop (``engine=`` on
:func:`evolutionary_search`): the host ``"numpy"`` engine below — the
reference semantics, mutating one offspring row at a time — and the
``"device"`` engine of :mod:`repro.core.device_search`, which compiles the
whole generation step (selection, the split/merge/swap chain, pricing,
ranking, survival) into one jitted program over the stacked
:class:`Population` arrays and keeps survivors accelerator-resident
between generations.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partitioner import (Evaluator, OptimizationResult,
                                    optimize_partitioning)
from repro.core.resilience import (FaultPlan, SearchCheckpointer,
                                   decode_bytes_set, encode_bytes_set,
                                   finite_mean, quarantine_rows,
                                   rng_from_state, rng_state,
                                   validate_resume_meta)
from repro.neuromorphic.network import SimNetwork
from repro.neuromorphic.noc import (Mapping, ordered_mapping, random_mapping,
                                    strided_mapping)
from repro.neuromorphic.partition import (Partition, layer_fits,
                                          max_cores_for_layer,
                                          minimal_partition)
from repro.neuromorphic.platform import ChipProfile
from repro.neuromorphic.timestep import SimReport

_STAGES = ("memory", "compute", "traffic")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """Fixed-shape genome: per-layer core counts + a permutation of every
    physical core slot (only the first ``total_cores`` genes are expressed
    as the mapping)."""

    cores: tuple[int, ...]
    perm: tuple[int, ...]

    @property
    def n_logical(self) -> int:
        return int(sum(self.cores))

    def partition(self) -> Partition:
        return Partition(self.cores)

    def mapping(self) -> Mapping:
        return Mapping(self.perm[:self.n_logical], name="evolved")


def encode(part: Partition, mapping: Mapping,
           n_cores_phys: int) -> Candidate:
    """(Partition, Mapping) -> fixed-shape genome.  The mapping's slots
    become the leading genes; unused physical slots follow in ascending
    order, so ``decode(encode(p, m))`` reproduces the partition and the
    ``phys`` placement exactly (the decoded mapping is named "evolved")."""
    used = tuple(int(p) for p in mapping.phys)
    taken = set(used)
    rest = tuple(s for s in range(n_cores_phys) if s not in taken)
    return Candidate(tuple(int(c) for c in part.cores), used + rest)


def decode(cand: Candidate) -> tuple[Partition, Mapping]:
    return cand.partition(), cand.mapping()


# ------------------------------------------------------------- population

@dataclasses.dataclass
class Population:
    """Tensor-first genome bank: row k of ``cores``/``perm`` IS candidate k.

    This is the representation the search loop mutates and selects on —
    and the interchange form for storage/transport.  :meth:`candidate` /
    :meth:`candidates` materialize per-individual :class:`Candidate` views
    on demand; :meth:`pairs` decodes the whole bank into the
    ``(Partition, Mapping)`` pairs the pricing backends consume.
    """

    cores: np.ndarray   # (K, n_layers) int32
    perm: np.ndarray    # (K, n_slots) int32

    def __post_init__(self):
        self.cores = np.asarray(self.cores, np.int32)
        self.perm = np.asarray(self.perm, np.int32)

    def __len__(self) -> int:
        return int(self.cores.shape[0])

    @property
    def n_logical(self) -> np.ndarray:
        """(K,) expressed-gene counts."""
        return self.cores.sum(axis=1)

    @staticmethod
    def from_candidates(cands: list[Candidate]) -> "Population":
        return Population(np.asarray([c.cores for c in cands], np.int32),
                          np.asarray([c.perm for c in cands], np.int32))

    def candidate(self, k: int) -> Candidate:
        return Candidate(tuple(int(x) for x in self.cores[k]),
                         tuple(int(x) for x in self.perm[k]))

    def candidates(self) -> list[Candidate]:
        return [self.candidate(k) for k in range(len(self))]

    def pairs(self) -> list[tuple[Partition, Mapping]]:
        out = []
        n_log = self.n_logical
        for k in range(len(self)):
            out.append((Partition(tuple(int(x) for x in self.cores[k])),
                        Mapping(tuple(int(x) for x in
                                      self.perm[k, :n_log[k]]),
                                name="evolved")))
        return out

    @staticmethod
    def row_key(cores_row: np.ndarray, perm_row: np.ndarray) -> bytes:
        """Expressed-genes dedup key for one genome row: two genomes that
        differ only in the unexpressed permutation tail decode to the same
        (partition, mapping) and must not be priced twice or hold two
        elitist slots.  The single source of the key format —
        ``phenotype`` and the offspring loop both go through here, so
        they can never diverge."""
        return (cores_row.tobytes()
                + perm_row[:int(cores_row.sum())].tobytes())

    def phenotype(self, k: int) -> bytes:
        return self.row_key(self.cores[k], self.perm[k])

    def take(self, idx) -> "Population":
        return Population(self.cores[idx], self.perm[idx])

    @staticmethod
    def concatenate(a: "Population", b: "Population") -> "Population":
        return Population(np.concatenate([a.cores, b.cores]),
                          np.concatenate([a.perm, b.perm]))


def encode_population(cands: list[Candidate]) -> tuple[np.ndarray, np.ndarray]:
    """Population -> ((K, n_layers) core counts, (K, n_cores_phys) perms):
    a thin view of :meth:`Population.from_candidates` kept for the original
    array-pair interchange API."""
    pop = Population.from_candidates(cands)
    return pop.cores, pop.perm


def decode_population(cores: np.ndarray, perm: np.ndarray) -> list[Candidate]:
    return Population(cores, perm).candidates()


# ------------------------------------------------------------ move tables

@dataclasses.dataclass(frozen=True)
class MoveTables:
    """Precomputed per-layer feasibility: ``feasible[l, c]`` is True iff
    assigning ``c`` cores to layer ``l`` satisfies the chip's granularity
    and per-core capacity limits.  Genome-level moves and row validation
    become table lookups — no :class:`Partition` objects, no per-candidate
    capacity walks."""

    feasible: np.ndarray    # (n_layers, n_cores_phys + 2) bool
    n_cores_phys: int

    def valid_rows(self, cores: np.ndarray) -> np.ndarray:
        """(K,) validity of each core-count row (the vectorized
        ``validate_partition``)."""
        cores = np.asarray(cores)
        c = np.clip(cores, 0, self.feasible.shape[1] - 1)
        ok = self.feasible[np.arange(cores.shape[1])[None, :], c]
        return ok.all(axis=1) & (cores.sum(axis=1) <= self.n_cores_phys)


def move_tables(net: SimNetwork, profile: ChipProfile) -> MoveTables:
    feas = np.zeros((len(net.layers), profile.n_cores + 2), bool)
    for l, layer in enumerate(net.layers):
        cap = min(max_cores_for_layer(net, l), profile.n_cores)
        if not profile.allow_partitioning:
            cap = 1
        for c in range(1, cap + 1):
            feas[l, c] = layer_fits(layer, c, profile)
    return MoveTables(feasible=feas, n_cores_phys=profile.n_cores)


# ---------------------------------------------------------------- fronts

def pareto_ranks(times: np.ndarray, energies: np.ndarray,
                 n_keep: int | None = None) -> np.ndarray:
    """(K,) nondomination rank per candidate (0 = Pareto-optimal) under
    (time, energy) minimization.  The lexicographic (time, energy) minimum
    is always rank 0, so ordering by ``(rank, time, energy)`` preserves the
    PR-2 elitism guarantees while letting energy-efficient candidates
    survive alongside equal-rank faster ones.

    ``n_keep`` caps the O(K^2)-per-front peeling for survival selection:
    peeling stops once at least ``n_keep`` rows are ranked (enough to fill
    every survivor slot), and every unpeeled row gets the sentinel rank
    ``K`` — larger than any real rank, so capped and uncapped orderings
    agree on everything below the cutoff (``tests/test_device_search.py``
    asserts this against the device counterpart).  Ties among unpeeled
    rows fall back to (time, energy) downstream, a documented deviation
    from uncapped ranking that only matters when phenotype dedup reaches
    past the cutoff (see :func:`repro.core.device_search.
    pareto_ranks_array`)."""
    t = np.asarray(times, np.float64)
    e = np.asarray(energies, np.float64)
    n = t.size
    cap = n if n_keep is None else min(int(n_keep), n)
    # dominated_by[i, j]: candidate j dominates candidate i
    dominated_by = ((t[None, :] <= t[:, None]) & (e[None, :] <= e[:, None])
                    & ((t[None, :] < t[:, None]) | (e[None, :] < e[:, None])))
    ranks = np.full(n, n, int)          # sentinel: never peeled
    remaining = np.ones(n, bool)
    r = 0
    peeled = 0
    while remaining.any() and peeled < cap:
        dom = (dominated_by & remaining[None, :]).sum(axis=1)
        frontier = remaining & (dom == 0)
        ranks[frontier] = r
        peeled += int(frontier.sum())
        remaining &= ~frontier
        r += 1
    return ranks


def knee_point(times, energies) -> int:
    """Index of the knee of a (time, energy) front: the point closest (in
    normalized objective space) to the ideal corner — the paper's "don't
    burn energy for no timing benefit" guard turned into a front pick."""
    t = np.asarray(times, np.float64)
    e = np.asarray(energies, np.float64)
    tn = (t - t.min()) / max(np.ptp(t), 1e-30)
    en = (e - e.min()) / max(np.ptp(e), 1e-30)
    return int(np.argmin(np.hypot(tn, en)))


class EpsParetoArchive:
    """Epsilon-dominance (time, energy) Pareto archive (Laumanns-style).

    A point enters iff no member multiplicatively epsilon-dominates it
    (``q.time <= p.time*(1+eps)`` and ``q.energy <= p.energy*(1+eps)``);
    on entry, members it plainly dominates are evicted.  The epsilon grid
    bounds the archive to O((log range / log(1+eps))) points, so it can
    absorb every candidate the search ever prices."""

    def __init__(self, eps: float = 0.01):
        self.eps = float(eps)
        self._items: list[dict] = []

    def __len__(self) -> int:
        return len(self._items)

    def add(self, time: float, energy: float, cores: np.ndarray,
            perm: np.ndarray, report: SimReport) -> bool:
        if not (np.isfinite(time) and np.isfinite(energy)):
            # NaN compares False against everything, so an unscreened NaN
            # point would pass both the epsilon-block test and the
            # eviction test below and sit in front() forever
            return False
        one_eps = 1.0 + self.eps
        for it in self._items:
            if it["time"] <= time * one_eps and \
                    it["energy"] <= energy * one_eps:
                return False
        self._items = [it for it in self._items
                       if not (time <= it["time"] and energy <= it["energy"])]
        self._items.append(dict(time=float(time), energy=float(energy),
                                cores=np.array(cores, np.int32),
                                perm=np.array(perm, np.int32),
                                report=report))
        return True

    def update(self, pop: Population, times: np.ndarray,
               energies: np.ndarray, reports: list[SimReport]) -> None:
        self.update_batch(times, energies, pop.cores, pop.perm,
                          reports=reports)

    def update_batch(self, times, energies, cores, perm, *,
                     reports: list | None = None) -> int:
        """One vectorized per-generation update, exactly equivalent to
        sequential :meth:`add` calls in batch order.

        A single stacked epsilon-domination test against the pre-update
        members culls the whole batch at once — in a converged search
        nearly every offspring dies here, so the per-generation cost is
        one (K, |archive|) comparison instead of K Python round-trips.
        Only the surviving handful is admitted through :meth:`add`
        (later survivors can be blocked by earlier admissions, which is
        inherently ordered).

        The prefilter stays exact under eviction: a point that evicts a
        member plainly dominates it, hence epsilon-blocks at least
        everything the evicted member blocked — so "blocked by a
        pre-update member" implies "blocked at this point's turn" no
        matter what the batch admits or evicts in between.  Returns the
        number of points admitted.
        """
        times = np.asarray(times, np.float64)
        energies = np.asarray(energies, np.float64)
        K = times.shape[0]
        if K == 0:
            return 0
        finite = np.isfinite(times) & np.isfinite(energies)
        if self._items:
            one_eps = 1.0 + self.eps
            at = np.asarray([it["time"] for it in self._items])
            ae = np.asarray([it["energy"] for it in self._items])
            blocked = ((at[None, :] <= times[:, None] * one_eps)
                       & (ae[None, :] <= energies[:, None] * one_eps)
                       ).any(axis=1)
        else:
            blocked = np.zeros(K, bool)
        blocked |= ~finite             # non-finite points never enter
        added = 0
        for k in np.flatnonzero(~blocked):
            added += self.add(float(times[k]), float(energies[k]),
                              cores[k], perm[k],
                              reports[k] if reports is not None else None)
        return added

    def front(self) -> tuple[list[Candidate], list[SimReport]]:
        """Archive contents sorted by time: (candidates, reports)."""
        items = sorted(self._items, key=lambda it: (it["time"], it["energy"]))
        cands = [Candidate(tuple(int(x) for x in it["cores"]),
                           tuple(int(x) for x in it["perm"]))
                 for it in items]
        return cands, [it["report"] for it in items]

    def state_arrays(self, n_layers: int, n_slots: int) -> dict:
        """Archive contents as stacked arrays in insertion order — the
        checkpoint interchange form.  Reports are not serialized; a resumed
        search re-prices the front once at the end (uncharged), exactly as
        the device engine always does."""
        items = self._items
        return dict(
            arch_times=np.asarray([it["time"] for it in items], np.float64),
            arch_energies=np.asarray([it["energy"] for it in items],
                                     np.float64),
            arch_cores=(np.stack([it["cores"] for it in items])
                        if items else np.zeros((0, n_layers), np.int32)),
            arch_perm=(np.stack([it["perm"] for it in items])
                       if items else np.zeros((0, n_slots), np.int32)))

    def load_state(self, arrays: dict) -> None:
        """Rebuild ``_items`` from :meth:`state_arrays` output.  Insertion
        order is preserved, so subsequent :meth:`add`/:meth:`update_batch`
        admissions and evictions replay identically to the run that wrote
        the snapshot."""
        self._items = [
            dict(time=float(t), energy=float(e),
                 cores=np.asarray(c, np.int32),
                 perm=np.asarray(p, np.int32), report=None)
            for t, e, c, p in zip(arrays["arch_times"],
                                  arrays["arch_energies"],
                                  arrays["arch_cores"],
                                  arrays["arch_perm"])]


@dataclasses.dataclass
class GenStats:
    """Per-generation progress record."""

    generation: int
    best_time: float
    best_energy: float
    mean_time: float        # over FINITE survivors (quarantined rows carry
                            # sentinel +inf fitness and are excluded)
    n_evals: int            # cumulative evaluations after this generation
    front_size: int = 0     # epsilon-archive size after this generation
    n_quarantined: int = 0  # non-finite pricing rows screened this gen


@dataclasses.dataclass
class SearchResult:
    candidate: Candidate
    partition: Partition
    mapping: Mapping
    report: SimReport
    history: list[GenStats]
    n_evals: int
    seed_best_time: float   # best initial-population time (never-worse bound)
    #: epsilon-nondominated (time, energy) candidates, sorted by time
    front: list[Candidate] = dataclasses.field(default_factory=list)
    front_reports: list[SimReport] = dataclasses.field(default_factory=list)
    #: backend demotions logged during THIS run (``resilience.Demotion``
    #: records from the evaluator's fallback chain or the device engine's
    #: mirror demotion); empty on a fault-free run
    demotions: list = dataclasses.field(default_factory=list)

    def knee(self) -> tuple[Candidate, SimReport] | None:
        """The front's knee point (None when the front is empty)."""
        if not self.front:
            return None
        i = knee_point([r.time_per_step for r in self.front_reports],
                       [r.energy_per_step for r in self.front_reports])
        return self.front[i], self.front_reports[i]


def _evaluate(evaluator: Evaluator, pop: Population) -> list[SimReport]:
    pairs = pop.pairs()
    ep = getattr(evaluator, "evaluate_population", None)
    if ep is not None:
        return ep(pairs)
    return [evaluator(p, m) for p, m in pairs]


def _reprice_uncharged(evaluator: Evaluator,
                       pop: Population) -> list[SimReport]:
    """Re-price rows for report materialization (resume bootstrap, front
    reports) without charging the evaluation ledger or consuming the
    evaluator's fault-plan schedule — bookkeeping, not search work."""
    n0 = getattr(evaluator, "n_evals", None)
    plan = getattr(evaluator, "fault_plan", None)
    if plan is not None:
        evaluator.fault_plan = None
    try:
        reports = _evaluate(evaluator, pop)
    finally:
        if plan is not None:
            evaluator.fault_plan = plan
    if n0 is not None:
        evaluator.n_evals = n0
    return reports


def _validate_search_args(net: SimNetwork, profile: ChipProfile, *,
                          population_size: int, generations: int,
                          seed_candidates) -> None:
    """Early, actionable argument validation shared by both engines (the
    alternative is a cryptic broadcast error generations into the run)."""
    if population_size < 2:
        raise ValueError(
            f"population_size must be >= 2, got {population_size}: "
            "tournament selection and (mu + lambda) survival need at "
            "least two candidates")
    if generations < 1:
        raise ValueError(f"generations must be >= 1, got {generations}")
    n_layers, n_slots = len(net.layers), int(profile.n_cores)
    for i, c in enumerate(seed_candidates or ()):
        if len(c.cores) != n_layers or len(c.perm) != n_slots:
            raise ValueError(
                f"seed candidate {i} has genome shape (cores={len(c.cores)},"
                f" perm={len(c.perm)}) but this (network, profile) needs "
                f"(cores={n_layers}, perm={n_slots})")


# ------------------------------------------------------------------ seeding

def seeded_population(net: SimNetwork, profile: ChipProfile, *, size: int,
                      rng: np.random.Generator,
                      greedy: OptimizationResult | None = None,
                      ) -> list[Candidate]:
    """Floorline-informed initial population.

    Seeds, in priority order (truncation keeps the head): the greedy
    optimizer's final (partition, mapping) and its accepted intermediate
    partitions under a strided mapping, the minimal partition under
    strided / ordered mappings, then random split-walks with random
    mappings up to ``size``.
    """
    P = profile.n_cores
    tables = move_tables(net, profile)
    seeds: list[Candidate] = []
    if greedy is not None:
        seeds.append(encode(greedy.partition, greedy.mapping, P))
        for step in greedy.history:
            if step.accepted:
                seeds.append(encode(step.partition,
                                    strided_mapping(step.partition, profile),
                                    P))
    p0 = minimal_partition(net, profile)
    seeds.append(encode(p0, strided_mapping(p0, profile), P))
    seeds.append(encode(p0, ordered_mapping(p0, profile), P))

    unique: list[Candidate] = []
    for c in seeds:
        if c not in unique:
            unique.append(c)
    unique = unique[:size]

    n_layers = len(net.layers)
    guard = 0
    while len(unique) < size and guard < 50 * size:
        guard += 1
        cores = np.asarray(p0.cores, np.int32).copy()
        for _ in range(int(rng.integers(0, n_layers * 2 + 1))):
            l = int(rng.integers(n_layers))
            if tables.feasible[l, cores[l] + 1] \
                    and cores.sum() + 1 <= P:
                cores[l] += 1
        part = Partition(tuple(int(x) for x in cores))
        c = encode(part, random_mapping(part, profile, rng), P)
        if c not in unique:
            unique.append(c)
    return unique


# ---------------------------------------------------------------- mutations

def _swap_rows(cores_row: np.ndarray, perm_row: np.ndarray,
               rng: np.random.Generator) -> tuple[np.ndarray, np.ndarray]:
    """Swap one expressed mapping gene with any other gene — re-places a
    logical core onto a different physical slot (possibly one currently
    unused).  Always yields a valid candidate."""
    perm = perm_row.copy()
    n = int(cores_row.sum())
    i = int(rng.integers(0, max(n, 1)))
    j = int(rng.integers(0, perm.shape[0]))
    if i == j:
        j = (j + 1) % perm.shape[0]
    perm[i], perm[j] = perm[j], perm[i]
    return cores_row, perm


def _hot_layer(cores_row: np.ndarray, per_core: np.ndarray) -> int:
    """Layer owning the max-loaded core (the M0 bottleneck unit), from the
    stacked genome row."""
    core_layers = np.repeat(np.arange(cores_row.shape[0]), cores_row)
    return int(core_layers[int(np.argmax(per_core))])


def _split_rows(cores_row: np.ndarray, perm_row: np.ndarray, hot: int,
                rng: np.random.Generator, tables: MoveTables,
                ) -> tuple[np.ndarray, np.ndarray] | None:
    """Split the bottleneck layer (or, failing that, a random splittable
    one) — the memory/compute assumption's move, gated by the feasibility
    table instead of a partition-object walk."""
    if cores_row.sum() + 1 > tables.n_cores_phys:
        return None
    for l in [hot] + [int(x) for x in rng.permutation(cores_row.shape[0])]:
        if tables.feasible[l, cores_row[l] + 1]:
            cores = cores_row.copy()
            cores[l] += 1
            return cores, perm_row
    return None


def _merge_rows(cores_row: np.ndarray, perm_row: np.ndarray,
                rng: np.random.Generator, tables: MoveTables,
                ) -> tuple[np.ndarray, np.ndarray] | None:
    """Coagulate a multi-core layer (§VI-A move (c): fewer cores -> less
    message duplication and active power)."""
    for l in rng.permutation(cores_row.shape[0]):
        l = int(l)
        if cores_row[l] > 1 and tables.feasible[l, cores_row[l] - 1]:
            cores = cores_row.copy()
            cores[l] -= 1
            return cores, perm_row
    return None


def _mutate_rows(cores_row: np.ndarray, perm_row: np.ndarray,
                 report: SimReport, rng: np.random.Generator,
                 tables: MoveTables, *, explore_prob: float,
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Floorline-guided mutation on one genome row: the parent's bottleneck
    stage selects the move family (§VI-A a/b/c), with probability
    ``explore_prob`` of a uniformly random stage instead.  Falls back
    across families until a valid, different row pair emerges (a gene swap
    always is)."""
    stage = report.bottleneck_stage
    if stage not in _STAGES or rng.random() < explore_prob:
        stage = _STAGES[int(rng.integers(len(_STAGES)))]
    for _ in range(4):
        if stage == "memory":
            child = _split_rows(cores_row, perm_row,
                                _hot_layer(cores_row, report.per_core_synops),
                                rng, tables)
        elif stage == "compute":
            child = _split_rows(cores_row, perm_row,
                                _hot_layer(cores_row, report.per_core_acts),
                                rng, tables)
        elif rng.random() < 0.5:
            child = _merge_rows(cores_row, perm_row, rng, tables)
        else:
            child = _swap_rows(cores_row, perm_row, rng)
        if child is not None:
            c, p = child
            changed = (not np.array_equal(c, cores_row)
                       or not np.array_equal(p, perm_row))
            if changed and tables.valid_rows(c[None, :])[0]:
                return c, p
        stage = _STAGES[int(rng.integers(len(_STAGES)))]
    return _swap_rows(cores_row, perm_row, rng)


def mutate(cand: Candidate, report: SimReport, net: SimNetwork,
           profile: ChipProfile, rng: np.random.Generator, *,
           explore_prob: float = 0.25,
           tables: MoveTables | None = None) -> Candidate:
    """Candidate-level wrapper over the row mutation (kept for the public
    API; the search loop mutates :class:`Population` rows directly)."""
    tables = tables or move_tables(net, profile)
    cores, perm = _mutate_rows(np.asarray(cand.cores, np.int32),
                               np.asarray(cand.perm, np.int32),
                               report, rng, tables,
                               explore_prob=explore_prob)
    return Candidate(tuple(int(x) for x in cores),
                     tuple(int(x) for x in perm))


# ------------------------------------------------------------------- search

def evolutionary_search(
    net: SimNetwork,
    profile: ChipProfile,
    evaluator: Evaluator,
    *,
    population_size: int = 24,
    generations: int = 16,
    tournament_k: int = 3,
    explore_prob: float = 0.25,
    seed: int = 0,
    max_evaluations: int | None = None,
    seed_candidates: list[Candidate] | None = None,
    greedy: OptimizationResult | None = None,
    pareto_eps: float = 0.01,
    engine: str = "numpy",
    n_islands: int | None = None,
    migrate_every: int = 5,
    n_migrants: int | None = None,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    checkpoint_keep: int = 3,
    resume: bool = False,
    fault_plan: FaultPlan | None = None,
) -> SearchResult:
    """Run the (mu + lambda) evolutionary mapping search, tensor-first.

    ``evaluator`` is the shared :data:`~repro.core.partitioner.Evaluator`;
    when it exposes ``evaluate_population`` (:class:`SimEvaluator` does)
    each generation is priced with the stacked population path of
    :func:`repro.neuromorphic.timestep.simulate_population` (or its jitted
    ``vmap`` backend).  ``max_evaluations`` caps total candidate pricings
    (iso-evaluation comparisons against the greedy walk); ``greedy`` feeds
    the accepted §VI-B moves into the initial population; ``pareto_eps``
    sets the epsilon-dominance grid of the (time, energy) archive returned
    as ``SearchResult.front``.  Deterministic for a fixed ``seed`` and
    evaluator.

    ``engine`` selects the generation loop itself: ``"numpy"`` (default,
    this function's host loop below — per-offspring mutation over NumPy
    rows, pricing through whichever backend the evaluator is configured
    with) or ``"device"`` — the fully accelerator-resident loop of
    :mod:`repro.core.device_search`, in which an entire generation
    (selection, mutation, pricing, ranking, survival) is one jitted
    program and survivor batches never leave the device.  The device
    engine needs a :class:`~repro.core.partitioner.SimEvaluator`-like
    evaluator and follows its own PRNG-key contract (``docs/search.md``);
    the two engines are deterministic per seed but not sample-for-sample
    identical to each other.  ``"sharded"`` scales the device engine's
    jitted generation across every visible device as an island model
    (``docs/distributed.md``): the population splits into ``n_islands``
    equal islands (default one per device; must divide
    ``population_size``), elites rotate one island around a ring every
    ``migrate_every`` generations (``n_migrants`` rows, default an eighth
    of the island), and with a single island it reproduces
    ``engine="device"`` bit-identically.  The island keywords are only
    meaningful for ``engine="sharded"``.

    Fault tolerance (``docs/robustness.md``): with ``checkpoint_dir`` the
    search writes an atomic, self-contained snapshot every
    ``checkpoint_every`` generations (``checkpoint_keep`` newest retained);
    ``resume=True`` continues from the newest one **bit-identically** to
    the uninterrupted run — the host RNG state, the phenotype dedup set,
    the survivor fitness and the epsilon-archive all travel in the
    snapshot.  Non-finite pricing rows are quarantined with sentinel-worst
    fitness every generation.  ``fault_plan`` scripts deterministic faults
    (injected backend failures, NaN rows, a simulated kill) for testing.
    """
    _validate_search_args(net, profile, population_size=population_size,
                          generations=generations,
                          seed_candidates=seed_candidates)
    if engine == "device":
        from repro.core.device_search import evolutionary_search_device
        return evolutionary_search_device(
            net, profile, evaluator, population_size=population_size,
            generations=generations, tournament_k=tournament_k,
            explore_prob=explore_prob, seed=seed,
            max_evaluations=max_evaluations,
            seed_candidates=seed_candidates, greedy=greedy,
            pareto_eps=pareto_eps, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            checkpoint_keep=checkpoint_keep, resume=resume,
            fault_plan=fault_plan)
    if engine == "sharded":
        from repro.core.device_search import evolutionary_search_sharded
        return evolutionary_search_sharded(
            net, profile, evaluator, population_size=population_size,
            generations=generations, tournament_k=tournament_k,
            explore_prob=explore_prob, seed=seed,
            max_evaluations=max_evaluations,
            seed_candidates=seed_candidates, greedy=greedy,
            pareto_eps=pareto_eps, n_islands=n_islands,
            migrate_every=migrate_every, n_migrants=n_migrants,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            checkpoint_keep=checkpoint_keep, resume=resume,
            fault_plan=fault_plan)
    if engine != "numpy":
        raise ValueError(f"unknown search engine {engine!r}")
    ckpt = (SearchCheckpointer(checkpoint_dir, every=checkpoint_every,
                               keep=checkpoint_keep)
            if checkpoint_dir else None)
    restored = ckpt.restore() if (ckpt is not None and resume) else None
    if fault_plan is not None:
        setattr(evaluator, "fault_plan", fault_plan)
    n_demote0 = len(getattr(evaluator, "demotions", ()))
    tables = move_tables(net, profile)
    archive = EpsParetoArchive(pareto_eps)
    n_layers = len(net.layers)
    n_slots = profile.n_cores

    if restored is not None:
        arrays, gen0, meta = restored
        validate_resume_meta(meta, engine="numpy",
                             checkpoint_dir=checkpoint_dir)
        rng = rng_from_state(meta["rng_state"])
        pop = Population(arrays["cores"], arrays["perm"])
        times = np.asarray(arrays["times"], np.float64)
        energies = np.asarray(arrays["energies"], np.float64)
        # survivor reports (bottleneck stages / hot layers feed mutation)
        # are rebuilt deterministically instead of being serialized; the
        # checkpointed times/energies above stay authoritative
        reports = _reprice_uncharged(evaluator, pop)
        tried = decode_bytes_set(arrays["tried_buf"], arrays["tried_lens"])
        archive.load_state(arrays)
        history = [GenStats(**h) for h in meta["history"]]
        evals_used = int(meta["evals_used"])
        seed_best_time = float(meta["seed_best_time"])
        start_gen = gen0 + 1
    else:
        rng = np.random.default_rng(seed)
        cands = list(seed_candidates if seed_candidates is not None else
                     seeded_population(net, profile, size=population_size,
                                       rng=rng, greedy=greedy))
        if not cands:
            raise ValueError("empty initial population")
        if max_evaluations is not None:
            cands = cands[:max(1, max_evaluations)]
        pop = Population.from_candidates(cands)
        reports = _evaluate(evaluator, pop)
        evals_used = len(pop)
        times, energies, bad0 = quarantine_rows(
            np, np.asarray([r.time_per_step for r in reports], np.float64),
            np.asarray([r.energy_per_step for r in reports], np.float64))
        seed_best_time = float(times.min())
        start_gen = 1

    # every phenotype ever priced, across generations (rebuilt on resume
    # from the snapshot — NOT from the survivors, which are a subset)
    if restored is None:
        tried = {pop.phenotype(k) for k in range(len(pop))}

    def _order(t, e):
        """(rank, time, energy) survival order — np.lexsort is keyed last
        first."""
        return np.lexsort((e, t, pareto_ranks(t, e)))

    def _snapshot(gen: int) -> None:
        arrays = dict(cores=pop.cores, perm=pop.perm, times=times,
                      energies=energies)
        arrays["tried_buf"], arrays["tried_lens"] = encode_bytes_set(tried)
        arrays.update(archive.state_arrays(n_layers, n_slots))
        meta = dict(engine="numpy", rng_state=rng_state(rng),
                    evals_used=int(evals_used),
                    seed_best_time=float(seed_best_time),
                    history=[dataclasses.asdict(g) for g in history])
        ckpt.save(gen, arrays, meta)

    if restored is None:
        order = _order(times, energies)
        pop = pop.take(order)
        reports = [reports[k] for k in order]
        times, energies = times[order], energies[order]
        archive.update(pop, times, energies, reports)

        history = [GenStats(generation=0,
                            best_time=float(times[0]),
                            best_energy=float(energies[0]),
                            mean_time=float(finite_mean(np, times)),
                            n_evals=evals_used,
                            front_size=len(archive),
                            n_quarantined=int(bad0.sum()))]
        if ckpt is not None:
            _snapshot(0)
        if fault_plan is not None:
            fault_plan.after_generation(0)

    for gen in range(start_gen, generations + 1):
        n_off = population_size
        if max_evaluations is not None:
            n_off = min(n_off, max_evaluations - evals_used)
        if n_off <= 0:
            break
        # vectorized tournament: the population is (rank, time, energy)-
        # sorted, so fitness order == index order and a tournament is a
        # row-min over the stacked draw matrix
        draws = rng.integers(0, len(pop),
                             size=(n_off, max(1, tournament_k)))
        parents = draws.min(axis=1)
        off_cores = np.empty((n_off, n_layers), np.int32)
        off_perm = np.empty((n_off, n_slots), np.int32)
        for j, i in enumerate(parents):
            i = int(i)
            c, p = _mutate_rows(pop.cores[i], pop.perm[i], reports[i], rng,
                                tables, explore_prob=explore_prob)
            for _ in range(4):          # don't waste budget on repeats
                if Population.row_key(c, p) not in tried:
                    break
                c, p = _mutate_rows(pop.cores[i], pop.perm[i], reports[i],
                                    rng, tables, explore_prob=explore_prob)
            tried.add(Population.row_key(c, p))
            off_cores[j], off_perm[j] = c, p
        off_pop = Population(off_cores, off_perm)
        off_reports = _evaluate(evaluator, off_pop)
        evals_used += len(off_pop)
        off_times, off_energies, off_bad = quarantine_rows(
            np,
            np.asarray([r.time_per_step for r in off_reports], np.float64),
            np.asarray([r.energy_per_step for r in off_reports], np.float64))
        archive.update(off_pop, off_times, off_energies, off_reports)

        # (mu + lambda) elitist survival over unique candidates
        all_pop = Population.concatenate(pop, off_pop)
        all_r = reports + off_reports
        all_t = np.concatenate([times, off_times])
        all_e = np.concatenate([energies, off_energies])
        order = _order(all_t, all_e)
        keep, seen = [], set()
        for k in order:
            key = all_pop.phenotype(int(k))
            if key in seen:
                continue
            seen.add(key)
            keep.append(int(k))
            if len(keep) == population_size:
                break
        pop = all_pop.take(keep)
        reports = [all_r[k] for k in keep]
        times, energies = all_t[keep], all_e[keep]
        history.append(GenStats(
            generation=gen,
            best_time=float(times[0]),
            best_energy=float(energies[0]),
            mean_time=float(finite_mean(np, times)),
            n_evals=evals_used,
            front_size=len(archive),
            n_quarantined=int(off_bad.sum())))
        if ckpt is not None and ckpt.due(gen, generations):
            _snapshot(gen)
        if fault_plan is not None:
            fault_plan.after_generation(gen)

    best, best_r = pop.candidate(0), reports[0]
    front, front_reports = archive.front()
    if front and any(r is None for r in front_reports):
        # restored archive items carry no report; materialize them once,
        # uncharged (front() is (time, energy)-sorted, as is the repricing)
        front_reports = _reprice_uncharged(
            evaluator, Population.from_candidates(front))
    return SearchResult(candidate=best, partition=best.partition(),
                        mapping=best.mapping(), report=best_r,
                        history=history, n_evals=evals_used,
                        seed_best_time=seed_best_time,
                        front=front, front_reports=front_reports,
                        demotions=list(
                            getattr(evaluator, "demotions", ()))[n_demote0:])


def greedy_then_evolve(net: SimNetwork, profile: ChipProfile,
                       evaluator: Evaluator, *,
                       max_evaluations: int | None = None,
                       **kw) -> tuple[OptimizationResult, SearchResult]:
    """The two optimizers end-to-end on one evaluator: run the §VI-B greedy
    walk, then the evolutionary search seeded from its accepted moves.  With
    elitism the search result is never worse than the greedy one."""
    greedy = optimize_partitioning(net, profile, evaluator)
    evo = evolutionary_search(net, profile, evaluator, greedy=greedy,
                              max_evaluations=max_evaluations, **kw)
    return greedy, evo
