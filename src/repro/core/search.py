"""Vectorized evolutionary search over (partition, mapping) candidates.

The paper's stage-2 optimizer (§VI-B, :mod:`repro.core.partitioner`) walks
one candidate at a time: split the bottleneck layer, re-price, backtrack.
That is cheap but easily trapped — a split that only pays off together with
a re-mapping is never found, and the walk prices exactly one candidate per
step.  Population-based search over accelerator mappings (cf. "Evolutionary
Mapping of Neural Networks to Spatial Accelerators") dominates greedy
hillclimbing on this problem precisely because it holds many (partition,
mapping) hypotheses at once; what made it affordable *here* is the batched
engine's pricing split: one functional run + per-layer counter cumsums
(:func:`repro.neuromorphic.timestep.precompute_pricing`) price an entire
generation with one stacked gather per layer
(:func:`repro.neuromorphic.timestep.simulate_population`).

Candidates are encoded as fixed-shape arrays regardless of how many cores a
partition uses:

* ``cores`` — per-layer core counts, shape ``(n_layers,)``;
* ``perm``  — a permutation of ALL physical core slots, shape
  ``(profile.n_cores,)``.  The decoded mapping is ``perm[:total_cores]``:
  a split simply pulls the next gene into use, a merge releases one, and a
  gene swap is always a valid mapping move.  ``encode``/``decode`` round-trip
  the partition and physical placement exactly (``tests/test_search.py``).

The generation loop is (mu + lambda) elitist: tournament parent selection,
floorline-guided mutation (the parent's bottleneck stage picks the move —
memory/compute -> split the hot layer, traffic -> re-map or coagulate, with
an exploration probability of a uniformly random move), then survival of the
``population_size`` best unique candidates.  Elitism plus floorline-informed
seeding (the greedy optimizer's accepted moves are injected into the initial
population) guarantee the search never returns a candidate worse than its
best seed — and never worse than the greedy result when seeded from it.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partitioner import (Evaluator, OptimizationResult,
                                    _argmax_layer, can_split,
                                    optimize_partitioning)
from repro.neuromorphic.network import SimNetwork
from repro.neuromorphic.noc import (Mapping, ordered_mapping, random_mapping,
                                    strided_mapping)
from repro.neuromorphic.partition import (Partition, minimal_partition,
                                          validate_partition)
from repro.neuromorphic.platform import ChipProfile
from repro.neuromorphic.timestep import SimReport

_STAGES = ("memory", "compute", "traffic")


@dataclasses.dataclass(frozen=True)
class Candidate:
    """Fixed-shape genome: per-layer core counts + a permutation of every
    physical core slot (only the first ``total_cores`` genes are expressed
    as the mapping)."""

    cores: tuple[int, ...]
    perm: tuple[int, ...]

    @property
    def n_logical(self) -> int:
        return int(sum(self.cores))

    def partition(self) -> Partition:
        return Partition(self.cores)

    def mapping(self) -> Mapping:
        return Mapping(self.perm[:self.n_logical], name="evolved")


def encode(part: Partition, mapping: Mapping,
           n_cores_phys: int) -> Candidate:
    """(Partition, Mapping) -> fixed-shape genome.  The mapping's slots
    become the leading genes; unused physical slots follow in ascending
    order, so ``decode(encode(p, m))`` reproduces the partition and the
    ``phys`` placement exactly (the decoded mapping is named "evolved")."""
    used = tuple(int(p) for p in mapping.phys)
    taken = set(used)
    rest = tuple(s for s in range(n_cores_phys) if s not in taken)
    return Candidate(tuple(int(c) for c in part.cores), used + rest)


def decode(cand: Candidate) -> tuple[Partition, Mapping]:
    return cand.partition(), cand.mapping()


def _phenotype(cand: Candidate) -> tuple:
    """Dedup key: only the expressed genes.  Two genomes that differ in the
    unexpressed permutation tail decode to the same (partition, mapping)
    and must not be priced twice or hold two elitist slots."""
    return (cand.cores, cand.perm[:cand.n_logical])


def encode_population(cands: list[Candidate]) -> tuple[np.ndarray, np.ndarray]:
    """Population -> ((K, n_layers) core counts, (K, n_cores_phys) perms),
    the fixed-shape array interchange form (storage, transport, or future
    array-level genome operators; the search itself mutates
    :class:`Candidate` objects)."""
    cores = np.asarray([c.cores for c in cands], np.int32)
    perm = np.asarray([c.perm for c in cands], np.int32)
    return cores, perm


def decode_population(cores: np.ndarray, perm: np.ndarray) -> list[Candidate]:
    return [Candidate(tuple(int(x) for x in cr), tuple(int(x) for x in pr))
            for cr, pr in zip(cores, perm)]


@dataclasses.dataclass
class GenStats:
    """Per-generation progress record."""

    generation: int
    best_time: float
    best_energy: float
    mean_time: float
    n_evals: int            # cumulative evaluations after this generation


@dataclasses.dataclass
class SearchResult:
    candidate: Candidate
    partition: Partition
    mapping: Mapping
    report: SimReport
    history: list[GenStats]
    n_evals: int
    seed_best_time: float   # best initial-population time (never-worse bound)


def _fitness(r: SimReport) -> tuple[float, float]:
    """Minimize time first, energy as the tie-break (the paper's energy
    guard: equal-time candidates should not burn more power)."""
    return (r.time_per_step, r.energy_per_step)


def _evaluate(evaluator: Evaluator, cands: list[Candidate]) -> list[SimReport]:
    pairs = [decode(c) for c in cands]
    ep = getattr(evaluator, "evaluate_population", None)
    if ep is not None:
        return ep(pairs)
    return [evaluator(p, m) for p, m in pairs]


# ------------------------------------------------------------------ seeding

def seeded_population(net: SimNetwork, profile: ChipProfile, *, size: int,
                      rng: np.random.Generator,
                      greedy: OptimizationResult | None = None,
                      ) -> list[Candidate]:
    """Floorline-informed initial population.

    Seeds, in priority order (truncation keeps the head): the greedy
    optimizer's final (partition, mapping) and its accepted intermediate
    partitions under a strided mapping, the minimal partition under
    strided / ordered mappings, then random split-walks with random
    mappings up to ``size``.
    """
    P = profile.n_cores
    seeds: list[Candidate] = []
    if greedy is not None:
        seeds.append(encode(greedy.partition, greedy.mapping, P))
        for step in greedy.history:
            if step.accepted:
                seeds.append(encode(step.partition,
                                    strided_mapping(step.partition, profile),
                                    P))
    p0 = minimal_partition(net, profile)
    seeds.append(encode(p0, strided_mapping(p0, profile), P))
    seeds.append(encode(p0, ordered_mapping(p0, profile), P))

    unique: list[Candidate] = []
    for c in seeds:
        if c not in unique:
            unique.append(c)
    unique = unique[:size]

    guard = 0
    while len(unique) < size and guard < 50 * size:
        guard += 1
        part = p0
        for _ in range(int(rng.integers(0, len(net.layers) * 2 + 1))):
            l = int(rng.integers(len(net.layers)))
            if can_split(net, part, l, profile):
                part = part.split(l)
        c = encode(part, random_mapping(part, profile, rng), P)
        if c not in unique:
            unique.append(c)
    return unique


# ---------------------------------------------------------------- mutations

def _swap_move(cand: Candidate, rng: np.random.Generator) -> Candidate:
    """Swap one expressed mapping gene with any other gene — re-places a
    logical core onto a different physical slot (possibly one currently
    unused).  Always yields a valid candidate."""
    perm = list(cand.perm)
    n = cand.n_logical
    i = int(rng.integers(0, max(n, 1)))
    j = int(rng.integers(0, len(perm)))
    if i == j:
        j = (j + 1) % len(perm)
    perm[i], perm[j] = perm[j], perm[i]
    return Candidate(cand.cores, tuple(perm))


def _split_move(cand: Candidate, per_core: np.ndarray, net: SimNetwork,
                profile: ChipProfile,
                rng: np.random.Generator) -> Candidate | None:
    """Split the bottleneck layer (or, failing that, a random splittable
    one) — the memory/compute assumption's move, locating the hot layer by
    the greedy walk's own rule."""
    part = cand.partition()
    hot = _argmax_layer(per_core, part)
    layers = [hot] + [int(l) for l in rng.permutation(len(part.cores))]
    for l in layers:
        if can_split(net, part, l, profile):
            return Candidate(part.split(l).cores, cand.perm)
    return None


def _merge_move(cand: Candidate, net: SimNetwork, profile: ChipProfile,
                rng: np.random.Generator) -> Candidate | None:
    """Coagulate a multi-core layer (§VI-A move (c): fewer cores -> less
    message duplication and active power)."""
    part = cand.partition()
    for l in rng.permutation(len(part.cores)):
        if part.cores[int(l)] > 1:
            merged = part.merge(int(l))
            if validate_partition(net, merged, profile):
                return Candidate(merged.cores, cand.perm)
    return None


def mutate(cand: Candidate, report: SimReport, net: SimNetwork,
           profile: ChipProfile, rng: np.random.Generator, *,
           explore_prob: float = 0.25) -> Candidate:
    """Floorline-guided mutation: the parent's bottleneck stage selects the
    move family (§VI-A a/b/c), with probability ``explore_prob`` of a
    uniformly random stage instead.  Falls back across families until a
    valid, different candidate emerges (a gene swap always is)."""
    stage = report.bottleneck_stage
    if stage not in _STAGES or rng.random() < explore_prob:
        stage = _STAGES[int(rng.integers(len(_STAGES)))]
    for _ in range(4):
        if stage == "memory":
            child = _split_move(cand, report.per_core_synops, net, profile,
                                rng)
        elif stage == "compute":
            child = _split_move(cand, report.per_core_acts, net, profile, rng)
        elif rng.random() < 0.5:
            child = _merge_move(cand, net, profile, rng)
        else:
            child = _swap_move(cand, rng)
        if (child is not None and child != cand
                and validate_partition(net, child.partition(), profile)):
            return child
        stage = _STAGES[int(rng.integers(len(_STAGES)))]
    return _swap_move(cand, rng)


def _tournament(reports: list[SimReport], k: int,
                rng: np.random.Generator) -> int:
    idx = rng.integers(0, len(reports), size=max(1, k))
    return int(min(idx, key=lambda i: _fitness(reports[int(i)])))


# ------------------------------------------------------------------- search

def evolutionary_search(
    net: SimNetwork,
    profile: ChipProfile,
    evaluator: Evaluator,
    *,
    population_size: int = 24,
    generations: int = 16,
    tournament_k: int = 3,
    explore_prob: float = 0.25,
    seed: int = 0,
    max_evaluations: int | None = None,
    seed_candidates: list[Candidate] | None = None,
    greedy: OptimizationResult | None = None,
) -> SearchResult:
    """Run the (mu + lambda) evolutionary mapping search.

    ``evaluator`` is the shared :data:`~repro.core.partitioner.Evaluator`;
    when it exposes ``evaluate_population`` (:class:`SimEvaluator` does)
    each generation is priced with the stacked population path of
    :func:`repro.neuromorphic.timestep.simulate_population`.
    ``max_evaluations`` caps total candidate pricings (iso-evaluation
    comparisons against the greedy walk); ``greedy`` feeds the accepted
    §VI-B moves into the initial population.  Deterministic for a fixed
    ``seed`` and evaluator.
    """
    rng = np.random.default_rng(seed)
    pop = list(seed_candidates if seed_candidates is not None else
               seeded_population(net, profile, size=population_size, rng=rng,
                                 greedy=greedy))
    if not pop:
        raise ValueError("empty initial population")
    if max_evaluations is not None:
        pop = pop[:max(1, max_evaluations)]
    reports = _evaluate(evaluator, pop)
    evals_used = len(pop)
    seed_best_time = min(r.time_per_step for r in reports)
    # every phenotype ever priced, across generations
    tried = {_phenotype(c) for c in pop}

    order = sorted(range(len(pop)), key=lambda k: _fitness(reports[k]))
    pop = [pop[k] for k in order]
    reports = [reports[k] for k in order]

    history = [GenStats(generation=0,
                        best_time=reports[0].time_per_step,
                        best_energy=reports[0].energy_per_step,
                        mean_time=float(np.mean([r.time_per_step
                                                 for r in reports])),
                        n_evals=evals_used)]

    for gen in range(1, generations + 1):
        n_off = population_size
        if max_evaluations is not None:
            n_off = min(n_off, max_evaluations - evals_used)
        if n_off <= 0:
            break
        offspring: list[Candidate] = []
        for _ in range(n_off):
            i = _tournament(reports, tournament_k, rng)
            child = mutate(pop[i], reports[i], net, profile, rng,
                           explore_prob=explore_prob)
            for _ in range(4):          # don't waste budget on repeats
                if _phenotype(child) not in tried:
                    break
                child = mutate(pop[i], reports[i], net, profile, rng,
                               explore_prob=explore_prob)
            tried.add(_phenotype(child))
            offspring.append(child)
        off_reports = _evaluate(evaluator, offspring)
        evals_used += len(offspring)

        # (mu + lambda) elitist survival over unique candidates
        all_c = pop + offspring
        all_r = reports + off_reports
        order = sorted(range(len(all_c)), key=lambda k: _fitness(all_r[k]))
        pop, reports, seen = [], [], set()
        for k in order:
            if _phenotype(all_c[k]) in seen:
                continue
            seen.add(_phenotype(all_c[k]))
            pop.append(all_c[k])
            reports.append(all_r[k])
            if len(pop) == population_size:
                break
        history.append(GenStats(
            generation=gen,
            best_time=reports[0].time_per_step,
            best_energy=reports[0].energy_per_step,
            mean_time=float(np.mean([r.time_per_step for r in reports])),
            n_evals=evals_used))

    best, best_r = pop[0], reports[0]
    return SearchResult(candidate=best, partition=best.partition(),
                        mapping=best.mapping(), report=best_r,
                        history=history, n_evals=evals_used,
                        seed_best_time=seed_best_time)


def greedy_then_evolve(net: SimNetwork, profile: ChipProfile,
                       evaluator: Evaluator, *,
                       max_evaluations: int | None = None,
                       **kw) -> tuple[OptimizationResult, SearchResult]:
    """The two optimizers end-to-end on one evaluator: run the §VI-B greedy
    walk, then the evolutionary search seeded from its accepted moves.  With
    elitism the search result is never worse than the greedy one."""
    greedy = optimize_partitioning(net, profile, evaluator)
    evo = evolutionary_search(net, profile, evaluator, greedy=greedy,
                              max_evaluations=max_evaluations, **kw)
    return greedy, evo
