"""The paper's primary contribution: bound-and-bottleneck analysis, the
floorline performance model, and the two-stage optimization methodology —
plus the population-based mapping search built on top of them."""

from repro.core.analytical import (Bottleneck, LayerConfig, OpCosts, OpCounts,
                                   layer_op_counts, min_cores_for_layer,
                                   predict_bottleneck)
from repro.core.floorline import (FloorlineModel, OptimizationMove,
                                  WorkloadPoint, fit_floorline, floorline_curve)
from repro.core.metrics import LoadStats, WorkloadMetrics, proxy_gap

# The optimizer/search layers sit ABOVE the simulator (they import
# repro.neuromorphic, whose modules import repro.core.metrics), so they are
# re-exported lazily to keep `import repro.neuromorphic.timestep` acyclic.
_LAZY = {name: "repro.core.partitioner" for name in (
    "Evaluator", "OptimizationResult", "OptStep", "SimEvaluator",
    "can_split", "optimize_partitioning")}
_LAZY.update({name: "repro.core.guidance" for name in (
    "LayerGuidance", "floorline_layer_guidance", "floorline_layer_weights")})
_LAZY.update({name: "repro.core.device_search" for name in (
    "DeviceSearchEngine", "evolutionary_search_device", "generation_draws",
    "mutate_rows_array", "survival_order_array")})
_LAZY.update({name: "repro.core.search" for name in (
    "Candidate", "EpsParetoArchive", "MoveTables", "Population",
    "SearchResult", "decode", "decode_population", "encode",
    "encode_population", "evolutionary_search", "greedy_then_evolve",
    "knee_point", "move_tables", "pareto_ranks", "seeded_population")})


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.core' has no attribute {name!r}")


__all__ = [
    "Bottleneck", "LayerConfig", "OpCosts", "OpCounts", "layer_op_counts",
    "min_cores_for_layer", "predict_bottleneck",
    "FloorlineModel", "OptimizationMove", "WorkloadPoint", "fit_floorline",
    "floorline_curve",
    "LoadStats", "WorkloadMetrics", "proxy_gap",
    "Evaluator", "OptimizationResult", "OptStep", "SimEvaluator", "can_split",
    "optimize_partitioning",
    "LayerGuidance", "floorline_layer_guidance", "floorline_layer_weights",
    "Candidate", "EpsParetoArchive", "MoveTables", "Population",
    "SearchResult", "decode", "decode_population", "encode",
    "encode_population", "evolutionary_search", "greedy_then_evolve",
    "knee_point", "move_tables", "pareto_ranks", "seeded_population",
    "DeviceSearchEngine", "evolutionary_search_device", "generation_draws",
    "mutate_rows_array", "survival_order_array",
]
