"""The paper's primary contribution: bound-and-bottleneck analysis, the
floorline performance model, and the two-stage optimization methodology."""

from repro.core.analytical import (Bottleneck, LayerConfig, OpCosts, OpCounts,
                                   layer_op_counts, min_cores_for_layer,
                                   predict_bottleneck)
from repro.core.floorline import (FloorlineModel, OptimizationMove,
                                  WorkloadPoint, fit_floorline, floorline_curve)
from repro.core.metrics import LoadStats, WorkloadMetrics, proxy_gap

__all__ = [
    "Bottleneck", "LayerConfig", "OpCosts", "OpCounts", "layer_op_counts",
    "min_cores_for_layer", "predict_bottleneck",
    "FloorlineModel", "OptimizationMove", "WorkloadPoint", "fit_floorline",
    "floorline_curve",
    "LoadStats", "WorkloadMetrics", "proxy_gap",
]
