"""Analytical bound-and-bottleneck model (paper Section III).

Closed-form operation counts for one fully-connected layer ``l_i`` of a
feed-forward network mapped onto a neuromorphic chip:

* ``N``  — neurons per layer (previous / current / next layers share N),
* ``w``  — weight density  (weight sparsity = 1 - w),
* ``m``  — message (activation) density of l_{i-1} and l_i,
* ``C``  — neurocores assigned to a layer ('voluntary' partitioning),
* ``x``  — width scale factor forcing 'involuntary' utilization (§III-D).

The three core operations (per §III):
  (a) synops            — weight fetch + multiply-accumulate, per neurocore,
  (b) activation computes — neuron updates, per neurocore,
  (c) message traffic   — NoC activation messages to the next layer (total).

All counts are *expected* values under uniform random sparsity, matching the
paper's asymptotic treatment.  These are used to (1) predict bottleneck states
before running the simulator and (2) property-test the simulator's measured
counters against theory.
"""

from __future__ import annotations

import dataclasses
import enum
import math


class Bottleneck(enum.Enum):
    """The three bottleneck states established by the paper (§III-E, M1-M3)."""

    MEMORY = "memory"      # M1: synop weight fetch / writeback dominates
    COMPUTE = "compute"    # M2: neuron activation computation dominates
    TRAFFIC = "traffic"    # M3: NoC message traffic dominates


@dataclasses.dataclass(frozen=True)
class LayerConfig:
    """Workload configuration knobs for one layer (paper §III-A)."""

    n_neurons: int             # N
    weight_density: float      # w in [0, 1]
    msg_density: float         # m in [0, 1] (activation density of l_{i-1} and l_i)
    cores: int = 1             # C_i  ('voluntary' partitioning)
    cores_next: int = 1        # C_{i+1}
    width_scale: float = 1.0   # x   ('involuntary' utilization, §III-D)

    def __post_init__(self) -> None:
        if not (0.0 <= self.weight_density <= 1.0):
            raise ValueError(f"weight_density must be in [0,1], got {self.weight_density}")
        if not (0.0 <= self.msg_density <= 1.0):
            raise ValueError(f"msg_density must be in [0,1], got {self.msg_density}")
        if self.cores < 1 or self.cores_next < 1:
            raise ValueError("core counts must be >= 1")
        if self.width_scale < 1.0:
            raise ValueError("width_scale (x) must be >= 1")


@dataclasses.dataclass(frozen=True)
class OpCounts:
    """Expected per-timestep operation counts for one layer."""

    synops_per_core: float
    act_computes_per_core: float
    traffic_total: float
    inputs_per_core: float      # messages arriving at each core of l_i
    cores_used: int

    def dominant(self, costs: "OpCosts") -> Bottleneck:
        """Which operation dominates the (pipelined) per-step cost."""
        t_mem = costs.c_synop * self.synops_per_core
        t_act = costs.c_act * self.act_computes_per_core
        t_msg = costs.c_msg * self.traffic_total
        best = max((t_mem, Bottleneck.MEMORY), (t_act, Bottleneck.COMPUTE),
                   (t_msg, Bottleneck.TRAFFIC), key=lambda p: p[0])
        return best[1]


@dataclasses.dataclass(frozen=True)
class OpCosts:
    """Relative unit costs; per the paper (§II-A, [12],[52]) the three are
    within one order of magnitude on real neuromorphic silicon."""

    c_synop: float = 1.0
    c_act: float = 2.0
    c_msg: float = 1.0


def expected_inputs(n_neurons: int, msg_density: float) -> float:
    """E[# input messages to l_i] = m * N  (§III-B)."""
    return msg_density * n_neurons


def p_neuron_messaged(n_inputs: float, weight_density: float) -> float:
    """P[a neuron receives >= 1 synop] = 1 - (1-w)^{mN}  (paper eq. 3)."""
    if weight_density >= 1.0:
        return 1.0 if n_inputs > 0 else 0.0
    if n_inputs <= 0:
        return 0.0
    # Compute in log space for numerical robustness with large mN.
    log_miss = n_inputs * math.log1p(-weight_density)
    return -math.expm1(log_miss)


def layer_op_counts(cfg: LayerConfig, *, idealized_acts: bool = False) -> OpCounts:
    """Expected per-timestep op counts for layer l_i under configuration cfg.

    Covers all three regimes of §III:
      * single core      (cfg.cores == 1, width_scale == 1)   -> §III-B
      * voluntary cores  (cfg.cores > 1)                       -> §III-C
      * forced width     (cfg.width_scale > 1)                 -> §III-D
        (voluntary partitioning may stack on top of forced utilization)

    With ``idealized_acts`` the activation-compute count uses the idealized
    assumption that a neuron only computes if it received >= 1 synop
    (paper eq. 3); otherwise every mapped neuron updates (~O(N/C), the
    behaviour the paper observes on synchronous hardware).
    """
    x = cfg.width_scale
    n = cfg.n_neurons * x                       # actual layer width
    inputs_total = cfg.msg_density * n          # mxN messages from l_{i-1}

    # §III-D: width scaling forces C = O(x^2) cores minimum; voluntary
    # partitioning multiplies on top.
    forced_cores = max(1, math.ceil(x * x))
    cores = int(cfg.cores * forced_cores)
    cores_next = int(cfg.cores_next * forced_cores)
    neurons_per_core = n / cores

    # (a) synops per core: each input fetches the w-dense weights of the
    # neurons mapped to that core.
    synops_core = inputs_total * cfg.weight_density * neurons_per_core

    # (b) activation computes per core.
    if idealized_acts:
        acts_core = neurons_per_core * p_neuron_messaged(inputs_total, cfg.weight_density)
    else:
        acts_core = neurons_per_core

    # (c) traffic: every one of the m*n output messages is duplicated to each
    # core of l_{i+1} (broadcast; §III-C).
    traffic = cfg.msg_density * n * cores_next

    return OpCounts(
        synops_per_core=synops_core,
        act_computes_per_core=acts_core,
        traffic_total=traffic,
        inputs_per_core=inputs_total,
        cores_used=cores,
    )


def predict_bottleneck(cfg: LayerConfig, costs: OpCosts | None = None) -> Bottleneck:
    """Predict the bottleneck state for a layer configuration (M1-M3)."""
    return layer_op_counts(cfg).dominant(costs or OpCosts())


def min_cores_for_layer(n_neurons: int, fanin: int, *, neurons_per_core: int,
                        synapses_per_core: int) -> int:
    """Minimum ('involuntary') neurocore count for a layer given chip limits
    (§III-D): the layer must fit both neuron-state and synaptic memory."""
    by_neurons = math.ceil(n_neurons / neurons_per_core)
    by_synapses = math.ceil((n_neurons * fanin) / synapses_per_core)
    return max(1, by_neurons, by_synapses)


def sweep_width_scaling(base: LayerConfig, scales: list[float]) -> list[OpCounts]:
    """§III-D sweep: op counts as width scales.  Used by tests to check the
    paper's claims: synops/core ~ constant, traffic ~ O(m x^3 N)."""
    return [layer_op_counts(dataclasses.replace(base, width_scale=float(s))) for s in scales]
