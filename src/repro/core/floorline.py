"""The floorline performance model (paper §VI-A, Fig. 9).

A visual/analytical model relating a workload's **intensity** — the maximum
synops executed by any active neurocore in a timestep — to its **performance**
— the timestep duration:

            time
              ^        /  <- memory bound: slope = per-synop memory latency
              |   x   /
              | x    /          x = traffic-bound workloads (above the line)
              |     /
              |____/______      <- compute floor: c_act * max activation
              |                    computes of any core (variable height)
              +------------------> max per-core synops ("intensity")

A workload's position relative to the floorline fully determines its
bottleneck state and the optimization move (§VI-A a/b/c):

  (a) on the slope  -> memory-bound  -> raise sparsity or partition the
                                        synop-bottleneck layer (down-left),
  (b) on the floor  -> compute-bound -> partition the act-compute-bottleneck
                                        layer (straight down),
  (c) above the line-> traffic-bound -> raise activation sparsity, coagulate
                                        cores, or improve the mapping (down).

The same model shape is reused for TPU programs by
:mod:`repro.core.tpu_floorline` (terms become HBM bytes / FLOPs / collective
bytes per chip).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

from repro.core.analytical import Bottleneck


@dataclasses.dataclass(frozen=True)
class WorkloadPoint:
    """One measured workload configuration, placed on the floorline.

    ``max_synops``/``max_acts`` are per-timestep maxima over active neurocores
    (the M0 neurocore-aware metrics); ``time`` is measured timestep duration;
    ``energy`` is optional measured energy/step.
    """

    max_synops: float
    max_acts: float
    time: float
    energy: float = float("nan")
    label: str = ""


@dataclasses.dataclass(frozen=True)
class OptimizationMove:
    """An actionable optimization recommendation (§VI-A bottom)."""

    state: Bottleneck
    action: str
    direction: str   # movement on the floorline plot


_MOVES = {
    Bottleneck.MEMORY: OptimizationMove(
        Bottleneck.MEMORY,
        action=("reduce max per-core synops: increase weight/activation "
                "sparsity or partition the synop-bottleneck layer"),
        direction="down-left along the memory slope",
    ),
    Bottleneck.COMPUTE: OptimizationMove(
        Bottleneck.COMPUTE,
        action=("reduce max per-core activation computes: partition the "
                "compute-bottleneck layer"),
        direction="straight down (lowers the floor)",
    ),
    Bottleneck.TRAFFIC: OptimizationMove(
        Bottleneck.TRAFFIC,
        action=("reduce NoC traffic: increase activation sparsity, coagulate "
                "into fewer cores, or improve the neurocore mapping"),
        direction="down toward the floorline",
    ),
}


@dataclasses.dataclass
class FloorlineModel:
    """Fitted floorline: time = max(mem_latency*S_max, act_latency*A_max) + t0.

    ``mem_latency``  — seconds per synop on the bottleneck core (the slope),
    ``act_latency``  — seconds per activation compute (sets the floor height
                       together with the workload's max per-core acts),
    ``t0``           — fixed per-timestep overhead (barrier sync etc.),
    ``traffic_tol``  — relative excess over the predicted bound beyond which a
                       point is classified traffic-bound (above the line).
    """

    mem_latency: float
    act_latency: float
    t0: float = 0.0
    traffic_tol: float = 0.25

    # ---------------------------------------------------------------- bounds
    def memory_bound(self, max_synops: float) -> float:
        return self.mem_latency * max_synops + self.t0

    def compute_floor(self, max_acts: float) -> float:
        return self.act_latency * max_acts + self.t0

    def predicted_time(self, max_synops: float, max_acts: float) -> float:
        """The floorline bound: pipelined stages overlap, so the slowest
        stage of the slowest core sets the timestep (§VI-A assumptions)."""
        return max(self.mem_latency * max_synops,
                   self.act_latency * max_acts) + self.t0

    # ---------------------------------------------------------- classification
    def classify(self, point: WorkloadPoint) -> Bottleneck:
        """Place a workload on the floorline -> bottleneck state (a)/(b)/(c)."""
        bound = self.predicted_time(point.max_synops, point.max_acts)
        if point.time > bound * (1.0 + self.traffic_tol):
            return Bottleneck.TRAFFIC
        mem_term = self.mem_latency * point.max_synops
        act_term = self.act_latency * point.max_acts
        return Bottleneck.MEMORY if mem_term >= act_term else Bottleneck.COMPUTE

    def recommend(self, point: WorkloadPoint) -> OptimizationMove:
        return _MOVES[self.classify(point)]

    def efficiency(self, point: WorkloadPoint) -> float:
        """Fraction of the floorline bound achieved (<=1 on/below the line)."""
        return self.predicted_time(point.max_synops, point.max_acts) / max(point.time, 1e-30)


def fit_floorline(points: Sequence[WorkloadPoint], *, n_iters: int = 50,
                  traffic_tol: float = 0.25) -> FloorlineModel:
    """Fit (mem_latency, act_latency, t0) from profiled workload points by
    alternating assignment: assign each point to its dominant term, then
    least-squares each term on its assigned points.  Traffic-bound outliers
    (far above the current bound) are excluded from the fit, mirroring how
    the paper draws boundaries from the lower envelope of measurements.
    """
    if not points:
        raise ValueError("need at least one point to fit a floorline")
    s = np.asarray([p.max_synops for p in points], dtype=np.float64)
    a = np.asarray([p.max_acts for p in points], dtype=np.float64)
    t = np.asarray([p.time for p in points], dtype=np.float64)

    # Initial guesses from extreme points.
    t0 = float(np.min(t)) * 0.1
    hi = int(np.argmax(s))
    mem = max((t[hi] - t0) / max(s[hi], 1e-30), 1e-30)
    lo = int(np.argmin(s))
    act = max((t[lo] - t0) / max(a[lo], 1e-30), 1e-30)

    for _ in range(n_iters):
        mem_term = mem * s
        act_term = act * a
        bound = np.maximum(mem_term, act_term) + t0
        keep = t <= bound * (1.0 + traffic_tol)          # drop traffic outliers
        if not np.any(keep):
            keep = np.ones_like(t, dtype=bool)
        mem_pts = keep & (mem_term >= act_term)
        act_pts = keep & ~mem_pts
        new_mem, new_act = mem, act
        if np.any(mem_pts) and np.sum(s[mem_pts] ** 2) > 0:
            new_mem = float(np.sum((t[mem_pts] - t0) * s[mem_pts])
                            / np.sum(s[mem_pts] ** 2))
        if np.any(act_pts) and np.sum(a[act_pts] ** 2) > 0:
            new_act = float(np.sum((t[act_pts] - t0) * a[act_pts])
                            / np.sum(a[act_pts] ** 2))
        new_mem = max(new_mem, 1e-30)
        new_act = max(new_act, 1e-30)
        if math.isclose(new_mem, mem, rel_tol=1e-9) and math.isclose(new_act, act, rel_tol=1e-9):
            mem, act = new_mem, new_act
            break
        mem, act = new_mem, new_act

    return FloorlineModel(mem_latency=mem, act_latency=act, t0=t0,
                          traffic_tol=traffic_tol)


def floorline_curve(model: FloorlineModel, max_acts: float,
                    synops_range: tuple[float, float], n: int = 64,
                    ) -> tuple[np.ndarray, np.ndarray]:
    """Sample the floorline boundary for plotting/reporting: the memory slope
    clipped below by the compute floor for a given max-acts workload."""
    xs = np.geomspace(max(synops_range[0], 1.0), max(synops_range[1], 2.0), n)
    ys = np.maximum(model.mem_latency * xs, model.act_latency * max_acts) + model.t0
    return xs, ys
