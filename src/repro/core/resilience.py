"""Fault-tolerance layer for the search/pricing stack.

The evolutionary mapping search is the repo's long-running job: a pop=1024,
50-generation run prices ~50k candidates, and before this module a crash at
generation 40 lost everything, a failed jit/compile aborted the run, and a
single NaN pricing row silently poisoned ``pareto_ranks`` (NaN comparisons
are all False, so a NaN row is never dominated and ranks 0).  Four
primitives fix that, shared by both generation engines
(:func:`repro.core.search.evolutionary_search` and
:mod:`repro.core.device_search`):

* :class:`SearchCheckpointer` — crash-safe per-generation snapshots on the
  atomic ``os.replace`` + versioned ``step_<N>.npz`` layout of
  :mod:`repro.train.checkpoint`.  Each snapshot is **self-contained**: the
  JSON meta (history, RNG state, eval ledger) rides inside the ``.npz``
  next to the arrays it describes, so a crash between the npz replace and
  the ``meta.json`` replace can never pair new arrays with stale meta.
  Resume is bit-identical to the uninterrupted run (``docs/robustness.md``).
* :class:`FallbackChain` — graceful pricing degradation
  ``device -> vmap -> numpy`` with structured retry/backoff.  The three
  population backends agree at float64 roundoff, so a mid-run demotion
  changes the trajectory by at most rtol=1e-9 against a numpy-only run.
* :func:`quarantine_rows` — non-finite screening: NaN/inf (time, energy)
  rows get sentinel-worst ``+inf`` fitness, so they lose tournaments and
  survival deterministically; finite rows keep their exact values and
  relative order.
* :class:`FaultPlan` — the deterministic fault-injection harness: scripted
  exception throws per backend site (``"device"`` / ``"vmap"`` /
  ``"numpy"`` for pricing, ``"device"`` / ``"sharded"`` for the jitted
  generation engines), scripted NaN pricing rows, and a simulated kill
  after generation ``g`` (:class:`SimulatedCrash`), raised only after the
  generation's checkpoint landed — the crash model the resume tests replay.

All three generation engines (numpy / device / sharded) write the same
self-contained snapshot layout and validate it on resume through
:func:`validate_resume_meta`: the engine tag must match, and any
engine-specific run configuration recorded in the meta (the sharded
engine's ``n_islands`` / ``migrate_every`` / ``n_migrants`` — resuming on
a different mesh would silently change the PRNG contract and the
migration ring) must match the resuming run's settings.
"""

from __future__ import annotations

import dataclasses
import json
import logging
import os
import time

import numpy as np

log = logging.getLogger("repro.resilience")

#: sentinel fitness for quarantined rows: +inf never dominates a finite row
#: and sorts after every finite (rank, time, energy) key.
QUARANTINE_SENTINEL = float("inf")

#: "fail this site forever" budget for :class:`FaultPlan` (any count larger
#: than the total number of pricing calls behaves identically).
ALWAYS = 1 << 30


class InjectedFault(RuntimeError):
    """A scripted backend failure thrown by a :class:`FaultPlan` — stands in
    for a jit/compile error, a device OOM, or a runtime pricing fault."""


class SimulatedCrash(BaseException):
    """A scripted process kill (:attr:`FaultPlan.kill_after_gen`).

    Derives from ``BaseException`` on purpose: a real ``kill -9`` is not
    catchable, so no retry/fallback handler in this module (or in user
    code catching ``Exception``) may absorb it — it must unwind to the
    test harness exactly like the crash it models."""


# ------------------------------------------------------------ fault plans

@dataclasses.dataclass
class FaultPlan:
    """Deterministic, scripted fault schedule for one search run.

    ``fail`` maps a *site* (a pricing-backend name — ``"device"`` /
    ``"vmap"`` / ``"numpy"`` — or the device engine's step, also
    ``"device"``) to a count: the first that-many :meth:`check` calls at
    the site raise :class:`InjectedFault` (use :data:`ALWAYS` for a
    permanent outage).  ``nan_rows`` maps a global pricing-call index
    (0-based, counted by :meth:`corrupt` over successful population
    pricings) to the row indices whose (time, energy) become NaN — the
    corruption survives retries, which model transport faults, not data
    faults.  ``kill_after_gen`` raises :class:`SimulatedCrash` from
    :meth:`after_generation` once that generation (and its checkpoint) has
    completed."""

    fail: dict = dataclasses.field(default_factory=dict)
    nan_rows: dict = dataclasses.field(default_factory=dict)
    kill_after_gen: int | None = None
    calls: int = 0          # successful population pricings seen so far

    def check(self, site: str) -> None:
        """Raise :class:`InjectedFault` while the site's budget lasts."""
        n = int(self.fail.get(site, 0))
        if n > 0:
            self.fail[site] = n - 1
            raise InjectedFault(f"injected failure at site {site!r}")

    def corrupt(self, reports: list) -> list:
        """Apply this pricing call's scripted NaN rows (in place) and
        advance the call counter."""
        rows = self.nan_rows.get(self.calls, ())
        self.calls += 1
        for k in rows:
            if 0 <= int(k) < len(reports):
                r = reports[int(k)]
                r.time_per_step = float("nan")
                r.energy_per_step = float("nan")
        return reports

    def corrupt_arrays(self, times, energies):
        """Array-form :meth:`corrupt` for pricers that hand back stacked
        objectives instead of report lists (the device engine's host
        mirror): same schedule, same call counter."""
        rows = [int(k) for k in self.nan_rows.get(self.calls, ())]
        self.calls += 1
        if rows:
            times = np.asarray(times, np.float64).copy()
            energies = np.asarray(energies, np.float64).copy()
            for k in rows:
                if 0 <= k < times.shape[0]:
                    times[k] = energies[k] = float("nan")
        return times, energies

    def after_generation(self, gen: int) -> None:
        """Kill the run (once) after generation ``gen`` completed."""
        if self.kill_after_gen is not None and gen >= self.kill_after_gen:
            self.kill_after_gen = None
            raise SimulatedCrash(f"injected kill after generation {gen}")


# --------------------------------------------------------- fallback chain

@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Structured retry before demotion: ``max_retries`` extra attempts per
    backend, sleeping ``backoff_s * multiplier**attempt`` between them
    (default: one immediate retry — transient faults clear, persistent
    ones demote fast)."""

    max_retries: int = 1
    backoff_s: float = 0.0
    multiplier: float = 2.0


@dataclasses.dataclass(frozen=True)
class Demotion:
    """One logged fallback-chain demotion record."""

    site: str       # where it happened ("population pricing", "step", ...)
    frm: str        # backend given up on
    to: str         # backend demoted to
    error: str      # repr of the final exception at ``frm``
    retries: int    # attempts burned at ``frm`` beyond the first


class FallbackChain:
    """Sticky pricing-backend degradation ``device -> vmap -> numpy``.

    :meth:`run` calls ``attempt(backend)`` with the current backend,
    retrying per the :class:`RetryPolicy`; when a backend's retries are
    exhausted it demotes to the next link (logged, recorded in
    :attr:`demotions`) and stays there — a failed compile will fail again,
    so flapping back is pointless.  The numpy reference backend is the last
    link; its failure propagates.  :class:`SimulatedCrash` is never
    absorbed (it models ``kill -9``)."""

    CHAIN = ("device", "vmap", "numpy")

    def __init__(self, backend: str = "numpy",
                 retry: RetryPolicy | None = None):
        self.backend = str(backend)
        self.retry = retry or RetryPolicy()
        self.demotions: list[Demotion] = []

    def _next(self) -> str | None:
        if self.backend in self.CHAIN:
            i = self.CHAIN.index(self.backend) + 1
            if i < len(self.CHAIN):
                return self.CHAIN[i]
        return None

    def run(self, attempt, *, site: str = "population pricing"):
        while True:
            delay = self.retry.backoff_s
            last: Exception | None = None
            for a in range(self.retry.max_retries + 1):
                if a and delay > 0:
                    time.sleep(delay)
                    delay *= self.retry.multiplier
                try:
                    return attempt(self.backend)
                except Exception as e:      # noqa: BLE001 — the whole point
                    last = e
            nxt = self._next()
            if nxt is None:
                raise last
            d = Demotion(site=site, frm=self.backend, to=nxt,
                         error=repr(last), retries=self.retry.max_retries)
            self.demotions.append(d)
            log.warning("fallback: %s backend %r failed after %d retries "
                        "(%s); demoting to %r", site, d.frm, d.retries,
                        d.error, d.to)
            self.backend = nxt


# --------------------------------------------------- non-finite quarantine

def quarantine_rows(xp, times, energies):
    """Screen per-candidate objectives for NaN/inf.

    Returns ``(times, energies, bad)`` where rows with a non-finite time
    *or* energy carry the sentinel-worst fitness ``(+inf, +inf)`` and
    ``bad`` marks them.  Finite rows are returned bit-unchanged, so
    rankings restricted to finite rows match the unscreened ordering
    exactly.  ``xp`` is ``numpy`` or ``jax.numpy`` (jit-traceable: pure
    ``where`` masking, no data-dependent shapes)."""
    bad = ~(xp.isfinite(times) & xp.isfinite(energies))
    inf = xp.asarray(QUARANTINE_SENTINEL, dtype=times.dtype)
    return xp.where(bad, inf, times), xp.where(bad, inf, energies), bad


def finite_mean(xp, values):
    """Mean over the finite entries (``+inf`` when none are finite) — the
    quarantine-safe ``mean_time`` statistic.  Equals ``values.mean()``
    bit-for-bit when everything is finite (same sum, same divisor)."""
    ok = xp.isfinite(values)
    n = ok.sum()
    total = xp.where(ok, values, 0.0).sum()
    return xp.where(n > 0, total / xp.maximum(n, 1),
                    xp.asarray(QUARANTINE_SENTINEL, dtype=values.dtype))


def validate_resume_meta(meta: dict, *, engine: str,
                         checkpoint_dir: str | None,
                         expect: dict | None = None) -> None:
    """Shared engine-tag + run-config validation for checkpoint resume.

    ``engine`` is the resuming engine's tag; ``expect`` maps meta keys to
    the values the resuming run was configured with.  Mismatches raise
    ``ValueError`` with an actionable message instead of continuing a
    trajectory that could silently diverge (a checkpoint is only
    bit-identical under the exact engine + configuration that wrote it).
    """
    got = meta.get("engine")
    if got != engine:
        raise ValueError(
            f"checkpoint in {checkpoint_dir!r} was written by the "
            f"{got!r} engine; resume it with engine={got!r}")
    for key, want in (expect or {}).items():
        have = meta.get(key)
        if have != want:
            raise ValueError(
                f"checkpoint in {checkpoint_dir!r} was written with "
                f"{key}={have!r} but this run uses {key}={want!r}; resume "
                "with the checkpoint's settings (or start a fresh run "
                "without resume=True)")


# ------------------------------------------------- serialization utilities

def encode_bytes_set(keys) -> tuple[np.ndarray, np.ndarray]:
    """A set of ``bytes`` phenotype keys -> (flat uint8 buffer, lengths),
    in sorted order (the set itself is unordered; sorting makes the
    snapshot deterministic)."""
    ordered = sorted(keys)
    buf = np.frombuffer(b"".join(ordered), np.uint8).copy() \
        if ordered else np.zeros(0, np.uint8)
    lens = np.asarray([len(k) for k in ordered], np.int64)
    return buf, lens


def decode_bytes_set(buf: np.ndarray, lens: np.ndarray) -> set:
    raw = np.asarray(buf, np.uint8).tobytes()
    out, pos = set(), 0
    for n in np.asarray(lens, np.int64):
        out.add(raw[pos:pos + int(n)])
        pos += int(n)
    return out


def rng_state(rng: np.random.Generator) -> dict:
    """JSON-serializable bit-generator state (PCG64 state dicts hold plain
    ints and strings; Python JSON handles the 128-bit ints natively)."""
    return rng.bit_generator.state


def rng_from_state(state: dict) -> np.random.Generator:
    rng = np.random.default_rng()
    if state["bit_generator"] != rng.bit_generator.state["bit_generator"]:
        raise ValueError(
            f"checkpoint RNG is {state['bit_generator']!r}; this NumPy's "
            f"default_rng is {rng.bit_generator.state['bit_generator']!r}")
    rng.bit_generator.state = state
    return rng


# ----------------------------------------------------------- checkpointer

_META_KEY = "_meta_json"


class SearchCheckpointer:
    """Crash-safe search snapshots on the ``train/checkpoint`` layout.

    ``save`` writes one self-contained ``step_<gen>.npz`` through
    :func:`repro.train.checkpoint.save` — tmp-file + atomic ``os.replace``,
    ``keep`` newest retained, ``meta.json`` updated last.  The snapshot's
    JSON meta is embedded in the npz (key ``_meta_json``) so every complete
    npz restores on its own; ``meta.json`` only carries a human-readable
    summary.  ``restore`` loads the newest complete snapshot (or an
    explicit ``step``), ignoring partial ``tmp.<N>`` writes by
    construction."""

    def __init__(self, ckpt_dir: str, *, every: int = 1, keep: int = 3):
        self.dir = str(ckpt_dir)
        self.every = max(1, int(every))
        self.keep = max(1, int(keep))

    def due(self, gen: int, generations: int) -> bool:
        """Snapshot cadence: every ``every`` generations and always the
        final one (so a finished run restores as finished)."""
        return gen % self.every == 0 or gen >= generations

    def save(self, gen: int, arrays: dict, meta: dict) -> str:
        from repro.train import checkpoint as ckpt
        state = {k: np.asarray(v) for k, v in arrays.items()}
        if _META_KEY in state:
            raise ValueError(f"array name {_META_KEY!r} is reserved")
        blob = json.dumps(meta).encode("utf-8")
        state[_META_KEY] = np.frombuffer(blob, np.uint8).copy()
        summary = {"generation": int(gen), "engine": meta.get("engine")}
        return ckpt.save(self.dir, int(gen), state, extra=summary,
                         keep=self.keep)

    def latest(self) -> int | None:
        from repro.train import checkpoint as ckpt
        if not os.path.isdir(self.dir):
            return None
        return ckpt.latest_step(self.dir)

    def restore(self, step: int | None = None):
        """-> (arrays, gen, meta) of the newest complete snapshot, or
        ``None`` when the directory holds no checkpoint yet (a resume of a
        never-started run starts fresh)."""
        step = self.latest() if step is None else int(step)
        if step is None:
            return None
        path = os.path.join(self.dir, f"step_{step:08d}.npz")
        with np.load(path) as data:
            arrays = {}
            for key in data.files:
                name = key
                # reverse train/checkpoint's flat dict-path naming:
                # {"cores": ...} flattens to the npz key "['cores']"
                if name.startswith("['") and name.endswith("']"):
                    name = name[2:-2]
                arrays[name] = data[key]
        meta = json.loads(arrays.pop(_META_KEY).tobytes().decode("utf-8"))
        log.info("restored search checkpoint %s (generation %d)", path, step)
        return arrays, step, meta
