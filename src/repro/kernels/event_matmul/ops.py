"""Public jit'd wrapper for the block-sparse event-driven matmul."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.event_matmul.kernel import event_matmul_pallas
from repro.kernels.event_matmul.ref import block_activity_ref


def _pad_to(a: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(a.shape, mult)]
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads)
    return a


def block_activity(x: jax.Array, threshold: float, bm: int = 128,
                   bk: int = 128) -> jax.Array:
    """(Mb, Kb) bool activity map (pads x up to tile multiples)."""
    x = _pad_to(x, (bm, bk))
    return block_activity_ref(x, threshold, bm, bk)


def _compact_indices(active: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per m-block, compact active k-block indices to the front.

    Returns (idx (Mb, Kb) int32, cnt (Mb,) int32).  Padding entries repeat
    the last active index (or 0 when a row is fully inactive) so the kernel's
    index map revisits an already-resident tile instead of DMA'ing a new one.
    """
    mb, kb = active.shape
    order = jnp.argsort(~active, axis=1, stable=True)     # actives first
    cnt = active.sum(axis=1).astype(jnp.int32)
    pos = jnp.arange(kb)[None, :]
    last = jnp.maximum(cnt - 1, 0)[:, None]
    idx = jnp.where(pos < cnt[:, None], order,
                    jnp.take_along_axis(order, last, axis=1))
    return idx.astype(jnp.int32), cnt


@functools.partial(jax.jit, static_argnames=("threshold", "bm", "bk", "bn",
                                             "interpret"))
def event_matmul(x: jax.Array, w: jax.Array, *, threshold: float = 0.0,
                 bm: int = 128, bk: int = 128, bn: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """``y = x @ w`` skipping event-free (bm, bk) activation tiles.

    The paper's synop accumulation adapted to the TPU memory hierarchy:
    weight tiles for event-free activation tiles are never DMA'd into VMEM
    and never touch the MXU.  Unstructured *element* sparsity inside an
    active tile is not exploited (matching the paper's CNN dense-format
    finding — structure is required for real fetch savings; on TPU the
    structure is the 128-tile).

    Args:
      x: (M, K) activations (any float dtype).
      w: (K, N) weights.
      threshold: |x| <= threshold counts as "no event".
      bm/bk/bn: VMEM tile sizes; MXU-aligned 128s by default.
      interpret: force Pallas interpret mode (auto: on for CPU backends).

    Returns: (M, N) in x.dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    active = block_activity_ref(xp, threshold, bm, bk)
    idx, cnt = _compact_indices(active)
    out = event_matmul_pallas(xp, wp, idx, cnt, bm=bm, bk=bk, bn=bn,
                              out_dtype=x.dtype, interpret=interpret)
    return out[:M, :N]
