"""Public jit'd wrapper for the block-sparse event-driven matmul."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.event_matmul.kernel import (event_matmul2_pallas,
                                               event_matmul_pallas)
from repro.kernels.event_matmul.ref import block_activity_ref


def _pad_to(a: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(a.shape, mult)]
    if any(p[1] for p in pads):
        a = jnp.pad(a, pads)
    return a


def block_activity(x: jax.Array, threshold: float, bm: int = 128,
                   bk: int = 128) -> jax.Array:
    """(Mb, Kb) bool activity map.

    Accepts either raw or already tile-aligned ``x``: ``_pad_to`` is a no-op
    on aligned inputs, so callers that pad for the kernel share one pad with
    this helper instead of paying a second copy.
    """
    x = _pad_to(x, (bm, bk))
    return block_activity_ref(x, threshold, bm, bk)


def pad_compact(x: jax.Array, threshold: float, bm: int = 128,
                bk: int = 128) -> tuple[jax.Array, jax.Array, jax.Array,
                                        jax.Array]:
    """One pad, one activity map, one compaction — shared by every consumer.

    Returns ``(xp, active, idx, cnt)``: the (bm, bk)-aligned operand, its
    (Mb, Kb) bool activity map, and the compacted per-m-block active
    k-tile indices + counts the kernel's scalar prefetch consumes.  This is
    the single entry point through which :func:`block_activity` and
    :func:`event_matmul` (and the simulator's event compute backend) derive
    their tile structures, so no caller ever pays a second pad.
    """
    xp = _pad_to(x, (bm, bk))
    active = block_activity_ref(xp, threshold, bm, bk)
    idx, cnt = _compact_indices(active)
    return xp, active, idx, cnt


def weight_block_occupancy(w: jax.Array, bk: int = 128,
                           bn: int = 128) -> jax.Array:
    """(Kb, Nb) bool block-CSR occupancy map: tile holds >= 1 nonzero weight.

    The host-side half of 2-D (activation x weight) sparsity: computed once
    per layer from the immutable weight mask, padded to the kernel's tile
    grid (padding tiles are all-zero, hence unoccupied), and intersected
    with the per-m-block activity lists by :func:`event_matmul` /
    :func:`event_matmul_pair` so all-zero weight tiles drive no DMA and no
    MXU issue.  Accepts the weights themselves or a 0/1 mask — occupancy is
    ``any(w != 0)`` either way.
    """
    wp = _pad_to(jnp.asarray(w), (bk, bn))
    K, N = wp.shape
    tiles = (wp != 0).reshape(K // bk, bk, N // bn, bn)
    return tiles.any(axis=(1, 3))


def _compact_indices_joint(active: jax.Array,
                           w_occ: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Intersect per-m-block activity with weight-tile occupancy.

    ``active`` (Mb, Kb) bool, ``w_occ`` (Kb, Nb) bool.  Returns the 2-D
    kernel's scalar-prefetch structure: ``idx`` (Mb, Nb, Kb) int32 compacted
    k lists per (m, n) block pair and ``cnt`` (Mb, Nb) int32 live counts —
    a k step survives only when the activation tile has an event AND the
    weight tile has a nonzero.  Reuses the stable cumsum compaction of
    :func:`_compact_indices` over the flattened (Mb * Nb) leading axis.
    """
    mb, kb = active.shape
    kb2, nb = w_occ.shape
    assert kb == kb2, (active.shape, w_occ.shape)
    joint = active[:, None, :] & w_occ.T[None, :, :]      # (Mb, Nb, Kb)
    idx, cnt = _compact_indices(joint.reshape(mb * nb, kb))
    return idx.reshape(mb, nb, kb), cnt.reshape(mb, nb)


def _compact_indices(active: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per m-block, compact active k-block indices to the front.

    Returns (idx (Mb, Kb) int32, cnt (Mb,) int32).  Padding entries repeat
    the last active index (or 0 when a row is fully inactive) so the kernel's
    index map revisits an already-resident tile instead of DMA'ing a new one.

    Stable cumsum compaction: each active column's destination slot is its
    running count minus one (O(Mb*Kb) scatter instead of an O(Kb log Kb)
    per-row argsort).
    """
    mb, kb = active.shape
    cum = jnp.cumsum(active, axis=1)
    cnt = cum[:, -1].astype(jnp.int32)
    # inactive columns scatter into an overflow slot that is sliced away
    dest = jnp.where(active, cum - 1, kb)
    rows = jnp.broadcast_to(jnp.arange(mb)[:, None], (mb, kb))
    cols = jnp.broadcast_to(jnp.arange(kb)[None, :], (mb, kb))
    idx = (jnp.zeros((mb, kb + 1), jnp.int32)
           .at[rows, dest].set(cols.astype(jnp.int32))[:, :kb])
    pos = jnp.arange(kb)[None, :]
    last = jnp.take_along_axis(idx, jnp.maximum(cnt - 1, 0)[:, None], axis=1)
    idx = jnp.where(pos < cnt[:, None], idx, last)
    return idx, cnt


@functools.partial(jax.jit, static_argnames=("threshold", "bm", "bk", "bn",
                                             "interpret"))
def event_matmul(x: jax.Array, w: jax.Array, w_occ: jax.Array | None = None,
                 *, threshold: float = 0.0,
                 bm: int = 128, bk: int = 128, bn: int = 128,
                 interpret: bool | None = None) -> jax.Array:
    """``y = x @ w`` skipping event-free (bm, bk) activation tiles.

    The paper's synop accumulation adapted to the TPU memory hierarchy:
    weight tiles for event-free activation tiles are never DMA'd into VMEM
    and never touch the MXU.  Unstructured *element* sparsity inside an
    active tile is not exploited (matching the paper's CNN dense-format
    finding — structure is required for real fetch savings; on TPU the
    structure is the 128-tile).

    With ``w_occ`` (the (Kb, Nb) block-CSR occupancy from
    :func:`weight_block_occupancy`), sparsity goes 2-D: a (k, n) weight
    tile that is all-zero is skipped even when the activation tile is
    active, so work scales with ``act_density x weight_block_density``.
    Skipping an all-zero tile is exact — its contribution is an exact zero.

    Args:
      x: (M, K) activations (any float dtype).
      w: (K, N) weights.
      w_occ: optional (Kb, Nb) bool weight-tile occupancy (padded grid).
      threshold: |x| <= threshold counts as "no event".
      bm/bk/bn: VMEM tile sizes; MXU-aligned 128s by default.
      interpret: force Pallas interpret mode (auto: on for CPU backends).

    Returns: (M, N) in x.dtype.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    K2, N = w.shape
    if K != K2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {w.shape}")
    xp, active, idx, cnt = pad_compact(x, threshold, bm, bk)
    wp = _pad_to(w, (bk, bn))
    if w_occ is None:
        out = event_matmul_pallas(xp, wp, idx, cnt, bm=bm, bk=bk, bn=bn,
                                  out_dtype=x.dtype, interpret=interpret)
    else:
        idx2, cnt2 = _compact_indices_joint(active, w_occ)
        out = event_matmul2_pallas(xp, wp, idx2, cnt2, bm=bm, bk=bk, bn=bn,
                                   out_dtype=x.dtype, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("threshold", "bm", "bk", "bn",
                                             "interpret"))
def event_matmul_pair(x: jax.Array, m: jax.Array, w: jax.Array,
                      wm: jax.Array, w_occ: jax.Array | None = None,
                      *, threshold: float = 0.0,
                      bm: int = 128, bk: int = 128, bn: int = 128,
                      interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Batched (T, ·) entry point for the simulator's event backend: the
    value matmul ``x @ w`` and the counter matmul ``m @ wm`` as ONE jitted
    program, each skipping its own event-free (bm, bk) tiles.

    ``x`` is the effective activation block (pre-activation GEMM input) and
    ``m`` its 0/1 wire-event mask; the two share a sparsity pattern only
    when no delta reconstruction is in play, so each operand gets its own
    :func:`pad_compact` — but both kernel launches, both pads and both
    compactions fuse into a single compiled program (one dispatch per
    simulated layer instead of two).

    With ``w_occ``, BOTH matmuls run through the 2-D joint-sparsity kernel
    with the same weight-tile occupancy: ``wm`` is the nnz mask of ``w``,
    so a tile that is all-zero in one is all-zero in the other — the value
    and counter contractions skip exactly the same (k, n) tiles, which is
    what keeps the event counters bit-identical to the dense reference.

    Returns ``(y, macs)`` cropped to ``(x.shape[0], w.shape[1])``.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    M, K = x.shape
    K2, N = w.shape
    if K != K2 or m.shape != x.shape or wm.shape != w.shape:
        raise ValueError(f"shape mismatch: {x.shape}/{m.shape} @ "
                         f"{w.shape}/{wm.shape}")
    xp, xa, xi, xc = pad_compact(x, threshold, bm, bk)
    mp, ma, mi, mc = pad_compact(m, 0.0, bm, bk)
    wp = _pad_to(w, (bk, bn))
    wmp = _pad_to(wm, (bk, bn))
    if w_occ is None:
        y = event_matmul_pallas(xp, wp, xi, xc, bm=bm, bk=bk, bn=bn,
                                out_dtype=x.dtype, interpret=interpret)
        macs = event_matmul_pallas(mp, wmp, mi, mc, bm=bm, bk=bk, bn=bn,
                                   out_dtype=m.dtype, interpret=interpret)
    else:
        xi2, xc2 = _compact_indices_joint(xa, w_occ)
        mi2, mc2 = _compact_indices_joint(ma, w_occ)
        y = event_matmul2_pallas(xp, wp, xi2, xc2, bm=bm, bk=bk, bn=bn,
                                 out_dtype=x.dtype, interpret=interpret)
        macs = event_matmul2_pallas(mp, wmp, mi2, mc2, bm=bm, bk=bk, bn=bn,
                                    out_dtype=m.dtype, interpret=interpret)
    return y[:M, :N], macs[:M, :N]
