"""Pure-jnp oracle for the block-sparse event-driven matmul."""

from __future__ import annotations

import jax.numpy as jnp


def block_activity_ref(x: jnp.ndarray, threshold: float, bm: int,
                       bk: int) -> jnp.ndarray:
    """(Mb, Kb) bool: tile has at least one event (|x| > threshold).

    M and K must be multiples of (bm, bk)."""
    M, K = x.shape
    tiles = jnp.abs(x).reshape(M // bm, bm, K // bk, bk)
    return (tiles.max(axis=(1, 3)) > threshold)


def event_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, *, threshold: float,
                     bm: int, bk: int, out_dtype=None) -> jnp.ndarray:
    """Zero event-free (m, k) activation tiles, then dense matmul in f32.

    This is the exact semantic contract of the kernel: *inactive tiles are
    exact zeros; active tiles contribute fully* (sub-threshold entries inside
    an active tile still count — block granularity, not element granularity).
    """
    out_dtype = out_dtype or x.dtype
    M, K = x.shape
    active = block_activity_ref(x, threshold, bm, bk)
    mask = jnp.repeat(jnp.repeat(active, bm, axis=0), bk, axis=1)
    x_masked = jnp.where(mask, x, 0).astype(x.dtype)
    y = jnp.dot(x_masked.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def event_matmul2_ref(x: jnp.ndarray, w: jnp.ndarray, w_occ: jnp.ndarray, *,
                      threshold: float, bm: int, bk: int, bn: int,
                      out_dtype=None) -> jnp.ndarray:
    """Oracle for 2-D (activation x weight tile) sparsity.

    Semantic contract of the joint kernel: a (m, n, k) grid step contributes
    iff the activation tile is active AND the weight tile is occupied; both
    failures contribute exact zeros.  Implemented by zeroing inactive
    activation tiles and unoccupied weight tiles, then one dense f32 matmul.
    When ``w_occ`` comes from :func:`..ops.weight_block_occupancy` on ``w``
    itself the weight zeroing is a no-op (unoccupied tiles are already
    all-zero) — the generic form exists so tests can probe arbitrary
    occupancy maps, including over-claimed all-zero rows.
    """
    out_dtype = out_dtype or x.dtype
    active = block_activity_ref(x, threshold, bm, bk)
    amask = jnp.repeat(jnp.repeat(active, bm, axis=0), bk, axis=1)
    x_masked = jnp.where(amask, x, 0).astype(x.dtype)
    wmask = jnp.repeat(jnp.repeat(w_occ, bk, axis=0), bn, axis=1)
    w_masked = jnp.where(wmask, w, 0).astype(w.dtype)
    y = jnp.dot(x_masked.astype(jnp.float32), w_masked.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


def event_stats_ref(x: jnp.ndarray, threshold: float, bm: int,
                    bk: int) -> dict:
    """Block-level event statistics — the TPU analog of the paper's synop
    counters (used by the M0 metrics): active tiles = weight-tile fetches."""
    act = block_activity_ref(x, threshold, bm, bk)
    total = act.size
    active = act.sum()
    return {
        "active_blocks": active,
        "total_blocks": total,
        "block_density": active / total,
        "element_density": (jnp.abs(x) > threshold).mean(),
        "skipped_weight_bytes_frac": 1.0 - active / total,
    }
