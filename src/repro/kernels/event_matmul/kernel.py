"""Block-sparse event-driven matmul — Pallas TPU kernel.

TPU adaptation of the paper's synop accumulation (DESIGN.md §3): activation
tiles with no events (all |x| <= threshold) are compacted away on the host
side; the kernel's grid walks only a compacted index list delivered through
scalar prefetch, so inactive (m, k) tiles drive **no weight-tile DMA and no
MXU issue** — the TPU analog of "a message is only sent for a nonzero
activation, and only its weights are fetched".

Grid: (M/bm, N/bn, K/bk), k innermost.  For grid step (m, n, k):

* x tile   <- x[m*bm:(m+1)*bm, idx[m,k]*bk:...]   (compacted k index)
* w tile   <- w[idx[m,k]*bk:..., n*bn:(n+1)*bn]
* guarded accumulate into a VMEM f32 scratch when k < n_active[m]; the
  compacted index map pins padding steps to the last active tile so Mosaic's
  revisit detection elides their copies.
* the accumulator is written to the output tile on the final k step.

Block shapes default to MXU-native 128x128x128 and must keep the last axis a
multiple of 128 and the second-to-last a multiple of 8 (f32) for VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _event_matmul_kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *,
                         n_k_blocks: int, out_dtype):
    m = pl.program_id(0)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[m])
    def _accumulate():                      # skipped for event-free tiles
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def _event_matmul2_kernel(idx_ref, cnt_ref, x_ref, w_ref, o_ref, acc_ref, *,
                          n_k_blocks: int, out_dtype):
    """2-D (activation x weight tile) sparsity: the compacted k list is per
    (m, n) block pair, so a grid step is skipped when EITHER the activation
    tile is event-free OR the weight tile is all-zero."""
    m = pl.program_id(0)
    n = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(k < cnt_ref[m, n])
    def _accumulate():                      # skipped: no events or no weights
        acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_k_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def event_matmul2_pallas(x: jax.Array, w: jax.Array, idx: jax.Array,
                         cnt: jax.Array, *, bm: int, bk: int, bn: int,
                         out_dtype=None, interpret: bool = False) -> jax.Array:
    """Joint-sparsity launch.  ``idx`` (Mb, Nb, Kb) int32 holds, per (m, n)
    block pair, the compacted k-block indices live in BOTH the activation
    row (tile has an event) and the weight column (tile has a nonzero
    weight); ``cnt`` (Mb, Nb) int32 holds the live counts.  Padding entries
    repeat the last live index so Mosaic's revisit detection elides their
    copies, exactly like the 1-D kernel."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    mb, kb, nb = M // bm, K // bk, N // bn
    assert idx.shape == (mb, nb, kb) and cnt.shape == (mb, nb)
    out_dtype = out_dtype or x.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mb, nb, kb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k, idx, cnt: (m, idx[m, n, k])),
            pl.BlockSpec((bk, bn), lambda m, n, k, idx, cnt: (idx[m, n, k], n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, idx, cnt: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_event_matmul2_kernel, n_k_blocks=kb,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
        name="event_matmul2",
    )(idx, cnt, x, w)


def event_matmul_pallas(x: jax.Array, w: jax.Array, idx: jax.Array,
                        cnt: jax.Array, *, bm: int, bk: int, bn: int,
                        out_dtype=None, interpret: bool = False) -> jax.Array:
    """Launch the kernel.  ``idx`` (Mb, Kb) int32 holds, per m-block, the
    compacted active k-block indices (padding entries repeat the last active
    index); ``cnt`` (Mb,) int32 holds the active counts.  All of M, K, N must
    already be padded to multiples of (bm, bk, bn)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0
    mb, kb, nb = M // bm, K // bk, N // bn
    assert idx.shape == (mb, kb) and cnt.shape == (mb,)
    out_dtype = out_dtype or x.dtype

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(mb, nb, kb),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k, idx, cnt: (m, idx[m, k])),
            pl.BlockSpec((bk, bn), lambda m, n, k, idx, cnt: (idx[m, k], n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k, idx, cnt: (m, n)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    kernel = functools.partial(_event_matmul_kernel, n_k_blocks=kb,
                               out_dtype=out_dtype)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        interpret=interpret,
        name="event_matmul",
    )(idx, cnt, x, w)
