"""Pallas TPU kernels for the paper's compute hot-spots.

The paper's dominant operation is event-driven synop accumulation: each
active input message triggers a sparse weight fetch + accumulate.  TPUs have
no efficient element-granular event path (the MXU wants dense 128x128 tiles),
so the TPU-native adaptation is **block-granular** event-driven execution
(see DESIGN.md §3):

* ``event_matmul`` — block-sparse activation matmul: (m, k) tiles of the
  activation whose entries are all below threshold skip both the weight-tile
  fetch (HBM->VMEM DMA via scalar-prefetch index compaction) and the MXU
  tile.  This is the synop-accumulation kernel.  With a block-CSR
  weight-tile occupancy map (``weight_block_occupancy``) sparsity goes 2-D:
  (k, n) weight tiles that are all-zero are skipped too, so work scales
  with ``act_density x weight_block_density``.
* ``sigma_delta`` — fused sigma-delta encoder (delta, threshold, quantize,
  state update) producing the sparse message stream the paper's PilotNet
  workload relies on [34], [46], plus ``window_reconstruct`` — temporal-tile
  delta reconstruction (per-window carried accumulator + within-window
  cumsum) replacing the dense time cumsum so quiet windows compact away
  before the matmul ever sees them.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper with padding/validation) and ``ref.py`` (pure-jnp
oracle used by the test sweeps).
"""

from repro.kernels.event_matmul.ops import (block_activity, event_matmul,
                                            event_matmul_pair, pad_compact,
                                            weight_block_occupancy)
from repro.kernels.sigma_delta.ops import (sigma_delta_encode,
                                           window_reconstruct)

__all__ = ["event_matmul", "event_matmul_pair", "block_activity",
           "pad_compact", "weight_block_occupancy", "sigma_delta_encode",
           "window_reconstruct"]
