"""Pure-jnp oracle for the fused sigma-delta encoder."""

from __future__ import annotations

import jax.numpy as jnp


def sigma_delta_ref(a: jnp.ndarray, s: jnp.ndarray, *, theta: float
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference semantics (f32 math, cast back to input dtypes)."""
    a32 = a.astype(jnp.float32)
    s32 = s.astype(jnp.float32)
    delta = a32 - s32
    q = jnp.where(jnp.abs(delta) >= theta,
                  jnp.round(delta / theta) * theta, 0.0)
    return q.astype(a.dtype), (s32 + q).astype(s.dtype)
