"""Pure-jnp oracle for the fused sigma-delta encoder."""

from __future__ import annotations

import jax.numpy as jnp


def sigma_delta_ref(a: jnp.ndarray, s: jnp.ndarray, *, theta: float
                    ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference semantics (f32 math, cast back to input dtypes)."""
    a32 = a.astype(jnp.float32)
    s32 = s.astype(jnp.float32)
    delta = a32 - s32
    q = jnp.where(jnp.abs(delta) >= theta,
                  jnp.round(delta / theta) * theta, 0.0)
    return q.astype(a.dtype), (s32 + q).astype(s.dtype)


def window_reconstruct_ref(x: jnp.ndarray, acc: jnp.ndarray, *, window: int
                           ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Pure-jnp oracle for windowed delta reconstruction.

    Decomposes the running reconstruction ``x_eff = acc + cumsum(x, time)``
    into temporal tiles: per-window base vectors (the carried accumulator at
    each window start) plus within-window cumulative sums, so that

        x_eff[t] == bases[t // window] + xwin[t]

    up to float reassociation.  Returns ``(bases (nw, n), xwin (T, n),
    new_acc (n,))`` where ``new_acc`` is the accumulator after the batch.
    """
    T, n = x.shape
    pt = (-T) % window
    xp = jnp.pad(x, ((0, pt), (0, 0)))
    xw = xp.reshape(-1, window, n)
    ws = xw.sum(axis=1)                              # per-window totals
    csum = jnp.cumsum(ws, axis=0)
    bases = acc[None, :] + jnp.concatenate(
        [jnp.zeros((1, n), csum.dtype), csum[:-1]], axis=0)
    xwin = jnp.cumsum(xw, axis=1).reshape(-1, n)[:T]
    new_acc = acc + csum[-1]
    return bases.astype(x.dtype), xwin.astype(x.dtype), new_acc.astype(x.dtype)
