"""Public jit'd wrapper for the fused sigma-delta encoder."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sigma_delta.kernel import sigma_delta_pallas


@functools.partial(jax.jit, static_argnames=("theta", "bm", "bd", "interpret"))
def sigma_delta_encode(a: jax.Array, s: jax.Array, *, theta: float,
                       bm: int = 256, bd: int = 512,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Sigma-delta encode activations against reconstruction state.

    Args:
      a: (..., D) new activations.
      s: (..., D) reconstruction state (what downstream has accumulated).
      theta: sigma-delta threshold (> 0).
    Returns:
      (q, s_new): quantized delta messages (sparse; mostly zeros for slowly
      varying inputs) and the updated state s + q.
    """
    if theta <= 0:
        raise ValueError("theta must be positive")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = a.shape
    a2 = a.reshape(-1, shape[-1])
    s2 = s.reshape(-1, shape[-1])
    M, D = a2.shape
    pm, pd = (-M) % bm, (-D) % bd
    if pm or pd:
        a2 = jnp.pad(a2, ((0, pm), (0, pd)))
        s2 = jnp.pad(s2, ((0, pm), (0, pd)))
    q, s_new = sigma_delta_pallas(a2, s2, theta=theta, bm=bm, bd=bd,
                                  interpret=interpret)
    return (q[:M, :D].reshape(shape), s_new[:M, :D].reshape(shape))
