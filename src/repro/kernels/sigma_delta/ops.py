"""Public jit'd wrapper for the fused sigma-delta encoder."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sigma_delta.kernel import (sigma_delta_pallas,
                                              window_cumsum_pallas)


@functools.partial(jax.jit, static_argnames=("theta", "bm", "bd", "interpret"))
def sigma_delta_encode(a: jax.Array, s: jax.Array, *, theta: float,
                       bm: int = 256, bd: int = 512,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Sigma-delta encode activations against reconstruction state.

    Args:
      a: (..., D) new activations.
      s: (..., D) reconstruction state (what downstream has accumulated).
      theta: sigma-delta threshold (> 0).
    Returns:
      (q, s_new): quantized delta messages (sparse; mostly zeros for slowly
      varying inputs) and the updated state s + q.
    """
    if theta <= 0:
        raise ValueError("theta must be positive")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    shape = a.shape
    a2 = a.reshape(-1, shape[-1])
    s2 = s.reshape(-1, shape[-1])
    M, D = a2.shape
    pm, pd = (-M) % bm, (-D) % bd
    if pm or pd:
        a2 = jnp.pad(a2, ((0, pm), (0, pd)))
        s2 = jnp.pad(s2, ((0, pm), (0, pd)))
    q, s_new = sigma_delta_pallas(a2, s2, theta=theta, bm=bm, bd=bd,
                                  interpret=interpret)
    return (q[:M, :D].reshape(shape), s_new[:M, :D].reshape(shape))


@functools.partial(jax.jit, static_argnames=("window", "bd", "interpret"))
def window_reconstruct(x: jax.Array, acc: jax.Array, *, window: int,
                       bd: int = 512, interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Windowed delta reconstruction — the temporal-tile replacement for the
    dense ``cumsum`` over the time axis of a sigma-delta input stream.

    Splits the (T, n) delta batch into ``window``-step temporal tiles and
    returns ``(bases, xwin, new_acc)`` with ``x_eff[t] == bases[t // window]
    + xwin[t]`` (see :func:`..ref.window_reconstruct_ref`): the per-window
    carried accumulators, the within-window cumulative sums (exact zeros
    throughout quiet windows, computed by the Pallas kernel which skips the
    cumsum matmul for windows with no events), and the accumulator to carry
    into the next batch.  Downstream, ``xwin`` feeds the event matmul —
    where its quiet windows compact away — and the ``T/window`` base rows
    pay one small dense contraction.

    ``window`` must be a multiple of 8 (f32 sublane tiling).
    """
    if window % 8:
        raise ValueError(f"window must be a multiple of 8, got {window}")
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    T, n = x.shape
    pt = (-T) % window
    xp = jnp.pad(x.astype(jnp.float32), ((0, pt), (0, 0)))
    xw = xp.reshape(-1, window, n)
    ws = xw.sum(axis=1)                              # per-window totals
    csum = jnp.cumsum(ws, axis=0)
    bases = acc[None, :] + jnp.concatenate(
        [jnp.zeros((1, n), csum.dtype), csum[:-1]], axis=0)
    new_acc = acc + csum[-1]
    live = jnp.any(xw != 0, axis=(1, 2)).astype(jnp.int32)
    bd_eff = min(bd, -(-n // 128) * 128)             # shrink for narrow layers
    pd = (-n) % bd_eff
    xpd = jnp.pad(xp, ((0, 0), (0, pd)))
    xwin = window_cumsum_pallas(xpd, live, window=window, bd=bd_eff,
                                interpret=interpret)
    return bases, xwin[:T, :n], new_acc
