"""Fused sigma-delta encoder — Pallas TPU kernel.

One VMEM pass produces the sparse delta-message stream and the updated
reconstruction state (paper workloads PilotNet [46]; sigma-delta networks
[34]).  Unfused, this is 4 HBM round-trips (delta, mask, quantize, state
add); fused it is a single elementwise tile walk:

    delta = a - s
    q     = round(delta / theta) * theta     where |delta| >= theta, else 0
    s'    = s + q

Emitting q (the message) and s' (the state) from one kernel halves HBM
traffic for the encoder — on a chip where the encoder runs every timestep
over every activation map, that is the memory-bound term of the floorline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sigma_delta_kernel(a_ref, s_ref, q_ref, s_out_ref, *, theta: float):
    a = a_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    delta = a - s
    q = jnp.where(jnp.abs(delta) >= theta,
                  jnp.round(delta / theta) * theta, 0.0)
    q_ref[...] = q.astype(q_ref.dtype)
    s_out_ref[...] = (s + q).astype(s_out_ref.dtype)


def _window_cumsum_kernel(live_ref, x_ref, o_ref):
    """Within-window cumulative sum over the time axis of one (W, bd) tile.

    The cumsum is an MXU-friendly lower-triangular ones matmul (in-kernel
    ``jnp.cumsum`` does not lower well on TPU); quiet windows — flagged by
    the scalar-prefetched ``live`` vector — skip the matmul entirely and
    write zeros, the temporal analog of the event matmul's tile skip.
    """
    i = pl.program_id(0)

    @pl.when(live_ref[i] > 0)
    def _run():
        x = x_ref[...].astype(jnp.float32)
        W = x.shape[0]
        r = jax.lax.broadcasted_iota(jnp.int32, (W, W), 0)
        c = jax.lax.broadcasted_iota(jnp.int32, (W, W), 1)
        tri = (r >= c).astype(jnp.float32)
        o_ref[...] = jnp.dot(tri, x,
                             preferred_element_type=jnp.float32
                             ).astype(o_ref.dtype)

    @pl.when(live_ref[i] == 0)
    def _quiet():
        o_ref[...] = jnp.zeros_like(o_ref)


def window_cumsum_pallas(x: jax.Array, live: jax.Array, *, window: int,
                         bd: int = 512,
                         interpret: bool = False) -> jax.Array:
    """(T, D) -> per-window cumulative sums along time.  ``T`` must be a
    multiple of ``window`` (a multiple of 8 for f32 sublane tiling), ``D``
    a multiple of ``bd``; ``live`` is the (T/window,) int32 quiet-window
    flag vector (0 -> the window's output rows are exact zeros)."""
    T, D = x.shape
    assert T % window == 0 and D % bd == 0, (x.shape, window, bd)
    assert live.shape == (T // window,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T // window, D // bd),
        in_specs=[pl.BlockSpec((window, bd), lambda i, j, live: (i, j))],
        out_specs=pl.BlockSpec((window, bd), lambda i, j, live: (i, j)),
    )
    return pl.pallas_call(
        _window_cumsum_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        interpret=interpret,
        name="window_cumsum",
    )(live, x)


def sigma_delta_pallas(a: jax.Array, s: jax.Array, *, theta: float,
                       bm: int = 256, bd: int = 512,
                       interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(M, D) activations + state -> (messages q, new state).  M, D must be
    padded to (bm, bd) multiples."""
    M, D = a.shape
    assert s.shape == (M, D)
    assert M % bm == 0 and D % bd == 0
    grid = (M // bm, D // bd)
    spec = pl.BlockSpec((bm, bd), lambda i, j: (i, j))
    kernel = functools.partial(_sigma_delta_kernel, theta=theta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((M, D), a.dtype),
                   jax.ShapeDtypeStruct((M, D), s.dtype)),
        interpret=interpret,
        name="sigma_delta_encode",
    )(a, s)
