"""Fused sigma-delta encoder — Pallas TPU kernel.

One VMEM pass produces the sparse delta-message stream and the updated
reconstruction state (paper workloads PilotNet [46]; sigma-delta networks
[34]).  Unfused, this is 4 HBM round-trips (delta, mask, quantize, state
add); fused it is a single elementwise tile walk:

    delta = a - s
    q     = round(delta / theta) * theta     where |delta| >= theta, else 0
    s'    = s + q

Emitting q (the message) and s' (the state) from one kernel halves HBM
traffic for the encoder — on a chip where the encoder runs every timestep
over every activation map, that is the memory-bound term of the floorline.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sigma_delta_kernel(a_ref, s_ref, q_ref, s_out_ref, *, theta: float):
    a = a_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    delta = a - s
    q = jnp.where(jnp.abs(delta) >= theta,
                  jnp.round(delta / theta) * theta, 0.0)
    q_ref[...] = q.astype(q_ref.dtype)
    s_out_ref[...] = (s + q).astype(s_out_ref.dtype)


def sigma_delta_pallas(a: jax.Array, s: jax.Array, *, theta: float,
                       bm: int = 256, bd: int = 512,
                       interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """(M, D) activations + state -> (messages q, new state).  M, D must be
    padded to (bm, bd) multiples."""
    M, D = a.shape
    assert s.shape == (M, D)
    assert M % bm == 0 and D % bd == 0
    grid = (M // bm, D // bd)
    spec = pl.BlockSpec((bm, bd), lambda i, j: (i, j))
    kernel = functools.partial(_sigma_delta_kernel, theta=theta)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=(spec, spec),
        out_shape=(jax.ShapeDtypeStruct((M, D), a.dtype),
                   jax.ShapeDtypeStruct((M, D), s.dtype)),
        interpret=interpret,
        name="sigma_delta_encode",
    )(a, s)
