"""Jitted wrapper for the flash-attention kernel with shape padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attn.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: int | None = None, softcap: float | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = True) -> jax.Array:
    """Pads Sq/Skv to block multiples, launches the kernel, slices back.
    Pad queries produce garbage rows that are sliced off; pad KV rows are
    masked inside the kernel via ``kv_len`` (the real key count), which
    keeps non-causal attention — encoder/cross blocks lowered by the
    model-zoo frontend — exact too."""
    B, Sq, H, hd = q.shape
    Skv = k.shape[1]
    pq = (-Sq) % bq
    pk = (-Skv) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    out = flash_attention_pallas(q, k, v, causal=causal, window=window,
                                 softcap=softcap, bq=bq, bk=bk,
                                 kv_len=Skv if pk else None,
                                 interpret=interpret)
    return out[:, :Sq]
