"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None,
                        softcap: float | None = None):
    """q: (B, Sq, H, hd); k/v: (B, Skv, K, hd). Exact softmax attention."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(hd)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= qp >= kp
    if window is not None:
        ok &= (qp - kp) < window
    s = jnp.where(ok[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
