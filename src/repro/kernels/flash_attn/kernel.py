"""Flash-attention Pallas TPU kernel (lazy-softmax, GQA-aware).

The §Roofline memory terms count attention-score tensors as VMEM-resident
— this kernel is what makes that true on the TPU target: the (Sq x Skv)
score block never leaves VMEM; HBM traffic is exactly q/k/v reads + o
writes.

Grid: (batch*kv_head, Sq/BQ, Skv/BK) with the KV axis innermost ("arbitrary"
sequential on TPU) so the running (m, l, acc) state persists in VMEM across
KV steps.  Block shapes are MXU-aligned (BQ x BK = 128k x 128k tiles; head
dim is a full lane dimension).  Causal masking with an optional local
window; softcap for gemma-2.  Validated against ref.py in interpret mode
(CPU) over shape/dtype sweeps (tests/test_kernels_flash.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, n_kv_steps: int, causal: bool,
                  window: int | None, softcap: float | None, scale: float,
                  kv_len: int | None):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    run = True
    if causal:
        # skip fully-masked KV blocks
        run = (ki * bk) <= (qi * bq + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale     # (bq, G*hd) -> per-head
        k = k_ref[0].astype(jnp.float32)             # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        if kv_len is not None:
            ok &= k_pos < kv_len        # sequence padding (non-causal too)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(ki == n_kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int | None = None,
                           softcap: float | None = None,
                           bq: int = 128, bk: int = 128,
                           kv_len: int | None = None,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, hd); k/v: (B, Skv, K, hd); H % K == 0.

    Query heads are grouped with their KV head: grid axis 0 iterates
    (B * K * G) query-head panels against that KV head's sequence."""
    B, Sq, H, hd = q.shape
    Skv, K = k.shape[1], k.shape[2]
    G = H // K
    assert Sq % bq == 0 and Skv % bk == 0, (Sq, Skv, bq, bk)
    scale = 1.0 / math.sqrt(hd)
    n_kv = Skv // bk

    # (B, S, H, hd) -> (B*H, S, hd) query panels; KV indexed by head group
    qp = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kp = k.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)
    vp = v.transpose(0, 2, 1, 3).reshape(B * K, Skv, hd)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv_steps=n_kv, causal=causal,
        window=window, softcap=softcap, scale=scale, kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, Sq // bq, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
