"""Training loop: jitted step, checkpoint/restart, straggler detection,
fault tolerance, optional int8-compressed DP gradients.

Fault model (exercised by tests):
  * process crash      -> restart with --resume: restore latest atomic
                          checkpoint + data-iterator state; loss curve
                          continues exactly;
  * node-count change  -> elastic: checkpoints restore onto the current
                          mesh (reshard-on-load);
  * straggler steps    -> StragglerMonitor flags steps > k x EWMA and
                          raises a hook (on real fleets: trigger backup
                          step / re-shard away from the slow host).  The
                          M0 metrics (max-vs-mean per-unit load) detect
                          *structural* stragglers (expert/shard imbalance)
                          before they show up in wall-time.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import collectives, sharding
from repro.models import encdec, lm
from repro.models.encdec import EncDecCfg
from repro.train import checkpoint as ckpt_lib
from repro.train import step as step_lib
from repro.train.optim import Optimizer

from repro.distributed.compat import shard_map


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    num_microbatches: int = 1
    resume: bool = False
    compress_grads: bool = False        # int8 + error feedback on DP path
    straggler_factor: float = 3.0
    seed: int = 0


class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than factor x EWMA."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2):
        self.factor, self.alpha = factor, alpha
        self.ewma: Optional[float] = None
        self.events: list[tuple[int, float, float]] = []

    def record(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.events.append((step, dt, self.ewma))
        self.ewma = dt if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * dt)
        return slow


def make_dp_compressed_step(cfg, ctx, optimizer: Optimizer):
    """DP-only train step with int8 error-feedback gradient reduction
    (params replicated; the whole step runs under shard_map over dp)."""
    loss_f = (encdec.loss_fn if isinstance(cfg, EncDecCfg) else lm.loss_fn)
    inner_ctx = dataclasses.replace(ctx, mesh=None)   # per-shard local math

    def local_step(state, batch):
        params, err = state["params"], state["err"]

        def lf(p):
            return loss_f(p, batch, cfg, inner_ctx)
        (_, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        g_mean, new_err = collectives.compressed_grad_mean(
            grads, err, tuple(ctx.dp))
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, tuple(ctx.dp)),
                               metrics)
        new_params, new_opt = optimizer.update(
            g_mean, state["opt"], params, state["step"])
        return ({"params": new_params, "opt": new_opt, "err": new_err,
                 "step": state["step"] + 1}, metrics)

    def step(state, batch):
        rep = P()
        state_specs = jax.tree.map(lambda _: rep, state)
        batch_specs = jax.tree.map(lambda _: P(ctx.dp), batch)
        # metrics structure from the (axis-free) local loss fn
        local_b = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                (x.shape[0] // ctx.dp_size,) + x.shape[1:], x.dtype), batch)
        mshape = jax.eval_shape(lambda p, b: loss_f(p, b, cfg, inner_ctx)[1],
                                state["params"], local_b)
        return shard_map(local_step, mesh=ctx.mesh,
                         in_specs=(state_specs, batch_specs),
                         out_specs=(state_specs,
                                    jax.tree.map(lambda _: rep, mshape)),
                         check_vma=False)(state, batch)
    return step


class Trainer:
    def __init__(self, cfg, mesh, optimizer: Optimizer, data,
                 tcfg: TrainerConfig):
        self.cfg, self.mesh, self.opt = cfg, mesh, optimizer
        self.data, self.tcfg = data, tcfg
        self.ctx = sharding.make_ctx(mesh)
        self.monitor = StragglerMonitor(tcfg.straggler_factor)
        self.history: list[dict] = []
        self.fault_hook: Optional[Callable[[int], None]] = None
        self._build()

    # ------------------------------------------------------------------
    def _build(self):
        cfg, ctx, opt, tcfg = self.cfg, self.ctx, self.opt, self.tcfg
        key = jax.random.PRNGKey(tcfg.seed)
        aparams = jax.eval_shape(
            lambda: (encdec.init_params if isinstance(cfg, EncDecCfg)
                     else lm.init_params)(cfg, key))
        self.pspecs = sharding.param_specs(cfg, ctx)
        sspecs = step_lib.state_spec_tree(cfg, ctx, opt, aparams)
        if tcfg.compress_grads:
            sspecs = {**sspecs, "err": jax.tree.map(
                lambda s: P(), self.pspecs)}
            step_fn = make_dp_compressed_step(cfg, ctx, opt)
        else:
            gspecs = sharding.grad_specs(aparams, self.pspecs, ctx)
            step_fn = step_lib.make_train_step(
                cfg, ctx, opt, num_microbatches=tcfg.num_microbatches,
                grad_spec_tree=gspecs)
        self.state_shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), sspecs)
        self.sspecs = sspecs
        self.step_fn = jax.jit(step_fn, donate_argnums=0)

        # init or resume
        start = 0
        if tcfg.resume and tcfg.ckpt_dir and \
                ckpt_lib.latest_step(tcfg.ckpt_dir) is not None:
            like = jax.eval_shape(
                lambda: self._fresh_state(key))
            state, start, extra = ckpt_lib.restore(
                tcfg.ckpt_dir, like, shardings=self.state_shardings)
            self.state = state
            self.data_step = extra.get("data_step", start)
            print(f"[trainer] resumed from step {start}")
        else:
            # init under jit: distinct output buffers per leaf (identical
            # zeros constants would otherwise alias and break donation)
            self.state = jax.jit(self._fresh_state,
                                 out_shardings=self.state_shardings)(key)
            self.data_step = 0
        self.start_step = start

    def _fresh_state(self, key):
        state = step_lib.init_state(self.cfg, self.opt, key)
        if self.tcfg.compress_grads:
            state["err"] = collectives.init_error_feedback(state["params"])
        return state

    # ------------------------------------------------------------------
    def _put_batch(self, batch_np):
        bspecs = sharding.batch_specs(batch_np, self.ctx)
        return jax.tree.map(
            lambda x, s: jax.device_put(
                jnp.asarray(x), NamedSharding(self.mesh, s)),
            batch_np, bspecs)

    def run(self) -> list[dict]:
        tcfg = self.tcfg
        step = int(self.start_step)
        while step < tcfg.steps:
            try:
                if self.fault_hook:
                    self.fault_hook(step)
                batch = self._put_batch(self.data.batch(self.data_step))
                t0 = time.time()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.time() - t0
                slow = self.monitor.record(step, dt)
                step += 1
                self.data_step += 1
                if step % tcfg.log_every == 0 or step == tcfg.steps:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=step, dt=round(dt, 4), straggler=slow)
                    self.history.append(m)
                    print(f"[trainer] step {step} loss {m['loss']:.4f} "
                          f"({dt*1e3:.0f} ms)" + (" STRAGGLER" if slow else ""))
                if tcfg.ckpt_dir and step % tcfg.ckpt_every == 0:
                    ckpt_lib.save(tcfg.ckpt_dir, step, self.state,
                                  extra={"data_step": self.data_step},
                                  keep=tcfg.keep)
            except (KeyboardInterrupt,):
                raise
            except RuntimeError as e:
                # fault-tolerance path: restore last checkpoint and retry
                if not (tcfg.ckpt_dir
                        and ckpt_lib.latest_step(tcfg.ckpt_dir) is not None):
                    raise
                print(f"[trainer] step {step} failed ({e}); restoring")
                like = jax.eval_shape(
                    lambda: self._fresh_state(jax.random.PRNGKey(0)))
                self.state, step, extra = ckpt_lib.restore(
                    tcfg.ckpt_dir, like, shardings=self.state_shardings)
                self.data_step = extra.get("data_step", step)
                self.fault_hook = None
        if tcfg.ckpt_dir:
            ckpt_lib.save(tcfg.ckpt_dir, step, self.state,
                          extra={"data_step": self.data_step},
                          keep=tcfg.keep)
        return self.history
