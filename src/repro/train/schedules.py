"""LR schedules. WSD (warmup-stable-decay) is the minicpm-2b preset."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        return jnp.float32(lr) * jnp.minimum(1.0, (s + 1) / max(warmup, 1))
    return fn


def cosine(lr: float, warmup: int, total: int, final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        c = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * w * c
    return fn


def wsd(lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.01):
    """Warmup-Stable-Decay (minicpm): linear warmup, flat stable phase,
    exponential-ish (linear here) decay tail."""
    def fn(step):
        s = step.astype(jnp.float32)
        w = jnp.minimum(1.0, (s + 1) / max(warmup, 1))
        d = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        return jnp.float32(lr) * w * (1.0 - (1.0 - final_frac) * d)
    return fn
