"""Train / serve step builders — shared by the launchers and the dry-run.

``make_train_step`` performs microbatched gradient accumulation with
``lax.scan``: per-microbatch backward passes release activation memory and
XLA overlaps the (reduce-scattered) gradient collectives of microbatch i
with the compute of microbatch i+1.  Gradient accumulators are constrained
to ``grad_specs`` (giant MoE leaves additionally shard over `pod` so the
cross-pod DP path is a reduce-scatter, never a replicated all-reduce).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import sharding
from repro.models import encdec, lm
from repro.models.common import ModelCfg
from repro.models.encdec import EncDecCfg
from repro.models.layers import ShardCtx
from repro.train.optim import Optimizer


def _loss_for(cfg):
    return encdec.loss_fn if isinstance(cfg, EncDecCfg) else lm.loss_fn


def make_train_step(cfg, ctx: ShardCtx, optimizer: Optimizer, *,
                    num_microbatches: int = 1,
                    grad_accum_dtype: str | None = None,
                    grad_spec_tree=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt", "step"}.  batch leaves have a leading global
    batch dim divisible by num_microbatches.
    """
    loss_f = _loss_for(cfg)
    M = num_microbatches

    def constrain_grads(g):
        if grad_spec_tree is None or ctx.mesh is None:
            return g
        return jax.lax.with_sharding_constraint(
            g, jax.tree.map(lambda s: NamedSharding(ctx.mesh, s),
                            grad_spec_tree))

    def train_step(state, batch):
        params = state["params"]

        def lf(p, mb):
            return loss_f(p, mb, cfg, ctx)

        if M == 1:
            (_, metrics), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
            grads = constrain_grads(grads)
        else:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            acc_dt = grad_accum_dtype or "float32"
            import repro.models.layers as L
            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, L.dt(acc_dt)), params)
            gz = constrain_grads(gz)

            def body(carry, mb):
                gacc, macc, n = carry
                (_, metrics), g = jax.value_and_grad(
                    lf, has_aux=True)(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), gacc, g)
                gacc = constrain_grads(gacc)
                macc = jax.tree.map(lambda a, b: a + b, macc, metrics)
                return (gacc, macc, n + 1), None

            m0 = jax.eval_shape(
                lambda p: lf(p, jax.tree.map(lambda x: x[0], mb_batch))[1],
                params)
            m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), m0)
            (grads, metrics, _), _ = jax.lax.scan(
                body, (gz, m0, 0), mb_batch)
            grads = jax.tree.map(lambda g: g / M, grads)
            metrics = jax.tree.map(lambda m: m / M, metrics)

        new_params, new_opt = optimizer.update(
            grads, state["opt"], params, state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg, ctx: ShardCtx) -> Callable:
    if isinstance(cfg, EncDecCfg):
        def step(params, batch):
            enc_out = encdec.encode(params, batch["frontend_embeds"], cfg,
                                    ctx)
            h = encdec.decode_train(params, enc_out, batch["tokens"], cfg,
                                    ctx)
            logits = jnp.einsum("bsd,dv->bsv", h[:, -1:],
                                params["embed"].T,
                                preferred_element_type=jnp.float32)
            return logits[:, 0], enc_out
        return step

    def step(params, batch):
        return lm.prefill(params, batch["tokens"], cfg, ctx,
                          frontend_embeds=batch.get("frontend_embeds"))
    return step


def make_serve_step(cfg, ctx: ShardCtx) -> Callable:
    """serve_step(params, cache, tokens, pos) -> (logits, new_cache)."""
    if isinstance(cfg, EncDecCfg):
        def step(params, cache, tokens, pos):
            return encdec.decode_step(params, tokens, cache, pos, cfg, ctx)
        return step

    def step(params, cache, tokens, pos):
        return lm.decode_step(params, tokens, cache, pos, cfg, ctx)
    return step


def init_state(cfg, optimizer: Optimizer, key):
    init_p = (encdec.init_params if isinstance(cfg, EncDecCfg)
              else lm.init_params)
    params = init_p(cfg, key)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_spec_tree(cfg, ctx: ShardCtx, optimizer: Optimizer,
                    abstract_params):
    pspecs = sharding.param_specs(cfg, ctx)
    ospecs = optimizer.state_specs(abstract_params, pspecs, ctx)
    return {"params": pspecs, "opt": ospecs, "step": P()}
