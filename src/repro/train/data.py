"""Deterministic synthetic data pipelines (offline container — no external
datasets).  Every pipeline is:

  * deterministic given (seed, step) — restart/elastic-safe: the iterator
    state IS the step counter, stored in every checkpoint;
  * host-sharded: ``batch_for_host(step, host_id, n_hosts)`` returns only
    this host's rows (the launcher device_puts with the batch sharding).

``lm_task`` generates token streams with learnable structure (a mixture of
Zipfian unigrams, a fixed Markov backbone, and copy motifs) so training
losses decrease measurably within a few hundred steps — used by the e2e
example and the convergence tests.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class LMTaskConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 64          # Markov backbone states


class SyntheticLM:
    """Markov-backbone token stream: next-token entropy is well below
    log(V), so a model that learns reduces loss quickly."""

    def __init__(self, cfg: LMTaskConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V, S = cfg.vocab_size, cfg.n_states
        # each backbone state prefers a small token subset
        self.emit = rng.integers(0, V, size=(S, 8))
        self.trans = rng.integers(0, S, size=(S, 4))

    def _rows(self, step: int, rows: np.ndarray) -> np.ndarray:
        cfg = self.cfg
        out = np.empty((len(rows), cfg.seq_len + 1), np.int32)
        for i, r in enumerate(rows):
            rng = np.random.default_rng(
                (self.cfg.seed * 1_000_003 + step) * 65_521 + int(r))
            s = int(rng.integers(0, cfg.n_states))
            for t in range(cfg.seq_len + 1):
                out[i, t] = self.emit[s, rng.integers(0, 8)]
                s = int(self.trans[s, rng.integers(0, 4)])
        return out

    def batch(self, step: int) -> dict[str, np.ndarray]:
        toks = self._rows(step, np.arange(self.cfg.global_batch))
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def batch_for_host(self, step: int, host_id: int,
                       n_hosts: int) -> dict[str, np.ndarray]:
        per = self.cfg.global_batch // n_hosts
        rows = np.arange(host_id * per, (host_id + 1) * per)
        toks = self._rows(step, rows)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class SyntheticDenoise:
    """(noisy, clean) feature pairs for the S5 audio-denoising reproduction
    (paper Table II / Fig 3): clean = sparse sinusoid mixture, noisy = clean
    + white noise."""

    def __init__(self, n_features: int, seq_len: int, global_batch: int,
                 seed: int = 0, snr: float = 0.5):
        self.n, self.S, self.B = n_features, seq_len, global_batch
        self.seed, self.snr = seed, snr

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 7919 + step)
        t = np.arange(self.S)[None, :, None] / self.S
        freqs = rng.integers(1, 12, size=(self.B, 1, self.n))
        phase = rng.uniform(0, 2 * np.pi, size=(self.B, 1, self.n))
        clean = np.sin(2 * np.pi * freqs * t + phase).astype(np.float32)
        mask = rng.random((self.B, 1, self.n)) < 0.5
        clean = clean * mask
        noisy = clean + self.snr * rng.standard_normal(
            clean.shape).astype(np.float32)
        return {"noisy": noisy, "clean": clean}


class SyntheticImages:
    """Procedural 10-class image-like classification task (AkidaNet /
    Speck reproduction stand-in for Imagenette/N-MNIST): class = which
    oriented-bar pattern dominates; solvable by small CNNs/MLPs."""

    def __init__(self, hw: int, channels: int, global_batch: int,
                 n_classes: int = 10, seed: int = 0):
        self.hw, self.c, self.B = hw, channels, global_batch
        self.k, self.seed = n_classes, seed
        rng = np.random.default_rng(seed)
        self.templates = rng.standard_normal(
            (n_classes, hw, hw, channels)).astype(np.float32)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(self.seed * 104_729 + step)
        y = rng.integers(0, self.k, size=self.B)
        noise = rng.standard_normal(
            (self.B, self.hw, self.hw, self.c)).astype(np.float32)
        x = self.templates[y] * 1.5 + noise
        return {"x": np.maximum(x, 0.0), "y": y.astype(np.int32)}
