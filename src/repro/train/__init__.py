"""Training loops: the distributed LM trainer (``repro.train.loop``) and
the floorline-guided sparsity-aware trainer (``repro.train.sparse``) that
closes the paper's iso-accuracy loop."""

from repro.train.sparse import (SparseTrainConfig, SparseTrainer,
                                deploy_mlp, mlp_fwd, mlp_init)

__all__ = ["SparseTrainConfig", "SparseTrainer", "deploy_mlp", "mlp_fwd",
           "mlp_init"]
