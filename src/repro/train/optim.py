"""Optimizers (pure pytree, no external deps) + ZeRO-1 state sharding specs.

* ``adamw``     — mixed precision: f32 master weights + f32 (m, v); ZeRO-1
                  shards all three over `data`.
* ``adafactor`` — factored second moments (rows/cols over the last two
  dims), update clipping, no master copy: the right choice when Adam states
  would not fit (kimi-k2 1T: Adam needs ~16 bytes/param = 16.4 TB; Adafactor
  ~4e-3 bytes/param of state).  Selected per-arch by the launcher.

Both expose:  init(params) -> state;  update(grads, state, params, step)
-> (new_params, new_state);  state_specs(params, param_specs, ctx).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import zero1_specs
from repro.models.layers import ShardCtx


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    state_specs: Callable[[Any, Any, ShardCtx], Any]


def _global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


# ------------------------------------------------------------------ AdamW

def adamw(lr_fn: Callable[[jax.Array], jax.Array], *, b1: float = 0.9,
          b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(f32, params),
                "v": jax.tree.map(f32, params),
                "master": jax.tree.map(
                    lambda p: p.astype(jnp.float32), params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, grad_clip)
        t = step.astype(jnp.float32) + 1.0
        lr = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, w):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            if w.ndim >= 2:                     # no decay on norms/scalars
                u = u + weight_decay * w
            w = w - lr * u
            return m, v, w
        out = jax.tree.map(upd, grads, state["m"], state["v"],
                           state["master"])
        m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda o: isinstance(o, tuple))
        v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda o: isinstance(o, tuple))
        master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda o: isinstance(o, tuple))
        new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master,
                                  params)
        return new_params, {"m": m, "v": v, "master": master}

    def state_specs(params, specs, ctx):
        z = zero1_specs(params, specs, ctx)
        return {"m": z, "v": z, "master": z}

    return Optimizer("adamw", init, update, state_specs)


# --------------------------------------------------------------- Adafactor

def adafactor(lr_fn: Callable[[jax.Array], jax.Array], *,
              eps: float = 1e-30, clip_threshold: float = 1.0,
              decay_pow: float = 0.8, weight_decay: float = 0.0,
              min_dim_factored: int = 128) -> Optimizer:
    def factored(p):
        return (p.ndim >= 2 and p.shape[-1] >= min_dim_factored
                and p.shape[-2] >= min_dim_factored)

    def init(params):
        def one(p):
            if factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        t = step.astype(jnp.float32) + 1.0
        beta2 = 1.0 - t ** (-decay_pow)
        lr = lr_fn(step)

        def upd(g, s, w):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if "vr" in s:
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                r = vr / jnp.mean(vr, axis=-1, keepdims=True)
                u = g / jnp.sqrt(r[..., None] * vc[..., None, :] + eps)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if weight_decay and w.ndim >= 2:
                u = u + weight_decay * w.astype(jnp.float32)
            new_w = (w.astype(jnp.float32) - lr * u).astype(w.dtype)
            return new_s, new_w

        out = jax.tree.map(upd, grads, state["fac"], params,
                           is_leaf=lambda x: isinstance(x, dict)
                           and ("v" in x or "vr" in x))
        fac = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda o: isinstance(o, tuple))
        new_params = jax.tree.map(lambda o: o[1], out,
                                  is_leaf=lambda o: isinstance(o, tuple))
        return new_params, {"fac": fac}

    def state_specs(params, specs, ctx):
        def one(p, s):
            dims = tuple(s) + (None,) * (p.ndim - len(tuple(s)))
            if factored(p):
                return {"vr": P(*dims[:-1]),
                        "vc": P(*(dims[:-2] + dims[-1:]))}
            return {"v": P(*dims)}
        return {"fac": jax.tree.map(one, params, specs)}

    return Optimizer("adafactor", init, update, state_specs)


def for_arch(arch_param_count: int, lr_fn) -> Optimizer:
    """Launcher policy: Adafactor above 100B params (memory-bound decision
    — the paper's M1 move applied to optimizer state), AdamW otherwise."""
    if arch_param_count > 100e9:
        return adafactor(lr_fn)
    return adamw(lr_fn)
