"""Floorline-guided sparsity-aware training (paper §VII-A, closing the loop).

The paper's headline iso-accuracy gains pair *training-time* sparsification
with the mapping optimizer.  :class:`SparseTrainer` is that training half:
a deterministic, checkpointable MLP training loop whose sparsity
regularizers (``tl1_regularizer`` / ``synops_loss``) are weighted per layer
by the floorline model — the deployed workload is priced once, each layer
is classified memory-/compute-/traffic-bound
(:func:`repro.core.guidance.floorline_layer_weights`), and the layers that
actually set the step time get pushed toward sparsity hardest.

Three §VII-A recipes are supported, composably:

* **activation regularization** — ``lam > 0`` with ``reg="tl1"`` (AKD1000)
  or ``reg="synops"`` (Speck), floorline-weighted per layer;
* **magnitude pruning + masked fine-tune** — ``prune_sparsity > 0``: after
  the dense/regularized phase, one-shot
  :func:`~repro.sparsity.pruning.magnitude_prune_masks` then
  ``finetune_steps`` of masked training (S5);
* **sigma-delta threshold calibration** — :meth:`calibrate_sigma_delta`
  solves per-layer thresholds for a target message density (PilotNet).

The product is a :class:`~repro.sparsity.profile.SparsityProfile` —
measured per-layer activation densities + the exact weight masks — which
feeds ``simulate`` / ``simulate_population`` / the evolutionary search in
place of synthetic density schedules (``benchmarks/iso_accuracy.py``).

Checkpointing uses :mod:`repro.train.checkpoint` (atomic, versioned);
training is bit-identically resumable: the data is deterministic in
(seed, step), the optimizer state and masks live in the checkpoint, and
the jitted update re-runs the same program — asserted by
``tests/test_train_sparse.py`` (kill-at-step-s == uninterrupted).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparsity import (SparsityProfile, apply_masks,
                            calibrate_thresholds, magnitude_prune_masks,
                            sigma_delta_densities, synops_loss,
                            tl1_regularizer)
from repro.train import checkpoint as ckpt_lib
from repro.train.data import SyntheticDenoise, SyntheticImages


# --------------------------------------------------------------- tiny MLP

def mlp_init(key, sizes):
    """He-ish dense stack init; one weight matrix per layer, no biases."""
    ps = []
    for i in range(len(sizes) - 1):
        k1, key = jax.random.split(key)
        ps.append(jax.random.normal(k1, (sizes[i], sizes[i + 1]))
                  / np.sqrt(sizes[i]))
    return ps


def mlp_fwd(ps, x):
    """(output, hidden relu activations); acts[l] is produced by layer l."""
    acts = []
    h = x
    for i, w in enumerate(ps):
        h = h @ w
        if i < len(ps) - 1:
            h = jax.nn.relu(h)
            acts.append(h)
    return h, acts


def deploy_mlp(ps, *, neuron_model="relu", thresholds=None,
               sends_deltas=False):
    """Lower trained (masked) weights into a priceable ``SimNetwork``."""
    from repro.neuromorphic.network import SimLayer, SimNetwork
    layers = []
    for i, w in enumerate(ps):
        last = i == len(ps) - 1
        layers.append(SimLayer(
            name=f"fc{i}", kind="fc", weights=np.asarray(w, np.float32),
            neuron_model=neuron_model if not last else
            ("sd_relu" if neuron_model == "sd_relu" else "relu"),
            threshold=(thresholds[i] if thresholds is not None else
                       (1.0 if neuron_model == "if" else 0.0)),
            sends_deltas=sends_deltas and not last))
    return SimNetwork(layers=layers, in_size=int(np.shape(ps[0])[0]))


# ------------------------------------------------------------------ config

@dataclasses.dataclass
class SparseTrainConfig:
    """One sparsity-aware training run (all phases share one step counter:
    ``[0, steps)`` dense/regularized, ``[steps, steps + finetune_steps)``
    masked fine-tune after the one-shot prune)."""

    sizes: tuple[int, ...] = (128, 256, 128, 10)
    task: str = "images"            # "images" | "denoise"
    steps: int = 200
    lam: float = 0.0                # regularizer strength (0 = dense)
    reg: str = "tl1"                # "tl1" | "synops"
    prune_sparsity: float = 0.0     # one-shot magnitude-prune target
    finetune_steps: int = 0         # masked fine-tune steps after the prune
    lr: float = 3e-3
    batch: int = 64
    seed: int = 0
    min_prune_size: int = 64
    ckpt_dir: str | None = None
    ckpt_every: int = 0             # 0 = no checkpoints
    ckpt_keep: int = 3

    def __post_init__(self):
        if self.prune_sparsity > 0 and self.finetune_steps < 1:
            raise ValueError("prune_sparsity > 0 needs finetune_steps >= 1 "
                             "(the masks are applied at the prune boundary "
                             "inside the training loop)")

    @property
    def total_steps(self) -> int:
        return self.steps + (self.finetune_steps
                             if self.prune_sparsity > 0 else 0)


class SparseTrainer:
    """Deterministic floorline-guided sparse training loop.

    ``layer_weights`` — per-hidden-layer regularizer multipliers (length
    ``len(sizes) - 2``), typically from :meth:`floorline_weights`; ``None``
    trains unguided (uniform weights).
    """

    def __init__(self, cfg: SparseTrainConfig, *, layer_weights=None):
        self.cfg = cfg
        if cfg.task == "images":
            hw = int(round(np.sqrt(cfg.sizes[0] / 2)))
            if hw * hw * 2 != cfg.sizes[0]:
                raise ValueError(f"images task needs sizes[0] = 2*hw^2; "
                                 f"got {cfg.sizes[0]}")
            self.data = SyntheticImages(hw=hw, channels=2,
                                        global_batch=cfg.batch,
                                        seed=cfg.seed)
        elif cfg.task == "denoise":
            self.data = SyntheticDenoise(n_features=cfg.sizes[0],
                                         seq_len=24,
                                         global_batch=max(cfg.batch // 4, 2),
                                         seed=cfg.seed)
        else:
            raise ValueError(f"unknown task {cfg.task!r}")
        n_hidden = len(cfg.sizes) - 2
        self.layer_weights = (None if layer_weights is None else
                              tuple(float(w) for w in layer_weights))
        if self.layer_weights is not None and \
                len(self.layer_weights) != n_hidden:
            raise ValueError(f"layer_weights must have {n_hidden} entries "
                             f"(one per hidden layer); got "
                             f"{len(self.layer_weights)}")
        self.fanouts = [cfg.sizes[i + 2] for i in range(n_hidden)]
        self.params = mlp_init(jax.random.PRNGKey(cfg.seed), cfg.sizes)
        self.masks = [jnp.ones_like(p) for p in self.params]
        self.opt_m = [jnp.zeros_like(p) for p in self.params]
        self.opt_v = [jnp.zeros_like(p) for p in self.params]
        self.step = 0
        self.losses: list[float] = []
        self._jit_step = jax.jit(self._update)

    # ------------------------------------------------------------- batches
    def _batch(self, t: int):
        b = self.data.batch(t)
        if self.cfg.task == "images":
            return (jnp.asarray(b["x"].reshape(len(b["y"]), -1)),
                    jnp.asarray(b["y"]))
        n = self.cfg.sizes[0]
        return (jnp.asarray(b["noisy"].reshape(-1, n)),
                jnp.asarray(b["clean"].reshape(-1, n)))

    # ---------------------------------------------------------------- loss
    def _loss(self, ps, batch):
        x, y = batch
        out, acts = mlp_fwd(ps, x)
        if self.cfg.task == "images":
            task = jnp.mean(-jax.nn.log_softmax(out)[jnp.arange(len(y)), y])
        else:
            task = jnp.mean((out - y) ** 2)
        if not self.cfg.lam:
            return task
        if self.cfg.reg == "tl1":
            reg = tl1_regularizer(acts, weights=self.layer_weights)
        elif self.cfg.reg == "synops":
            reg = synops_loss(acts, self.fanouts,
                              weights=self.layer_weights)
        else:
            raise ValueError(f"unknown reg {self.cfg.reg!r}")
        return task + self.cfg.lam * reg

    def _update(self, ps, m, v, masks, batch):
        pz = [w * k for w, k in zip(ps, masks)]
        l, g = jax.value_and_grad(self._loss)(pz, batch)
        lr = self.cfg.lr
        m = [0.9 * a + 0.1 * b for a, b in zip(m, g)]
        v = [0.99 * a + 0.01 * b * b for a, b in zip(v, g)]
        ps = [(p - lr * mm / (jnp.sqrt(vv) + 1e-8)) * k
              for p, mm, vv, k in zip(pz, m, v, masks)]
        return ps, m, v, l

    # ------------------------------------------------------------ guidance
    def floorline_weights(self, chip, *, probe_steps: int = 4,
                          state_weights=None) -> np.ndarray:
        """Per-hidden-layer regularizer weights from the floorline: deploy
        the CURRENT weights, price a probe batch, classify each layer
        (§VI-A) and weight traffic-/memory-bound layers hardest.  Feed the
        result back via a new trainer's ``layer_weights``."""
        from repro.core.guidance import floorline_layer_weights
        net = self.deploy()
        xs = self._probe_xs(probe_steps)
        w = floorline_layer_weights(net, xs, chip,
                                    state_weights=state_weights)
        return w[:len(self.cfg.sizes) - 2]

    def _probe_xs(self, steps: int) -> np.ndarray:
        x, _ = self._batch(10_999)
        return np.maximum(np.asarray(x[:steps], np.float32), 0.0)

    # ----------------------------------------------------------- main loop
    def train(self, *, resume: bool = False, stop_after: int | None = None
              ) -> "SparseTrainer":
        """Run (or resume) the full schedule.  ``stop_after`` halts once
        the global step counter reaches it (the kill point of the
        checkpoint-parity contract); call again with ``resume=True`` to
        continue bit-identically."""
        cfg = self.cfg
        if resume:
            if not cfg.ckpt_dir:
                raise ValueError("resume=True needs cfg.ckpt_dir")
            like = {"params": self.params, "m": self.opt_m, "v": self.opt_v,
                    "masks": self.masks}
            state, step, extra = ckpt_lib.restore(cfg.ckpt_dir, like)
            self.params = [jnp.asarray(p) for p in state["params"]]
            self.opt_m = [jnp.asarray(p) for p in state["m"]]
            self.opt_v = [jnp.asarray(p) for p in state["v"]]
            self.masks = [jnp.asarray(p) for p in state["masks"]]
            self.step = step
            self.losses = [float(l) for l in extra.get("losses", [])]
        while self.step < cfg.total_steps:
            if stop_after is not None and self.step >= stop_after:
                break
            if cfg.prune_sparsity > 0 and self.step == cfg.steps:
                self.masks = jax.tree.leaves(magnitude_prune_masks(
                    {f"w{i}": w for i, w in enumerate(self.params)},
                    cfg.prune_sparsity, min_size=cfg.min_prune_size))
                self.params = [w * k for w, k in
                               zip(self.params, self.masks)]
            self.params, self.opt_m, self.opt_v, l = self._jit_step(
                self.params, self.opt_m, self.opt_v, self.masks,
                self._batch(self.step))
            self.step += 1
            self.losses.append(float(l))
            if (cfg.ckpt_dir and cfg.ckpt_every
                    and self.step % cfg.ckpt_every == 0):
                self._save()
        if cfg.ckpt_dir and cfg.ckpt_every and self.step == cfg.total_steps:
            self._save()
        return self

    def _save(self):
        state = {"params": self.params, "m": self.opt_m, "v": self.opt_v,
                 "masks": self.masks}
        ckpt_lib.save(self.cfg.ckpt_dir, self.step, state,
                      extra={"losses": self.losses},
                      keep=self.cfg.ckpt_keep)

    # ------------------------------------------------------------- metrics
    def masked_params(self):
        return [np.asarray(w * k, np.float32)
                for w, k in zip(self.params, self.masks)]

    def eval_metrics(self, *, t: int = 10_000) -> dict:
        """Held-out task metric (training never touches step >= 10_000)."""
        x, y = self._batch(t)
        out, acts = mlp_fwd([jnp.asarray(p) for p in self.masked_params()],
                            x)
        dens = float(np.mean([np.mean(np.asarray(a) > 0) for a in acts]))
        if self.cfg.task == "images":
            acc = float(jnp.mean(jnp.argmax(out, -1) == y))
            return {"acc": acc, "act_density": dens}
        return {"mse": float(jnp.mean((out - y) ** 2)),
                "act_density": dens}

    # ------------------------------------------------------------- profile
    def extract_profile(self, *, t: int = 10_000, meta=None
                        ) -> SparsityProfile:
        """Measure the trained sparsity profile on a held-out batch:
        per-layer message densities of the DEPLOYED network (hidden relu
        activations + positive output fraction), exact weight masks, and
        the input stream's density."""
        x, _ = self._batch(t)
        ps = [jnp.asarray(p) for p in self.masked_params()]
        out, acts = mlp_fwd(ps, x)
        per_layer = [np.asarray(a) for a in acts] + [np.asarray(out)]
        names = [f"fc{i}" for i in range(len(ps))]
        return SparsityProfile.from_activations(
            names, per_layer, masks=[np.asarray(m, np.float32)
                                     for m in self.masks],
            input_density=float(np.mean(np.asarray(x) > 0)),
            meta={"task": self.cfg.task, "steps": self.step,
                  "lam": self.cfg.lam, "reg": self.cfg.reg,
                  "prune_sparsity": self.cfg.prune_sparsity,
                  **(meta or {})})

    def deploy(self, **kw):
        return deploy_mlp(self.masked_params(), **kw)

    # --------------------------------------------------------- sigma-delta
    def calibrate_sigma_delta(self, target_density, *, t: int = 11_000):
        """PilotNet recipe: solve per-layer Σ-Δ thresholds so each hidden
        layer's message density hits ``target_density`` (scalar or
        per-layer), measured on one held-out temporal sequence.  Returns
        ``(profile, net)`` — the profile carries the thresholds and the
        *measured* Σ-Δ densities; ``net`` is the deployed sigma-delta
        network."""
        if self.cfg.task != "denoise":
            raise ValueError("sigma-delta calibration needs the temporal "
                             "'denoise' task")
        b = self.data.batch(t)
        seq = jnp.asarray(b["noisy"][0])                   # (S, n)
        ps = [jnp.asarray(p) for p in self.masked_params()]
        acts_seq, h = [], seq
        for w in ps[:-1]:
            h = jax.nn.relu(h @ w)
            acts_seq.append(np.asarray(h))
        n_hidden = len(acts_seq)
        targets = ([float(target_density)] * n_hidden
                   if np.isscalar(target_density) else
                   [float(d) for d in target_density])
        deltas = [np.diff(a, axis=0).reshape(-1) for a in acts_seq]
        thetas = calibrate_thresholds(deltas, [1.0 - d for d in targets])
        dens = sigma_delta_densities(acts_seq, thetas)
        out = np.asarray(acts_seq[-1] @ ps[-1])
        names = [f"fc{i}" for i in range(len(ps))]
        profile = SparsityProfile(
            layer_names=names,
            act_density=np.asarray(dens + [float(np.mean(out > 0))]),
            weight_density=np.array([float(np.mean(np.asarray(m) != 0))
                                     for m in self.masks]),
            weight_masks=tuple(np.asarray(m, np.float32)
                               for m in self.masks),
            thresholds=tuple(thetas) + (1e-6,),
            input_density=float(np.mean(np.asarray(seq) > 0)),
            meta={"task": self.cfg.task, "recipe": "sigma_delta",
                  "target_density": targets})
        net = deploy_mlp(self.masked_params(), neuron_model="sd_relu",
                         thresholds=list(thetas) + [1e-6],
                         sends_deltas=True)
        return profile, net
