"""Fault-tolerant checkpointing: atomic, versioned, resumable, elastic.

* atomic: write to ``<dir>/tmp.<step>`` then ``os.replace`` — a crash never
  leaves a partial checkpoint visible;
* versioned: ``step_<N>.npz`` + ``meta.json``; ``keep`` newest retained;
* resumable: restore returns (state, step, extra) — extra carries the data
  iterator state so restarts are bit-identical;
* elastic: leaves are saved as full (unsharded) arrays and ``device_put``
  against the *current* mesh/sharding on restore — a job can come back on a
  different mesh shape (checkpoint-reshard on load), which is the elastic
  re-scaling path exercised by tests/test_train.py.

For multi-host fleets the same layout shards by host
(``step_<N>.host<k>.npz`` — addressable shards only); this container is
single-host so the single-file path is exercised.
"""

from __future__ import annotations

import json
import os
import threading

import jax
import numpy as np


def _flatten(state):
    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, state, extra: dict | None = None,
         keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    keys, vals, _ = _flatten(state)
    arrays = {}
    for k, v in zip(keys, vals):
        a = np.asarray(jax.device_get(v))
        arrays[k] = a
    tmp = os.path.join(ckpt_dir, f"tmp.{step}.npz")
    final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with open(tmp, "wb") as f:
        np.savez(f, **{k.replace("/", "|"): v for k, v in arrays.items()})
    os.replace(tmp, final)                                   # atomic
    meta = {"latest_step": step, "extra": extra or {}}
    mtmp = os.path.join(ckpt_dir, "meta.tmp")
    with open(mtmp, "w") as f:
        json.dump(meta, f)
    os.replace(mtmp, os.path.join(ckpt_dir, "meta.json"))
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, state, extra=None,
               keep: int = 3) -> threading.Thread:
    """Snapshot to host memory synchronously, write to disk off-thread
    (training continues during the write)."""
    keys, vals, _ = _flatten(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in zip(keys, vals)}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"tmp.{step}.npz")
        final = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
        with open(tmp, "wb") as f:
            np.savez(f, **{k.replace("/", "|"): v for k, v in host.items()})
        os.replace(tmp, final)
        mtmp = os.path.join(ckpt_dir, "meta.tmp")
        with open(mtmp, "w") as f:
            json.dump({"latest_step": step, "extra": extra or {}}, f)
        os.replace(mtmp, os.path.join(ckpt_dir, "meta.json"))
        _gc(ckpt_dir, keep)
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep: int):
    ckpts = sorted(f for f in os.listdir(ckpt_dir)
                   if f.startswith("step_") and f.endswith(".npz"))
    for f in ckpts[:-keep]:
        try:
            os.remove(os.path.join(ckpt_dir, f))
        except OSError:
            pass


def _scan_steps(ckpt_dir: str) -> list[int]:
    """Step numbers of the complete single-file checkpoints on disk.
    Partial writes never match: they live under ``tmp.<step>.npz`` until
    the atomic ``os.replace``."""
    try:
        names = os.listdir(ckpt_dir)
    except FileNotFoundError:
        return []
    steps = []
    for f in names:
        if f.startswith("step_") and f.endswith(".npz"):
            try:
                steps.append(int(f[len("step_"):-len(".npz")]))
            except ValueError:          # host-sharded / foreign names
                pass
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    """Newest COMPLETE checkpoint step.

    The ``step_<N>.npz`` files are authoritative — each lands via one
    atomic ``os.replace``, so scanning them survives a crash *between*
    the npz replace and the ``meta.json`` replace (where meta is one step
    stale) and a torn/lost ``meta.json``.  ``meta.json`` is consulted
    only when no single-file checkpoints are found (multi-host shards use
    ``step_<N>.host<k>.npz`` names the scan skips)."""
    steps = _scan_steps(ckpt_dir)
    if steps:
        return steps[-1]
    meta = os.path.join(ckpt_dir, "meta.json")
    if not os.path.exists(meta):
        return None
    with open(meta) as f:
        return json.load(f).get("latest_step")


def restore(ckpt_dir: str, like_state, *, shardings=None,
            step: int | None = None):
    """Restore into the structure of ``like_state`` (arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings built against the CURRENT mesh (elastic reshard-on-load).
    Returns (state, step, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    keys, vals, treedef = _flatten(like_state)
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda s: s is None) if shardings is not None
        else [None] * len(vals))
    out = []
    for k, like, sh in zip(keys, vals, sh_leaves):
        a = data[k.replace("/", "|")]
        a = a.astype(like.dtype) if a.dtype != like.dtype else a
        out.append(jax.device_put(a, sh) if sh is not None else a)
    state = jax.tree_util.tree_unflatten(treedef, out)
    extra = {}
    meta_path = os.path.join(ckpt_dir, "meta.json")
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
        # extra describes the step meta.json last recorded; pairing it
        # with a different step's arrays (older step requested, or meta
        # one step behind after a crash between the two replaces) would
        # silently desynchronize e.g. the data-iterator state
        if meta.get("latest_step") == step:
            extra = meta.get("extra", {})
    return state, step, extra
