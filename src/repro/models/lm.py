"""Decoder-only LM assembling the mixers/MLPs in layers.py + moe.py.

Layer execution uses ``lax.scan`` over the repeated block *pattern* with
stacked parameters — the HLO is O(pattern) not O(depth), which keeps the
512-device AOT compiles fast and is how the 61-layer / 1T-param kimi-k2
lowers on one CPU host.  ``cfg.remat="block"`` wraps the scan body in
``jax.checkpoint`` (activation recomputation per scan unit).

Public entry points:
  init_params / init_cache         (use jax.eval_shape(...) for the dry-run)
  forward(params, batch, ...)      -> final hidden states
  loss_fn(params, batch, ...)      -> (loss, metrics)  [vocab-sharded CE]
  decode_step(params, tokens, cache, pos, ...) -> (logits, new_cache)
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import moe as moe_lib
from repro.models.common import BlockCfg, ModelCfg
from repro.models.layers import (KeyGen, ShardCtx, attention, attention_decode,
                                 attn_params, dt, mlp, mlp_params, rglru_mixer,
                                 rglru_params, rms_norm, rope, softcap,
                                 ssd_mixer, ssd_params, _init)

AUX_SUM = ("moe_lb_loss", "moe_z_loss", "dropped_frac")
AUX_MAX = ("max_expert_load",)


# --------------------------------------------------------------------------
# Parameter construction
# --------------------------------------------------------------------------

def _block_params(kg: KeyGen, blk: BlockCfg, cfg: ModelCfg, dtype) -> dict:
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": jnp.zeros((d,), dtype)}
    if blk.kind == "attn":
        p["attn"] = attn_params(kg, cfg, dtype)
    elif blk.kind == "ssd":
        p["ssd"] = ssd_params(kg, cfg, blk.ssd, dtype)
    elif blk.kind == "rglru":
        p["rglru"] = rglru_params(kg, cfg, blk.rglru, dtype)
    else:
        raise ValueError(blk.kind)
    if blk.moe is not None:
        p["norm2"] = jnp.zeros((d,), dtype)
        p["moe"] = moe_lib.moe_params(kg, cfg, blk.moe, dtype)
    elif blk.d_ff:
        p["norm2"] = jnp.zeros((d,), dtype)
        p["mlp"] = mlp_params(kg, cfg.d_model, blk.d_ff, dtype)
    if blk.post_norms:
        p["norm1_post"] = jnp.zeros((d,), dtype)
        p["norm2_post"] = jnp.zeros((d,), dtype)
    return p


def init_params(cfg: ModelCfg, key) -> dict:
    dtype = dt(cfg.param_dtype)
    kg = KeyGen(key)
    params: dict[str, Any] = {
        "embed": _init(kg(), (cfg.vocab_size, cfg.d_model), cfg.d_model,
                       dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(kg(), (cfg.d_model, cfg.vocab_size),
                                  cfg.d_model, dtype)
    for i, blk in enumerate(cfg.prefix):
        params[f"pre{i}"] = _block_params(kg, blk, cfg, dtype)
    if cfg.n_repeats:
        def one_repeat(k):
            kg_r = KeyGen(k)
            return {f"blk{j}": _block_params(kg_r, blk, cfg, dtype)
                    for j, blk in enumerate(cfg.pattern)}
        keys = jax.random.split(kg(), cfg.n_repeats)
        params["pattern"] = jax.vmap(one_repeat)(keys)
    for i, blk in enumerate(cfg.suffix):
        params[f"suf{i}"] = _block_params(kg, blk, cfg, dtype)
    return params


# --------------------------------------------------------------------------
# Decode cache construction
# --------------------------------------------------------------------------

def _block_cache(blk: BlockCfg, cfg: ModelCfg, B: int, max_len: int, dtype):
    if blk.kind == "attn":
        W = min(blk.window, max_len) if blk.window else max_len
        shape = (B, W, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if blk.kind == "ssd":
        s = blk.ssd
        H = s.d_inner // s.head_dim
        conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
        return {"conv": jnp.zeros((B, s.d_conv - 1, conv_ch), dtype),
                "state": jnp.zeros((B, H, s.head_dim, s.d_state),
                                   jnp.float32)}
    if blk.kind == "rglru":
        r = blk.rglru
        return {"conv": jnp.zeros((B, r.d_conv - 1, r.d_rnn), dtype),
                "h": jnp.zeros((B, r.d_rnn), jnp.float32)}
    raise ValueError(blk.kind)


def init_cache(cfg: ModelCfg, B: int, max_len: int) -> dict:
    dtype = dt(cfg.param_dtype)
    cache: dict[str, Any] = {}
    for i, blk in enumerate(cfg.prefix):
        cache[f"pre{i}"] = _block_cache(blk, cfg, B, max_len, dtype)
    if cfg.n_repeats:
        def one(_):
            return {f"blk{j}": _block_cache(blk, cfg, B, max_len, dtype)
                    for j, blk in enumerate(cfg.pattern)}
        cache["pattern"] = jax.vmap(one)(jnp.arange(cfg.n_repeats))
    for i, blk in enumerate(cfg.suffix):
        cache[f"suf{i}"] = _block_cache(blk, cfg, B, max_len, dtype)
    return cache


def cache_spec(cfg: ModelCfg, ctx: ShardCtx):
    """PartitionSpec tree for the decode cache: KV sequence over `model`
    (flash-decoding), recurrent states channel-sharded over `model`."""
    from jax.sharding import PartitionSpec as P
    dp = ctx.dp_spec

    def blk_spec(blk: BlockCfg):
        if blk.kind == "attn":
            return {"k": P(dp, ctx.tp, None, None),
                    "v": P(dp, ctx.tp, None, None)}
        if blk.kind == "ssd":
            return {"conv": P(dp, None, ctx.tp),
                    "state": P(dp, ctx.tp, None, None)}
        return {"conv": P(dp, None, ctx.tp), "h": P(dp, ctx.tp)}

    spec: dict[str, Any] = {}
    for i, blk in enumerate(cfg.prefix):
        spec[f"pre{i}"] = blk_spec(blk)
    if cfg.n_repeats:
        spec["pattern"] = {f"blk{j}": jax.tree.map(
            lambda s: P(*((None,) + tuple(s))), blk_spec(blk),
            is_leaf=lambda s: isinstance(s, P))
            for j, blk in enumerate(cfg.pattern)}
    for i, blk in enumerate(cfg.suffix):
        spec[f"suf{i}"] = blk_spec(blk)
    return spec


# --------------------------------------------------------------------------
# Block application
# --------------------------------------------------------------------------

def _zero_aux():
    return {k: jnp.float32(0.0) for k in AUX_SUM + AUX_MAX}


def _merge_aux(acc, new):
    out = dict(acc)
    for k in AUX_SUM:
        out[k] = acc[k] + new.get(k, 0.0)
    for k in AUX_MAX:
        out[k] = jnp.maximum(acc[k], new.get(k, 0.0))
    return out


def apply_block(h, p, blk: BlockCfg, cfg: ModelCfg, ctx: ShardCtx, *,
                positions=None, cache=None, pos=None, decode: bool = False,
                collect_cache: bool = False):
    """One residual block. Returns (h, new_cache, aux).

    ``collect_cache`` (prefill): emit the decode cache from a full-sequence
    pass (attention K/V, SSD conv+state, RG-LRU conv+h)."""
    aux = {}
    x = rms_norm(h, p["norm1"], cfg.norm_eps)
    new_cache = cache
    if blk.kind == "attn":
        if decode:
            y, ck, cv = attention_decode(x, p["attn"], blk, cfg, ctx,
                                         cache_k=cache["k"],
                                         cache_v=cache["v"], pos=pos)
            new_cache = {"k": ck, "v": cv}
        elif collect_cache:
            y, (ck, cv) = attention(x, p["attn"], blk, cfg, ctx,
                                    positions=positions, return_kv=True)
            new_cache = {"k": ck, "v": cv}
        else:
            y = attention(x, p["attn"], blk, cfg, ctx, positions=positions)
    elif blk.kind == "ssd":
        y, conv, state = ssd_mixer(
            x, p["ssd"], blk.ssd, cfg, ctx, decode=decode,
            conv_state=None if cache is None else cache["conv"],
            ssm_state=None if cache is None else cache["state"])
        if cache is not None or collect_cache:
            new_cache = {"conv": conv, "state": state}
    elif blk.kind == "rglru":
        y, conv, hst = rglru_mixer(
            x, p["rglru"], blk.rglru, cfg, ctx, decode=decode,
            conv_state=None if cache is None else cache["conv"],
            h_state=None if cache is None else cache["h"])
        if cache is not None or collect_cache:
            new_cache = {"conv": conv, "h": hst}
    if blk.post_norms:
        y = rms_norm(y, p["norm1_post"], cfg.norm_eps)
    h = h + y

    if blk.moe is not None or blk.d_ff:
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        if blk.moe is not None:
            y, aux = moe_lib.moe(x, p["moe"], blk.moe, cfg, ctx,
                                 decode=decode)
        else:
            y = mlp(x, p["mlp"], cfg, ctx)
        if blk.post_norms:
            y = rms_norm(y, p["norm2_post"], cfg.norm_eps)
        h = h + y
    return h, new_cache, aux


# --------------------------------------------------------------------------
# Forward / loss
# --------------------------------------------------------------------------

def embed_tokens(params, tokens, cfg: ModelCfg, ctx: ShardCtx,
                 frontend_embeds=None):
    h = jnp.take(params["embed"], tokens, axis=0).astype(dt(cfg.compute_dtype))
    if cfg.emb_scale:
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(h.dtype)
    if frontend_embeds is not None:
        h = jnp.concatenate(
            [frontend_embeds.astype(h.dtype), h], axis=1)
    return ctx.cs_res(h)


def forward(params, tokens, cfg: ModelCfg, ctx: ShardCtx,
            frontend_embeds=None):
    """Full-sequence forward -> (final hidden states, aux)."""
    h = embed_tokens(params, tokens, cfg, ctx, frontend_embeds)
    S = h.shape[1]
    positions = jnp.arange(S)
    aux = _zero_aux()
    for i, blk in enumerate(cfg.prefix):
        h, _, a = apply_block(h, params[f"pre{i}"], blk, cfg, ctx,
                              positions=positions)
        aux = _merge_aux(aux, a)

    if cfg.n_repeats:
        def body(carry, p_slice):
            h, aux = carry
            for j, blk in enumerate(cfg.pattern):
                h, _, a = apply_block(h, p_slice[f"blk{j}"], blk, cfg, ctx,
                                      positions=positions)
                aux = _merge_aux(aux, a)
            return (h, aux), None
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["pattern"])

    for i, blk in enumerate(cfg.suffix):
        h, _, a = apply_block(h, params[f"suf{i}"], blk, cfg, ctx,
                              positions=positions)
        aux = _merge_aux(aux, a)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return h, aux


def logits_from_h(params, h, cfg: ModelCfg, ctx: ShardCtx):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", h, w,
                        preferred_element_type=jnp.float32)
    logits = ctx.cs(logits, ctx.dp_spec, None, ctx.tp)
    return softcap(logits, cfg.final_softcap)


def sharded_xent(logits, labels, weights=None):
    """Cross entropy over a vocab-sharded logits tensor.  All reductions run
    over the sharded vocab dim — GSPMD inserts the (tiny) all-reduces; the
    full logits are never gathered."""
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = (jnp.arange(V, dtype=labels.dtype)[None, None, :]
              == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    if weights is None:
        weights = jnp.ones_like(nll)
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    loss = jnp.sum(nll * weights) / denom
    z_loss = jnp.sum(jnp.square(lse) * weights) / denom
    return loss, z_loss


def loss_fn(params, batch, cfg: ModelCfg, ctx: ShardCtx, *,
            z_weight: float = 1e-4):
    """batch: {"tokens": (B,S'), "labels": (B,S), ["frontend_embeds"],
    ["weights"]}.  Returns (total_loss, metrics)."""
    h, aux = forward(params, batch["tokens"], cfg, ctx,
                     frontend_embeds=batch.get("frontend_embeds"))
    logits = logits_from_h(params, h, cfg, ctx)
    loss, z_loss = sharded_xent(logits, batch["labels"],
                                batch.get("weights"))
    total = loss + z_weight * z_loss
    moe_blocks = any(b.moe is not None for b in cfg.all_blocks())
    if moe_blocks:
        m = next(b.moe for b in cfg.all_blocks() if b.moe is not None)
        total = (total + m.router_aux_weight * aux["moe_lb_loss"]
                 + m.router_z_weight * aux["moe_z_loss"])
    metrics = {"loss": loss, "z_loss": z_loss, **aux}
    return total, metrics


def prefill(params, tokens, cfg: ModelCfg, ctx: ShardCtx,
            frontend_embeds=None):
    """Full-context prefill: returns (last-position logits (B,V), cache).

    The cache layout matches init_cache with max_len == S (window blocks
    keep the last `window` positions; the serving engine copies it into its
    preallocated ring/linear buffers)."""
    h = embed_tokens(params, tokens, cfg, ctx, frontend_embeds)
    S = h.shape[1]
    positions = jnp.arange(S)
    cache: dict[str, Any] = {}
    for i, blk in enumerate(cfg.prefix):
        h, c, _ = apply_block(h, params[f"pre{i}"], blk, cfg, ctx,
                              positions=positions, collect_cache=True)
        cache[f"pre{i}"] = c

    if cfg.n_repeats:
        def body(h, p_slice):
            new_c = {}
            for j, blk in enumerate(cfg.pattern):
                h, c, _ = apply_block(h, p_slice[f"blk{j}"], blk, cfg, ctx,
                                      positions=positions,
                                      collect_cache=True)
                new_c[f"blk{j}"] = c
            return h, new_c
        h, pat_cache = jax.lax.scan(body, h, params["pattern"])
        cache["pattern"] = pat_cache

    for i, blk in enumerate(cfg.suffix):
        h, c, _ = apply_block(h, params[f"suf{i}"], blk, cfg, ctx,
                              positions=positions, collect_cache=True)
        cache[f"suf{i}"] = c
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_h(params, h[:, -1:], cfg, ctx)
    return logits[:, 0], cache


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_step(params, tokens, cache, pos, cfg: ModelCfg, ctx: ShardCtx):
    """One-token decode. tokens: (B, 1); pos: scalar int32 (current index;
    cache holds positions < pos... pos).  Returns (logits (B, V), cache)."""
    h = embed_tokens(params, tokens, cfg, ctx)
    aux = _zero_aux()
    new_cache: dict[str, Any] = {}
    for i, blk in enumerate(cfg.prefix):
        h, c, a = apply_block(h, params[f"pre{i}"], blk, cfg, ctx,
                              cache=cache[f"pre{i}"], pos=pos, decode=True)
        new_cache[f"pre{i}"] = c
        aux = _merge_aux(aux, a)

    if cfg.n_repeats:
        def body(carry, xs):
            h, aux = carry
            p_slice, c_slice = xs
            new_c = {}
            for j, blk in enumerate(cfg.pattern):
                h, c, a = apply_block(h, p_slice[f"blk{j}"], blk, cfg, ctx,
                                      cache=c_slice[f"blk{j}"], pos=pos,
                                      decode=True)
                new_c[f"blk{j}"] = c
                aux = _merge_aux(aux, a)
            return (h, aux), new_c
        (h, aux), pat_cache = jax.lax.scan(
            body, (h, aux), (params["pattern"], cache["pattern"]))
        new_cache["pattern"] = pat_cache

    for i, blk in enumerate(cfg.suffix):
        h, c, a = apply_block(h, params[f"suf{i}"], blk, cfg, ctx,
                              cache=cache[f"suf{i}"], pos=pos, decode=True)
        new_cache[f"suf{i}"] = c
        aux = _merge_aux(aux, a)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = logits_from_h(params, h, cfg, ctx)
    return logits[:, 0], new_cache
