from repro.models.common import (BlockCfg, ModelCfg, MoECfg, RGLRUCfg,
                                 SSDCfg)
from repro.models.layers import ShardCtx, single_device_mesh

__all__ = ["BlockCfg", "ModelCfg", "MoECfg", "RGLRUCfg", "SSDCfg",
           "ShardCtx", "single_device_mesh"]
