"""Composable JAX layers for the assigned architectures.

Sharding design (mesh axes ``("pod","data","model")`` or ``("data","model")``):

* batch / tokens shard over the DP axes (``pod`` x ``data``);
* ``model`` carries TP: column/row-parallel projections (heads when the head
  count divides the axis, otherwise head_dim + context-parallel attention),
  MLP ff dim, MoE expert-FF dim, SSD/RG-LRU channel dims;
* MoE experts shard over the DP axes (EP) with capacity-based all_to_all
  dispatch inside ``shard_map`` (see moe.py);
* decode uses a sequence-sharded KV cache ("flash-decoding": per-shard partial
  attention, GSPMD merges the softmax statistics with tiny all-reduces).

Everything is written against *global* semantics with
``with_sharding_constraint`` hints; the same code runs unsharded on one CPU
device (``ShardCtx(mesh=None)`` turns every hint into a no-op).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import BlockCfg, ModelCfg, RGLRUCfg, SSDCfg

# --------------------------------------------------------------------------
# Sharding context
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PerfFlags:
    """Hillclimb knobs (EXPERIMENTS.md §Perf).  Defaults = paper-faithful
    baseline; each flag is one candidate move in the floorline-style
    backtracking optimization (distributed/autoshard.py)."""

    moe_sp_dispatch: bool = False   # slice MoE a2a payload over `model`
    sp_residual: bool = False       # Megatron-SP: residual stream sequence-
                                    # sharded over `model` (ag/rs per block)


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Mesh + axis roles threaded through every layer.

    ``mesh=None`` disables all constraints (single-device smoke tests).
    """

    mesh: Optional[Mesh] = None
    dp: tuple[str, ...] = ("data",)     # batch axes (("pod","data") multi-pod)
    tp: Optional[str] = "model"
    batch_sharded: bool = True          # False when B < |dp| (e.g. long_500k)
    flags: PerfFlags = PerfFlags()

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp is None:
            return 1
        return self.mesh.shape[self.tp]

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in self.dp]))

    @property
    def dp_spec(self):
        return self.dp if self.batch_sharded else None

    def cs(self, x: jax.Array, *dims) -> jax.Array:
        """with_sharding_constraint helper; dims are PartitionSpec entries."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*dims)))

    def cs_res(self, y: jax.Array) -> jax.Array:
        """Residual-stream constraint for (B, S, d) tensors: sequence-
        sharded over `model` when flags.sp_residual (Megatron-SP), else
        replicated over `model`."""
        if self.mesh is None:
            return y
        sp = self.tp if (self.flags.sp_residual
                         and y.shape[1] % max(self.tp_size, 1) == 0) else None
        return self.cs(y, self.dp_spec, sp, None)

    def can_shard(self, dim_size: int) -> bool:
        return self.tp is not None and dim_size % max(self.tp_size, 1) == 0


def single_device_mesh() -> Mesh:
    """1-device mesh with the production axis names (for smoke tests)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=np.array(jax.devices()[:1]))


# --------------------------------------------------------------------------
# dtype / init helpers
# --------------------------------------------------------------------------

def dt(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def _init(key, shape, fan_in, dtype):
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


class KeyGen:
    """Deterministic per-leaf key derivation."""

    def __init__(self, key):
        self.key = key
        self.n = 0

    def __call__(self):
        self.n += 1
        return jax.random.fold_in(self.key, self.n)


# --------------------------------------------------------------------------
# Norms and positional embeddings
# --------------------------------------------------------------------------

def rms_norm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, hd/2)
    angles = angles[..., None, :]                                # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


ACTS: dict[str, Callable] = {
    "silu": jax.nn.silu, "gelu": functools.partial(jax.nn.gelu, approximate=True),
    "relu": jax.nn.relu,
}


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def attn_params(kg: KeyGen, cfg: ModelCfg, dtype) -> dict:
    d = cfg.d_model
    p = {
        "wq": _init(kg(), (d, cfg.n_heads, cfg.head_dim), d, dtype),
        "wk": _init(kg(), (d, cfg.n_kv_heads, cfg.head_dim), d, dtype),
        "wv": _init(kg(), (d, cfg.n_kv_heads, cfg.head_dim), d, dtype),
        "wo": _init(kg(), (cfg.n_heads, cfg.head_dim, d), cfg.q_dim, dtype),
    }
    if cfg.qk_norm:
        p["q_gamma"] = jnp.zeros((cfg.head_dim,), dtype)
        p["k_gamma"] = jnp.zeros((cfg.head_dim,), dtype)
    return p


def _mask_bias(q_pos: jax.Array, kv_pos: jax.Array,
               window: Optional[int], *, causal: bool = True) -> jax.Array:
    """(..., Sq, Skv) additive mask bias in f32."""
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    ok = (d >= 0) if causal else jnp.ones_like(d, dtype=bool)
    if window is not None:
        ok &= d < window
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def _sdpa(q, k, v, bias, cfg: ModelCfg):
    """Grouped-query attention core. q:(B,Sq,H,hd) k/v:(B,Skv,K,hd)."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = softcap(scores, cfg.attn_softcap)
    scores = scores + bias[..., None, None, :, :] if bias.ndim == 2 else scores + bias
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _chunked_sdpa(q, k, v, q_pos, kv_pos, window, cfg: ModelCfg,
                  kv_chunk: int = 1024, causal: bool = True):
    """Lazy-softmax (flash-style) attention: scan over KV chunks carrying
    running (max, denom, acc). Keeps the score matrix at
    (B,K,G,Sq,kv_chunk) instead of (..., Skv) — required for 32k prefill."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    Skv = k.shape[1]
    n_chunks = Skv // kv_chunk
    qg = (q.reshape(B, Sq, K, G, hd).astype(jnp.float32)
          / math.sqrt(hd))
    kc = k.reshape(B, n_chunks, kv_chunk, K, hd)
    vc = v.reshape(B, n_chunks, kv_chunk, K, hd)
    pc = kv_pos.reshape(n_chunks, kv_chunk)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kb.astype(jnp.float32))
        s = softcap(s, cfg.attn_softcap)
        s = s + _mask_bias(q_pos, pb, window, causal=causal)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + jnp.sum(p, axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, K, G, Sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, (1, 2), (2, 3))          # (B,Sq,K,G,hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def attention(x: jax.Array, p: dict, blk: BlockCfg, cfg: ModelCfg,
              ctx: ShardCtx, *, positions: jax.Array,
              causal: bool = True, xkv: jax.Array | None = None,
              return_kv: bool = False):
    """Full-sequence attention (training / prefill).

    TP mode: "head" (H % tp == 0) shards Q heads; otherwise context-parallel:
    Q is sequence-sharded and KV gathered — no duplicated FLOPs either way.
    ``xkv`` switches to cross-attention (whisper decoder).
    ``return_kv`` additionally returns the rotary-embedded (k, v) for
    prefill cache construction (window blocks: last ``window`` positions).
    """
    B, S, dmod = x.shape
    head_tp = ctx.can_shard(cfg.n_heads)
    kv_src = x if xkv is None else xkv

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_gamma"], cfg.norm_eps)
        k = rms_norm(k, p["k_gamma"], cfg.norm_eps)
    kv_pos = positions if xkv is None else jnp.arange(kv_src.shape[1])
    if blk.kind == "attn" and xkv is None:
        # cross-attention is content-based (no rope), matching decode
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)

    dp = ctx.dp_spec
    if head_tp:
        kv_tp = ctx.can_shard(cfg.n_kv_heads)
        q = ctx.cs(q, dp, None, ctx.tp, None)
        # kv heads that don't divide tp are replicated (GQA kv is small);
        # the weights stay head_dim-sharded for memory — GSPMD emits one
        # small all-gather after the projection.
        k = ctx.cs(k, dp, None, ctx.tp if kv_tp else None, None)
        v = ctx.cs(v, dp, None, ctx.tp if kv_tp else None, None)
    else:
        # context parallel: shard sequence of Q; KV gathered (small for GQA)
        q = ctx.cs(q, dp, ctx.tp, None, None)
        k = ctx.cs(k, dp, None, None, None)
        v = ctx.cs(v, dp, None, None, None)

    Skv = k.shape[1]
    if Skv > 4096 and Skv % 1024 == 0:
        out = _chunked_sdpa(q, k, v, positions, kv_pos, blk.window, cfg,
                            causal=causal)
    else:
        bias = _mask_bias(positions, kv_pos, blk.window, causal=causal)
        out = _sdpa(q, k, v, bias, cfg)

    if head_tp:
        out = ctx.cs(out, dp, None, ctx.tp, None)
    else:
        out = ctx.cs(out, dp, ctx.tp, None, None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = ctx.cs_res(y)
    if return_kv:
        if blk.window is not None and k.shape[1] > blk.window:
            k, v = k[:, -blk.window:], v[:, -blk.window:]
        return y, (k, v)
    return y


def attention_decode(x: jax.Array, p: dict, blk: BlockCfg, cfg: ModelCfg,
                     ctx: ShardCtx, *, cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, cross: bool = False):
    """Single-token decode against a sequence-sharded KV cache
    ("flash-decoding": cache S over `model`; partial softmax merged by GSPMD).

    Projections are row-parallel over head_dim (divisible by 16 for every
    assigned arch) so no FLOPs are duplicated regardless of head count.
    Returns (y, new_cache_k, new_cache_v).  x: (B, 1, d).
    """
    dp = ctx.dp_spec
    W = cache_k.shape[1]

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_gamma"], cfg.norm_eps)
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if cfg.qk_norm:
            k_new = rms_norm(k_new, p["k_gamma"], cfg.norm_eps)
        q = rope(q, pos[None], cfg.rope_theta) if blk.kind == "attn" else q
        if blk.kind == "attn":
            k_new = rope(k_new, pos[None], cfg.rope_theta)
        slot = pos % W if blk.window is not None else pos   # ring buffer
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new.astype(cache_k.dtype), slot, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new.astype(cache_v.dtype), slot, axis=1)
    else:
        q = q  # cross-attention: cache is the precomputed encoder K/V

    cache_k = ctx.cs(cache_k, dp, ctx.tp, None, None)
    cache_v = ctx.cs(cache_v, dp, ctx.tp, None, None)
    q = ctx.cs(q, dp, None, None, None)

    # valid-slot mask
    idx = jnp.arange(W)
    if cross:
        valid = jnp.ones((W,), bool)
        kv_pos = idx
    elif blk.window is not None:
        # ring buffer holds positions (pos-W, pos]; slot s holds the largest
        # p <= pos with p % W == s.
        kv_pos = pos - ((pos - idx) % W)
        valid = kv_pos >= 0
    else:
        kv_pos = idx
        valid = idx <= pos

    B, _, H, hd = q.shape
    K = cache_k.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, cache_k,
                   preferred_element_type=jnp.float32) / math.sqrt(hd)
    s = softcap(s, cfg.attn_softcap)
    s = jnp.where(valid[None, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", w.astype(cache_v.dtype), cache_v)
    out = out.reshape(B, 1, H, hd)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return ctx.cs(y, dp, None, None), cache_k, cache_v


# --------------------------------------------------------------------------
# Dense MLP (SwiGLU family)
# --------------------------------------------------------------------------

def mlp_params(kg: KeyGen, d: int, d_ff: int, dtype) -> dict:
    return {
        "wi": _init(kg(), (d, d_ff), d, dtype),
        "wg": _init(kg(), (d, d_ff), d, dtype),
        "wo": _init(kg(), (d_ff, d), d_ff, dtype),
    }


def mlp(x: jax.Array, p: dict, cfg: ModelCfg, ctx: ShardCtx) -> jax.Array:
    """Gated MLP, column->row parallel over `model` (one psum per block)."""
    dp = ctx.dp_spec
    act = ACTS[cfg.act_fn]
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    h = ctx.cs(h, dp, None, ctx.tp)
    g = ctx.cs(g, dp, None, ctx.tp)
    y = jnp.einsum("bsf,fd->bsd", act(g) * h, p["wo"])
    return ctx.cs_res(y)


# --------------------------------------------------------------------------
# Mamba-2 SSD mixer (chunked, matmul-dominant — MXU friendly)
# --------------------------------------------------------------------------

def ssd_params(kg: KeyGen, cfg: ModelCfg, s: SSDCfg, dtype) -> dict:
    d = cfg.d_model
    H = s.d_inner // s.head_dim
    conv_ch = s.d_inner + 2 * s.n_groups * s.d_state
    return {
        "in_xz": _init(kg(), (d, 2 * s.d_inner), d, dtype),
        "in_bc": _init(kg(), (d, 2 * s.n_groups * s.d_state), d, dtype),
        "in_dt": _init(kg(), (d, H), d, dtype),
        "conv_w": _init(kg(), (s.d_conv, conv_ch), s.d_conv, dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),
        "norm_g": jnp.zeros((s.d_inner,), dtype),
        "out": _init(kg(), (s.d_inner, d), s.d_inner, dtype),
    }


def _ssd_chunk_scan(xh, a_log_dt, Bm, Cm, chunk: int, init_state=None):
    """SSD (state-space duality) chunked scan.

    xh: (B,S,H,P) inputs (already dt-scaled), a_log_dt: (B,S,H) log decay,
    Bm/Cm: (B,S,G,N) input/output maps. Returns (y (B,S,H,P), final_state
    (B,H,P,N)). Intra-chunk handled with dense matmuls; inter-chunk carried
    by a lax.scan over S/chunk steps.
    """
    Bsz, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = S // chunk
    rep = H // G
    xc = xh.reshape(Bsz, nc, chunk, H, Pd)
    ac = a_log_dt.reshape(Bsz, nc, chunk, H)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, axis=3)

    cum = jnp.cumsum(ac, axis=2)                         # (B,nc,L,H)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Lq,Lk,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk (diag block): y_intra = (C B^T * L) @ x
    cb = jnp.einsum("bnqhs,bnkhs->bnqkh", Cc, Bc)
    y_intra = jnp.einsum("bnqkh,bnqkh,bnkhp->bnqhp", cb, L, xc)

    # chunk-local state contribution: sum_k exp(cum_end - cum_k) B_k x_k
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (B,nc,L,H)
    chunk_states = jnp.einsum("bnkhs,bnkh,bnkhp->bnhps",
                              Bc, decay_to_end, xc)      # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])              # (B,nc,H)

    def body(state, xs):
        cs_, cd_, cum_ = xs                              # per-chunk
        new_state = state * cd_[..., None, None] + cs_
        return new_state, state                          # emit state *before* chunk

    s0 = (jnp.zeros((Bsz, H, Pd, N), xh.dtype) if init_state is None
          else init_state)
    final, prev_states = jax.lax.scan(
        body, s0, (jnp.moveaxis(chunk_states, 1, 0),
                   jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(cum, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (B,nc,H,P,N)

    # inter-chunk: y_inter = C_q exp(cum_q) @ state_in
    y_inter = jnp.einsum("bnqhs,bnqh,bnhps->bnqhp",
                         Cc, jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y, final


def ssd_mixer(x, p, s: SSDCfg, cfg: ModelCfg, ctx: ShardCtx,
              *, conv_state=None, ssm_state=None, decode: bool = False):
    """Mamba-2 block. Channels (d_inner, heads) shard over `model`."""
    dp = ctx.dp_spec
    B, S, _ = x.shape
    H = s.d_inner // s.head_dim
    xz = jnp.einsum("bsd,de->bse", x, p["in_xz"])
    bc = jnp.einsum("bsd,de->bse", x, p["in_bc"])
    dtv = jnp.einsum("bsd,dh->bsh", x, p["in_dt"]).astype(jnp.float32)
    xz = ctx.cs(xz, dp, None, ctx.tp)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_in = jnp.concatenate([xi, bc], axis=-1)

    if decode:
        # causal depthwise conv over the last d_conv inputs
        window = jnp.concatenate([conv_state, conv_in], axis=1)
        new_conv_state = window[:, 1:]
        conv_out = jnp.einsum("btc,tc->bc", window, p["conv_w"])[:, None, :]
    else:
        pad = jnp.zeros((B, s.d_conv - 1, conv_in.shape[-1]), conv_in.dtype)
        win = jnp.concatenate([pad, conv_in], axis=1)
        idx = jnp.arange(S)[:, None] + jnp.arange(s.d_conv)[None, :]
        conv_out = jnp.einsum("bstc,tc->bsc", win[:, idx], p["conv_w"])
        new_conv_state = win[:, -(s.d_conv - 1):] if s.d_conv > 1 else None
    conv_out = jax.nn.silu(conv_out)
    xi = conv_out[..., :s.d_inner]
    Bm, Cm = jnp.split(
        conv_out[..., s.d_inner:].reshape(B, -1, 2 * s.n_groups, s.d_state),
        2, axis=2)

    dtv = jax.nn.softplus(dtv + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    a_log_dt = dtv * A                                    # (B,S,H) log decay
    xi_h = xi.reshape(B, -1, H, s.head_dim).astype(jnp.float32)
    xh = xi_h * dtv[..., None]

    if decode:
        a = jnp.exp(a_log_dt)[:, 0]                       # (B,H)
        st = ssm_state * a[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xh[:, 0],
            jnp.repeat(Bm[:, 0], H // s.n_groups, axis=1).astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", st,
                       jnp.repeat(Cm[:, 0], H // s.n_groups,
                                  axis=1).astype(jnp.float32))[:, None]
        new_ssm_state = st
        y = y.reshape(B, 1, H, s.head_dim)
    else:
        chunk = next(c for c in range(min(s.chunk, S), 0, -1) if S % c == 0)
        y, new_ssm_state = _ssd_chunk_scan(
            xh, a_log_dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
            chunk, init_state=ssm_state)
        y = y.reshape(B, S, H, s.head_dim)

    y = y + xi_h * p["D"][:, None]                        # skip (D term)
    y = y.reshape(B, -1, s.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, p["norm_g"], cfg.norm_eps)
    y = ctx.cs(y, dp, None, ctx.tp)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return ctx.cs_res(out), new_conv_state, new_ssm_state


# --------------------------------------------------------------------------
# RG-LRU mixer (RecurrentGemma)
# --------------------------------------------------------------------------

def rglru_params(kg: KeyGen, cfg: ModelCfg, r: RGLRUCfg, dtype) -> dict:
    d = cfg.d_model
    return {
        "in_xy": _init(kg(), (d, 2 * r.d_rnn), d, dtype),
        "conv_w": _init(kg(), (r.d_conv, r.d_rnn), r.d_conv, dtype),
        "w_r": _init(kg(), (r.d_rnn, r.d_rnn), r.d_rnn, dtype),
        "w_i": _init(kg(), (r.d_rnn, r.d_rnn), r.d_rnn, dtype),
        # a = sigmoid(a_param)^(c*r): init so a^c ~ 0.9..0.999
        "a_param": jnp.asarray(
            np.log(np.expm1(np.linspace(0.9, 0.999, r.d_rnn) ** (
                1.0 / r.c_exponent))), jnp.float32),
        "out": _init(kg(), (r.d_rnn, d), r.d_rnn, dtype),
    }


def rglru_mixer(x, p, r: RGLRUCfg, cfg: ModelCfg, ctx: ShardCtx,
                *, conv_state=None, h_state=None, decode: bool = False):
    """Real-gated LRU: h_t = a_t*h_{t-1} + sqrt(1-a_t^2)*(i_t * x_t)."""
    dp = ctx.dp_spec
    B, S, _ = x.shape
    xy = jnp.einsum("bsd,de->bse", x, p["in_xy"])
    xy = ctx.cs(xy, dp, None, ctx.tp)
    xb, gate_y = jnp.split(xy, 2, axis=-1)

    if decode:
        window = jnp.concatenate([conv_state, xb], axis=1)
        new_conv_state = window[:, 1:]
        xc = jnp.einsum("btc,tc->bc", window, p["conv_w"])[:, None, :]
    else:
        pad = jnp.zeros((B, r.d_conv - 1, xb.shape[-1]), xb.dtype)
        win = jnp.concatenate([pad, xb], axis=1)
        idx = jnp.arange(S)[:, None] + jnp.arange(r.d_conv)[None, :]
        xc = jnp.einsum("bstc,tc->bsc", win[:, idx], p["conv_w"])
        new_conv_state = win[:, -(r.d_conv - 1):] if r.d_conv > 1 else None

    rg = jax.nn.sigmoid(jnp.einsum("bsc,ce->bse", xc, p["w_r"])
                        .astype(jnp.float32))
    ig = jax.nn.sigmoid(jnp.einsum("bsc,ce->bse", xc, p["w_i"])
                        .astype(jnp.float32))
    log_a0 = jax.nn.log_sigmoid(p["a_param"])            # (d_rnn,)
    log_a = r.c_exponent * rg * log_a0                   # (B,S,d_rnn)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * ig * xc.astype(jnp.float32)

    if decode:
        h = a[:, 0] * h_state + gated[:, 0]
        new_h, hs = h, h[:, None]
    else:
        if h_state is not None:
            gated = gated.at[:, 0].add(a[:, 0] * h_state)

        def comb(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        av, hs = jax.lax.associative_scan(comb, (a, gated), axis=1)
        new_h = hs[:, -1]

    y = hs.astype(x.dtype) * jax.nn.gelu(gate_y)
    y = ctx.cs(y, dp, None, ctx.tp)
    out = jnp.einsum("bse,ed->bsd", y, p["out"])
    return ctx.cs_res(out), new_conv_state, new_h
