"""Encoder-decoder backbone (whisper-base).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings
(B, n_frames, d).  The transformer backbone is fully implemented: a
bidirectional encoder and a causal decoder with cross-attention, both
scan-over-layers.  Hardware adaptation note: we use RoPE in self-attention
in place of whisper's learned/sinusoidal absolute embeddings (a positional
parameterization choice, orthogonal to the paper's technique).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import BlockCfg, ModelCfg
from repro.models.layers import (KeyGen, ShardCtx, attention, attention_decode,
                                 attn_params, dt, mlp, mlp_params, rms_norm,
                                 _init)
from repro.models.lm import sharded_xent


@dataclasses.dataclass(frozen=True)
class EncDecCfg:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    vocab_size: int
    d_ff: int
    n_enc_layers: int
    n_dec_layers: int
    n_frames: int = 1500
    act_fn: str = "gelu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "block"

    @property
    def mc(self) -> ModelCfg:
        """Inner ModelCfg view used by the shared attention/MLP layers."""
        return ModelCfg(
            name=self.name, d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            vocab_size=self.vocab_size, act_fn=self.act_fn,
            rope_theta=self.rope_theta, norm_eps=self.norm_eps,
            tie_embeddings=True, param_dtype=self.param_dtype,
            compute_dtype=self.compute_dtype)

    @property
    def n_layers(self) -> int:
        return self.n_enc_layers + self.n_dec_layers

    def param_count(self) -> int:
        d, ff = self.d_model, self.d_ff
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * d
        enc = self.n_enc_layers * (attn + 3 * d * ff + 2 * d)
        dec = self.n_dec_layers * (2 * attn + 3 * d * ff + 3 * d)
        return self.vocab_size * d + enc + dec + 2 * d


_BLK = BlockCfg(kind="attn")


def init_params(cfg: EncDecCfg, key) -> dict:
    dtype = dt(cfg.param_dtype)
    kg = KeyGen(key)
    mc = cfg.mc

    def enc_block(k):
        kg_b = KeyGen(k)
        return {"norm1": jnp.zeros((cfg.d_model,), dtype),
                "attn": attn_params(kg_b, mc, dtype),
                "norm2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": mlp_params(kg_b, cfg.d_model, cfg.d_ff, dtype)}

    def dec_block(k):
        kg_b = KeyGen(k)
        return {"norm1": jnp.zeros((cfg.d_model,), dtype),
                "attn": attn_params(kg_b, mc, dtype),
                "norm_x": jnp.zeros((cfg.d_model,), dtype),
                "xattn": attn_params(kg_b, mc, dtype),
                "norm2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": mlp_params(kg_b, cfg.d_model, cfg.d_ff, dtype)}

    return {
        "embed": _init(kg(), (cfg.vocab_size, cfg.d_model), cfg.d_model,
                       dtype),
        "enc": jax.vmap(enc_block)(jax.random.split(kg(), cfg.n_enc_layers)),
        "dec": jax.vmap(dec_block)(jax.random.split(kg(), cfg.n_dec_layers)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "dec_norm": jnp.zeros((cfg.d_model,), dtype),
    }


def encode(params, frames, cfg: EncDecCfg, ctx: ShardCtx):
    """frames: (B, n_frames, d) precomputed embeddings (frontend stub)."""
    mc = cfg.mc
    h = ctx.cs(frames.astype(dt(cfg.compute_dtype)), ctx.dp_spec, None, None)
    positions = jnp.arange(h.shape[1])

    def body(h, p):
        x = rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + attention(x, p["attn"], _BLK, mc, ctx, positions=positions,
                          causal=False)
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        h = h + mlp(x, p["mlp"], mc, ctx)
        return h, None
    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["enc"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def decode_train(params, enc_out, tokens, cfg: EncDecCfg, ctx: ShardCtx):
    mc = cfg.mc
    h = jnp.take(params["embed"], tokens, axis=0).astype(
        dt(cfg.compute_dtype))
    h = ctx.cs(h, ctx.dp_spec, None, None)
    positions = jnp.arange(h.shape[1])

    def body(h, p):
        x = rms_norm(h, p["norm1"], cfg.norm_eps)
        h = h + attention(x, p["attn"], _BLK, mc, ctx, positions=positions)
        x = rms_norm(h, p["norm_x"], cfg.norm_eps)
        h = h + attention(x, p["xattn"], _BLK, mc, ctx, positions=positions,
                          causal=False, xkv=enc_out)
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        h = h + mlp(x, p["mlp"], mc, ctx)
        return h, None
    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = jax.lax.scan(body, h, params["dec"])
    return rms_norm(h, params["dec_norm"], cfg.norm_eps)


def loss_fn(params, batch, cfg: EncDecCfg, ctx: ShardCtx, *,
            z_weight: float = 1e-4):
    enc_out = encode(params, batch["frontend_embeds"], cfg, ctx)
    h = decode_train(params, enc_out, batch["tokens"], cfg, ctx)
    logits = jnp.einsum("bsd,dv->bsv", h, params["embed"].T,
                        preferred_element_type=jnp.float32)
    logits = ctx.cs(logits, ctx.dp_spec, None, ctx.tp)
    loss, z_loss = sharded_xent(logits, batch["labels"],
                                batch.get("weights"))
    return loss + z_weight * z_loss, {"loss": loss, "z_loss": z_loss}


# ---------------------------------------------------------------- decoding

def init_cache(cfg: EncDecCfg, B: int, max_len: int) -> dict:
    """Self-attn KV (ring over max_len) + precomputed cross K/V slots."""
    dtype = dt(cfg.param_dtype)
    kv = (B, max_len, cfg.n_kv_heads, cfg.head_dim)
    xv = (B, cfg.n_frames, cfg.n_kv_heads, cfg.head_dim)
    def one(_):
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
                "xk": jnp.zeros(xv, dtype), "xv": jnp.zeros(xv, dtype)}
    return {"dec": jax.vmap(one)(jnp.arange(cfg.n_dec_layers))}


def cache_spec(cfg: EncDecCfg, ctx: ShardCtx):
    from jax.sharding import PartitionSpec as P
    dp = ctx.dp_spec
    s = P(None, dp, ctx.tp, None, None)     # (L, B, S, K, hd): S over model
    # cross K/V span the fixed 1500 encoder frames (not 16-divisible, and
    # small) -> replicated over `model`
    x = P(None, dp, None, None, None)
    return {"dec": {"k": s, "v": s, "xk": x, "xv": x}}


def precompute_cross_cache(params, enc_out, cfg: EncDecCfg, ctx: ShardCtx,
                           cache: dict) -> dict:
    """Fill the cross-attention K/V from the encoder output once."""
    def one(p, c):
        xk = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wk"])
        xv = jnp.einsum("bsd,dhk->bshk", enc_out, p["xattn"]["wv"])
        return {**c, "xk": xk.astype(c["xk"].dtype),
                "xv": xv.astype(c["xv"].dtype)}
    dec = jax.vmap(one)(params["dec"], cache["dec"])
    return {"dec": dec}


def decode_step(params, tokens, cache, pos, cfg: EncDecCfg, ctx: ShardCtx):
    """One decoder token against self-KV cache + precomputed cross K/V."""
    mc = cfg.mc
    h = jnp.take(params["embed"], tokens, axis=0).astype(
        dt(cfg.compute_dtype))
    h = ctx.cs(h, ctx.dp_spec, None, None)

    def body(h, xs):
        p, c = xs
        x = rms_norm(h, p["norm1"], cfg.norm_eps)
        y, ck, cv = attention_decode(x, p["attn"], _BLK, mc, ctx,
                                     cache_k=c["k"], cache_v=c["v"], pos=pos)
        h = h + y
        x = rms_norm(h, p["norm_x"], cfg.norm_eps)
        y, _, _ = attention_decode(x, p["xattn"], _BLK, mc, ctx,
                                   cache_k=c["xk"], cache_v=c["xv"], pos=pos,
                                   cross=True)
        h = h + y
        x = rms_norm(h, p["norm2"], cfg.norm_eps)
        h = h + mlp(x, p["mlp"], mc, ctx)
        return h, {**c, "k": ck, "v": cv}

    h, dec_cache = jax.lax.scan(body, h, (params["dec"], cache["dec"]))
    h = rms_norm(h, params["dec_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", h, params["embed"].T,
                        preferred_element_type=jnp.float32)
    logits = ctx.cs(logits, ctx.dp_spec, None, ctx.tp)
    return logits[:, 0], {"dec": dec_cache}
