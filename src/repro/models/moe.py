"""Mixture-of-Experts channel block (token-choice top-k).

Distribution: experts shard over the ``data`` axis (EP=16 inside a pod —
expert parallelism stays on intra-pod ICI; pods replicate experts and act as
pure DP, which is also why the 1T kimi-k2 fits: weights live over
data x model = 256 ways).  Each expert's FF dim shards over ``model`` (TP).

Dispatch is capacity-based with a deterministic slot layout so that a single
tiled ``all_to_all`` moves tokens to their expert owners:

    send buffer (EP, E_loc, C3, d):  slot (dest, e_local, c) holds the c-th
    token this sender routes to expert dest*E_loc+e_local; C3 = ceil(T*k/E*cf)
    tokens per (sender, expert) pair; overflow tokens are dropped (standard
    capacity-factor semantics).

The paper's M0 insight (max-per-unit load, not aggregate, bounds step time)
maps 1:1 onto experts: `aux["max_expert_load"]` is the neurocore-aware metric
and the load-balance loss is the stage-1 "sparsity/balance-aware training"
analog.  See EXPERIMENTS.md §Perf for the dispatch-layout hillclimb.

``sp_dispatch=True`` slices the token payload over ``model`` before the
all_to_all (each TP shard moves d/16 of every token) instead of sending the
full ``d`` redundantly on every TP replica — 16x fewer wire bytes for the
dispatch at the cost of one extra all-gather after the return path.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import MoECfg, ModelCfg
from repro.models.layers import ACTS, KeyGen, ShardCtx, _init

from repro.distributed.compat import shard_map


def moe_params(kg: KeyGen, cfg: ModelCfg, m: MoECfg, dtype) -> dict:
    d = cfg.d_model
    p = {
        "router": _init(kg(), (d, m.n_experts), d, jnp.float32),
        "wi": _init(kg(), (m.n_experts, d, m.d_ff), d, dtype),
        "wg": _init(kg(), (m.n_experts, d, m.d_ff), d, dtype),
        "wo": _init(kg(), (m.n_experts, m.d_ff, d), m.d_ff, dtype),
    }
    if m.n_shared_experts:
        ffs = m.d_ff * m.n_shared_experts
        p["s_wi"] = _init(kg(), (d, ffs), d, dtype)
        p["s_wg"] = _init(kg(), (d, ffs), d, dtype)
        p["s_wo"] = _init(kg(), (ffs, d), ffs, dtype)
    return p


def moe_param_specs(cfg: ModelCfg, m: MoECfg, ctx: ShardCtx) -> dict:
    ep = "data" if ctx.mesh is not None else None
    tp = ctx.tp
    specs = {
        "router": P(None, None),
        "wi": P(ep, None, tp),
        "wg": P(ep, None, tp),
        "wo": P(ep, tp, None),
    }
    if m.n_shared_experts:
        specs.update({"s_wi": P(None, tp), "s_wg": P(None, tp),
                      "s_wo": P(tp, None)})
    return specs


def _local_moe(x, p, *, m: MoECfg, cfg: ModelCfg, ep: int, tp_name: str,
               dp_names: tuple[str, ...], capacity_factor: float,
               sp_dispatch: bool):
    """Per-device body (runs under shard_map). x: (B_loc, S, d)."""
    B, S, d = x.shape
    T = B * S
    E, k = m.n_experts, m.top_k
    E_loc = E // ep
    C3 = max(1, math.ceil(T * k / E * capacity_factor))
    act = ACTS[cfg.act_fn]

    xf = x.reshape(T, d)
    logits = (xf.astype(jnp.float32) @ p["router"])          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                      # (T, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # ---- aux: load-balance + z losses, M0 max-expert-load metric ----------
    counts = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    counts = jax.lax.psum(counts, dp_names)
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_prob = jax.lax.pmean(jnp.mean(probs, axis=0), dp_names)
    lb_loss = E * jnp.sum(frac * mean_prob)
    z_loss = jax.lax.pmean(
        jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))), dp_names)
    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "max_expert_load": jnp.max(counts),
        "mean_expert_load": jnp.mean(counts),
        "dropped_frac": jnp.float32(0.0),                    # filled below
    }

    # ---- dispatch slots ----------------------------------------------------
    flat_e = ids.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E, dtype=sorted_e.dtype))
    pos = jnp.arange(T * k) - starts[sorted_e]
    keep = pos < C3
    aux["dropped_frac"] = jax.lax.pmean(
        1.0 - jnp.mean(keep.astype(jnp.float32)), dp_names)
    dest = sorted_e // E_loc
    loc_e = sorted_e % E_loc
    slot = dest * (E_loc * C3) + loc_e * C3 + pos
    slot = jnp.where(keep, slot, ep * E_loc * C3)            # OOB -> dropped
    tok = order // k

    payload = xf
    if sp_dispatch:
        # each TP shard ships a distinct d/tp slice of every routed token
        tp_size = jax.lax.axis_size(tp_name)
        tp_idx = jax.lax.axis_index(tp_name)
        dsh = d // tp_size
        payload = jax.lax.dynamic_slice_in_dim(xf, tp_idx * dsh, dsh, axis=1)
    dd = payload.shape[1]
    send = jnp.zeros((ep * E_loc * C3, dd), payload.dtype)
    send = send.at[slot].set(payload[tok], mode="drop")
    recv = jax.lax.all_to_all(send.reshape(ep, E_loc * C3, dd), "data",
                              split_axis=0, concat_axis=0, tiled=True)
    # (EP_src, E_loc, C3, dd) -> (E_loc, EP_src*C3, dd)
    xe = recv.reshape(ep, E_loc, C3, dd).transpose(1, 0, 2, 3) \
             .reshape(E_loc, ep * C3, dd)
    if sp_dispatch:
        xe = jax.lax.all_gather(xe, tp_name, axis=2, tiled=True)  # full d

    # ---- expert FFN (ff sharded over `model`) -----------------------------
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
    ye = jnp.einsum("ecf,efd->ecd", act(g) * h, p["wo"])
    if sp_dispatch:
        # reduce-scatter instead of all-reduce: each TP shard directly owns
        # the d/tp slice it will ship on the return all_to_all.
        ye = jax.lax.psum_scatter(ye, tp_name, scatter_dimension=2,
                                  tiled=True)
    else:
        ye = jax.lax.psum(ye, tp_name)                       # row-parallel

    # ---- return path -------------------------------------------------------
    back = ye.reshape(E_loc, ep, C3, -1).transpose(1, 0, 2, 3) \
             .reshape(ep, E_loc * C3, -1)
    back = jax.lax.all_to_all(back, "data", split_axis=0, concat_axis=0,
                              tiled=True)
    back = back.reshape(ep * E_loc * C3, -1)
    back = jnp.concatenate(
        [back, jnp.zeros((1, back.shape[1]), back.dtype)], axis=0)
    gathered = back[slot]                                    # sorted order
    gate_sorted = gate.reshape(T * k)[order]
    contrib = gathered * (gate_sorted * keep)[:, None].astype(back.dtype)
    y = jnp.zeros((T, back.shape[1]), back.dtype).at[tok].add(contrib)
    if sp_dispatch:
        y = jax.lax.all_gather(y, tp_name, axis=1, tiled=True)

    # ---- shared (always-on) experts ---------------------------------------
    if m.n_shared_experts:
        hs = act(xf @ p["s_wg"]) * (xf @ p["s_wi"])
        ys = jax.lax.psum(hs @ p["s_wo"], tp_name)
        y = y + ys

    return y.reshape(B, S, d).astype(x.dtype), aux


def moe(x: jax.Array, p: dict, m: MoECfg, cfg: ModelCfg, ctx: ShardCtx,
        *, decode: bool = False, sp_dispatch: bool | None = None):
    """MoE block entry point. Returns (y, aux-dict of scalars)."""
    if sp_dispatch is None:
        sp_dispatch = ctx.flags.moe_sp_dispatch
    if ctx.mesh is None:
        raise ValueError("MoE requires a mesh (use single_device_mesh() "
                         "for CPU smoke tests)")
    ep = ctx.mesh.shape["data"]
    cf = m.decode_capacity_factor if decode else m.capacity_factor
    dp = ctx.dp if ctx.batch_sharded else ()
    specs = moe_param_specs(cfg, m, ctx)
    in_specs = (P(ctx.dp_spec, None, None),
                {k: specs[k] for k in p})
    out_specs = (P(ctx.dp_spec, None, None),
                 {k: P() for k in ["moe_lb_loss", "moe_z_loss",
                                   "max_expert_load", "mean_expert_load",
                                   "dropped_frac"]})
    body = functools.partial(
        _local_moe, m=m, cfg=cfg, ep=ep, tp_name=ctx.tp,
        dp_names=tuple(ctx.dp), capacity_factor=cf, sp_dispatch=sp_dispatch)
    fn = shard_map(body, mesh=ctx.mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return fn(x, p)
