"""Model configuration dataclasses shared by every assigned architecture.

One composable stack (`repro.models.lm`) expresses all 10 assigned
architectures.  A model is: embedding -> `prefix` blocks -> `pattern` blocks
repeated `n_repeats` times (executed under `lax.scan` with stacked params so
the HLO stays compact for 512-device AOT compiles) -> final norm -> LM head.

Each :class:`BlockCfg` describes one residual block: a mixer (attention /
RG-LRU / Mamba-2 SSD) followed by a channel MLP (dense or MoE).  Heterogeneous
layer patterns (gemma-2 local/global alternation, recurrentgemma 1:2
recurrent:attention) are expressed by multi-block patterns; the scan unit is
one full pattern repetition.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    """Mixture-of-experts channel block (token-choice top-k, capacity-based
    dispatch over an expert-parallel axis; see models/moe.py)."""

    n_experts: int
    top_k: int
    d_ff: int                       # per-expert hidden width
    capacity_factor: float = 1.25
    decode_capacity_factor: float = 4.0   # routing variance matters more at tiny T
    n_shared_experts: int = 0       # always-on experts (kimi-k2 style)
    router_aux_weight: float = 0.01  # load-balance loss (Switch-style)
    router_z_weight: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSDCfg:
    """Mamba-2 SSD mixer (state-space duality, chunked matmul form)."""

    d_inner: int
    head_dim: int = 64
    d_state: int = 128
    n_groups: int = 1
    chunk: int = 256
    d_conv: int = 4


@dataclasses.dataclass(frozen=True)
class RGLRUCfg:
    """RG-LRU mixer (RecurrentGemma / Griffin real-gated linear recurrence)."""

    d_rnn: int
    d_conv: int = 4
    c_exponent: float = 8.0         # a = a_param^(c * r_gate)


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    """One residual block = mixer + channel MLP."""

    kind: str                       # "attn" | "ssd" | "rglru"
    d_ff: int = 0                   # dense MLP hidden width (0 = no MLP)
    moe: Optional[MoECfg] = None    # MoE replaces the dense MLP when set
    window: Optional[int] = None    # local (sliding-window) attention
    post_norms: bool = False        # gemma-2 style post-block RMSNorm
    ssd: Optional[SSDCfg] = None
    rglru: Optional[RGLRUCfg] = None


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Full decoder-only LM configuration (see encdec.py for whisper)."""

    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    vocab_size: int
    prefix: tuple[BlockCfg, ...] = ()
    pattern: tuple[BlockCfg, ...] = ()
    n_repeats: int = 0
    suffix: tuple[BlockCfg, ...] = ()

    act_fn: str = "silu"            # "silu" | "gelu" | "relu"
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    attn_softcap: Optional[float] = None     # gemma-2 logit soft-capping
    final_softcap: Optional[float] = None
    tie_embeddings: bool = False
    emb_scale: bool = False         # gemma-style sqrt(d_model) embed scaling
    qk_norm: bool = False

    # VLM / audio frontends are STUBS: input_specs() provides precomputed
    # patch/frame embeddings that are concatenated before the first block.
    frontend: str = "none"          # "none" | "patches" | "frames"
    frontend_tokens: int = 0        # number of pre-embedded positions

    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "block"            # "none" | "block" (checkpoint each scan unit)

    @property
    def n_layers(self) -> int:
        return (len(self.prefix) + len(self.pattern) * self.n_repeats
                + len(self.suffix))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def all_blocks(self) -> list[BlockCfg]:
        return (list(self.prefix) + list(self.pattern) * self.n_repeats
                + list(self.suffix))

    def param_count(self) -> int:
        """Exact parameter count (embeddings included once if tied)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        total += d                              # final norm
        for blk in self.all_blocks():
            total += d                          # mixer pre-norm
            if blk.moe is not None or blk.d_ff:
                total += d                      # mlp pre-norm
            if blk.post_norms:
                total += 2 * d
            if blk.kind == "attn":
                total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
                if self.qk_norm:
                    total += 2 * self.head_dim
            elif blk.kind == "ssd":
                s = blk.ssd
                h = s.d_inner // s.head_dim
                total += d * (2 * s.d_inner + 2 * s.n_groups * s.d_state + h)
                total += s.d_conv * (s.d_inner + 2 * s.n_groups * s.d_state)
                total += 3 * h                  # A_log, D, dt_bias
                total += s.d_inner              # gate norm
                total += s.d_inner * d
            elif blk.kind == "rglru":
                r = blk.rglru
                total += 2 * d * r.d_rnn        # in proj (x + gate)
                total += r.d_rnn * d            # out proj
                total += r.d_conv * r.d_rnn     # depthwise conv
                total += 2 * r.d_rnn * r.d_rnn  # r,i gates
                total += r.d_rnn                # a_param
            if blk.moe is not None:
                m = blk.moe
                total += d * m.n_experts        # router
                total += m.n_experts * 3 * d * m.d_ff
                total += m.n_shared_experts * 3 * d * m.d_ff
            elif blk.d_ff:
                total += 3 * d * blk.d_ff       # SwiGLU wi/wg/wo
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        total = self.param_count()
        for blk in self.all_blocks():
            if blk.moe is not None:
                m = blk.moe
                inactive = m.n_experts - m.top_k
                total -= inactive * 3 * self.d_model * m.d_ff
        return total


def dense_block(d_ff: int, *, window: int | None = None,
                post_norms: bool = False) -> BlockCfg:
    return BlockCfg(kind="attn", d_ff=d_ff, window=window, post_norms=post_norms)


def moe_block(moe: MoECfg, *, window: int | None = None) -> BlockCfg:
    return BlockCfg(kind="attn", moe=moe, window=window)
