"""Floorline-informed sharding optimization (paper §VI-B on TPU).

The paper's stage-2 procedure, adapted: the "workload position" is the
three-term bound from the compiled dry-run (core.tpu_floorline), the
"partitioning moves" are sharding/layout/remat/microbatch variants, and the
loop is the same assumption-driven backtracking:

  1. measure the baseline; identify the dominant term (= bottleneck state);
  2. apply the candidate move with the best predicted delta on that term;
  3. re-lower + re-analyze; keep if the bound improved >= min_gain,
     else BACKTRACK (revert the move — extra complexity without improvement
     costs exactly like neurocore over-utilization costs power);
  4. when the dominant term's moves are exhausted, shift the assumption to
     the next term; stop when every move fails (true boundary reached).

Every step is an OptStep-style record — EXPERIMENTS.md §Perf is generated
from these logs (hypothesis -> change -> before -> after -> verdict).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.core.analytical import Bottleneck


@dataclasses.dataclass
class Move:
    name: str
    hypothesis: str              # napkin math / predicted delta
    targets: Bottleneck          # which term this move attacks
    overrides: dict              # kwargs for the evaluator


@dataclasses.dataclass
class HillStep:
    iteration: int
    move: str
    hypothesis: str
    before: dict
    after: dict
    accepted: bool
    verdict: str


@dataclasses.dataclass
class HillResult:
    best: dict
    best_overrides: dict
    log: list[HillStep]

    def markdown(self) -> str:
        rows = ["| # | move | hypothesis | bound before | bound after | "
                "verdict |", "|---|------|------------|-----|-----|---------|"]
        for s in self.log:
            rows.append(
                f"| {s.iteration} | {s.move} | {s.hypothesis[:80]} | "
                f"{s.before['bound_s']:.4f}s | {s.after['bound_s']:.4f}s | "
                f"{'ACCEPT' if s.accepted else 'backtrack'}: {s.verdict} |")
        return "\n".join(rows)


def hillclimb(evaluate: Callable[..., dict], moves: list[Move], *,
              min_gain: float = 0.02, max_iters: int = 12) -> HillResult:
    """``evaluate(**overrides) -> roofline row dict`` (must include
    bound_s / t_compute_s / t_memory_s / t_collective_s / dominant)."""
    base = evaluate()
    current = dict(base)
    applied: dict = {}
    log: list[HillStep] = []
    remaining = list(moves)
    it = 0
    while remaining and it < max_iters:
        dom = current["dominant"]
        # paper ordering: attack the dominant term first, then the others
        remaining.sort(key=lambda m: 0 if m.targets.value == dom else 1)
        move = remaining.pop(0)
        it += 1
        trial = {**applied, **move.overrides}
        after = evaluate(**trial)
        gain = (current["bound_s"] - after["bound_s"]) / max(
            current["bound_s"], 1e-30)
        accepted = gain >= min_gain
        verdict = (f"bound {'-' if gain >= 0 else '+'}"
                   f"{abs(gain) * 100:.1f}%")
        log.append(HillStep(it, move.name, move.hypothesis,
                            dict(current), dict(after), accepted, verdict))
        if accepted:
            applied = trial
            current = dict(after)
    return HillResult(best=current, best_overrides=applied, log=log)
