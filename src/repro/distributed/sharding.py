"""Per-architecture parameter / batch / gradient PartitionSpecs.

Sharding rules (mesh ``("pod","data","model")`` / ``("data","model")``):

  batch            -> (pod, data)             [replicated when B < |dp|]
  attention        -> Q heads over `model` when divisible (Megatron TP),
                      otherwise head_dim for the projections + context-
                      parallel attention (rules live in models/layers.py;
                      the weight specs here must match)
  MLP / expert FF  -> column->row parallel over `model`
  MoE experts      -> over `data` (EP=16 intra-pod; pods replicate experts)
  vocab            -> over `model` (embed rows / unembed cols; the CE loss
                      reduces over the sharded vocab dim, never gathers)
  SSD / RG-LRU     -> channel dims over `model`
  optimizer state  -> ZeRO-1: + `data` on the first unsharded divisible dim
  giant gradients  -> + `pod` (reduce-scatter instead of all-reduce on the
                      cross-pod DP path) for leaves above ~0.5 GiB

The spec trees are built by mirroring the constructors in models/lm.py so
tree structure always matches ``init_params`` exactly (checked by tests).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import moe as moe_lib
from repro.models.common import BlockCfg, ModelCfg
from repro.models.encdec import EncDecCfg
from repro.models.layers import ShardCtx


def island_mesh(n_islands: int | None = None, *, devices=None):
    """1-D ``("island",)`` mesh for the sharded evolutionary search.

    The search population's K axis is sharded over this single axis: each
    device holds one island's subpopulation (``docs/distributed.md``).
    ``n_islands`` defaults to every visible device; on CPU, more than one
    device requires ``--xla_force_host_platform_device_count`` to be set
    *before* jax initializes (``benchmarks.run --devices N`` or
    :func:`repro.launch.mesh.force_host_device_count`)."""
    devs = list(devices) if devices is not None else jax.devices()
    n = len(devs) if n_islands is None else int(n_islands)
    if n < 1:
        raise ValueError(f"island mesh needs at least 1 device, got {n}")
    if n > len(devs):
        raise RuntimeError(
            f"island mesh needs {n} devices but only {len(devs)} are "
            "visible — on CPU launch via `python -m benchmarks.run "
            f"--devices {n}` (or set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before python imports jax)")
    from repro.distributed.compat import make_mesh
    return make_mesh((n,), ("island",), devices=devs[:n])


def make_ctx(mesh, *, batch_size: int | None = None) -> ShardCtx:
    """ShardCtx from a production mesh (axis names decide dp)."""
    if mesh is None:
        return ShardCtx(mesh=None)
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    sharded = batch_size is None or batch_size % dp_size == 0
    return ShardCtx(mesh=mesh, dp=dp, tp="model", batch_sharded=sharded)


# ----------------------------------------------------------------- params

def _attn_specs(cfg: ModelCfg, ctx: ShardCtx) -> dict:
    tp = ctx.tp
    head_tp = ctx.can_shard(cfg.n_heads)
    kv_tp = ctx.can_shard(cfg.n_kv_heads)
    if head_tp:
        sp = {"wq": P(None, tp, None),
              "wk": P(None, tp if kv_tp else None, None if kv_tp else tp),
              "wv": P(None, tp if kv_tp else None, None if kv_tp else tp),
              "wo": P(tp, None, None)}
    else:   # context-parallel attention: shard head_dim on the projections
        sp = {"wq": P(None, None, tp), "wk": P(None, None, tp),
              "wv": P(None, None, tp), "wo": P(None, tp, None)}
    if cfg.qk_norm:
        sp["q_gamma"] = P(None)
        sp["k_gamma"] = P(None)
    return sp


def _mlp_specs(ctx: ShardCtx) -> dict:
    return {"wi": P(None, ctx.tp), "wg": P(None, ctx.tp),
            "wo": P(ctx.tp, None)}


def _ssd_specs(ctx: ShardCtx) -> dict:
    tp = ctx.tp
    return {"in_xz": P(None, tp), "in_bc": P(None, None),
            "in_dt": P(None, None), "conv_w": P(None, None),
            "A_log": P(None), "D": P(None), "dt_bias": P(None),
            "norm_g": P(tp), "out": P(tp, None)}


def _rglru_specs(ctx: ShardCtx) -> dict:
    tp = ctx.tp
    return {"in_xy": P(None, tp), "conv_w": P(None, tp),
            "w_r": P(None, tp), "w_i": P(None, tp),
            "a_param": P(tp), "out": P(tp, None)}


def _block_specs(blk: BlockCfg, cfg: ModelCfg, ctx: ShardCtx) -> dict:
    sp: dict[str, Any] = {"norm1": P(None)}
    if blk.kind == "attn":
        sp["attn"] = _attn_specs(cfg, ctx)
    elif blk.kind == "ssd":
        sp["ssd"] = _ssd_specs(ctx)
    elif blk.kind == "rglru":
        sp["rglru"] = _rglru_specs(ctx)
    if blk.moe is not None:
        sp["norm2"] = P(None)
        sp["moe"] = moe_lib.moe_param_specs(cfg, blk.moe, ctx)
    elif blk.d_ff:
        sp["norm2"] = P(None)
        sp["mlp"] = _mlp_specs(ctx)
    if blk.post_norms:
        sp["norm1_post"] = P(None)
        sp["norm2_post"] = P(None)
    return sp


def _stack(spec_tree):
    """Prepend the scan (n_repeats) axis to every leaf spec."""
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def lm_param_specs(cfg: ModelCfg, ctx: ShardCtx) -> dict:
    tp = ctx.tp
    specs: dict[str, Any] = {"embed": P(tp, None), "final_norm": P(None)}
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, tp)
    for i, blk in enumerate(cfg.prefix):
        specs[f"pre{i}"] = _block_specs(blk, cfg, ctx)
    if cfg.n_repeats:
        specs["pattern"] = _stack(
            {f"blk{j}": _block_specs(blk, cfg, ctx)
             for j, blk in enumerate(cfg.pattern)})
    for i, blk in enumerate(cfg.suffix):
        specs[f"suf{i}"] = _block_specs(blk, cfg, ctx)
    return specs


def encdec_param_specs(cfg: EncDecCfg, ctx: ShardCtx) -> dict:
    mc = cfg.mc

    def enc_block():
        return {"norm1": P(None), "attn": _attn_specs(mc, ctx),
                "norm2": P(None), "mlp": _mlp_specs(ctx)}

    def dec_block():
        return {"norm1": P(None), "attn": _attn_specs(mc, ctx),
                "norm_x": P(None), "xattn": _attn_specs(mc, ctx),
                "norm2": P(None), "mlp": _mlp_specs(ctx)}

    return {"embed": P(ctx.tp, None),
            "enc": _stack(enc_block()), "dec": _stack(dec_block()),
            "enc_norm": P(None), "dec_norm": P(None)}


def param_specs(cfg, ctx: ShardCtx) -> dict:
    if isinstance(cfg, EncDecCfg):
        return encdec_param_specs(cfg, ctx)
    return lm_param_specs(cfg, ctx)


# ------------------------------------------------------- batch / grad / opt

def batch_specs(batch_tree, ctx: ShardCtx):
    """Shard dim 0 (batch) of every input over the DP axes."""
    dp = ctx.dp_spec

    def leaf(x):
        if hasattr(x, "ndim") and x.ndim >= 1:
            return P(*((dp,) + (None,) * (x.ndim - 1)))
        return P()
    return jax.tree.map(leaf, batch_tree)


_GIANT = 256 * 2**20        # elements; ~0.5 GiB in bf16


def grad_specs(params_tree, specs_tree, ctx: ShardCtx):
    """Gradient shardings: same as params, plus `pod` on the first unsharded
    divisible dim of giant leaves (cross-pod reduce-scatter instead of
    all-reduce — the MoE expert tensors of kimi-k2)."""
    if ctx.mesh is None or "pod" not in ctx.mesh.axis_names:
        return specs_tree
    pod = ctx.mesh.shape["pod"]

    def leaf(x, s):
        if np.prod(x.shape) < _GIANT:
            return s
        dims = list(tuple(s) + (None,) * (x.ndim - len(tuple(s))))
        used = set()
        for d in dims:
            for a in (d if isinstance(d, tuple) else (d,)):
                used.add(a)
        if "pod" in used:
            return s
        for i, d in enumerate(dims):
            if d is None and x.shape[i] % pod == 0:
                dims[i] = "pod"
                return P(*dims)
        return s
    return jax.tree.map(leaf, params_tree, specs_tree,
                        is_leaf=lambda s: isinstance(s, P))


def zero1_specs(params_tree, specs_tree, ctx: ShardCtx):
    """Optimizer-state shardings: params spec + `data` on the first
    unsharded divisible dim (ZeRO-1 state sharding over the DP axis)."""
    if ctx.mesh is None:
        return specs_tree
    data = ctx.mesh.shape["data"]

    def leaf(x, s):
        dims = list(tuple(s) + (None,) * (x.ndim - len(tuple(s))))
        used = set()
        for d in dims:
            for a in (d if isinstance(d, tuple) else (d,)):
                used.add(a)
        if "data" in used:
            return s
        for i, d in enumerate(dims):
            if d is None and x.shape[i] % data == 0 and x.shape[i] >= data:
                dims[i] = "data"
                return P(*dims)
        return s
    return jax.tree.map(leaf, params_tree, specs_tree,
                        is_leaf=lambda s: isinstance(s, P))


def to_shardings(spec_tree, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))
