"""Distributed collectives: island-migration primitives for the sharded
search plus the int8-compressed gradient all-reduce with error feedback.

**Island migration** (``engine="sharded"`` in ``repro.core.search``): the
population's K axis is sharded over a 1-D ``("island",)`` mesh, and every
``migrate_every`` generations each island rotates its elite block to the
next island on a ring — :func:`ring_shift` is that ``jax.lax.ppermute``,
applied leaf-wise to the whole survivor-state pytree so genomes travel with
their cached objectives.  A ring *rotation* (not a copy) preserves the
global genome multiset exactly: every row changes island, no row is
duplicated or dropped (tests/test_sharded_search.py asserts the multiset).
:func:`gather_islands` is the matching ``all_gather`` used to assemble
global Pareto/GenStats values inside the sharded step.

**Compressed gradient reduction** (original module contents):
int8-compressed gradient all-reduce with error feedback.

The DP gradient reduction moves |params| bytes per step across the `data`
(and `pod` / DCI) links — at 1T params that IS the collective term.  The
standard mitigation is quantized reduction with error feedback (1-bit Adam /
PowerSGD family):

    q      = quantize_int8(g + err)      # per-leaf scale = max|.| / 127
    g_hat  = psum(q) * scale / n
    err'   = (g + err) - dequant(q)      # local residual, re-injected next step

Error feedback keeps the *accumulated* quantization error bounded, so SGD/
Adam convergence is preserved (verified by tests/test_collectives.py: an
int8-compressed run matches the exact run's loss curve within tolerance).

Usage: inside a ``shard_map`` over the DP axes (see train/loop.py's
``dp_compressed`` mode); the wire payload is 1/4 of bf16, 1/8 of f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def ring_shift(tree, *, size: int, axis_name: str = "island",
               shift: int = 1):
    """Rotate every leaf's shard ``shift`` positions around the mesh ring:
    island ``i`` sends its block to island ``(i + shift) % size`` and
    receives island ``(i - shift) % size``'s.  ``size`` is the static mesh
    axis size (``ppermute`` permutations must be python data — a traced
    ``axis_size`` cannot build them, see ``distributed.compat``).  Only
    valid inside a ``shard_map`` over ``axis_name``."""
    size = int(size)
    if size < 1:
        raise ValueError(f"ring over {size} islands")
    perm = [(i, (i + shift) % size) for i in range(size)]
    return jax.tree.map(
        lambda v: jax.lax.ppermute(v, axis_name, perm), tree)


def gather_islands(tree, *, axis_name: str = "island", axis: int = 0,
                   tiled: bool = False):
    """Leaf-wise ``jax.lax.all_gather`` over the island axis: every island
    ends up holding the stacked (``tiled=False``, new leading axis) or
    concatenated (``tiled=True``) per-island values — the assembly step for
    global fronts/stats inside the sharded search."""
    return jax.tree.map(
        lambda v: jax.lax.all_gather(v, axis_name, axis=axis, tiled=tiled),
        tree)


def quantize_int8(x: jax.Array):
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jax.Array, err: jax.Array, axis_names):
    """One leaf: error-feedback int8 mean-reduction over ``axis_names``.
    Returns (mean_estimate f32, new_err)."""
    xf = x.astype(jnp.float32) + err
    q, scale = quantize_int8(xf)
    local_dq = dequantize_int8(q, scale)
    new_err = xf - local_dq
    # int8 payloads psum; scales are per-shard -> reduce the dequantized
    # value but transmit int8: sum_i dq_i = sum_i q_i*scale_i.  With a
    # shared (max) scale the wire format is exactly int8 + one f32.
    gmax = jax.lax.pmax(scale, axis_names)
    q2 = jnp.clip(jnp.round(xf / gmax), -127, 127).astype(jnp.int8)
    new_err = xf - q2.astype(jnp.float32) * gmax
    total = jax.lax.psum(q2.astype(jnp.int32), axis_names)
    from repro.distributed.compat import axis_size
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        n *= axis_size(a)
    return total.astype(jnp.float32) * gmax / n, new_err


def compressed_grad_mean(grads, err_tree, axis_names):
    """Tree version. Returns (mean_grads f32, new_err_tree)."""
    fn = functools.partial(compressed_psum_mean, axis_names=axis_names)
    out = jax.tree.map(lambda g, e: fn(g, e), grads, err_tree)
    g = jax.tree.map(lambda o: o[0], out,
                     is_leaf=lambda o: isinstance(o, tuple))
    e = jax.tree.map(lambda o: o[1], out,
                     is_leaf=lambda o: isinstance(o, tuple))
    return g, e


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
