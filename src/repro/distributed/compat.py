"""JAX version-compatibility shims.

The codebase targets the jax >= 0.6 public API (``jax.shard_map`` with a
``check_vma`` argument); older runtimes only have
``jax.experimental.shard_map.shard_map`` whose equivalent flag is named
``check_rep``.  Import ``shard_map`` from here instead of from ``jax``.
Same story for mesh construction (``jax.make_mesh`` vs hand-reshaping
devices into ``jax.sharding.Mesh``) and for axis sizes inside collectives
(``jax.lax.axis_size`` vs the ``psum(1)`` fallback).
"""

from __future__ import annotations

import inspect
import math

import jax

try:                                            # jax >= 0.6 public API
    _shard_map = jax.shard_map
except AttributeError:                          # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kw):
    """``jax.shard_map`` with ``check_vma`` translated for older runtimes."""
    if "check_vma" in kw and "check_vma" not in _PARAMS:
        if "check_rep" in _PARAMS:
            kw["check_rep"] = kw.pop("check_vma")
        else:
            kw.pop("check_vma")
    return _shard_map(f, **kw)


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a psum(1) fallback for older runtimes.

    NOTE: the fallback is a *traced* value — collectives whose permutation
    must be static python data (``ppermute`` rings) cannot use it; pass the
    mesh axis size explicitly instead (see
    :func:`repro.distributed.collectives.ring_shift`)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def make_mesh(shape, axis_names, *, devices=None):
    """``jax.make_mesh`` with a ``jax.sharding.Mesh`` fallback for runtimes
    predating it.  ``devices`` defaults to the first ``prod(shape)`` local
    devices; too few visible devices raise the usual jax error."""
    if hasattr(jax, "make_mesh"):
        kw = {} if devices is None else {"devices": devices}
        return jax.make_mesh(tuple(shape), tuple(axis_names), **kw)
    import numpy as np                          # pragma: no cover - old jax
    n = math.prod(shape)
    devs = list(devices) if devices is not None else jax.devices()[:n]
    return jax.sharding.Mesh(np.asarray(devs).reshape(shape),
                             tuple(axis_names))
