"""JAX version-compatibility shims.

The codebase targets the jax >= 0.6 public API (``jax.shard_map`` with a
``check_vma`` argument); older runtimes only have
``jax.experimental.shard_map.shard_map`` whose equivalent flag is named
``check_rep``.  Import ``shard_map`` from here instead of from ``jax``.
"""

from __future__ import annotations

import inspect

import jax

try:                                            # jax >= 0.6 public API
    _shard_map = jax.shard_map
except AttributeError:                          # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kw):
    """``jax.shard_map`` with ``check_vma`` translated for older runtimes."""
    if "check_vma" in kw and "check_vma" not in _PARAMS:
        if "check_rep" in _PARAMS:
            kw["check_rep"] = kw.pop("check_vma")
        else:
            kw.pop("check_vma")
    return _shard_map(f, **kw)


def axis_size(axis_name):
    """``jax.lax.axis_size`` with a psum(1) fallback for older runtimes."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)
