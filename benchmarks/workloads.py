"""The four paper workloads (Table II) as simulator networks + their
JAX-trainable counterparts for the stage-1 sparsity experiments.

Sizes are scaled down from the paper's (Imagenette-AkidaNet etc.) so the
whole benchmark suite runs in minutes on one CPU — the validation targets
are the paper's *trends and ratios* (its own results are normalized too).
"""

from __future__ import annotations

import numpy as np

from repro.neuromorphic.network import (SimLayer, SimNetwork, fc_network,
                                        make_inputs, programmed_fc_network)
from repro.neuromorphic.platform import (akd1000_like, loihi2_like,
                                         speck_like)


# ------------------------------------------------------------ sim networks

def conv_net(in_hw=(16, 16), cin=2, channels=(8, 16, 32), fc_out=10, *,
             neuron_model="relu", weight_density=1.0, act_gates=None,
             force_active=False, seed=0, weight_format=None,
             sends_deltas=False, threshold=0.0) -> SimNetwork:
    """Small CNN in the AkidaNet/PilotNet mold (3x3 convs stride 2 + fc)."""
    rng = np.random.default_rng(seed)
    from repro.neuromorphic.network import _exact_density_mask
    layers = []
    h, w = in_hw
    c_prev = cin
    for i, c in enumerate(channels):
        wgt = rng.normal(0, 1.0 / np.sqrt(9 * c_prev),
                         (3, 3, c_prev, c)).astype(np.float32)
        wgt *= _exact_density_mask(wgt.shape, weight_density, rng)
        gate = None
        if act_gates is not None:
            n = c * (h // 2) * (w // 2)
            gate = _exact_density_mask((n,), act_gates[i], rng)
        layers.append(SimLayer(
            name=f"conv{i}", kind="conv", weights=wgt, stride=2,
            in_hw=(h, w), neuron_model=neuron_model, msg_gate=gate,
            force_active=force_active, weight_format=weight_format,
            sends_deltas=sends_deltas, threshold=threshold))
        h, w, c_prev = h // 2, w // 2, c
    fanin = h * w * c_prev
    wfc = rng.normal(0, 1.0 / np.sqrt(fanin),
                     (fanin, fc_out)).astype(np.float32)
    from repro.neuromorphic.network import _exact_density_mask as edm
    wfc *= edm(wfc.shape, weight_density, rng)
    gate = (edm((fc_out,), act_gates[-1], rng)
            if act_gates is not None else None)
    layers.append(SimLayer(name="fc", kind="fc", weights=wfc,
                           neuron_model=neuron_model, msg_gate=gate,
                           force_active=force_active,
                           weight_format=weight_format))
    return SimNetwork(layers=layers, in_size=np.prod(in_hw) * cin)


def akidanet_sim(**kw):
    return conv_net(in_hw=(16, 16), cin=2, channels=(8, 16, 32), **kw), \
        akd1000_like()


def speck_sim(**kw):
    kw.setdefault("neuron_model", "if")
    kw.setdefault("threshold", 1.0)
    return conv_net(in_hw=(16, 16), cin=2, channels=(8, 16), **kw), \
        speck_like()


def pilotnet_sim(**kw):
    kw.setdefault("neuron_model", "sd_relu")
    kw.setdefault("sends_deltas", True)
    return conv_net(in_hw=(16, 16), cin=2, channels=(8, 16, 32), fc_out=1,
                    **kw), loihi2_like()


def s5_sim(sizes=(64, 128, 128, 128, 64), **kw):
    kw.setdefault("neuron_model", "ssm")
    net = fc_network(list(sizes), **kw)
    return net, loihi2_like()


def s5_programmed(sizes=(64, 128, 128, 128, 64), *, weight_densities,
                  act_densities, seed=0, weight_format=None):
    net = programmed_fc_network(
        list(sizes), weight_densities=weight_densities,
        act_densities=act_densities, seed=seed, weight_format=weight_format,
        neuron_model="ssm")
    return net, loihi2_like()


def sim_inputs(net: SimNetwork, density: float, steps: int = 6,
               seed: int = 0) -> np.ndarray:
    return make_inputs(net.in_size, density, steps, seed)


# ------------------------------------------------------- model-zoo family

#: compiled-model workloads priced by default (one per paper-relevant
#: family: attention LM, SSM, MoE); any ``repro.configs.registry`` id works
MODEL_ZOO_ARCHS = ("gemma2-2b", "mamba2-1.3b", "olmoe-1b-7b")


def model_zoo(arch_id: str = MODEL_ZOO_ARCHS[0], *,
              act_density: float | None = None, seq_len: int = 16,
              seed: int = 0):
    """Real-model workload (``--arch``): compile a registry arch's smoke
    config through :mod:`repro.neuromorphic.frontend` and pair it with the
    loihi2-like profile (the only baked-in profile whose partitioning
    allows the compiled stacks' layer splits).  Returns
    ``(CompiledNetwork, profile)``; ``compiled.net`` drops into every
    simulate/pricing/search surface like the fc/conv workloads above."""
    from repro.neuromorphic.frontend import compile_network
    compiled = compile_network(arch_id, seq_len=seq_len,
                               act_density=act_density, seed=seed)
    return compiled, loihi2_like()


# ------------------------------------------------------- schedule helpers

def schedule(name: str, n_layers: int, total: float) -> list[float]:
    """Per-layer activation DENSITY schedules with (approximately) the same
    network-mean density ``total`` (paper Fig. 5): Uniform / LoHi /
    Increasing / Decreasing."""
    t = float(total)
    if name == "uniform":
        d = [t] * n_layers
    elif name == "lohi":
        d = [min(2 * t, 1.0) if i % 2 == 0 else max(2 * t - 1.0, 0.0)
             if 2 * t > 1 else 0.0 for i in range(n_layers)]
        # re-center to hit the mean
        gap = t - float(np.mean(d))
        d = [min(max(x + gap, 0.0), 1.0) for x in d]
    elif name == "increasing":
        d = list(np.clip(np.linspace(0.2 * t, 1.8 * t, n_layers), 0, 1))
    elif name == "decreasing":
        d = list(np.clip(np.linspace(1.8 * t, 0.2 * t, n_layers), 0, 1))
    else:
        raise ValueError(name)
    return [float(x) for x in d]
