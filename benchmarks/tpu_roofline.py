"""§Roofline: the 32-cell x 2-mesh table from the committed dry-run
artifacts (experiments/dryrun/*.json).  Single-pod is the roofline table
per the assignment; multipod rows prove the `pod` axis shards."""

from __future__ import annotations

import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..",
                          "experiments", "dryrun")


def load_records(mesh: str = "pod") -> list[dict]:
    recs = []
    if not os.path.isdir(DRYRUN_DIR):
        return recs
    for f in sorted(os.listdir(DRYRUN_DIR)):
        if f.endswith(f"__{mesh}.json"):
            recs.append(json.load(open(os.path.join(DRYRUN_DIR, f))))
    return recs


def run(quick: bool = False) -> dict:
    recs = load_records("pod")
    rows = []
    for r in recs:
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "t_compute": t["t_compute_s"], "t_memory": t["t_memory_s"],
            "t_collective": t["t_collective_s"], "bound": t["bound_s"],
            "dominant": t["dominant"],
            "useful_ratio": t["useful_flops_ratio"],
            "roofline_fraction": t["roofline_fraction"],
        })
    dom = {}
    for r in rows:
        dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
    return {"rows": rows, "dominant_counts": dom,
            "n_multipod_ok": len(load_records("multipod"))}


def report(res: dict) -> str:
    lines = ["## §Roofline — single-pod (16x16) terms per cell",
             f"{'arch':22s} {'shape':12s} {'compute':>9s} {'memory':>9s} "
             f"{'collect':>9s} {'bound(s)':>9s} {'dom':8s} {'useful':>7s} "
             f"{'roofl%':>7s}"]
    for r in res["rows"]:
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['t_compute']:9.4f} "
            f"{r['t_memory']:9.4f} {r['t_collective']:9.4f} "
            f"{r['bound']:9.4f} {r['dominant']:8s} "
            f"{r['useful_ratio']:7.3f} {r['roofline_fraction']*100:6.1f}%")
    lines.append(f"dominant-term counts: {res['dominant_counts']}; "
                 f"multipod cells compiled: {res['n_multipod_ok']}")
    return "\n".join(lines)
