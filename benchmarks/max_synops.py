"""Fig 6: memory-bound bottleneck — time/step is linear in MAX per-core
synops across widely varying sparsity/load-balance configs, down to a
compute floor.  The floorline's memory slope comes from this fit."""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.core.floorline import WorkloadPoint, fit_floorline
from repro.neuromorphic.timestep import simulate

SIZES = (64, 192, 192, 192, 64)


def collect_points(quick: bool = False):
    # batched engine: longer windows are ~free -> tighter floorline fits
    steps = 3 if quick else 10
    pts = []
    for sched in ("uniform", "lohi", "increasing", "decreasing"):
        for tot in (0.8, 0.5, 0.2, 0.05):
            for wd in (1.0, 0.5):
                dens = W.schedule(sched, len(SIZES) - 1, tot)
                net, prof = W.s5_programmed(
                    SIZES, weight_densities=[wd] * (len(SIZES) - 1),
                    act_densities=dens, seed=1)
                xs = W.sim_inputs(net, tot, steps, seed=2)
                r = simulate(net, xs, prof)
                pts.append(WorkloadPoint(
                    max_synops=r.max_synops, max_acts=r.max_acts,
                    time=r.time_per_step, energy=r.energy_per_step,
                    label=f"{sched}/{tot}/{wd}"))
    return pts


def run(quick: bool = False) -> dict:
    pts = collect_points(quick)
    model = fit_floorline(pts)
    # linearity in the memory-bound region (above the floor knee)
    knee = model.compute_floor(max(p.max_acts for p in pts)) * 1.5
    mem_pts = [p for p in pts if p.time > knee]
    x = np.array([p.max_synops for p in mem_pts])
    y = np.array([p.time for p in mem_pts])
    corr = float(np.corrcoef(x, y)[0, 1]) if len(mem_pts) > 3 else None
    e = np.array([p.energy for p in pts])
    s = np.array([p.max_synops for p in pts])
    return {"n_points": len(pts),
            "mem_region_corr": corr,
            "energy_corr": float(np.corrcoef(s, e)[0, 1]),
            "slope": model.mem_latency, "floor_act_latency": model.act_latency,
            "t0": model.t0}


def report(res: dict) -> str:
    return ("## Fig 6 — max-synops memory bound\n"
            f"  {res['n_points']} configs: corr(time, max core synops) in "
            f"memory region = {res['mem_region_corr']:+.4f} "
            "(paper: linear boundary)\n"
            f"  corr(energy, max synops) = {res['energy_corr']:+.4f}; "
            f"fitted slope={res['slope']:.3g} floor act-latency="
            f"{res['floor_act_latency']:.3g}")
