"""Fig 2 + Fig 3: weight-sparsity performance.

Claims reproduced:
  * CNNs (AKD1000 / Loihi 2 / Speck): ~no RUNTIME benefit from weight
    sparsity under the dense (default) format; small energy benefit only.
  * S5 linear net (sparse default format): runtime scales ~linearly with
    weight density — weight sparsity is as valuable as activation sparsity.
"""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.timestep import simulate

WDS = [1.0, 0.7, 0.4, 0.1]          # weight density (sparsity = 1 - wd)


def run(quick: bool = False) -> dict:
    # layer-major batched simulate() made long eval windows cheap: 2x the
    # seed's step count for tighter means at negligible wall-clock cost
    steps = 4 if quick else 12
    out = {"cnn": {}, "s5": {}}

    # paper §V-A: activation sparsity held CONSTANT (programmed gates)
    # while weight sparsity sweeps — otherwise the two effects confound
    n_conv_layers = 4
    from repro.neuromorphic.platform import loihi2_like

    def loihi2_cnn(**kw):
        # characterization mode: plain ReLU (Σ-Δ deltas would re-couple
        # activations to the weights); same conv topology as PilotNet
        return W.conv_net(in_hw=(16, 16), cin=2, channels=(8, 16, 32),
                          fc_out=1, **kw), loihi2_like()

    for name, builder in [("akd1000", W.akidanet_sim),
                          ("pilotnet-loihi2", loihi2_cnn)]:
        rows = []
        for wd in WDS:
            net, prof = builder(weight_density=wd, seed=1,
                                act_gates=[0.5] * n_conv_layers,
                                force_active=True)
            xs = W.sim_inputs(net, 0.5, steps, seed=2)
            r = simulate(net, xs, prof)
            rows.append({"weight_density": wd, "time": r.time_per_step,
                         "energy": r.energy_per_step})
        out["cnn"][name] = rows

    rows = []
    for wd in WDS:
        net, prof = W.s5_programmed(
            weight_densities=[wd] * 4, act_densities=[0.5] * 4, seed=1)
        xs = W.sim_inputs(net, 0.5, steps, seed=2)
        r = simulate(net, xs, prof)
        rows.append({"weight_density": wd, "time": r.time_per_step,
                     "energy": r.energy_per_step})
    out["s5"]["loihi2"] = rows

    # --- claims ---------------------------------------------------------
    for name, rows in list(out["cnn"].items()):
        t = [r["time"] for r in rows]
        out["cnn"][name + "_time_spread"] = (max(t) - min(t)) / max(t)
    t = [r["time"] for r in out["s5"]["loihi2"]]
    out["s5"]["speedup_0.9_sparsity"] = t[0] / t[-1]
    return out


def report(res: dict) -> str:
    lines = ["## Fig 2/3 — weight sparsity"]
    for name in ("akd1000", "pilotnet-loihi2"):
        spread = res["cnn"][name + "_time_spread"]
        lines.append(f"  {name:16s} CNN time spread over wd sweep: "
                     f"{spread * 100:.1f}%  (paper: ~0, dense format)")
    lines.append(f"  s5/loihi2       time speedup at 0.9 weight sparsity: "
                 f"{res['s5']['speedup_0.9_sparsity']:.2f}x "
                 "(paper: ~linear in density)")
    return "\n".join(lines)
